"""Paper Figs. 15/16: Betweenness Centrality, TEPS metric.

Uses the complemented-mask forward sweep (MSA; MCA unsupported per paper
§8.4) with a source batch, like the paper's batch=512 (scaled down)."""
from __future__ import annotations

import numpy as np

from repro.core.formats import erdos_renyi, rmat
from repro.graphs.betweenness import betweenness_centrality, bc_teps
from .common import save

ALGOS = ("msa", "heap")


def run(batch: int = 32):
    graphs = {
        "er_512_d8": erdos_renyi(512, 8, seed=1),
        "rmat_9_e8": rmat(9, 8, seed=2),
        "rmat_10_e4": rmat(10, 4, seed=3),
    }
    out = {}
    for gname, g in graphs.items():
        rng = np.random.default_rng(0)
        srcs = rng.choice(g.shape[0], size=min(batch, g.shape[0]),
                          replace=False)
        row = {}
        for algo in ALGOS:
            bc, secs, calls = betweenness_centrality(g, sources=srcs,
                                                     algorithm=algo)
            row[algo] = {"seconds": secs, "calls": calls,
                         "mteps": bc_teps(g, secs, len(srcs)) / 1e6}
            print(f"[bc] {gname:12s} {algo:5s} spgemm={secs*1e3:.0f}ms "
                  f"calls={calls} MTEPS={row[algo]['mteps']:.2f}",
                  flush=True)
        out[gname] = row
    save("betweenness", out)
    return out


if __name__ == "__main__":
    run()
