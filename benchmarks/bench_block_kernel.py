"""Beyond-paper: the TPU-native tile path.

Two measurements (structural, CPU container):
1. masked tile kernels (interpret) vs jnp oracle — correctness + the tile
   worklist's flop saving vs a dense product (paper Fig. 1 at MXU scale).
2. block_masked vs dense_masked attention: XLA-compiled flop counts from
   cost_analysis — the saving the dry-run rooflines rely on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import bcsr_from_dense
from repro.kernels.masked_matmul.ops import block_spgemm, \
    build_spgemm_schedule
from repro.models.attention import (block_masked_attention,
                                    dense_masked_attention)
from .common import save


def flops_of(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", float("nan")))


def run():
    out = {}
    # --- tile worklist sizes: scheduled tiles vs dense tiles --------------
    # block-structured sparsity (tile-granular masks ARE block-structured:
    # attention/SSD masks switch whole MXU tiles on or off)
    rng = np.random.default_rng(0)
    n, bs = 512, 32
    nb = n // bs

    def block_sparse(dens, seed):
        r = np.random.default_rng(seed)
        tiles = (r.random((nb, nb)) < dens)
        return (np.kron(tiles, np.ones((bs, bs)))
                * r.standard_normal((n, n))).astype(np.float32)

    for dens in (0.05, 0.2, 0.5):
        A = block_sparse(dens, 1)
        B = block_sparse(dens, 2)
        M = (block_sparse(dens, 3) != 0).astype(np.float32)
        Ab, Bb, Mb = (bcsr_from_dense(A, bs), bcsr_from_dense(B, bs),
                      bcsr_from_dense(M, bs))
        rank, pa, pb, flags = build_spgemm_schedule(Ab, Bb, Mb)
        real = int((flags & 2).astype(bool).sum())
        dense_tiles = (n // bs) ** 3
        out[f"spgemm_dens{dens}"] = {
            "worklist_products": real,
            "dense_tile_products": dense_tiles,
            "flop_fraction": real / dense_tiles,
        }
        print(f"[block] density={dens}: {real}/{dense_tiles} tile products "
              f"({real / dense_tiles:.3f} of dense)", flush=True)

    # --- attention: compiled flops, block vs dense ------------------------
    b, h, s, d = 1, 2, 1024, 64
    q = jax.ShapeDtypeStruct((b, h, s, d), jnp.bfloat16)
    for name, kw in [("causal", dict(causal=True)),
                     ("window256", dict(causal=True, window=256))]:
        f_dense = flops_of(lambda q_, k_, v_: dense_masked_attention(
            q_, k_, v_, **kw), q, q, q)
        f_block = flops_of(lambda q_, k_, v_: block_masked_attention(
            q_, k_, v_, bq=128, bk=128, **kw), q, q, q)
        out[f"attn_{name}"] = {"dense_flops": f_dense,
                               "block_flops": f_block,
                               "saving": 1 - f_block / f_dense}
        print(f"[block] attention {name}: dense={f_dense:.3e} "
              f"block={f_block:.3e} saving={1 - f_block / f_dense:.1%}",
              flush=True)
    save("block_kernel", out)
    return out


if __name__ == "__main__":
    run()
