"""Paper Fig. 7: best algorithm as input degree x mask degree vary (ER).

Grid over (d_input, d_mask); every algorithm timed on C = M (.) (A B) with
ER(n, d) inputs and an ER-pattern mask.  The paper's phase structure to
reproduce: Inner wins when the mask is much sparser than the inputs; Heap
when inputs are much sparser than the mask; MSA/Hash in between.
"""
from __future__ import annotations

from repro.core.formats import erdos_renyi, er_mask  # noqa: F401 (er_mask
# re-exported: bench_planner/bench_tile and older scripts import it here)
from repro.core.masked_spgemm import masked_spgemm
from .common import timeit, save

ALGOS = ("msa", "hash", "mca", "heap", "heapdot", "inner")


def run(n: int = 1024, degrees=(2, 8, 32), mask_degrees=(2, 8, 32),
        iters: int = 3):
    table = {}
    for d in degrees:
        A = erdos_renyi(n, d, seed=10 + d)
        B = erdos_renyi(n, d, seed=20 + d)
        for dm in mask_degrees:
            M = er_mask(n, dm, seed=30 + dm)
            cell = {}
            for algo in ALGOS:
                def go():
                    out = masked_spgemm(A, B, M, algorithm=algo)
                    out.vals.block_until_ready()
                cell[algo] = timeit(go, iters=iters)
            best = min(cell, key=cell.get)
            table[f"d{d}_m{dm}"] = {"times": cell, "best": best}
            print(f"[density] input_deg={d:3d} mask_deg={dm:3d} "
                  f"best={best:8s} "
                  + " ".join(f"{a}={cell[a]*1e3:.1f}ms" for a in ALGOS),
                  flush=True)
    save("density_grid", table)
    return table


if __name__ == "__main__":
    run()
