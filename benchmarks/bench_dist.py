"""Distributed grid: sparse ring vs dense ring vs row-parallel over
B-density x mesh size (forced host devices).

The parent process must keep seeing one device (the other benches depend
on it), so the measured grid runs in a child interpreter with
``--xla_force_host_platform_device_count``, exactly like the distributed
tests.  Points are block-structured operands (the tile pipeline's regime:
whole ``bs x bs`` tiles on/off, dense within) at several B tile densities,
plus a uniform-ER control where the row route must keep winning.  The
child writes ``results/bench/dist_grid.json``:

* per point/mesh: wall time of the sparse BCSR ring
  (``ring_sparse_masked_spgemm``), the dense ring (``ring_masked_matmul``
  on pre-materialized dense operands — generous to it: its densify cost is
  not billed), and the row-parallel route, plus the distributed planner's
  election;
* ``_sparse_beats_dense_somewhere`` — the sparse ring beats the dense ring
  on at least one sparse-B point (B tile density <= ``SPARSE_B_TD``);
* ``_auto_ok`` — at every point the elected route is within
  ``PICK_TOLERANCE`` of the measured best route.

Re-tune ``planner.DIST_COST`` against this grid (see ROADMAP "Open
items").
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

#: a point fails if the elected route is slower than (1 + this) x best
PICK_TOLERANCE = 0.10
#: B tile densities at or below this count as "sparse-B" for the
#: sparse-vs-dense-ring acceptance flag
SPARSE_B_TD = 0.05


def _child(n: int, mesh_sizes, densities_b, iters: int) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core.distributed import (distributed_masked_spgemm,
                                        ring_masked_matmul,
                                        ring_sparse_masked_spgemm)
    from repro.core.formats import block_sparse, csr_from_dense, erdos_renyi
    from repro.core.planner import collect_stats, decide_distributed
    from .common import save, timeit

    bs = 32

    points = [(f"block_tdb{td}", block_sparse(n, bs, 0.1, 0.9, seed=1),
               block_sparse(n, bs, td, 0.9, seed=2),
               block_sparse(n, bs, 0.2, 1.0, seed=3, mask=True),
               td) for td in densities_b]
    # uniform-ER control: no block structure, the row route must win and
    # the planner must keep the ring unelected
    points.append(("er_control", erdos_renyi(n, 8, seed=1).to_dense(),
                   erdos_renyi(n, 8, seed=2).to_dense(),
                   erdos_renyi(n, 8, seed=3).to_dense(), None))

    table = {}
    sparse_beats_dense = False
    auto_ok = True
    for pname, A, B, M, td in points:
        Ac, Bc, Mc = (csr_from_dense(np.asarray(A)),
                      csr_from_dense(np.asarray(B)),
                      csr_from_dense(np.asarray(M)))
        a_d, b_d, m_d = (jnp.asarray(A), jnp.asarray(B), jnp.asarray(M))
        stats = collect_stats(Ac, Bc, Mc)
        for p in mesh_sizes:
            mesh = Mesh(np.array(jax.devices()[:p]), ("data",))
            dplan = decide_distributed(stats, p)

            def go_sparse():
                out = ring_sparse_masked_spgemm(
                    Ac, Bc, Mc, mesh, block_size=dplan.tile_block or None)
                out.vals.block_until_ready()

            def go_dense():
                out = ring_masked_matmul(a_d, b_d, m_d, mesh, axis="data")
                out.block_until_ready()

            def go_row():
                out = distributed_masked_spgemm(
                    Ac, Bc, Mc, mesh, algorithm="row",
                    row_algorithm=dplan.row_algorithm)
                out.vals.block_until_ready()

            times = {"ring": timeit(go_sparse, iters=iters),
                     "ring_dense": timeit(go_dense, iters=iters),
                     # the row route loses by construction off the control
                     # point and can take tens of seconds there — one
                     # timed call is plenty to establish the ranking
                     "row": timeit(go_row,
                                   iters=1 if td is not None else iters)}
            best = min(("ring", "row"), key=times.get)
            point_ok = times[dplan.route] <= (1 + PICK_TOLERANCE) * times[best]
            auto_ok &= point_ok
            if td is not None and td <= SPARSE_B_TD \
                    and times["ring"] < times["ring_dense"]:
                sparse_beats_dense = True
            name = f"{pname}_p{p}"
            table[name] = {
                "n": n, "tile_density_b": td, "p": p, "times": times,
                "chosen": dplan.route, "tile_block": dplan.tile_block,
                "modeled": dict(dplan.costs), "best": best, "ok": point_ok,
            }
            print(f"[dist] {name:22s} ring={times['ring'] * 1e3:7.1f}ms "
                  f"dense={times['ring_dense'] * 1e3:7.1f}ms "
                  f"row={times['row'] * 1e3:7.1f}ms "
                  f"chosen={dplan.route:4s} "
                  f"{'OK' if point_ok else 'MISS'}", flush=True)
    table["_sparse_beats_dense_somewhere"] = sparse_beats_dense
    table["_auto_ok"] = auto_ok
    print(f"[dist] sparse_beats_dense_somewhere={sparse_beats_dense} "
          f"auto_ok={auto_ok}", flush=True)
    save("dist_grid", table)


def run(n: int = 2048, mesh_sizes=(2, 4, 8),
        densities_b=(0.02, 0.1, 0.3), iters: int = 3) -> dict:
    """Spawn the forced-multi-device child and return the written grid."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{max(mesh_sizes)} " + env.get("XLA_FLAGS", ""))
    spec = json.dumps({"n": n, "mesh_sizes": list(mesh_sizes),
                       "densities_b": list(densities_b), "iters": iters})
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_dist", "--child", spec],
        env=env, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_dist child failed: {proc.returncode}")
    from .common import results_dir
    with open(os.path.join(results_dir(), "dist_grid.json")) as f:
        return json.load(f)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + 1 iteration (CI smoke job)")
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.child is not None:
        spec = json.loads(args.child)
        _child(spec["n"], spec["mesh_sizes"], spec["densities_b"],
               spec["iters"])
    elif args.smoke:
        run(n=256, mesh_sizes=(2, 4), densities_b=(0.02, 0.3), iters=1)
    else:
        run()


if __name__ == "__main__":
    main()
