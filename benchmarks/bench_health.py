"""Health-intelligence gate: monitored serving must stay (nearly) free.

PR 10 attaches a :class:`repro.obs.health.HealthMonitor` — streaming
window aggregation + SLO burn rates + cost-model drift detection — to
the span stream.  This bench holds that machinery to its claims and
writes ``results/bench/health_grid.json``:

* ``overhead`` — one stream served by the SAME engine alternately under
  a plain in-memory sink and under a HealthMonitor: monitored tracing
  must stay within ``OVERHEAD_TOLERANCE`` of plain tracing, results
  bitwise equal and ``deterministic_snapshot()`` EQUAL between a
  plain-traced and a monitor-traced engine (``_health_ok``);
* ``pressure`` — a live engine reports /health 200 "ok"; a deterministic
  error storm (hash+complement is NotImplemented) must burn the error
  budget and flip /health to 503 with concrete reasons
  (``_pressure_ok``);
* ``drift`` — a calibrated cost table stays quiet, then the same table
  warped x256 must trip the detector with the matching
  ``repro.tune --only`` recommendation (``_drift_ok``);
* ``report`` — ``repro.obs.report`` must render every committed bench
  grid, console + HTML (``_report_ok``).
"""
from __future__ import annotations

import json
import os
import urllib.error
import urllib.request
from typing import Dict, List

import numpy as np

from repro import obs
from repro.core import accumulators as acc
from repro.core.formats import CSR, er_mask, erdos_renyi
from repro.obs.drift import DriftDetector
from repro.obs.health import HealthMonitor
from repro.obs.sinks import InMemorySink
from repro.serving import QueryEngine

from .bench_obs import OVERHEAD_TOLERANCE, _bitwise_equal, _serve, _timed_pair
from .common import save

#: multiplicative warp applied to every cost constant in the drift
#: scenario — far outside the detector band, so the verdict is
#: unambiguous even with cold-compile outliers in the stream
DRIFT_WARP = 256.0

#: detector band for the bench: wide enough that an honestly calibrated
#: table (residuals within ~2x plus decaying cold-start outliers) stays
#: quiet on any CI host, narrow enough that a x256 warp trips instantly
DRIFT_BAND = 8.0


def _revalue(x: CSR, seed: int) -> CSR:
    rng = np.random.default_rng(seed)
    return CSR(x.indptr, x.indices,
               rng.uniform(0.5, 1.5, x.nnz).astype(np.float32), x.shape)


def _burst(n: int, queries: int, seed: int = 0):
    A0 = erdos_renyi(n, 2, seed=100 + seed)
    B0 = erdos_renyi(n, 2, seed=200 + seed)
    M0 = er_mask(n, max(8, n // 8), seed=300 + seed)
    return [(_revalue(A0, 1000 + seed + q), B0, M0) for q in range(queries)]


def run(n: int = 1024, queries: int = 96, iters: int = 61,
        smoke: bool = False) -> Dict:
    table: Dict = {}

    # ---- monitored vs plain-traced serve throughput -----------------------
    # The PR 9 gate already bounds tracing vs untraced; this one bounds the
    # *aggregation* increment: the same stream, the same engine, traced
    # into a bare InMemorySink (A) vs a HealthMonitor (B).  Same timing
    # discipline as bench_obs (same engine both callbacks, alternation,
    # midmean of paired ratios) — see _timed_pair for why.
    stream = _burst(n, queries)
    plain = QueryEngine(cache_results=False)
    monitored = QueryEngine(cache_results=False)
    mon_check = HealthMonitor(inner=InMemorySink(capacity=16384))
    try:
        with obs.tracing(InMemorySink(capacity=16384)):
            want = _serve(plain, stream)
        with obs.tracing(mon_check):
            got = _serve(monitored, stream)
        bitwise_ok = all(_bitwise_equal(g, w) for g, w in zip(got, want))
        snap_equal = (plain.metrics.deterministic_snapshot()
                      == monitored.metrics.deterministic_snapshot())
        agg_names = mon_check.aggregator.window(60).names

        sink = InMemorySink(capacity=16384)
        mon_timed = HealthMonitor()           # aggregation + drift, no tee

        def plain_pass():
            with obs.tracing(sink):
                _serve(plain, stream)

        def monitored_pass():
            with obs.tracing(mon_timed):
                _serve(plain, stream)

        t_plain, t_mon = _timed_pair(plain_pass, monitored_pass, iters)
        overhead = t_mon / max(t_plain, 1e-12) - 1.0
        health_ok = (overhead <= OVERHEAD_TOLERANCE and bitwise_ok
                     and snap_equal)
        table["overhead"] = {
            "n": n, "queries": queries, "iters": iters,
            "plain_traced_s": t_plain, "monitored_s": t_mon,
            "plain_qps": queries / max(t_plain, 1e-12),
            "monitored_qps": queries / max(t_mon, 1e-12),
            "overhead_frac": overhead, "tolerance": OVERHEAD_TOLERANCE,
            "window_names": agg_names,
            "bitwise_equal": bitwise_ok,
            "deterministic_snapshot_equal": snap_equal,
        }
        print(f"[health] overhead n={n} q={queries}: plain "
              f"{t_plain * 1e3:7.1f}ms monitored {t_mon * 1e3:7.1f}ms "
              f"(+{overhead * 100:.2f}%, bar "
              f"{OVERHEAD_TOLERANCE * 100:.0f}%) bitwise="
              f"{'OK' if bitwise_ok else 'FAIL'} snap_eq={snap_equal}",
              flush=True)
    finally:
        plain.close()
        monitored.close()

    # ---- induced pressure: /health flips to 503-with-reasons --------------
    press_n = 64 if smoke else 256
    monitor = HealthMonitor(drift=None)
    engine = QueryEngine(monitor=monitor, expose_port=0)
    try:
        base = engine.obs_server.url
        with obs.tracing(monitor):
            _serve(engine, _burst(press_n, 8, seed=7))
            with urllib.request.urlopen(f"{base}/health", timeout=10) as r:
                healthy = json.loads(r.read().decode("utf-8"))
                healthy_code = r.status
            A, B, M = _burst(press_n, 1, seed=7)[0]
            storm = [engine.submit(A, B, M, algorithm="hash",
                                   complement=True) for _ in range(16)]
            engine.flush()
            failures = 0
            for t in storm:
                try:
                    t.result()
                except NotImplementedError:
                    failures += 1
            verdict = engine.health()
            try:
                urllib.request.urlopen(f"{base}/health", timeout=10)
                failing_code, failing = 200, {}
            except urllib.error.HTTPError as e:
                failing_code = e.code
                failing = json.loads(e.read().decode("utf-8"))
        pressure_ok = (healthy_code == 200 and healthy["status"] == "ok"
                       and failures == 16
                       and verdict.status == "failing"
                       and failing_code == 503
                       and failing.get("status") == "failing"
                       and any("serve-errors" in r
                               for r in failing.get("reasons", ())))
        table["pressure"] = {
            "healthy_code": healthy_code, "healthy": healthy,
            "induced_failures": failures,
            "failing_code": failing_code, "failing": failing,
        }
        print(f"[health] pressure {healthy_code} -> {failing_code} "
              f"({failures} induced failures, verdict={verdict.status}, "
              f"reasons={len(failing.get('reasons', ()))})", flush=True)
    finally:
        engine.close()

    # ---- cost-model drift: warped table trips, calibrated stays quiet ----
    drift_n = 64 if smoke else 256
    drift_q = 16 if smoke else 24
    det = DriftDetector(band=DRIFT_BAND)
    drift_mon = HealthMonitor(drift=det)
    # max_batch=1 + use_burst=False: every query is its own non-burst
    # exec span, so the per-query cost model prices exactly what the
    # span measures (burst replays are skipped by design)
    engine = QueryEngine(max_batch=1, use_burst=False, cache_results=False,
                         monitor=drift_mon)
    originals = {k: dict(v) for k, v in acc.COST_CONSTANTS.items()}
    try:
        with obs.tracing(drift_mon):
            _serve(engine, _burst(drift_n, drift_q, seed=11))
        quiet_flags = det.flags()
        quiet_stats = {k: dict(count=v["count"],
                               ewma_residual=v["ewma_residual"])
                       for k, v in det.snapshot().items()}
        # warp the LIVE table: cost_model_token() changes, the detector
        # resets (old residuals say nothing about the new model) and the
        # fresh residuals land ~1/DRIFT_WARP
        for name, consts in acc.COST_CONSTANTS.items():
            for k in consts:
                consts[k] = originals[name][k] * DRIFT_WARP
        with obs.tracing(drift_mon):
            _serve(engine, _burst(drift_n, drift_q, seed=11))
        warped_flags = det.flags()
        rep = det.report()
        drift_ok = (not quiet_flags and len(warped_flags) >= 1
                    and "row" in rep.families
                    and "python -m repro.tune --only" in rep.command)
        table["drift"] = {
            "band": DRIFT_BAND, "warp": DRIFT_WARP,
            "queries_per_phase": drift_q,
            "quiet_flags": len(quiet_flags),
            "quiet_stats": quiet_stats,
            "warped_flags": [f.as_dict() for f in warped_flags],
            "recommendation": rep.command,
        }
        print(f"[health] drift quiet={len(quiet_flags)} flags, warped="
              f"{len(warped_flags)} flags, families={list(rep.families)}",
              flush=True)
        if warped_flags:
            print(f"[health]   {rep.command}", flush=True)
    finally:
        for name, consts in acc.COST_CONSTANTS.items():
            consts.clear()
            consts.update(originals[name])
        engine.close()

    # ---- trajectory report over the committed grids -----------------------
    from repro.obs import report as report_mod
    bench_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "..", "results", "bench")
    rep_obj = report_mod.build_report(bench_dir)
    html = report_mod.render_html(rep_obj)
    console = report_mod.render_console(rep_obj, max_rows=3)
    grids: List[str] = sorted(rep_obj["grids"])
    report_ok = len(grids) >= 8 and "<svg" in html
    table["report"] = {
        "grids_rendered": len(grids), "grids": grids,
        "regressions": rep_obj["regressions"],
        "html_bytes": len(html), "console_lines": console.count("\n") + 1,
    }
    print(f"[health] report {len(grids)} grids "
          f"({', '.join(grids)}), {len(rep_obj['regressions'])} "
          f"regression flags, html {len(html)}B", flush=True)

    table["_health_ok"] = bool(health_ok)
    table["_pressure_ok"] = bool(pressure_ok)
    table["_drift_ok"] = bool(drift_ok)
    table["_report_ok"] = bool(report_ok)
    save("health_grid", table)
    return table


if __name__ == "__main__":
    run()
