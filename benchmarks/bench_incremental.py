"""Incremental serving vs recompute-from-scratch on an edge-delta stream.

Interleaves update batches with query batches against one burst-eligible
structure and writes ``results/bench/incremental_grid.json``:

* ``update`` — per-delta time-to-ready.  Incremental:
  ``QueryEngine.submit_delta`` (apply + O(changed rows) signature update +
  plan revalidation + lane patch + scoped result invalidation).
  Recompute: apply the same delta, drop every structure-derived artifact
  (plan cache, burst programs/patches/lineage), then cold-plan and
  cold-build the burst program.  The compiled fold memo stays warm in
  BOTH streams — the comparison is structure rebuild, not XLA retracing,
  which is conservative toward the incremental path.
* ``serve`` — the query batches riding between updates, answered from the
  patched (resp. rebuilt) programs.  Every served result — both streams,
  every round — must be bitwise-equal to the one-shot
  ``masked_spgemm`` oracle on the post-delta operands.

``_incremental_wins``: median update speedup >= INCREMENTAL_WIN with the
per-round delta touching <= 1% of rows, and bitwise equality everywhere.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core.formats import (CSR, CSRDelta, apply_csr_delta, erdos_renyi,
                                er_mask)
from repro.core.masked_spgemm import masked_spgemm
from repro.core.planner import clear_plan_cache, plan
from repro.core.semiring import PLUS_TIMES
from repro.serving import QueryEngine, burst

from .common import save

#: incremental readiness must beat the recompute path by this factor
INCREMENTAL_WIN = 5.0


def _structure(n: int):
    """Same regime as bench_serve's burst case: sparse inputs + dense
    mask elect the scatter plan, which routes onto the burst program —
    the artifact whose incremental patching is under test."""
    return (erdos_renyi(n, 2, seed=100), erdos_renyi(n, 2, seed=200),
            er_mask(n, max(8, n // 8), seed=300))


def _revalue(x: CSR, seed: int) -> CSR:
    rng = np.random.default_rng(seed)
    return CSR(x.indptr, x.indices,
               rng.uniform(0.5, 1.5, x.nnz).astype(np.float32), x.shape)


def _delta_stream(n: int, rounds: int, k: int) -> List[CSRDelta]:
    """One upsert batch per round, each touching k distinct rows (k/n is
    the delta fraction).  Coordinate batches are pure data, so the same
    stream replays identically through both serving modes."""
    rng = np.random.default_rng(11)
    out = []
    for _ in range(rounds):
        rows = rng.choice(n, size=k, replace=False).astype(np.int64)
        cols = rng.integers(n, size=k).astype(np.int64)
        vals = rng.uniform(0.5, 1.5, k).astype(np.float32)
        out.append(CSRDelta.upserts(rows, cols, vals))
    return out


def _bitwise_equal(got, want) -> bool:
    return (np.array_equal(np.asarray(got.vals), np.asarray(want.vals))
            and np.array_equal(np.asarray(got.present),
                               np.asarray(want.present))
            and np.array_equal(np.asarray(got.mask_cols),
                               np.asarray(want.mask_cols)))


def _drop_structure_artifacts() -> None:
    """What a delta invalidates when there is no incremental path: every
    structure-keyed artifact.  (The jit fold memo survives — see module
    docstring.)"""
    clear_plan_cache()
    burst._programs.clear()
    burst._patches.clear()
    burst._lineage.clear()


def _serve_round(engine: QueryEngine, queries) -> List:
    tickets = [engine.submit(A, B, M) for A, B, M in queries]
    engine.flush()
    return [t.result() for t in tickets]


def run(n: int = 1024, rounds: int = 8, deltas_per_round: int = 4,
        queries_per_round: int = 3) -> dict:
    A0, B, M = _structure(n)
    deltas = _delta_stream(n, rounds, deltas_per_round)

    def queries_for(a: CSR, r: int):
        return [(_revalue(a, 1000 * r + i), B, M)
                for i in range(queries_per_round)]

    # ---- incremental stream: submit_delta keeps the serving state warm
    _drop_structure_artifacts()
    eng = QueryEngine(max_batch=max(4, queries_per_round))
    _serve_round(eng, queries_for(A0, 0))          # warm plan + program
    a = A0
    upd_inc, serve_inc, bitwise_ok = [], [], True
    for r, d in enumerate(deltas, start=1):
        t0 = time.perf_counter()
        out = eng.submit_delta(a, B, M, delta_a=d)
        upd_inc.append(time.perf_counter() - t0)
        a = out.A
        qs = queries_for(a, r)
        t0 = time.perf_counter()
        got = _serve_round(eng, qs)
        serve_inc.append(time.perf_counter() - t0)
        for g, q in zip(got, qs):
            bitwise_ok &= _bitwise_equal(g, masked_spgemm(*q))
    inc_metrics = eng.metrics.snapshot()

    # ---- recompute stream: same deltas, structure state dropped per round
    _drop_structure_artifacts()
    eng2 = QueryEngine(max_batch=max(4, queries_per_round))
    _serve_round(eng2, queries_for(A0, 0))
    a = A0
    upd_cold, serve_cold = [], []
    for r, d in enumerate(deltas, start=1):
        t0 = time.perf_counter()
        res = apply_csr_delta(a, d)
        a = res.csr
        _drop_structure_artifacts()
        p = plan(a, B, M)
        burst.get_program(a, B, M, PLUS_TIMES, p.widths[2])
        upd_cold.append(time.perf_counter() - t0)
        qs = queries_for(a, r)
        t0 = time.perf_counter()
        got = _serve_round(eng2, qs)
        serve_cold.append(time.perf_counter() - t0)
        for g, q in zip(got, qs):
            bitwise_ok &= _bitwise_equal(g, masked_spgemm(*q))

    med_inc = float(np.median(upd_inc))
    med_cold = float(np.median(upd_cold))
    speedup = med_cold / med_inc if med_inc > 0 else float("inf")
    e2e_inc = sum(upd_inc) + sum(serve_inc)
    e2e_cold = sum(upd_cold) + sum(serve_cold)
    delta_fraction = deltas_per_round / n

    table = {
        "n": n,
        "rounds": rounds,
        "deltas_per_round": deltas_per_round,
        "queries_per_round": queries_per_round,
        "delta_fraction": delta_fraction,
        "update_ms": {
            "incremental": [round(t * 1e3, 3) for t in upd_inc],
            "recompute": [round(t * 1e3, 3) for t in upd_cold],
            "median_incremental": round(med_inc * 1e3, 3),
            "median_recompute": round(med_cold * 1e3, 3),
            "speedup": round(speedup, 2),
        },
        "serve_ms": {
            "incremental": [round(t * 1e3, 3) for t in serve_inc],
            "recompute": [round(t * 1e3, 3) for t in serve_cold],
        },
        "end_to_end_speedup": round(e2e_cold / e2e_inc, 2) if e2e_inc else 0,
        "metrics": {k: inc_metrics[k] for k in
                    ("delta_applied", "plans_revalidated", "lanes_patched",
                     "rows_invalidated")},
        "_bitwise_ok": bool(bitwise_ok),
        "_incremental_wins": bool(bitwise_ok
                                  and delta_fraction <= 0.01
                                  and speedup >= INCREMENTAL_WIN),
    }
    path = save("incremental_grid", table)
    print(f"[bench_incremental] update {med_cold * 1e3:.2f} ms -> "
          f"{med_inc * 1e3:.2f} ms ({speedup:.2f}x) at "
          f"{100 * delta_fraction:.2f}% delta fraction, "
          f"bitwise_ok={bitwise_ok} -> {path}")
    return table


if __name__ == "__main__":
    run()
