"""Paper Figs. 12-14: k-truss (k=5), GFLOPS = summed masked-SpGEMM flops /
summed masked-SpGEMM time, iterating as the graph prunes."""
from __future__ import annotations

from repro.graphs.ktruss import ktruss
from .common import graph_suite, perf_profile, save

ALGOS = ("msa", "hash", "mca", "inner")


def run(small: bool = True, k: int = 5):
    suite = graph_suite(small)
    times = {}
    for gname, g in suite.items():
        row = {}
        sizes = {}
        for algo in ALGOS:
            for phase in ("1p", "2p"):
                truss, secs, iters, flops = ktruss(
                    g, k, algorithm=algo, two_phase=phase == "2p")
                row[f"{algo}-{phase}"] = secs
                sizes.setdefault("edges", truss.nnz)
                assert sizes["edges"] == truss.nnz
                if phase == "1p":
                    print(f"[ktruss] {gname:12s} {algo:5s} iters={iters} "
                          f"gflops={flops / max(secs, 1e-9) / 1e9:.3f}",
                          flush=True)
        times[gname] = row
    payload = {"times": times, "profile": perf_profile(times)}
    save("ktruss", payload)
    return payload


if __name__ == "__main__":
    run()
