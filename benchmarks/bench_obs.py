"""Observability overhead gate: traced serving must cost (almost) nothing.

PR 9's tracing claims near-zero disabled cost and low enabled cost.  This
bench holds the serving layer to that and writes
``results/bench/obs_overhead_grid.json``:

* ``overhead`` — one burst stream served twice by identical engines, once
  untraced and once under ``obs.tracing()``: traced throughput must stay
  within ``OVERHEAD_TOLERANCE`` of untraced, results bitwise equal, and
  ``deterministic_snapshot()`` EQUAL (spans never feed scheduling)
  (``_obs_overhead_ok``).
* ``golden`` — the committed golden trace replayed traced and untraced:
  replay digests and deterministic counters must match
  (``_golden_traced_equal``).
* ``scrape`` — ``QueryEngine(expose_port=0)``: /metrics must parse under
  :func:`repro.obs.exposition.parse_prometheus` and /health must report a
  live engine (``_metrics_parse_ok``).
* ``export`` — the traced run's spans must survive the Chrome
  trace-event/Perfetto round trip (``_export_ok``).
"""
from __future__ import annotations

import json
import os
import tempfile
import time
import urllib.request
from typing import Dict, List

import numpy as np

from repro import obs
from repro.core.formats import CSR, er_mask, erdos_renyi
from repro.obs.exposition import parse_prometheus
from repro.serving import QueryEngine
from repro.serving.trace import Trace, golden_trace_path, replay_trace

from .common import save

#: traced serve wall time may exceed untraced by at most this fraction
OVERHEAD_TOLERANCE = 0.05


def _revalue(x: CSR, seed: int) -> CSR:
    rng = np.random.default_rng(seed)
    return CSR(x.indptr, x.indices,
               rng.uniform(0.5, 1.5, x.nnz).astype(np.float32), x.shape)


def _burst(n: int, queries: int):
    A0 = erdos_renyi(n, 2, seed=100)
    B0 = erdos_renyi(n, 2, seed=200)
    M0 = er_mask(n, max(8, n // 8), seed=300)
    return [(_revalue(A0, 1000 + q), B0, M0) for q in range(queries)]


def _serve(engine: QueryEngine, stream) -> List:
    tickets = [engine.submit(A, B, M) for A, B, M in stream]
    engine.flush()
    out = [t.result() for t in tickets]
    for r in out:
        r.vals.block_until_ready()
    return out


def _bitwise_equal(got, want) -> bool:
    return (np.array_equal(np.asarray(got.vals), np.asarray(want.vals))
            and np.array_equal(np.asarray(got.present),
                               np.asarray(want.present))
            and np.array_equal(np.asarray(got.mask_cols),
                               np.asarray(want.mask_cols)))


def _timed_pair(fn_a, fn_b, iters: int):
    """A/B timing built for a noisy shared host: median of per-pair
    ratios, with per-iteration order alternation.

    Each iteration times both variants back to back and yields one
    paired ratio.  Contention epochs longer than a pair (~100ms) slow
    both sides of the pair equally, so each ratio is drift-immune; brief
    one-sided spikes produce outlier ratios that the median over many
    pairs discards.  Alternation randomizes the sign of mid-pair epoch
    boundaries.  GC is paused so collection pauses triggered by one
    side's allocations aren't billed to it alone.

    (Two rejected estimators, for the next person tempted to "simplify":
    independent min-of-iters is corrupted by brief FAST windows that
    only one side samples — it reported traced 13% faster than untraced,
    physically impossible; and any two-engine design carries a ~4%
    allocation-layout bias between instances, so both callbacks must
    drive the SAME engine.)

    Returns ``(t_a, t_b)`` where ``t_a`` is the median A pass and
    ``t_b = t_a * r`` with ``r`` the midmean (mean of the interquartile
    range) of the pair ratios — as outlier-proof as the median but with
    ~20% less trial-to-trial variance, which is exactly the margin a 5%
    bar needs when the true overhead is ~2%.  ``t_b / t_a`` IS the
    robust overhead estimate.
    """
    import gc
    samples_a, ratios = [], []
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for i in range(max(1, iters)):
            first, second = (fn_a, fn_b) if i % 2 == 0 else (fn_b, fn_a)
            t0 = time.perf_counter()
            first()
            t1 = time.perf_counter()
            second()
            t2 = time.perf_counter()
            dt_first, dt_second = t1 - t0, t2 - t1
            dt_a, dt_b = ((dt_first, dt_second) if i % 2 == 0
                          else (dt_second, dt_first))
            samples_a.append(dt_a)
            ratios.append(dt_b / max(dt_a, 1e-12))
            gc.collect(0)  # drain young garbage between iterations
    finally:
        if gc_was_enabled:
            gc.enable()
    t_a = sorted(samples_a)[len(samples_a) // 2]
    rs = sorted(ratios)
    mid = rs[len(rs) // 4: -(len(rs) // 4) or None]
    ratio = sum(mid) / len(mid)
    return t_a, t_a * ratio


def run(n: int = 512, queries: int = 48, iters: int = 3,
        smoke: bool = False) -> Dict:
    table: Dict = {}
    stream = _burst(n, queries)

    # ---- traced vs untraced serve throughput ------------------------------
    # Correctness uses TWO identical engines (one never traced, one traced)
    # so deterministic_snapshot() equality proves spans don't leak into the
    # metrics either engine accumulates.  Timing uses ONE engine serving
    # the same stream alternately traced and untraced: two engine
    # instances carry a measurable (~4%) allocation-layout bias that would
    # otherwise drown the ~1% tracing cost under a 5% bar.
    # cache_results=False so every query exercises the full
    # span-instrumented execute path.
    plain = QueryEngine(cache_results=False)
    traced = QueryEngine(cache_results=False)
    try:
        want = _serve(plain, stream)                 # warm both engines
        with obs.tracing(capacity=16384) as tr:
            got = _serve(traced, stream)
        sink = tr.sink
        spans = sink.spans()          # one pass worth, before timing refills
        bitwise_ok = all(_bitwise_equal(g, w) for g, w in zip(got, want))
        # one pass each at this point: identical deterministic state
        snap_equal = (plain.metrics.deterministic_snapshot()
                      == traced.metrics.deterministic_snapshot())

        def plain_pass():
            _serve(plain, stream)

        def traced_pass():
            with obs.tracing(sink):
                _serve(plain, stream)

        t_plain, t_traced = _timed_pair(plain_pass, traced_pass, iters)
        overhead = t_traced / max(t_plain, 1e-12) - 1.0
        span_names = sorted({r["name"] for r in spans})
        table["overhead"] = {
            "n": n, "queries": queries, "iters": iters,
            "untraced_s": t_plain, "traced_s": t_traced,
            "untraced_qps": queries / max(t_plain, 1e-12),
            "traced_qps": queries / max(t_traced, 1e-12),
            "overhead_frac": overhead,
            "tolerance": OVERHEAD_TOLERANCE,
            "spans_per_pass": len(spans),
            "span_names": span_names,
            "bitwise_equal": bitwise_ok,
            "deterministic_snapshot_equal": snap_equal,
        }
        overhead_ok = (overhead <= OVERHEAD_TOLERANCE and bitwise_ok
                       and snap_equal)
        print(f"[obs] overhead n={n} q={queries}: untraced "
              f"{t_plain * 1e3:7.1f}ms traced {t_traced * 1e3:7.1f}ms "
              f"(+{overhead * 100:.2f}%, bar {OVERHEAD_TOLERANCE * 100:.0f}%)"
              f" spans={len(spans)}/pass bitwise="
              f"{'OK' if bitwise_ok else 'FAIL'} snap_eq={snap_equal}",
              flush=True)
    finally:
        plain.close()
        traced.close()

    # ---- golden trace: traced replay must not perturb determinism --------
    trace = Trace.load(golden_trace_path())
    rep_plain = replay_trace(trace)
    with obs.tracing():
        rep_traced = replay_trace(trace)
    golden_equal = (rep_plain.digest == rep_traced.digest
                    and rep_plain.counters == rep_traced.counters
                    and rep_plain.schedule == rep_traced.schedule)
    table["golden"] = {
        "trace": trace.name, "n_requests": rep_plain.n_requests,
        "untraced_digest": rep_plain.digest,
        "traced_digest": rep_traced.digest,
        "counters_equal": rep_plain.counters == rep_traced.counters,
    }
    print(f"[obs] golden  digests untraced={rep_plain.digest} "
          f"traced={rep_traced.digest} equal={golden_equal}", flush=True)

    # ---- /metrics + /health scrape ----------------------------------------
    scrape_n = 64 if smoke else n
    engine = QueryEngine(expose_port=0)
    try:
        _serve(engine, _burst(scrape_n, 4))
        _serve(engine, _burst(scrape_n, 4))          # replay -> cache hits
        base = engine.obs_server.url
        with urllib.request.urlopen(f"{base}/metrics", timeout=10) as r:
            text = r.read().decode("utf-8")
        with urllib.request.urlopen(f"{base}/health", timeout=10) as r:
            health = json.loads(r.read().decode("utf-8"))
        samples = parse_prometheus(text)
        hits = samples.get(("repro_serve_result_cache_hits_total", ()), 0)
        parse_ok = (len(samples) > 0 and health["status"] == "ok"
                    and hits == 4.0)
        table["scrape"] = {
            "url": "/metrics", "samples": len(samples),
            "result_cache_hits": hits, "health": health,
        }
        print(f"[obs] scrape  {len(samples)} samples, hits={hits}, "
              f"health={health['status']}", flush=True)
    finally:
        engine.close()

    # ---- Perfetto/Chrome export round trip --------------------------------
    events = obs.chrome_trace(spans)
    with tempfile.TemporaryDirectory(prefix="repro-obs-") as d:
        path = os.path.join(d, "trace.json")
        obs.save_chrome_trace(path, spans)
        with open(path) as f:
            loaded = json.load(f)
    export_ok = (len(loaded["traceEvents"]) == len(spans)
                 and len(events["traceEvents"]) == len(spans)
                 # slices export as "X"; counter tracks (queue depth,
                 # in-flight, hit-rate — PR 10) as Perfetto "C" events
                 and all(e["ph"] in ("X", "C")
                         for e in events["traceEvents"])
                 and any(e["ph"] == "C" for e in events["traceEvents"]))
    table["export"] = {"events": len(events["traceEvents"])}
    print(f"[obs] export  {len(events['traceEvents'])} trace events "
          f"(round trip {'OK' if export_ok else 'FAIL'})", flush=True)

    table["_obs_overhead_ok"] = bool(overhead_ok)
    table["_golden_traced_equal"] = bool(golden_equal)
    table["_metrics_parse_ok"] = bool(parse_ok)
    table["_export_ok"] = bool(export_ok)
    save("obs_overhead_grid", table)
    return table


if __name__ == "__main__":
    run()
