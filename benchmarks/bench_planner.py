"""Planner validation: ``algorithm="auto"`` vs best/worst fixed algorithm.

Re-runs the paper's density sweep (Fig. 7 grid: ER inputs x ER mask) timing
every fixed algorithm plus the planner's auto dispatch.  The acceptance bar:
auto within 10% of the best fixed algorithm — the planner picked (nearly)
the right kernel — and strictly faster than the worst at every grid point.
Also reports the chosen algorithm and the plan-cache hit rate (warm calls
must re-plan nothing).
"""
from __future__ import annotations

import time

from repro.core.masked_spgemm import ALGORITHMS, masked_spgemm
from repro.core.formats import erdos_renyi
from repro.core.planner import clear_plan_cache, plan, plan_cache_info
from .bench_density import er_mask
from .common import save

#: auto must be within this factor of the best fixed algorithm
AUTO_TOLERANCE = 1.10


def _time_interleaved(contenders, iters):
    """Round-robin timing: every contender runs once per round; the
    per-contender minimum across rounds is reported.  Interleaving makes
    process-wide slowdowns (shared CPU, allocator phases) hit all
    contenders alike, and min-of-k is the standard noise-robust estimator
    of a deterministic program's true cost (additive noise only inflates
    samples)."""
    import random
    for fn in contenders.values():   # warmup round: compile everything
        fn()
    samples = {name: [] for name in contenders}
    order = list(contenders)
    rng = random.Random(0)
    for _ in range(iters):
        rng.shuffle(order)           # no contender owns a fixed position
        for name in order:
            t0 = time.perf_counter()
            contenders[name]()
            samples[name].append(time.perf_counter() - t0)
    return {name: float(min(ts)) for name, ts in samples.items()}


def run(n: int = 1024, degrees=(2, 8, 32), mask_degrees=(2, 8, 32),
        iters: int = 6):
    clear_plan_cache()
    table = {}
    ok = True
    for d in degrees:
        A = erdos_renyi(n, d, seed=10 + d)
        B = erdos_renyi(n, d, seed=20 + d)
        for dm in mask_degrees:
            M = er_mask(n, dm, seed=30 + dm)

            def make(algo):
                def go():
                    out = masked_spgemm(A, B, M, algorithm=algo)
                    out.vals.block_until_ready()
                return go

            timed = _time_interleaved(
                {**{a: make(a) for a in ALGORITHMS}, "auto": make("auto")},
                iters)
            t_auto = timed.pop("auto")
            cell = timed
            chosen = plan(A, B, M).algorithm   # cache hit: already planned
            best = min(cell, key=cell.get)
            worst = max(cell, key=cell.get)
            vs_best = t_auto / cell[best]
            vs_worst = t_auto / cell[worst]
            # dispatch overhead: a warm auto call is the chosen fixed
            # algorithm plus exactly this (plan-cache lookup = CRC of the
            # index arrays).  When the planner picked the measured-best
            # algorithm, auto and best run the SAME compiled program, and
            # this overhead — not a noisy re-timing of that program — is
            # the true cost of auto.
            t0 = time.perf_counter()
            for _ in range(5):
                plan(A, B, M)
            t_plan = (time.perf_counter() - t0) / 5
            cell_ok = t_auto < cell[worst] and (
                vs_best <= AUTO_TOLERANCE
                or (chosen == best
                    and t_plan <= (AUTO_TOLERANCE - 1.0) * cell[best]))
            ok &= cell_ok
            table[f"d{d}_m{dm}"] = {
                "times": cell, "auto": t_auto, "chosen": chosen,
                "best": best, "worst": worst, "plan_overhead": t_plan,
                "auto_vs_best": vs_best, "auto_vs_worst": vs_worst,
                "ok": cell_ok,
            }
            print(f"[planner] input_deg={d:3d} mask_deg={dm:3d} "
                  f"auto={t_auto*1e3:7.1f}ms ({chosen:7s}) "
                  f"best={best:7s} {cell[best]*1e3:7.1f}ms "
                  f"worst={worst:7s} {cell[worst]*1e3:7.1f}ms "
                  f"vs_best={vs_best:.2f} plan={t_plan*1e3:.2f}ms "
                  f"{'OK' if cell_ok else 'MISS'}",
                  flush=True)
    info = plan_cache_info()
    table["_plan_cache"] = info
    table["_all_ok"] = ok
    print(f"[planner] cache: {info}  all_ok={ok}", flush=True)
    save("planner_grid", table)
    return table


if __name__ == "__main__":
    run()
