"""Replay-based perf-regression gate over the committed golden trace.

Replays ``results/traces/golden_v1.jsonl`` (a recorded mixed-structure
query stream with repeats) through ``repro.serving.replay_trace`` and
writes ``results/bench/replay_grid.json``:

* ``_replay_deterministic``    — two default-knob replays (plus an async
  replay) produce bit-identical digests: same bucket schedule, same
  deterministic counters, byte-exact results.
* ``_replay_matches_oneshot``  — every replayed result is byte-equal to a
  sequential one-shot ``masked_spgemm`` oracle over the same trace.
* ``_autotuned_beats_default`` — one autotuner pass (the default config is
  in its grid) yields knobs whose replayed throughput is at least the
  default knobs' throughput, within noise tolerance.
* ``_replay_throughput_ok``    — the machine-relative floor: engine replay
  throughput >= ``REPLAY_FLOOR`` x the warm sequential one-shot loop on
  the SAME host.  Absolute q/s is machine-dependent; this ratio is the
  quantity a batching regression actually moves, so CI gates on it.

``--strict`` in ``benchmarks.run`` fails the job when any flag is False.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.masked_spgemm import masked_spgemm
from repro.serving.trace import (Trace, _result_crc, golden_trace_path,
                                 replay_trace, synthesize_trace)
from repro.tuning.autotune import DEFAULT_KNOBS, autotune

from .common import save

#: engine replay must reach this fraction of the warm sequential loop's
#: throughput on the same host (batching + caching should beat 1.0x; the
#: floor only trips on a real serving-path regression, not host noise)
REPLAY_FLOOR = 0.8

#: autotuned knobs must reach this fraction of the default knobs'
#: throughput (the default config is in the search grid, so the winner is
#: >= default up to re-measurement noise)
AUTOTUNE_TOLERANCE = 0.95


def _best_of(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _sequential_oracle(events) -> List[int]:
    """One-shot results for every trace event, in arrival order."""
    crcs = []
    for (_t, A, B, M, kw) in events:
        res = masked_spgemm(A, B, M, semiring=kw["semiring"],
                            complement=kw["complement"],
                            algorithm=kw.get("algorithm") or "auto")
        crcs.append(_result_crc(res))
    return crcs


def run(*, iters: int = 3, smoke: bool = False,
        trace_path: str = None, autotune_rounds: int = 1) -> Dict:
    path = trace_path or golden_trace_path()
    trace = Trace.load(path)
    print(f"[replay] trace {trace.name}: {trace.n_requests} requests over "
          f"{trace.duration_s * 1e3:.1f}ms (from {path})", flush=True)

    # -- determinism: two sync replays + one async must agree bitwise ------
    rep1 = replay_trace(trace, knobs=DEFAULT_KNOBS)
    rep2 = replay_trace(trace, knobs=DEFAULT_KNOBS)
    rep_async = replay_trace(trace, knobs=DEFAULT_KNOBS, async_mode=True)
    deterministic = (rep1.digest == rep2.digest == rep_async.digest
                     and rep1.schedule == rep2.schedule == rep_async.schedule
                     and rep1.result_crcs == rep2.result_crcs
                     == rep_async.result_crcs)
    print(f"[replay] digests sync={rep1.digest},{rep2.digest} "
          f"async={rep_async.digest} deterministic={deterministic}",
          flush=True)

    # -- correctness: replayed results == sequential one-shot oracle -------
    events = trace.materialized()
    oracle_crcs = _sequential_oracle(events)          # also warms caches
    matches_oneshot = oracle_crcs == rep1.result_crcs

    # -- machine-relative throughput floor (both sides warm) ---------------
    seq_s = _best_of(lambda: _sequential_oracle(events), iters)
    replay_best = min(replay_trace(trace, knobs=DEFAULT_KNOBS).wall_s
                      for _ in range(max(1, iters)))
    default_qps = trace.n_requests / max(replay_best, 1e-12)
    seq_qps = trace.n_requests / max(seq_s, 1e-12)
    throughput_ok = default_qps >= REPLAY_FLOOR * seq_qps
    print(f"[replay] default knobs {default_qps:.1f} q/s vs sequential "
          f"{seq_qps:.1f} q/s (floor {REPLAY_FLOOR}x -> "
          f"{'ok' if throughput_ok else 'REGRESSION'})", flush=True)

    # -- closed loop: autotuned knobs must not lose to the defaults --------
    tuned = autotune(trace, smoke=smoke, rounds=autotune_rounds,
                     verbose=False)
    win = tuned["winner"]
    if win["knobs"] == DEFAULT_KNOBS:
        beats_default = True
        tuned_qps = default_qps
    else:
        tuned_best = min(replay_trace(trace, knobs=win["knobs"]).wall_s
                         for _ in range(max(1, iters)))
        tuned_qps = trace.n_requests / max(tuned_best, 1e-12)
        beats_default = tuned_qps >= AUTOTUNE_TOLERANCE * default_qps
    print(f"[replay] autotuned {win['knobs']} -> {tuned_qps:.1f} q/s "
          f"({tuned_qps / max(default_qps, 1e-12):.2f}x default)",
          flush=True)

    table = {
        "trace": {"name": trace.name, "path": path,
                  "requests": trace.n_requests,
                  "duration_s": trace.duration_s},
        "digest": rep1.digest,
        "digest_async": rep_async.digest,
        "counters": rep1.counters,
        "schedule_len": len(rep1.schedule),
        "default_knobs": dict(DEFAULT_KNOBS),
        "default_qps": default_qps,
        "sequential_qps": seq_qps,
        "replay_floor": REPLAY_FLOOR,
        "autotuned_knobs": win["knobs"],
        "autotuned_qps": tuned_qps,
        "autotune_improvement": tuned["improvement"],
        "lat_p50_s": rep1.lat_p50_s,
        "lat_p99_s": rep1.lat_p99_s,
        "_replay_deterministic": deterministic,
        "_replay_matches_oneshot": matches_oneshot,
        "_replay_throughput_ok": throughput_ok,
        "_autotuned_beats_default": beats_default,
    }
    out = save("replay_grid", table)
    print(f"[replay] wrote {out}", flush=True)
    return table


def export_golden(path: str = None) -> str:
    """Regenerate the canonical golden trace (fixed parameters/seed)."""
    trace = synthesize_trace(name="golden_v1", n=96, n_structs=3,
                             queries=48, mean_gap_ms=0.5, seed=7)
    return trace.save(path or golden_trace_path())


if __name__ == "__main__":
    run()
