"""Paper Fig. 10: Triangle-Counting GFLOPS vs R-MAT scale."""
from __future__ import annotations

from repro.core.formats import rmat
from repro.graphs.triangle_counting import triangle_count, tc_flops
from .common import save, timeit

ALGOS = ("msa", "hash", "mca", "inner")


def run(scales=(8, 9, 10, 11), edge_factor: int = 8, iters: int = 2):
    out = {}
    for scale in scales:
        g = rmat(scale, edge_factor, seed=scale)
        flops = tc_flops(g)
        row = {}
        for algo in ALGOS:
            def go():
                triangle_count(g, algorithm=algo)
            t = timeit(go, warmup=0, iters=iters)
            row[algo] = {"seconds": t, "gflops": flops / t / 1e9}
        out[f"scale{scale}"] = {"nnz": g.nnz, "flops": flops, **row}
        print(f"[rmat] scale={scale} nnz={g.nnz:9d} " +
              " ".join(f"{a}={row[a]['gflops']:.3f}GF" for a in ALGOS),
              flush=True)
    save("rmat_scale", out)
    return out


if __name__ == "__main__":
    run()
