"""Paper Fig. 11: strong scaling.

The paper scales OpenMP threads; the analogue here is devices: the
row-parallel masked SpGEMM under shard_map on 1/2/4/8 forced host devices
(subprocesses, because the device count locks at backend init).
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

from .common import save

_CHILD = r"""
import os, sys, time, json
n = int(sys.argv[1])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
import jax, numpy as np
from repro.core.formats import erdos_renyi, padded_from_csr, random_mask_like
from repro.core.distributed import row_parallel_masked_spgemm, pad_rows_to

g = erdos_renyi(4096, 16, seed=1)
m = random_mask_like(g, 0.5, seed=2)
A = padded_from_csr(g); B = padded_from_csr(g); M = padded_from_csr(m)
mesh = jax.make_mesh((n,), ("data",))
A, M = pad_rows_to(n, A, M)
def go():
    vals, present = row_parallel_masked_spgemm(A, B, M, mesh,
                                               algorithm="msa")
    vals.block_until_ready()
go()
ts = []
for _ in range(3):
    t0 = time.perf_counter(); go(); ts.append(time.perf_counter() - t0)
print(json.dumps({"n": n, "seconds": float(np.median(ts))}))
"""


def run(device_counts=(1, 2, 4, 8)):
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src")
    env.pop("XLA_FLAGS", None)
    out = {}
    for n in device_counts:
        r = subprocess.run([sys.executable, "-c", _CHILD, str(n)],
                           capture_output=True, text=True, timeout=600,
                           env=env)
        if r.returncode != 0:
            out[str(n)] = {"error": r.stderr[-500:]}
            continue
        d = json.loads(r.stdout.strip().splitlines()[-1])
        out[str(n)] = d
        base = out.get("1", d)["seconds"]
        print(f"[scaling] devices={n} t={d['seconds']*1e3:.1f}ms "
              f"speedup={base / d['seconds']:.2f}x", flush=True)
    save("strong_scaling", out)
    return out


if __name__ == "__main__":
    run()
