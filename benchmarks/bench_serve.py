"""Serving-layer load generator: batched engine vs sequential one-shot.

Drives ``repro.serving.QueryEngine`` with the query streams a deployment
sees and writes ``results/bench/serve_grid.json``:

* ``burst``  — a same-structure burst (one operand structure, fresh values
  per query): the bucket case.  Acceptance: engine throughput >= 3x the
  sequential one-shot loop AND every served result bitwise-equal to the
  one-shot oracle (``_serve_batching_wins``).
* ``mix``    — several structures shuffled together: bucketing must
  reassemble them (queries-per-second vs sequential, per-bucket sizes).
* ``cold``   — first-query latency from empty caches vs a warm query.
* ``replay`` — the exact stream twice: second pass must be ~all result
  cache hits.

Sequential baseline and engine both run warm (plans cached, programs
compiled) and both block until results are ready — the measured difference
is dispatch/batching, which is the serving layer's whole claim.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro import caches
from repro.core.formats import CSR, erdos_renyi, er_mask
from repro.core.masked_spgemm import masked_spgemm
from repro.core.planner import clear_plan_cache
from repro.serving import QueryEngine

from .common import save

#: batched engine must beat sequential one-shot by this factor on the burst
BATCHING_WIN = 3.0


def _revalue(x: CSR, seed: int) -> CSR:
    """Same structure, fresh values — a query against a shared pattern."""
    rng = np.random.default_rng(seed)
    return CSR(x.indptr, x.indices,
               rng.uniform(0.5, 1.5, x.nnz).astype(np.float32), x.shape)


def _burst_structure(n: int):
    """Sparse inputs + dense mask: the mca/msa regime, where the serving
    layer's structure-compiled replay pays off hardest (plan election is
    what routes the bucket onto it — nothing is forced)."""
    return (erdos_renyi(n, 2, seed=100), erdos_renyi(n, 2, seed=200),
            er_mask(n, max(8, n // 8), seed=300))


def _structures(n: int, n_structs: int):
    """Mixed regimes: burst-eligible scatter plans plus inner-elected ER
    points that stay on the batched row driver."""
    out = [_burst_structure(n)]
    for s in range(1, n_structs):
        out.append((erdos_renyi(n, 2 + 2 * s, seed=100 + s),
                    erdos_renyi(n, 2 + 2 * s, seed=200 + s),
                    er_mask(n, 8 * s, seed=300 + s)))
    return out


def _sequential(queries) -> List:
    return [masked_spgemm(A, B, M) for A, B, M in queries]


def _engine_serve(engine: QueryEngine, queries) -> List:
    tickets = [engine.submit(A, B, M) for A, B, M in queries]
    engine.flush()
    return [t.result() for t in tickets]


def _bitwise_equal(got, want) -> bool:
    return (np.array_equal(np.asarray(got.vals), np.asarray(want.vals))
            and np.array_equal(np.asarray(got.present),
                               np.asarray(want.present))
            and np.array_equal(np.asarray(got.mask_cols),
                               np.asarray(want.mask_cols)))


def _block(results) -> None:
    for r in results:
        r.vals.block_until_ready()


def run(n: int = 512, queries: int = 48, n_structs: int = 4,
        max_batch: int = 64, iters: int = 3):
    table = {}

    # ---- burst: one structure, fresh values per query ---------------------
    A0, B0, M0 = _burst_structure(n)
    burst = [(_revalue(A0, 1000 + q), B0, M0) for q in range(queries)]

    engine = QueryEngine(max_batch=max_batch, queue_cap=4 * max_batch,
                         cache_results=False)
    _block(_sequential(burst))            # warm: plan + compile both paths
    _block(_engine_serve(engine, burst))

    t_seq = min(_timed(lambda: _block(_sequential(burst)), iters))
    t_eng = min(_timed(lambda: _block(_engine_serve(engine, burst)), iters))
    want = _sequential(burst)
    got = _engine_serve(engine, burst)
    bitwise_ok = all(_bitwise_equal(g, w) for g, w in zip(got, want))
    ratio = t_seq / max(t_eng, 1e-12)
    log = engine.metrics.bucket_log()
    table["burst"] = {
        "n": n, "queries": queries,
        "seq_s": t_seq, "engine_s": t_eng, "speedup": ratio,
        "seq_qps": queries / t_seq, "engine_qps": queries / t_eng,
        "bitwise_equal": bitwise_ok,
        "route": log[-1]["route"] if log else None,
        "algorithm": log[-1]["algorithm"] if log else None,
        "metrics": engine.metrics.snapshot(),
    }
    print(f"[serve] burst   n={n} q={queries}: seq {t_seq*1e3:7.1f}ms "
          f"engine {t_eng*1e3:7.1f}ms  speedup {ratio:.2f}x "
          f"route={table['burst']['route']} "
          f"bitwise={'OK' if bitwise_ok else 'FAIL'}", flush=True)
    engine.close()

    # ---- mix: shuffled multi-structure stream -----------------------------
    structs = _structures(n, n_structs)
    rng = np.random.default_rng(0)
    mix = []
    for q in range(queries):
        A, B, M = structs[int(rng.integers(n_structs))]
        mix.append((_revalue(A, 2000 + q), B, M))

    engine = QueryEngine(max_batch=max_batch, queue_cap=4 * max_batch,
                         cache_results=False)
    _block(_sequential(mix))
    _block(_engine_serve(engine, mix))
    t_seq_mix = min(_timed(lambda: _block(_sequential(mix)), iters))
    t_eng_mix = min(_timed(lambda: _block(_engine_serve(engine, mix)),
                           iters))
    want = _sequential(mix)
    got = _engine_serve(engine, mix)
    mix_bitwise = all(_bitwise_equal(g, w) for g, w in zip(got, want))
    snap = engine.metrics.snapshot()
    table["mix"] = {
        "n": n, "queries": queries, "structures": n_structs,
        "seq_s": t_seq_mix, "engine_s": t_eng_mix,
        "speedup": t_seq_mix / max(t_eng_mix, 1e-12),
        "mean_batch": snap["mean_batch"], "bitwise_equal": mix_bitwise,
        "metrics": snap,
    }
    print(f"[serve] mix     n={n} q={queries} s={n_structs}: "
          f"seq {t_seq_mix*1e3:7.1f}ms engine {t_eng_mix*1e3:7.1f}ms "
          f"speedup {table['mix']['speedup']:.2f}x "
          f"mean_batch {snap['mean_batch']:.1f} "
          f"bitwise={'OK' if mix_bitwise else 'FAIL'}", flush=True)
    engine.close()

    # ---- cold start: first query from empty caches ------------------------
    caches.clear_all()
    clear_plan_cache()
    engine = QueryEngine(max_batch=max_batch)
    q0 = burst[0]
    t0 = time.perf_counter()
    engine.serve([q0])
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    engine.serve([(_revalue(A0, 1), B0, M0)])   # warm: same structure
    warm_s = time.perf_counter() - t0
    table["cold"] = {"cold_s": cold_s, "warm_s": warm_s,
                     "ratio": cold_s / max(warm_s, 1e-12)}
    print(f"[serve] cold    first {cold_s*1e3:.1f}ms vs warm "
          f"{warm_s*1e3:.1f}ms", flush=True)

    # ---- replay: identical stream twice -> result-cache hits --------------
    replay = burst[: max(8, queries // 2)]
    engine.results.clear()
    engine.metrics.reset()
    first = _engine_serve(engine, replay)
    t0 = time.perf_counter()
    second = _engine_serve(engine, replay)
    replay_s = time.perf_counter() - t0
    hits = engine.metrics.snapshot()["result_cache_hits"]
    replay_ok = (hits == len(replay)
                 and all(_bitwise_equal(g, w)
                         for g, w in zip(second, first)))
    table["replay"] = {"queries": len(replay), "cache_hits": hits,
                      "second_pass_s": replay_s,
                      "cache_info": engine.results.info(),
                      "_replay_all_hits": replay_ok}
    print(f"[serve] replay  {hits}/{len(replay)} cache hits, second pass "
          f"{replay_s*1e3:.1f}ms", flush=True)
    engine.close()

    table["_serve_batching_wins"] = bool(ratio >= BATCHING_WIN
                                         and bitwise_ok)
    table["_bitwise_ok"] = bool(bitwise_ok and mix_bitwise)
    print(f"[serve] batching_wins={table['_serve_batching_wins']} "
          f"(speedup {ratio:.2f}x vs bar {BATCHING_WIN}x)", flush=True)
    save("serve_grid", table)
    return table


def _timed(fn, iters: int) -> List[float]:
    out = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        out.append(time.perf_counter() - t0)
    return out


if __name__ == "__main__":
    run()
