"""Tile-route validation grid: block size x input density x mask occupancy.

Times the end-to-end BCSR tile route (``masked_spgemm(algorithm="tile")`` —
conversion + vectorized schedule + both executor replays + extraction)
against every row kernel on two families:

* block-structured operands (whole tiles on/off, dense within a tile) at
  several tile densities and mask tile occupancies — the regime the tile
  path exists for (attention/SSD-style masks switch MXU tiles wholesale);
* a uniform-ER control point per block size, where the row kernels must
  keep winning and the planner must not elect the tile route.

Acceptance (recorded in tile_grid.json):
  * ``_tile_wins_somewhere`` — the tile route beats the best row kernel on
    at least one dense-block point;
  * ``_planner_ok`` — at every point where auto elected the tile route it
    is within ``PICK_TOLERANCE`` of the best row kernel (the planner never
    picks tile where it loses by >10%).
Re-tune ``planner.TILE_COST`` / ``TILE_MIN_*`` against this grid (see
ROADMAP "Open items").
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.formats import (block_sparse,  # noqa: F401 (re-exported)
                                csr_from_dense, erdos_renyi)
from repro.core.masked_spgemm import ALGORITHMS, masked_spgemm
from repro.core.planner import clear_plan_cache, plan
from .bench_density import er_mask
from .common import save, timeit

#: a point where auto elected "tile" fails if tile is slower than
#: (1 + this) x the best row kernel
PICK_TOLERANCE = 0.10


def _time_point(A, B, M, bs, iters):
    times = {}
    for algo in ALGORITHMS:
        def go(algo=algo):
            out = masked_spgemm(A, B, M, algorithm=algo)
            out.vals.block_until_ready()
        times[algo] = timeit(go, iters=iters)

    def go_tile():
        out = masked_spgemm(A, B, M, algorithm="tile", tile_block=bs)
        out.vals.block_until_ready()
    t_tile = timeit(go_tile, iters=iters)

    p = plan(A, B, M)   # may pay a one-shot trial; timed auto call is warm

    def go_auto():
        out = masked_spgemm(A, B, M, algorithm="auto")
        out.vals.block_until_ready()
    t_auto = timeit(go_auto, iters=iters)
    return times, t_tile, t_auto, p


def run(n: int = 512, block_sizes=(8, 32), tile_densities=(0.1, 0.3),
        mask_occupancies=(0.2, 0.6), iters: int = 3):
    clear_plan_cache()
    table = {}
    tile_wins = False
    planner_ok = True
    for bs in block_sizes:
        points = [
            (f"bs{bs}_td{td}_mo{mo}",
             block_sparse(n, bs, td, 0.9, seed=100 + bs, mask=False),
             block_sparse(n, bs, td, 0.9, seed=200 + bs, mask=False),
             block_sparse(n, bs, mo, 1.0, seed=300 + int(mo * 10), mask=True))
            for td in tile_densities for mo in mask_occupancies
        ]
        # uniform-sparse control: the tile route must lose AND not be picked
        g = erdos_renyi(n, 4, seed=bs)
        points.append((f"bs{bs}_er_control", g.to_dense(),
                       erdos_renyi(n, 4, seed=bs + 1).to_dense(),
                       er_mask(n, 8, seed=bs + 2).to_dense()))
        for name, A, B, M in points:
            Ac, Bc, Mc = (csr_from_dense(np.asarray(A)),
                          csr_from_dense(np.asarray(B)),
                          csr_from_dense(np.asarray(M)))
            times, t_tile, t_auto, p = _time_point(Ac, Bc, Mc, bs, iters)
            best_row = min(times, key=times.get)
            beats = t_tile < times[best_row]
            control = name.endswith("_control")
            if beats and not control:
                tile_wins = True
            point_ok = (p.algorithm != "tile"
                        or t_tile <= (1 + PICK_TOLERANCE) * times[best_row])
            planner_ok &= point_ok
            table[name] = {
                "row_times": times, "tile": t_tile, "auto": t_auto,
                "chosen": p.algorithm, "tile_eligible": p.tile_eligible,
                "tile_block": p.tile_block, "best_row": best_row,
                "tile_vs_best_row": t_tile / times[best_row],
                "ok": point_ok,
            }
            print(f"[tile] {name:24s} tile={t_tile * 1e3:7.1f}ms "
                  f"best_row={best_row:7s} {times[best_row] * 1e3:7.1f}ms "
                  f"ratio={t_tile / times[best_row]:5.2f} "
                  f"chosen={p.algorithm:7s} "
                  f"{'OK' if point_ok else 'MISS'}", flush=True)
    table["_tile_wins_somewhere"] = tile_wins
    table["_planner_ok"] = planner_ok
    print(f"[tile] tile_wins_somewhere={tile_wins} planner_ok={planner_ok}",
          flush=True)
    save("tile_grid", table)
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + 1 iteration (CI smoke job)")
    args = ap.parse_args()
    if args.smoke:
        run(n=128, block_sizes=(8, 16), tile_densities=(0.3,),
            mask_occupancies=(0.5,), iters=1)
    else:
        run()


if __name__ == "__main__":
    main()
