"""Paper Figs. 8/9: Triangle Counting across the graph suite.

Times every algorithm (1P and 2P) per graph; emits Dolan-More performance
profiles.  Validates the paper claims: (i) 1P beats 2P, (ii) MSA-1P leads
the profile.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.triangle_counting import triangle_count, tc_flops
from .common import graph_suite, perf_profile, save, timeit

ALGOS = ("msa", "hash", "mca", "heap", "inner")


def run(small: bool = True, iters: int = 2):
    suite = graph_suite(small)
    times = {}
    counts = {}
    for gname, g in suite.items():
        row = {}
        for algo in ALGOS:
            for phase in ("1p", "2p"):
                tri, _ = triangle_count(g, algorithm=algo,
                                        two_phase=phase == "2p")
                counts.setdefault(gname, tri)
                assert counts[gname] == tri, (gname, algo, phase)

                def go():
                    triangle_count(g, algorithm=algo,
                                   two_phase=phase == "2p")
                row[f"{algo}-{phase}"] = timeit(go, warmup=0, iters=iters)
        times[gname] = row
        flops = tc_flops(g)
        best = min(row, key=row.get)
        print(f"[tc] {gname:12s} tri={counts[gname]:8d} best={best:10s} "
              f"gflops(best)={flops / row[best] / 1e9:.3f}", flush=True)
    prof = perf_profile(times)
    # paper-claim checks (soft: recorded, not asserted)
    one_vs_two = np.mean([row[f"{a}-1p"] <= row[f"{a}-2p"]
                          for row in times.values() for a in ALGOS])
    payload = {"times": times, "profile": prof,
               "frac_1p_not_slower": float(one_vs_two),
               "triangles": counts}
    save("triangle_counting", payload)
    print(f"[tc] fraction of cases where 1P <= 2P: {one_vs_two:.2f}")
    return payload


if __name__ == "__main__":
    run()
