"""Benchmark utilities: timing, graph suite, result IO."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import numpy as np

def results_dir() -> str:
    """Output directory, resolved per call: ``benchmarks.run --smoke``
    redirects to a scratch dir via $REPRO_BENCH_OUT so smoke tiers never
    clobber the committed full-tier grids.  (Deliberately NOT an
    import-time constant — a snapshot taken before run.py sets the env
    var would re-introduce the clobbering.)"""
    return os.environ.get("REPRO_BENCH_OUT", "results/bench")


def timeit(fn: Callable, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn() (fn must block until ready)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def save(name: str, payload) -> str:
    out = results_dir()
    os.makedirs(out, exist_ok=True)
    if isinstance(payload, dict) and "_cache_info" not in payload:
        # end-of-run registry state (hit/miss/occupancy per process cache)
        # rides along with every grid: a cost-model regression often shows
        # up first as a plan-cache hit-rate change, and the committed grids
        # are the only durable record of a full-tier run
        from repro import caches
        payload["_cache_info"] = caches.cache_info()
    path = os.path.join(out, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    return path


def graph_suite(small: bool = True) -> Dict[str, "CSR"]:
    """Stand-in for the paper's 26 SuiteSparse graphs (offline container):
    ER + R-MAT graphs spanning the same density regimes."""
    from repro.core.formats import erdos_renyi, rmat
    if small:
        return {
            "er_1k_d4": erdos_renyi(1024, 4, seed=1),
            "er_1k_d16": erdos_renyi(1024, 16, seed=2),
            "er_4k_d8": erdos_renyi(4096, 8, seed=3),
            "rmat_9_e8": rmat(9, 8, seed=4),
            "rmat_10_e8": rmat(10, 8, seed=5),
            "rmat_11_e4": rmat(11, 4, seed=6),
        }
    return {
        **graph_suite(True),
        "rmat_12_e8": rmat(12, 8, seed=7),
        "rmat_13_e8": rmat(13, 8, seed=8),
        "er_16k_d16": erdos_renyi(16384, 16, seed=9),
    }


def perf_profile(times: Dict[str, Dict[str, float]]) -> Dict[str, List]:
    """Dolan-More performance profile: for each algo, sorted ratios to the
    per-instance best (the paper's Figs. 8/9/12/13/16)."""
    algos = sorted({a for row in times.values() for a in row})
    prof = {}
    for a in algos:
        ratios = []
        for inst, row in times.items():
            if a not in row:
                continue
            best = min(row.values())
            ratios.append(row[a] / best)
        prof[a] = sorted(ratios)
    return prof
