"""§Roofline: three-term analysis per (arch x shape) from the dry-run.

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 MXU, v5e)
    memory     = HLO_bytes_per_device / 819e9         (HBM)
    collective = collective_bytes_per_device / 50e9   (ICI per link)

Sources: the dry-run emits two lowerings per cell — the scan form (real
compile + memory_analysis) and the REPRO_UNROLL form (exact per-device
flops/bytes/collective counts; XLA's HloCostAnalysis visits while bodies
once, so the rolled numbers undercount by the layer count).  MODEL_FLOPS
(6·N·D forward-backward, or 2·N·D decode) comes from an analytic param
count; the ratio MODEL_FLOPS/HLO_FLOPs measures how much compiled compute
is "useful".
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip (v5e)
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)


def param_count(arch: str) -> Dict[str, float]:
    """Analytic parameter counts (total and active-per-token for MoE)."""
    from repro.configs.base import get_config
    import jax
    from repro.models import transformer as T

    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    total = sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))
    active = total
    if cfg.moe is not None:
        mo = cfg.moe
        n_moe_layers = cfg.n_layers - cfg.first_k_dense
        per_expert = 3 * cfg.d_model * mo.d_ff_expert
        inactive = n_moe_layers * (mo.n_experts - mo.top_k) * per_expert
        active = total - inactive
    return {"total": float(total), "active": float(active)}


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·D for train; 2·N_active per token for decode/prefill fwd."""
    from repro.configs.base import SHAPES
    p = param_count(arch)["active"]
    sh = SHAPES[shape_name]
    if sh.kind == "train":
        tokens = sh.seq_len * sh.global_batch
        return 6.0 * p * tokens
    if sh.kind == "prefill":
        tokens = sh.seq_len * sh.global_batch
        return 2.0 * p * tokens
    return 2.0 * p * sh.global_batch          # decode: one token/sequence


def analyze_cell(base: dict, unrolled: Optional[dict]) -> dict:
    n_dev = base.get("n_devices", 256)
    src = unrolled if (unrolled and unrolled.get("status") == "ok") else base
    acct_kind = (unrolled or {}).get("accounting", "unrolled") \
        if src is not base else "rolled(UNDERCOUNTS scanned layers)"
    flops_dev = src.get("cost_analysis", {}).get("flops", float("nan"))
    bytes_dev = src.get("cost_analysis", {}).get("bytes accessed",
                                                 float("nan"))
    coll = src.get("collective_bytes_per_device", {})
    coll_dev = float(sum(v for v in coll.values()
                         if isinstance(v, (int, float))))
    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=lambda k: (terms[k]
                                    if terms[k] == terms[k] else -1))
    mf = model_flops(base["arch"], base["shape"])
    mf_dev = mf / n_dev
    bound = max(t_comp, t_mem, t_coll)
    return {
        "arch": base["arch"], "shape": base["shape"], "mesh": base["mesh"],
        "status": base["status"],
        "accounting": acct_kind,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dom,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "model_flops_per_device": mf_dev,
        "model_over_hlo_flops": (mf_dev / flops_dev
                                 if flops_dev else float("nan")),
        "roofline_fraction": ((mf_dev / PEAK_FLOPS) / bound
                              if bound and bound == bound else float("nan")),
        "memory_temp_gb": (base.get("memory_analysis", {})
                           .get("temp_size_in_bytes") or 0) / 1e9,
        "fits_16g": ((base.get("memory_analysis", {})
                      .get("temp_size_in_bytes") or 0)
                     + (base.get("memory_analysis", {})
                        .get("argument_size_in_bytes") or 0)) < 16e9,
    }


def load(outdir: str, arch: str, shape: str, mesh: str, tag: str = ""):
    suffix = f".{tag}" if tag else ""
    path = os.path.join(outdir, f"{arch}.{shape}.{mesh}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run(outdir: str = "results/dryrun", mesh: str = "pod",
        save_to: str = "results/bench/roofline.json"):
    from repro.configs.base import ARCH_IDS, SHAPES
    rows = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            base = load(outdir, arch, shape, mesh)
            if base is None:
                continue
            if base.get("status") == "skipped":
                rows.append({"arch": arch, "shape": shape,
                             "mesh": base["mesh"], "status": "skipped",
                             "reason": base.get("reason", "")})
                continue
            acct = load(outdir, arch, shape, mesh, tag="acct") or \
                load(outdir, arch, shape, mesh, tag="unroll")
            rows.append(analyze_cell(base, acct))
    os.makedirs(os.path.dirname(save_to), exist_ok=True)
    with open(save_to, "w") as f:
        json.dump(rows, f, indent=2)
    hdr = (f"{'arch':24s} {'shape':12s} {'comp(s)':>9s} {'mem(s)':>9s} "
           f"{'coll(s)':>9s} {'dominant':>12s} {'MF/HLO':>7s} {'RLfrac':>7s}")
    print(hdr)
    for r in rows:
        if r.get("status") == "skipped":
            print(f"{r['arch']:24s} {r['shape']:12s} -- skipped: "
                  f"{r.get('reason', '')[:40]}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['compute_s']:9.4f} {r['memory_s']:9.4f} "
              f"{r['collective_s']:9.4f} {r['dominant']:>12s} "
              f"{r['model_over_hlo_flops']:7.3f} "
              f"{r['roofline_fraction']:7.3f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    run(args.outdir, args.mesh)
