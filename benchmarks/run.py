"""Benchmark aggregator: one module per paper figure + the tile-path bench.

  PYTHONPATH=src python -m benchmarks.run            # small CPU sizes
  PYTHONPATH=src python -m benchmarks.run --full     # larger suite
  PYTHONPATH=src python -m benchmarks.run --only density,triangle

Roofline (needs results/dryrun from repro.launch.dryrun):
  PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
import traceback

ORDER = ("density", "planner", "tile", "dist", "serve", "incremental",
         "replay", "obs", "health", "triangle", "rmat", "scaling",
         "ktruss", "bc", "block")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + 1 iteration (CI smoke job); writes "
                         "to a scratch dir so the committed full-tier "
                         "grids under results/bench/ survive")
    ap.add_argument("--only", default="",
                    help=f"comma-separated subset of: {', '.join(ORDER)}")
    ap.add_argument("--strict", action="store_true",
                    help="fail when a bench reports a False acceptance "
                         "flag (its _-prefixed booleans, e.g. _all_ok)")
    args = ap.parse_args()
    if args.smoke and not os.environ.get("REPRO_BENCH_OUT"):
        # smoke tiers must never clobber the committed full-tier grids
        # (dist_grid.json/tile_grid.json are calibration artifacts); the
        # env var also reaches bench_dist's forced-device child process
        os.environ["REPRO_BENCH_OUT"] = tempfile.mkdtemp(
            prefix="repro-bench-smoke-")
        print(f"[smoke] writing results to "
              f"{os.environ['REPRO_BENCH_OUT']} (committed results/bench/ "
              f"untouched)", flush=True)
    if args.only:
        only = {name.strip() for name in args.only.split(",")
                if name.strip()}
        unknown = sorted(only - set(ORDER))
        if unknown or not only:
            raise SystemExit(
                f"benchmarks.run: unknown --only job names {unknown}; "
                f"valid names: {', '.join(ORDER)}")
    else:
        only = set(ORDER)

    from . import (bench_bc, bench_block_kernel, bench_density, bench_dist,
                   bench_health, bench_incremental, bench_ktruss,
                   bench_obs, bench_planner, bench_replay,
                   bench_rmat_scale, bench_scaling, bench_serve,
                   bench_tile, bench_triangle)
    if args.smoke:
        density_kw = dict(n=256, degrees=(2, 8), mask_degrees=(2, 8),
                          iters=3)
        tile_kw = dict(n=128, block_sizes=(8, 16), tile_densities=(0.3,),
                       mask_occupancies=(0.5,), iters=1)
        dist_kw = dict(n=256, mesh_sizes=(2, 4), densities_b=(0.02, 0.3),
                       iters=1)
        serve_kw = dict(n=128, queries=16, n_structs=2, iters=2)
        # trims rounds/queries but NOT n: the >=5x readiness win is
        # scale-dependent (the cold rebuild it beats is O(mask nnz)), so
        # shrinking the structure would fail --strict for the wrong reason
        incremental_kw = dict(rounds=3, queries_per_round=2)
        # the golden trace is tiny; smoke trims timing iters + the knob grid
        replay_kw = dict(iters=1, smoke=True)
        # iters stays high even in smoke: the gate is a ratio of two
        # noisy ~ms passes; the median needs samples to converge
        obs_kw = dict(n=128, queries=16, iters=21, smoke=True)
        # n stays at 256 in smoke: the monitor's per-record aggregation
        # cost is fixed (~2us), so the pass must be long enough that 5%
        # of it clears the measurement noise floor (at n=128 the bar
        # equals the jitter and the gate coin-flips)
        health_kw = dict(n=256, queries=24, iters=21, smoke=True)
    else:
        density_kw = dict(n=2048 if args.full else 1024)
        tile_kw = dict(n=512)
        # full tier matches the committed dist_grid.json calibration run;
        # the default tier trims the grid like its neighbors do
        dist_kw = dict() if args.full else dict(n=1024, mesh_sizes=(2, 4),
                                                densities_b=(0.02, 0.3))
        serve_kw = dict(n=1024 if args.full else 512,
                        queries=96 if args.full else 48)
        incremental_kw = dict(n=2048 if args.full else 1024,
                              rounds=12 if args.full else 8)
        replay_kw = dict(iters=3, autotune_rounds=2 if args.full else 1)
        # heavier per-query work than serve_kw (the ~µs-per-span budget
        # amortizes to ~1% of an n=1024 pass) and many paired iterations:
        # the gate is a ratio of two noisy ~40ms passes, so the median
        # needs samples to converge under scheduler jitter (~5s total)
        obs_kw = dict(n=1024, queries=128 if args.full else 96, iters=61)
        # same scale story as obs_kw: the monitored-vs-plain ratio needs
        # ~60ms passes and many pairs to resolve a ~1% true cost
        health_kw = dict(n=1024, queries=96 if args.full else 48,
                         iters=61 if args.full else 41)
    jobs = {
        "density": lambda: bench_density.run(**density_kw),
        "planner": lambda: bench_planner.run(**density_kw),
        "tile": lambda: bench_tile.run(**tile_kw),
        "dist": lambda: bench_dist.run(**dist_kw),
        "serve": lambda: bench_serve.run(**serve_kw),
        "incremental": lambda: bench_incremental.run(**incremental_kw),
        "replay": lambda: bench_replay.run(**replay_kw),
        "obs": lambda: bench_obs.run(**obs_kw),
        "health": lambda: bench_health.run(**health_kw),
        "triangle": lambda: bench_triangle.run(small=not args.full),
        "rmat": lambda: bench_rmat_scale.run(
            scales=(8, 9, 10, 11, 12) if args.full else (8, 9, 10)),
        "scaling": lambda: bench_scaling.run(),
        "ktruss": lambda: bench_ktruss.run(small=not args.full),
        "bc": lambda: bench_bc.run(batch=64 if args.full else 16),
        "block": lambda: bench_block_kernel.run(),
    }
    failures = []
    for name in ORDER:
        if name not in only:
            continue
        print(f"\n===== bench: {name} =====", flush=True)
        t0 = time.time()
        try:
            table = jobs[name]()
            bad_flags = [k for k, v in table.items()
                         if k.startswith("_") and v is False] \
                if isinstance(table, dict) else []
            if args.strict and bad_flags:
                failures.append(f"{name}:{','.join(bad_flags)}")
                print(f"===== {name} FAILED acceptance flags "
                      f"{bad_flags} =====", flush=True)
            else:
                print(f"===== {name} done in {time.time() - t0:.1f}s =====",
                      flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")
    print("\nall benchmarks completed; results in results/bench/")


if __name__ == "__main__":
    main()
