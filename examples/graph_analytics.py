"""Graph analytics with Masked SpGEMM: TC, k-truss, betweenness centrality
(the paper's three benchmarks end-to-end, on an R-MAT graph).

    PYTHONPATH=src python examples/graph_analytics.py
"""
import numpy as np

from repro.core.formats import rmat
from repro.graphs import betweenness_centrality, ktruss, triangle_count


def main():
    g = rmat(9, 8, seed=7)
    print(f"R-MAT scale 9: n={g.shape[0]}, edges={g.nnz // 2}")

    tri, secs = triangle_count(g, algorithm="msa")
    print(f"triangles: {tri}  (masked-spgemm {secs * 1e3:.0f} ms)")

    truss, secs, iters, flops = ktruss(g, k=5, algorithm="msa")
    print(f"5-truss: {truss.nnz // 2} edges after {iters} iterations "
          f"({flops / max(secs, 1e-9) / 1e9:.2f} GFLOPS)")

    srcs = np.random.default_rng(0).choice(g.shape[0], 16, replace=False)
    bc, secs, calls = betweenness_centrality(g, sources=srcs,
                                             algorithm="msa")
    top = np.argsort(-bc)[:5]
    print(f"betweenness (batch=16, {calls} masked-spgemm calls, "
          f"{secs * 1e3:.0f} ms): top vertices {top.tolist()}")


if __name__ == "__main__":
    main()
