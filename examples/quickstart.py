"""Quickstart: Masked SpGEMM in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Covers: the adaptive planner (``algorithm="auto"``, the default), the six
fixed algorithms, semirings, complemented masks, the block/tile path,
backend calibration profiles, and triangle counting.
"""
import numpy as np

from repro.core.formats import (bcsr_from_csr, csr_from_dense,
                                erdos_renyi, tril)
from repro.core.masked_spgemm import masked_spgemm, dense_oracle
from repro.core.planner import plan, plan_cache_info
from repro.core.semiring import MIN_PLUS, PLUS_TIMES
from repro.graphs import triangle_count
from repro.kernels.masked_matmul.ops import block_spgemm


def main():
    rng = np.random.default_rng(0)
    m, k, n = 64, 48, 56
    A = ((rng.random((m, k)) < 0.2) * rng.uniform(1, 2, (m, k))
         ).astype(np.float32)
    B = ((rng.random((k, n)) < 0.2) * rng.uniform(1, 2, (k, n))
         ).astype(np.float32)
    M = (rng.random((m, n)) < 0.3).astype(np.float32)

    # --- 0. the default entry point: let the planner pick -----------------
    # ``algorithm="auto"`` inspects cheap structural statistics (densities,
    # padded widths, a sampled symbolic probe) and dispatches to the
    # cheapest kernel per the paper's Sec. 7-8 guidelines.  Plans are
    # cached by structural signature, so repeated shapes skip re-planning.
    out = masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                        csr_from_dense(M))            # algorithm="auto"
    p = plan(csr_from_dense(A), csr_from_dense(B), csr_from_dense(M))
    print(f"auto     nnz(C) = {int(out.nnz)}  "
          f"(planner chose {p.algorithm!r}; "
          f"tile_eligible={p.tile_eligible}; cache={plan_cache_info()})")

    # --- 1. C = M .* (A @ B) with every fixed algorithm -------------------
    for algo in ("msa", "hash", "mca", "heap", "heapdot", "inner"):
        out = masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                            csr_from_dense(M), algorithm=algo)
        print(f"{algo:8s} nnz(C) = {int(out.nnz)}")

    # --- 2. semirings: min-plus shortest-path style product ---------------
    out = masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                        csr_from_dense(M), algorithm="msa",
                        semiring=MIN_PLUS)
    print("min_plus nnz(C) =", int(out.nnz))

    # --- 3. complemented mask (BC-style traversal) -------------------------
    vals, present = masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                                  csr_from_dense(M), algorithm="msa",
                                  complement=True)
    print("complement nnz =", int(np.asarray(present).sum()))

    # --- 4. TPU-native tile route (BCSR, densify-free) --------------------
    # ``algorithm="tile"`` runs the whole product on the block executors
    # (Pallas on TPU, compiled XLA elsewhere): CSR operands scatter straight
    # into occupied blocks, the vectorized host schedule is the paper's
    # symbolic phase made free by the mask bound, and the result comes back
    # in the same mask-aligned layout as the row kernels.  With
    # ``algorithm="auto"`` the planner elects this route itself whenever its
    # modeled cost beats every row kernel (dense-block operands).
    out = masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                        csr_from_dense(M), algorithm="tile", tile_block=8)
    print("tile     nnz(C) =", int(out.nnz))

    # the lower-level BCSR entry point, for operands already in block form
    Ab = bcsr_from_csr(csr_from_dense(A[:, :48]), 8)
    Bb = bcsr_from_csr(csr_from_dense(B[:48, :48]), 8)
    Mb = bcsr_from_csr(
        csr_from_dense((rng.random((64, 48)) < 0.3).astype(np.float32)), 8)
    C = block_spgemm(Ab, Bb, Mb)
    print("block_spgemm tiles =", C.nnzb)

    # --- 5. distributed: the same product across a mesh --------------------
    # ``distributed_masked_spgemm`` is the mesh counterpart of
    # ``masked_spgemm``: ``algorithm="auto"`` weighs replicating B
    # (row-parallel, zero numeric-phase communication) against rotating
    # B's occupied BCSR K-slabs around a ring (sparse ring-SUMMA — no
    # dense (k, n)/(m, n) array anywhere, memory O(nnzb/p) per device).
    # Runs on any mesh; here the 1-device degenerate ring.  Multi-device
    # CPU runs force fake host devices BEFORE importing jax, e.g.
    #   XLA_FLAGS=--xla_force_host_platform_device_count=8
    # (see tests/dist_sparse_check.py for the 8-way harness).
    import jax
    from jax.sharding import Mesh
    from repro.core.distributed import distributed_masked_spgemm
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    out = distributed_masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                                    csr_from_dense(M), mesh)
    print("distributed nnz(C) =", int(out.nnz))
    forced = distributed_masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                                       csr_from_dense(M), mesh,
                                       algorithm="ring", block_size=8)
    print("sparse ring nnz(C) =", int(forced.nnz))

    # --- 6. calibrating the planner for YOUR backend ------------------------
    # Every decision above was priced by cost tables fit on the reference
    # CPU container.  On other hardware, don't hand-tune them — fit them:
    #
    #   PYTHONPATH=src python -m repro.tune            # full probe grids
    #   PYTHONPATH=src python -m repro.tune --smoke    # minute-scale fit
    #   PYTHONPATH=src python -m repro.tune --only row,tile,dist
    #
    # That times the row kernels / tile route / distributed routes on small
    # synthetic grids, solves the planner's cost models for their constants
    # (reporting fit residuals), and registers the profile under
    # results/profiles/ keyed by backend signature.  Install one with
    # ``repro.tuning.activate(profile)`` in-process, or export
    # ``REPRO_TUNE_PROFILE=/path/to/profile.json`` for whole process trees
    # (benchmarks, CI).  Activation can never serve stale decisions: plan
    # caches are keyed by the active profile's version token.
    from repro.tuning import active_version, lookup
    prof, exact = lookup()     # this backend's profile (default fallback)
    print(f"calibration: active={active_version()!r} "
          f"registry={prof.name!r} (exact={exact}, "
          f"version={prof.version})")

    # --- 7. a real application: triangle counting --------------------------
    g = erdos_renyi(512, 8, seed=1)
    tri, secs = triangle_count(g, algorithm="msa")
    print(f"triangles = {tri} ({secs * 1e3:.0f} ms masked-SpGEMM time)")

    # --- 8. serving a query stream -----------------------------------------
    # ``QueryEngine`` amortizes structure-dependent decisions across
    # queries: requests are bucketed by structural signature, each bucket
    # is served by ONE cached plan + one compiled program, and a bounded
    # content-keyed result cache catches exact repeats.  Same-structure
    # bursts on scatter plans (msa/hash/mca) run the structure-compiled
    # replay: 8-18x one-shot throughput, bitwise-identical results
    # (results/bench/serve_grid.json; python -m benchmarks.run --only
    # serve).
    from repro.serving import QueryEngine
    from repro.core.formats import CSR
    A_c, B_c, M_c = (csr_from_dense(A), csr_from_dense(B),
                     csr_from_dense(M))

    def fresh_values(x, seed):
        r = np.random.default_rng(seed)
        return CSR(x.indptr, x.indices,
                   r.uniform(1, 2, x.nnz).astype(np.float32), x.shape)

    with QueryEngine(max_batch=32) as engine:     # sync mode
        tickets = [engine.submit(fresh_values(A_c, s), B_c, M_c)
                   for s in range(8)]             # one bucket, one plan
        tri_ticket = engine.submit_triangle(g)    # composites batch too
        engine.flush()
        print("served nnz(C) =", int(tickets[0].result().nnz),
              "| triangles =", tri_ticket.result())
        replay = engine.submit(fresh_values(A_c, 0), B_c, M_c)
        print("result-cache hit:", replay.done(),   # byte-equal operands
              "| stats:", engine.metrics.snapshot()["result_cache_hits"],
              "hits |", engine.results.info())

    # async mode: submit returns future-like tickets immediately; a worker
    # thread flushes full buckets at once and partial buckets after
    # max_wait_ms.  Backpressure: at most queue_cap requests pending.
    with QueryEngine(async_mode=True, max_batch=16,
                     max_wait_ms=2.0) as engine:
        t = engine.submit(A_c, B_c, M_c)
        print("async nnz(C) =", int(t.result(timeout=30).nnz))

    # every cache in the process is bounded and visible:
    from repro import caches
    sizes = {k: v["size"] for k, v in caches.cache_info().items()}
    print("caches:", sizes)                       # caches.clear_all() empties

    # --- 9. record -> replay -> autotune the serving knobs -----------------
    # Capture real traffic with a recorder on the engine, replay it
    # deterministically under a virtual clock (bit-identical bucket
    # schedule + byte-exact results, sync or async), then search the knob
    # grid against the replayed stream and pin the winner:
    #
    #     python -m repro.autotune                # golden trace, full grid
    #     python -m repro.autotune --smoke        # CI-sized search
    #
    from repro.serving import TraceRecorder, Trace, replay_trace
    from repro.serving.trace import spec_inline
    rec = TraceRecorder(name="quickstart")
    with QueryEngine(recorder=rec, cache_results=False) as engine:
        # register_operand(obj, spec) records a generator spec instead of
        # inlining bytes; unregistered operands embed base64 CSR payloads
        rec.register_operand(A_c, spec_inline(A_c))
        for s in range(4):
            engine.submit(fresh_values(A_c, s), B_c, M_c)
        engine.flush()
    trace = Trace.loads(rec.trace().dumps())      # JSONL round-trip
    r1 = replay_trace(trace)
    r2 = replay_trace(trace, async_mode=True)
    print("replay digests (sync == async):", r1.digest, r2.digest,
          "| qps:", round(r1.qps, 1))
    # the autotuner ranks knob configs by replayed throughput/latency and
    # writes results/profiles/serving_<backend>.json with the same
    # cost_model_token staleness guard the plan caches use; serve with:
    #     from repro.tuning.autotune import load_serving_knobs
    #     engine = QueryEngine(**load_serving_knobs())
    # and CI replays the committed golden trace as a perf-regression gate
    # (python -m benchmarks.run --smoke --strict --only replay).

    # --- 10. the invariant linter: machine-checked correctness rules -------
    #
    # The hard-won rules from the PRs above are enforced statically by
    # `repro.analysis` (AST-based, never imports your code):
    #
    #     PYTHONPATH=src python -m repro.lint                 # text report
    #     PYTHONPATH=src python -m repro.lint --format=json   # CI gate
    #     PYTHONPATH=src python -m repro.lint --list-rules
    #     PYTHONPATH=src python -m repro.lint --only lock-discipline
    #
    # Six rules: no-densify (no to_dense on core/kernels/serving hot
    # paths), clock-discipline (serving scheduling reads the injectable
    # clock — replay determinism), cache-registry (every module cache
    # registered in repro.caches — bounded memory), plan-cache-key
    # (structure-derived keys carry cost_model_token() — stale-plan
    # guard), lock-discipline (a lock-set race detector over the serving
    # worker/submit paths), and jit-retrace (no mutable captures or
    # per-call container literals at jax.jit boundaries).
    #
    # Intentional exceptions are in-code annotations with a mandatory
    # reason — one escape name per rule, e.g.:
    #
    #     t0 = time.perf_counter()  # lint: clock-ok(duration measurement)
    #     hit = cache.get(key)      # lint: plan-key-ok(structure-pure)
    #     self._hits += 1           # lint: unlocked-ok(approximate stat)
    #
    # Findings can also be suppressed via the committed lint-baseline.json
    # (fingerprints are anchored to line CONTENT, so editing a baselined
    # line revives the finding) — but policy keeps serving/ and core/ at
    # zero baseline entries, enforced by tests/test_lint.py.
    import os

    import repro.analysis
    from repro.analysis import run_lint
    pkg_root = os.path.dirname(os.path.dirname(repro.analysis.__file__))
    findings = run_lint(pkg_root)
    print("invariant linter findings on src/repro:", len(findings))

    # --- 11. incremental serving: edge deltas without a cold restart -------
    #
    # Production graphs mutate under traffic.  `QueryEngine.submit_delta`
    # folds a batch of edge upserts/deletes into the served operands and
    # keeps every structure-derived artifact warm instead of rebuilding:
    # the operand's incremental signature updates in O(changed rows), the
    # plan REVALIDATES (kept while nnz/width drift stays inside the
    # planner's hysteresis band), the compiled burst program's gather
    # lanes are patched only in the changed rows' slot columns (bitwise-
    # equal to a cold rebuild, by construction), and result-cache entries
    # are dropped only for the delta'd structure x affected row range —
    # entries for other structures, or rows the delta provably cannot
    # reach, stay cached.
    from repro.core.formats import CSRDelta
    d_engine = QueryEngine(max_batch=8)
    A_d, B_d, M_d = A_c, B_c, M_c                 # the section-9 operands
    d_engine.submit(A_d, B_d, M_d)
    d_engine.flush()                              # warm plan + program
    delta = CSRDelta.upserts([0, 3], [5, 7], [1.5, 0.25])
    out = d_engine.submit_delta(A_d, B_d, M_d, delta_a=delta)
    A_d = out.A                                   # post-delta operand
    snap = d_engine.metrics.snapshot()
    print("delta:", {k: snap[k] for k in
                     ("delta_applied", "plans_revalidated",
                      "lanes_patched", "rows_invalidated")},
          "| plan survived:", out.plan_survived)
    # A delta goes COLD (ordinary re-plan/rebuild on next use — still
    # correct, just not incremental) when it leaves the local regime:
    # nnz or row-width drift beyond the hysteresis band, a mask pad-width
    # or lane-count change that needs a different compiled shape, or a
    # structural change to B (its values regather; its pattern is pinned).
    # `benchmarks/bench_incremental.py` measures the payoff — readiness
    # after a small delta beats recompute-from-scratch by >= 5x
    # (`results/bench/incremental_grid.json`, `_incremental_wins`).
    #
    # For long-running serving, `RotatingTraceSink` streams the capture
    # of section 9 to size-capped JSONL segments (logrotate-style, with
    # an optional seeded sample_rate) — each segment replays standalone:
    #     sink = RotatingTraceSink("trace.jsonl", max_bytes=1 << 20)
    #     rec = TraceRecorder(engine, sink=sink, keep_events=False)

    # --- 12. observability: spans, plan explain, /metrics ------------------
    #
    # `repro.obs` threads structured tracing through the whole request
    # lifecycle (submit -> queue wait -> plan -> host prep -> device exec
    # -> cache put/hit, plus the delta path).  Off by default: every
    # instrumented site costs one global read + one branch until you
    # enable it — bench_obs.py pins traced serving within 5% of untraced
    # with bitwise-equal results and an EQUAL deterministic_snapshot()
    # (spans never feed scheduling).
    from repro import obs
    with obs.tracing() as trc:                     # scoped enable
        with QueryEngine(max_batch=8) as engine:
            for s in range(4):
                engine.submit(fresh_values(A_c, s), B_c, M_c)
            engine.flush()
    spans = trc.sink.spans()
    print("observed span kinds:", sorted({r["name"] for r in spans}))

    # every `serve.plan` span carries `planner.explain(plan)` — the
    # elected algorithm, the cost-feature vector, and each candidate's
    # modeled cost, so modeled-vs-measured residuals fall out of a trace:
    from repro.core.planner import explain
    info = explain(plan(A_c, B_c, M_c))
    print("plan explain: elected", info["elected"], "| modeled ms:",
          {k: round(v, 4) for k, v in info["costs_ms"].items()})
    print("exec residuals:", obs.export.residual_summary(spans))

    # export the capture for chrome://tracing / https://ui.perfetto.dev
    # (obs.save_chrome_trace(path, spans) writes the same JSON to disk),
    # or stream spans to rotating JSONL with obs.JsonlSpanSink(path):
    print("perfetto events:", len(obs.chrome_trace(spans)["traceEvents"]))

    # live exposition: any engine serves Prometheus text + health JSON
    # from a daemon thread (also standalone: python -m repro.obs.serve)
    import urllib.request
    with QueryEngine(expose_port=0) as engine:     # 0 = ephemeral port
        engine.serve([(A_c, B_c, M_c)])
        with urllib.request.urlopen(
                engine.obs_server.url + "/metrics", timeout=10) as resp:
            families = obs.parse_prometheus(resp.read().decode())
    print("scraped", len(families), "prometheus samples")

    # --- 13. health intelligence: SLO burn rates + cost-model drift --------
    #
    # `repro.obs.health` turns that span stream into an online verdict.
    # HealthMonitor is itself a sink: ring-sharded sliding windows (O(1)
    # memory on the injectable clock), declarative SLOs evaluated as
    # SRE-style multi-window burn rates ("failing" needs the error
    # budget burning >= 2x on BOTH the 5s and 60s windows, so a single
    # blip never pages), and a drift detector streaming each exec
    # span's modeled-vs-measured residual per (tune family, algorithm,
    # regime).
    from repro.obs.health import HealthMonitor
    monitor = HealthMonitor()            # DEFAULT_SLOS + drift detector
    with obs.tracing(monitor):
        with QueryEngine(monitor=monitor) as engine:
            for s in range(4):
                engine.submit(fresh_values(A_c, s), B_c, M_c)
            engine.flush()
            print("healthy verdict:", engine.health().status)

            # induced pressure: hash + complement is NotImplemented, so
            # this storm burns the serve-errors budget on both windows;
            # with expose_port= the /health endpoint now answers 503
            # carrying exactly these reasons
            storm = [engine.submit(A_c, B_c, M_c, algorithm="hash",
                                   complement=True) for _ in range(8)]
            engine.flush()
            for t in storm:
                try:
                    t.result()
                except NotImplementedError:
                    pass
            verdict = engine.health()
    print("under pressure:", verdict.status, "-", verdict.reasons[0])

    # a drift flag names the exact refit (`python -m repro.tune --only
    # <family>`) and resets itself when the cost table is retuned; the
    # cross-PR perf trajectory over results/bench/*_grid.json renders
    # via `python -m repro.obs.report` (--check gates flag regressions)
    print("drift:",
          monitor.drift.report().command or "cost model calibrated")


if __name__ == "__main__":
    main()
