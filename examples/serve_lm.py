"""Serving example: batched prefill + decode with KV/state caches.

    PYTHONPATH=src python examples/serve_lm.py

Loads a smoke-size model per family (GQA cache, MLA low-rank cache, SSM
state) and generates continuations for a batch of prompts — including the
induction-copy check: after training-free priming with a repeated motif,
even a random model produces *valid* cache behavior (shape/latency demo;
see examples/train_lm.py for a trained model).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import transformer as T
from repro.serve.decode import generate


def main():
    for arch in ("llama3_2_1b", "deepseek_v2_lite_16b", "zamba2_7b"):
        cfg = get_config(arch, smoke=True)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 12)),
                              jnp.int32)
        t0 = time.time()
        out = generate(params, cfg, prompts, max_new=8)
        dt = time.time() - t0
        assert out.shape == (4, 20)
        kind = ("MLA low-rank cache" if cfg.mla else
                "SSM state" if cfg.family in ("ssm", "hybrid")
                else "GQA KV cache")
        print(f"{arch:24s} [{kind:18s}] generated {out.shape} in {dt:.1f}s")
        print("   sample:", np.asarray(out[0, -10:]).tolist())


if __name__ == "__main__":
    main()
