"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # smoke (~1 min)

The model is the llama3.2 family scaled to ~100M params, trained on the
deterministic synthetic stream (Zipf + induction-copy segments).  Loss
must fall well below the unigram entropy as the model learns to copy —
that drop is asserted at the end.  Checkpoints publish atomically; rerun
the same command after killing it and it resumes from LATEST.
"""
import argparse
import os

import numpy as np

from repro.configs.base import get_config
from repro.launch.train import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.tiny:
        steps = args.steps or 30
        losses, _ = run("llama3_2_1b", smoke=True, steps=steps, batch=4,
                        seq=64, ckpt_dir=args.ckpt_dir, ckpt_every=10,
                        lr=3e-3, log_every=5)
    else:
        # ~100M: 12 layers x d512 x ff2048, 32k vocab (llama3.2 family)
        import repro.configs.llama3_2_1b as base
        cfg100m = base.CONFIG.replace(
            name="llama-100m", n_layers=12, d_model=512, n_heads=8,
            n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
            tie_embeddings=False, dtype="float32", remat="none",
            attn_block=64)
        import repro.configs.base as cb
        # register on the fly so the launcher can find it
        import sys
        import types
        mod = types.ModuleType("repro.configs.llama_100m")
        mod.CONFIG = cfg100m
        mod.SMOKE = cfg100m
        sys.modules["repro.configs.llama_100m"] = mod
        steps = args.steps or 200
        losses, _ = run("llama_100m", smoke=False, steps=steps, batch=4,
                        seq=128, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                        lr=1e-3, log_every=10,
                        max_seconds=float(os.environ.get(
                            "TRAIN_LM_MAX_SECONDS", 0)) or 0.0)

    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    print(f"\nloss: {first:.3f} -> {last:.3f}")
    assert last < first - 0.5, "model failed to learn"
    print("OK: model learned the synthetic stream")


if __name__ == "__main__":
    main()
