"""``repro.analysis`` — the invariant linter.

This repo's correctness rules were each discovered by a production-style
bug (see the rule docstrings); this package enforces them mechanically so
a regression is a lint failure, not a bitwise-divergent serving stream:

* ``no-densify``       — no dense materialization on core/kernels/serving
  hot paths (the paper's central discipline).
* ``clock-discipline`` — serving scheduling reads ``engine.clock``, never
  wall-clock (PR 6 replay determinism).
* ``cache-registry``   — every module-level cache registers in
  ``repro.caches`` (PR 5's bounded-memory contract).
* ``plan-cache-key``   — structure-keyed cache keys carry
  ``cost_model_token()`` (the PR 4 stale-plan class).
* ``lock-discipline``  — serving attributes shared between the worker
  thread and the submit/flush path hold a common lock (the PR 5 plan race
  and PR 6 half-taken-work classes).
* ``jit-retrace``      — ``jax.jit`` boundaries neither capture mutable
  module state nor take per-call container literals (the recompile class
  the serving bucket caches exist to prevent).

Intentional escapes are in-code annotations, one per rule — e.g.
``# lint: clock-ok(reason)`` — so every exemption carries its reason at
the site.  Run ``python -m repro.lint`` (see that module for the CLI).
"""
from .engine import LintEngine, run_lint
from .findings import Baseline, Finding
from .rules import RULES, rule_names

__all__ = ["LintEngine", "run_lint", "Finding", "Baseline", "RULES",
           "rule_names"]
