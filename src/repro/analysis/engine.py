"""Rule engine: per-file AST walk + a cross-module symbol table.

The engine parses every ``*.py`` under the scan roots (never imports or
executes them), builds a :class:`SymbolTable` over the whole tree so rules
can see imports, ``repro.caches`` registrations, jit wrappers, and
module-level state across modules, then runs each rule per module.

Intentional escapes are in-code annotations::

    time.perf_counter()   # lint: clock-ok(measurement, not scheduling)

One escape name per rule (``Rule.escape``); the reason inside the parens
is mandatory — an empty reason does not suppress.  An escape suppresses
findings on its own line, on the following statement when it sits alone
on the line above, and anywhere inside a multi-line statement it ends.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .findings import Finding, assign_occurrences

_ESCAPE_RE = re.compile(r"#\s*lint:\s*([A-Za-z0-9_-]+)\s*\(([^)]*)\)")

#: spellings of the cache-registry entry points (``repro.caches``)
REGISTER_FUNCS = {"register", "register_lru"}
REGISTER_MODULES = {"repro.caches", "caches"}

#: decorators that make a function a process-lifetime memo
LRU_DECORATORS = {"functools.lru_cache", "lru_cache", "functools.cache",
                  "cache"}

#: constructors of mutable module-level containers
MUTABLE_CTORS = {"dict", "list", "set", "OrderedDict", "defaultdict",
                 "deque", "collections.OrderedDict",
                 "collections.defaultdict", "collections.deque"}

#: spellings of the jit entry points
JIT_FUNCS = {"jax.jit", "jit", "pjit", "jax.pjit"}

#: functions whose return value keys caches by structure (taint sources
#: for the plan-cache-key rule); the table extends this with discovered
#: key-builder functions
STRUCTURE_TAINT_FUNCS = {"structure_signature", "content_fingerprint",
                         "incremental_signature"}

_CACHE_NAME_RE = re.compile(r"cache|memo|program", re.IGNORECASE)


def call_name(node: ast.AST) -> Optional[str]:
    """Dotted spelling of a call target / decorator / attribute chain
    (``jax.jit``, ``caches.register_lru``); None for anything dynamic."""
    if isinstance(node, ast.Call):
        return call_name(node.func)
    if isinstance(node, ast.Attribute):
        base = call_name(node.value)
        return None if base is None else f"{base}.{node.attr}"
    if isinstance(node, ast.Name):
        return node.id
    return None


def last_segment(name: Optional[str]) -> Optional[str]:
    return None if name is None else name.rsplit(".", 1)[-1]


def walk_names(node: ast.AST) -> Set[str]:
    """Every identifier referenced in a subtree (lambda bodies included)."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


@dataclasses.dataclass
class ModuleInfo:
    """One parsed source file plus everything rules need from it."""

    path: Path
    relpath: str                      # posix, relative to the scan root
    module: str                       # dotted name ("serving.engine")
    tree: ast.Module
    lines: List[str]
    escapes: Dict[int, Set[str]]      # line -> escape names with reasons
    imports: Dict[str, str]           # local alias -> fully qualified name

    @property
    def parts(self) -> Tuple[str, ...]:
        return tuple(Path(self.relpath).parts)

    @property
    def basename(self) -> str:
        return Path(self.relpath).name

    def in_dir(self, component: str) -> bool:
        """True when ``component`` is a directory on this file's path."""
        return component in self.parts[:-1]

    def qualify(self, name: str) -> str:
        """Best-effort fully qualified name for a module-scope identifier."""
        if name in self.imports:
            return self.imports[name]
        return f"{self.module}.{name}" if self.module else name

    def qualify_dotted(self, dotted: Optional[str]) -> Optional[str]:
        """Qualify a dotted spelling through this module's imports
        (``planner.cost_model_token`` -> ``repro.core.planner.cost_model_token``)."""
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        base = self.imports.get(head)
        if base is None:
            return dotted
        return f"{base}.{rest}" if rest else base

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def escaped(self, escape: str, lineno: int,
                end_lineno: Optional[int] = None) -> bool:
        """Is an escape annotation in force over [lineno, end_lineno]?"""
        lo = max(1, lineno - 1)
        hi = end_lineno if end_lineno is not None else lineno
        return any(escape in self.escapes.get(ln, ())
                   for ln in range(lo, hi + 1))


def _module_name(relpath: str) -> str:
    parts = list(Path(relpath).with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _parse_escapes(lines: Sequence[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(lines, 1):
        if "#" not in line:
            continue
        names = {m.group(1) for m in _ESCAPE_RE.finditer(line)
                 if m.group(2).strip()}   # empty reason does not suppress
        if names:
            out[i] = names
    return out


def _collect_imports(tree: ast.Module, module: str) -> Dict[str, str]:
    pkg_parts = module.split(".")[:-1] if module else []
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
                if a.asname is None and "." in a.name:
                    # "import a.b.c" binds "a" but rules often compare the
                    # full dotted spelling; keep the bare root mapping
                    out[a.name.split(".")[0]] = a.name.split(".")[0]
                elif a.asname:
                    out[a.asname] = a.name
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base_parts = pkg_parts[:len(pkg_parts) - (node.level - 1)] \
                    if node.level > 1 else list(pkg_parts)
                prefix = ".".join(base_parts + ([node.module]
                                                if node.module else []))
            else:
                prefix = node.module or ""
            for a in node.names:
                if a.name == "*":
                    continue
                full = f"{prefix}.{a.name}" if prefix else a.name
                out[a.asname or a.name] = full
    return out


def parse_module(path: Path, root: Path) -> Optional[ModuleInfo]:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (SyntaxError, UnicodeDecodeError, OSError):
        return None
    rel = path.relative_to(root).as_posix()
    module = _module_name(rel)
    lines = source.splitlines()
    return ModuleInfo(path=path, relpath=rel, module=module, tree=tree,
                      lines=lines, escapes=_parse_escapes(lines),
                      imports=_collect_imports(tree, module))


# ---------------------------------------------------------------------------
# cross-module symbol table
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CacheDef:
    """A module-level cache discovered in one module."""

    module: str
    name: str
    kind: str        # "lru" | "dict" | "lrucache"
    lineno: int
    col: int
    end_lineno: int


class SymbolTable:
    """What every rule may need to see across module boundaries."""

    def __init__(self):
        #: identifiers referenced inside ``caches.register*`` calls,
        #: both bare ("_sched") and qualified ("kernels.flash_mask.ops._sched")
        self.registered: Set[str] = set()
        #: module-level caches, per module name
        self.caches: Dict[str, List[CacheDef]] = {}
        #: jit-wrapped functions (bare + qualified names)
        self.jitted: Set[str] = set()
        #: module-level mutable containers (qualified), per module
        self.mutable_state: Dict[str, Set[str]] = {}
        #: functions returning structure-derived cache keys (bare + qualified)
        self.taint_fns: Set[str] = set(STRUCTURE_TAINT_FUNCS)
        #: module-level LRUCache/registered-dict variables (qualified) —
        #: receivers the plan-cache-key rule treats as caches
        self.cache_vars: Set[str] = set()

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, modules: Sequence[ModuleInfo]) -> "SymbolTable":
        table = cls()
        for mod in modules:
            table._scan_module(mod)
        # one propagation round: functions returning calls to key builders
        # discovered above are key builders too
        for mod in modules:
            table._scan_key_builders(mod)
        return table

    def _is_register_call(self, mod: ModuleInfo, node: ast.Call) -> bool:
        name = call_name(node)
        if name is None or last_segment(name) not in REGISTER_FUNCS:
            return False
        qual = mod.qualify_dotted(name) or name
        return (qual.rsplit(".", 1)[0] in REGISTER_MODULES
                or qual.startswith("repro.caches.")
                or name.split(".")[0] == "caches"
                or name in REGISTER_FUNCS)  # "from repro.caches import register"

    def _scan_module(self, mod: ModuleInfo) -> None:
        defs: List[CacheDef] = []
        mutable: Set[str] = set()

        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and self._is_register_call(mod,
                                                                     node):
                for arg in list(node.args) + [kw.value
                                              for kw in node.keywords]:
                    for name in walk_names(arg):
                        self.registered.add(name)
                        self.registered.add(mod.qualify(name))
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if self._jit_decorated(mod, node):
                    self.jitted.add(node.name)
                    self.jitted.add(mod.qualify(node.name))

        for node in mod.tree.body:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if self._lru_decorated(mod, node):
                    defs.append(CacheDef(mod.module, node.name, "lru",
                                         node.lineno, node.col_offset,
                                         node.lineno))
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                kind = self._container_kind(mod, node.value)
                if kind == "lrucache":
                    defs.append(CacheDef(mod.module, name, "lrucache",
                                         node.lineno, node.col_offset,
                                         node.end_lineno or node.lineno))
                    self.cache_vars.add(mod.qualify(name))
                elif kind == "mutable":
                    mutable.add(mod.qualify(name))
                    if self._dict_used_as_cache(mod, name):
                        defs.append(CacheDef(mod.module, name, "dict",
                                             node.lineno, node.col_offset,
                                             node.end_lineno or node.lineno))
                        self.cache_vars.add(mod.qualify(name))
                # "x = jax.jit(f)" wraps f: treat both names as jitted
                if isinstance(node.value, ast.Call):
                    cname = call_name(node.value)
                    if cname is not None and (
                            cname in JIT_FUNCS
                            or (mod.qualify_dotted(cname) or "") in
                            {"jax.jit", "jax.pjit"}):
                        self.jitted.add(name)
                        self.jitted.add(mod.qualify(name))
                        for inner in node.value.args[:1]:
                            if isinstance(inner, ast.Name):
                                self.jitted.add(inner.id)
                                self.jitted.add(mod.qualify(inner.id))
        self.caches[mod.module] = defs
        self.mutable_state[mod.module] = mutable

    def _scan_key_builders(self, mod: ModuleInfo) -> None:
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for ret in ast.walk(node):
                if isinstance(ret, ast.Return) and ret.value is not None \
                        and self._expr_structure_tainted(ret.value):
                    self.taint_fns.add(node.name)
                    self.taint_fns.add(mod.qualify(node.name))
                    break

    def _expr_structure_tainted(self, expr: ast.AST) -> bool:
        for n in ast.walk(expr):
            if isinstance(n, ast.Call):
                seg = last_segment(call_name(n))
                if seg in self.taint_fns:
                    return True
        return False

    # -- classification helpers --------------------------------------------

    def _lru_decorated(self, mod: ModuleInfo, node) -> bool:
        for dec in node.decorator_list:
            name = call_name(dec)
            if name is None:
                continue
            if name in LRU_DECORATORS:
                return True
            qual = mod.qualify_dotted(name) or name
            if qual in {"functools.lru_cache", "functools.cache"}:
                return True
        return False

    def _jit_decorated(self, mod: ModuleInfo, node) -> bool:
        for dec in node.decorator_list:
            name = call_name(dec)
            if name in JIT_FUNCS:
                return True
            qual = mod.qualify_dotted(name) if name else None
            if qual in {"jax.jit", "jax.pjit"}:
                return True
            # functools.partial(jax.jit, ...) / partial(jit, ...)
            if isinstance(dec, ast.Call) and last_segment(name) == "partial" \
                    and dec.args:
                inner = call_name(dec.args[0])
                if inner in JIT_FUNCS or \
                        (mod.qualify_dotted(inner) if inner else None) in \
                        {"jax.jit", "jax.pjit"}:
                    return True
        return False

    def _container_kind(self, mod: ModuleInfo, value: ast.AST
                        ) -> Optional[str]:
        if isinstance(value, ast.Call):
            name = call_name(value)
            qual = mod.qualify_dotted(name) if name else None
            if (qual or name) in {"repro.caches.LRUCache", "caches.LRUCache",
                                  "LRUCache"}:
                return "lrucache"
            if name in MUTABLE_CTORS or last_segment(name) in {
                    "OrderedDict", "defaultdict", "deque"}:
                return "mutable"
            return None
        if isinstance(value, (ast.Dict, ast.DictComp, ast.List, ast.ListComp,
                              ast.Set, ast.SetComp)):
            return "mutable"
        return None

    def _dict_used_as_cache(self, mod: ModuleInfo, name: str) -> bool:
        """A module-level dict is a cache when in-module functions write it
        by key AND either read it by key or its name says cache/memo."""
        wrote = read = False
        for fn in ast.walk(mod.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                continue
            for n in ast.walk(fn):
                if isinstance(n, ast.Subscript) and \
                        isinstance(n.value, ast.Name) and n.value.id == name:
                    if isinstance(n.ctx, ast.Store):
                        wrote = True
                    else:
                        read = True
                elif isinstance(n, ast.Call):
                    cname = call_name(n)
                    if cname is None or "." not in cname:
                        continue
                    base, _, meth = cname.rpartition(".")
                    if base != name:
                        continue
                    if meth in {"setdefault", "update"}:
                        wrote = True
                    elif meth in {"get", "pop"}:
                        read = True
        return wrote and (read or bool(_CACHE_NAME_RE.search(name)))

    # -- queries ------------------------------------------------------------

    def is_registered(self, module: str, name: str) -> bool:
        return f"{module}.{name}" in self.registered or \
            name in self.registered

    def is_jitted_call(self, mod: ModuleInfo, node: ast.Call) -> bool:
        name = call_name(node)
        if name is None:
            return False
        if name in self.jitted or last_segment(name) in self.jitted:
            return True
        qual = mod.qualify_dotted(name)
        return qual in self.jitted


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------


def discover_files(root: Path) -> List[Path]:
    if root.is_file():
        return [root]
    return sorted(p for p in root.rglob("*.py")
                  if "__pycache__" not in p.parts
                  and not any(part.startswith(".") for part in p.parts))


class LintEngine:
    """Parse a tree once, run the selected rules over every module."""

    def __init__(self, root, rules: Optional[Sequence] = None):
        from .rules import RULES
        self.root = Path(root).resolve()
        self.rules = list(rules) if rules is not None else [r() for r in
                                                            RULES]
        scan_base = self.root if self.root.is_dir() else self.root.parent
        self.modules: List[ModuleInfo] = []
        for path in discover_files(self.root):
            mod = parse_module(path, scan_base)
            if mod is not None:
                self.modules.append(mod)
        self.table = SymbolTable.build(self.modules)

    def run(self, only: Optional[Iterable[str]] = None) -> List[Finding]:
        wanted = set(only) if only else None
        findings: List[Finding] = []
        for rule in self.rules:
            if wanted is not None and rule.name not in wanted:
                continue
            for mod in self.modules:
                if not rule.applies_to(mod):
                    continue
                for site in rule.check(mod, self.table):
                    lineno, col, end_lineno, message = site[:4]
                    # a site may append escapable=False: some violations
                    # (e.g. time.sleep in serving) accept no annotation
                    escapable = site[4] if len(site) > 4 else True
                    if escapable and rule.escape and \
                            mod.escaped(rule.escape, lineno, end_lineno):
                        continue
                    findings.append(Finding(
                        rule=rule.name, path=mod.relpath, line=lineno,
                        col=col, message=message, severity=rule.severity,
                        line_text=mod.line_text(lineno)))
        return assign_occurrences(findings)


def run_lint(root, only: Optional[Iterable[str]] = None) -> List[Finding]:
    """Convenience one-shot: lint ``root`` with (optionally) a rule subset."""
    return LintEngine(root).run(only=only)
