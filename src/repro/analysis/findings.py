"""Structured lint findings and the committed-baseline suppression file.

A :class:`Finding` is one rule violation at one site.  Its *fingerprint*
is content-anchored — rule name, root-relative path, the stripped source
line, and a per-(rule, path, line-text) occurrence index — so unrelated
edits that only shift line numbers do not churn the baseline, while
editing the offending line itself invalidates its suppression (the site
must be re-justified or fixed).

The baseline (:class:`Baseline`) is a committed JSON file listing
fingerprints that are *known and accepted* with a reason each.  The CLI
exits non-zero on any finding not in the baseline; ``--write-baseline``
regenerates it.  Policy (enforced by tests, not this module): findings in
``serving/`` and ``core/`` must be fixed or escape-annotated in code,
never baselined.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, Iterable, List, Optional

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str               # posix path relative to the scan root
    line: int
    col: int
    message: str
    severity: str = "error"
    line_text: str = ""     # stripped source line (fingerprint anchor)
    occurrence: int = 0     # index among same (rule, path, line_text)

    @property
    def fingerprint(self) -> str:
        payload = "\x1f".join((self.rule, self.path, self.line_text,
                               str(self.occurrence)))
        return hashlib.sha1(payload.encode()).hexdigest()[:16]

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "severity": self.severity,
                "message": self.message, "fingerprint": self.fingerprint}

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"[{self.rule}] {self.severity}: {self.message}")


def assign_occurrences(findings: Iterable[Finding]) -> List[Finding]:
    """Number duplicate (rule, path, line_text) findings so each gets a
    distinct fingerprint (two identical offending lines in one file are
    two sites, suppressible independently)."""
    seen: Dict[tuple, int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        key = (f.rule, f.path, f.line_text)
        n = seen.get(key, 0)
        seen[key] = n + 1
        out.append(dataclasses.replace(f, occurrence=n))
    return out


class Baseline:
    """Committed suppression file: fingerprint -> {rule, path, reason}."""

    VERSION = 1

    def __init__(self, entries: Optional[Dict[str, Dict]] = None,
                 path: Optional[str] = None):
        self.entries = dict(entries or {})
        self.path = path

    @classmethod
    def load(cls, path) -> "Baseline":
        with open(path) as f:
            raw = json.load(f)
        if raw.get("version") != cls.VERSION:
            raise ValueError(
                f"baseline {path}: unsupported version {raw.get('version')!r}"
                f" (expected {cls.VERSION})")
        entries = {e["fingerprint"]: e for e in raw.get("findings", [])}
        return cls(entries, path=str(path))

    @classmethod
    def from_findings(cls, findings: Iterable[Finding],
                      reason: str = "baselined pre-existing finding"
                      ) -> "Baseline":
        entries = {
            f.fingerprint: {"fingerprint": f.fingerprint, "rule": f.rule,
                            "path": f.path, "line": f.line,
                            "reason": reason}
            for f in findings}
        return cls(entries)

    def dumps(self) -> str:
        rows = sorted(self.entries.values(),
                      key=lambda e: (e.get("path", ""), e.get("line", 0),
                                     e["fingerprint"]))
        return json.dumps({"version": self.VERSION, "findings": rows},
                          indent=2, sort_keys=False) + "\n"

    def save(self, path) -> None:
        with open(path, "w") as f:
            f.write(self.dumps())

    def suppresses(self, finding: Finding) -> bool:
        return finding.fingerprint in self.entries

    def __len__(self) -> int:
        return len(self.entries)


def split_by_baseline(findings: Iterable[Finding], baseline: Baseline
                      ) -> tuple:
    """(new, suppressed) partition of ``findings`` against ``baseline``."""
    new, suppressed = [], []
    for f in findings:
        (suppressed if baseline.suppresses(f) else new).append(f)
    return new, suppressed
