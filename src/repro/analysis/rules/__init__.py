"""The six invariant rules, each born from a bug class this repo hit.

A rule declares its ``name`` (CLI ``--only``), its ``escape`` annotation
(``# lint: <escape>(reason)``), and yields ``(lineno, col, end_lineno,
message)`` sites from :meth:`check`; the engine applies escapes and turns
sites into structured findings.
"""
from __future__ import annotations

from typing import Iterator, List, Tuple

#: (lineno, col, end_lineno, message[, escapable]) — a 5th element of
#: False marks a violation that NO annotation may suppress
Site = Tuple


class Rule:
    """Base class: subclasses fill in the class attributes and check()."""

    name: str = ""
    escape: str = ""
    severity: str = "error"
    description: str = ""

    def applies_to(self, mod) -> bool:
        return True

    def check(self, mod, table) -> Iterator[Site]:
        raise NotImplementedError

    @staticmethod
    def at(node, message: str, escapable: bool = True) -> Site:
        return (node.lineno, node.col_offset,
                getattr(node, "end_lineno", None) or node.lineno, message,
                escapable)


from .no_densify import NoDensifyRule            # noqa: E402
from .clock_discipline import ClockDisciplineRule  # noqa: E402
from .cache_registry import CacheRegistryRule    # noqa: E402
from .plan_cache_key import PlanCacheKeyRule     # noqa: E402
from .lock_discipline import LockDisciplineRule  # noqa: E402
from .jit_retrace import JitRetraceRule          # noqa: E402

RULES: List[type] = [NoDensifyRule, ClockDisciplineRule, CacheRegistryRule,
                     PlanCacheKeyRule, LockDisciplineRule, JitRetraceRule]


def rule_names() -> List[str]:
    return [r.name for r in RULES]


__all__ = ["Rule", "Site", "RULES", "rule_names"]
