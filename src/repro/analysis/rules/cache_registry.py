"""Rule 3 — cache-registry: every process cache lives in ``repro.caches``.

PR 5's bounded-memory contract: a long-running serving process must not
grow memory as the structure stream drifts, so every module-level cache —
``functools.lru_cache`` memos, dict caches, compiled-program tables — is
either a self-registering ``repro.caches.LRUCache`` or registered with
``caches.register`` / ``caches.register_lru`` so ``cache_info()`` sees it
and ``clear_all()`` empties it.

Cross-module check: the registration may live anywhere in the scanned
tree (the symbol table records every identifier referenced inside a
``register*`` call, bare and fully qualified).  A module-level dict
counts as a cache when functions in its module write it by key and
either read it by key or its name says cache/memo/program.  Escapes:
``# lint: cache-ok(reason)`` on the definition.
"""
from __future__ import annotations

from typing import Iterator

from . import Rule, Site

EXEMPT_BASENAMES = {"caches.py"}


class CacheRegistryRule(Rule):
    name = "cache-registry"
    escape = "cache-ok"
    severity = "error"
    description = ("module-level lru_cache/dict caches must be registered "
                   "in repro.caches (register/register_lru) or be "
                   "LRUCache instances")

    def applies_to(self, mod) -> bool:
        return mod.basename not in EXEMPT_BASENAMES and \
            "tests" not in mod.parts

    def check(self, mod, table) -> Iterator[Site]:
        for cd in table.caches.get(mod.module, ()):
            if cd.kind == "lrucache":       # LRUCache self-registers
                continue
            if table.is_registered(cd.module, cd.name):
                continue
            what = ("functools.lru_cache function" if cd.kind == "lru"
                    else "dict cache")
            yield (cd.lineno, cd.col, cd.end_lineno,
                   f"module-level {what} `{cd.name}` is not registered in "
                   f"repro.caches: unbounded/invisible process state — "
                   f"call `caches.register_lru({cd.name!r}-style-name, "
                   f"{cd.name})` (or `caches.register(...)` with "
                   f"clear/size handles), or annotate "
                   f"`# lint: cache-ok(reason)`")
