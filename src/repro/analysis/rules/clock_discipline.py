"""Rule 2 — clock-discipline: serving scheduling reads ``engine.clock``.

PR 6's replay determinism rests on one invariant: every *scheduling*
decision in ``repro.serving`` (bucket aging, flush deadlines, submit
timestamps) reads the engine's injectable clock, so a recorded trace
replays to a bit-identical bucket schedule.  One stray wall-clock read
re-introduces timing nondeterminism that only shows up as a divergent
replay digest.

Flags, in any file under a ``serving/`` or ``obs/`` directory except
``clock.py`` (the one module allowed to touch real time).  ``repro.obs``
is covered because its spans measure wall durations INSIDE the request
lifecycle: every ``perf_counter`` read there is a measurement site and
must carry the same ``# lint: clock-ok(reason)`` annotation — and a
``time.sleep`` or scheduling-from-wall-time bug in a span would perturb
exactly the replay determinism this rule protects.

* ``time.time`` / ``time.monotonic`` / ``time.sleep`` — always an error,
  annotations included: scheduling from wall time or real sleeps cannot
  be replayed.  Use ``engine.clock.now()`` / ``clock.wait_on``.
* ``time.perf_counter`` — allowed only at sites annotated
  ``# lint: clock-ok(reason)``: *measuring* a duration (metrics, bench
  wall time) is legitimate; an unannotated read is assumed to be a
  scheduling decision until a human says otherwise.
* ``from time import <any of those>`` — same treatment at the import.
"""
from __future__ import annotations

import ast
from typing import Iterator

from . import Rule, Site

FORBIDDEN = {"time", "monotonic", "sleep"}     # attributes of module time
ANNOTATABLE = {"perf_counter", "perf_counter_ns", "monotonic_ns"}
EXEMPT_BASENAMES = {"clock.py"}


class ClockDisciplineRule(Rule):
    name = "clock-discipline"
    escape = "clock-ok"
    severity = "error"
    description = ("serving + obs code reads the injectable engine clock; "
                   "wall-clock time only in clock.py or at annotated "
                   "measurement sites")

    def applies_to(self, mod) -> bool:
        return ((mod.in_dir("serving") or mod.in_dir("obs"))
                and mod.basename not in EXEMPT_BASENAMES)

    def check(self, mod, table) -> Iterator[Site]:
        time_aliases = {alias for alias, full in mod.imports.items()
                        if full == "time"}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Name) and \
                    node.value.id in time_aliases:
                yield from self._site(mod, node, node.attr)
            elif isinstance(node, ast.ImportFrom) and node.module == "time" \
                    and node.level == 0:
                for a in node.names:
                    yield from self._site(mod, node, a.name)
            elif isinstance(node, ast.Name) and node.id in mod.imports and \
                    mod.imports[node.id] in {
                        f"time.{fn}" for fn in FORBIDDEN | ANNOTATABLE}:
                # a from-imported name used bare; the import line itself is
                # also flagged, but a use far from its import deserves its
                # own site (the import may be annotated, the use not)
                yield from self._site(mod, node,
                                      mod.imports[node.id].split(".", 1)[1])

    def _site(self, mod, node, attr: str) -> Iterator[Site]:
        if attr in FORBIDDEN:
            yield self.at(node, (
                f"`time.{attr}` in serving/obs code: scheduling must read the "
                f"injectable engine clock (`clock.now()` / "
                f"`clock.wait_on`) or move into serving/clock.py — replay "
                f"determinism (PR 6) breaks otherwise; no annotation "
                f"exempts this"), escapable=False)
        elif attr in ANNOTATABLE:
            yield self.at(node, (
                f"unannotated `time.{attr}` in serving/obs code: if this is a "
                f"duration measurement (not a scheduling decision), "
                f"annotate `# lint: clock-ok(reason)`; scheduling must use "
                f"the engine clock"))
