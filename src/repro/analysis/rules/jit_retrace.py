"""Rule 6 — jit-retrace: no mutable captures or per-call containers at
``jax.jit`` boundaries.

The recompile class the serving bucket caches exist to prevent: a
``jax.jit`` trace is keyed by argument *structure* and bakes captured
Python values in as constants.  Two hazards:

* **mutable module state in the closure** — a jitted function reading a
  module-level dict/list/set captures its contents at first trace;
  later mutation (retuning a table, growing a registry) is silently
  invisible, the stale-constant twin of the PR 4 stale-plan bug.  Pass
  the data as an argument (retrace on change) or hash it into a static
  argument.
* **container literals at call sites** — calling a jitted function with
  a fresh ``[...]``/``{...}`` literal makes the pytree structure part of
  the trace key; every distinct length/keyset recompiles.  The serving
  layer exists to amortize traces across a bucket — per-call containers
  defeat it.

Escapes: ``# lint: jit-ok(reason)`` (e.g. a module table that is frozen
after import, or a literal whose shape is provably fixed).
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from . import Rule, Site
from ..engine import call_name

CONTAINER_LITERALS = (ast.List, ast.Dict, ast.Set, ast.ListComp,
                      ast.DictComp, ast.SetComp, ast.GeneratorExp)


def _bound_names(fn) -> Set[str]:
    """Names bound inside the function: params, assignments, imports,
    nested defs, comprehension targets — reads of these are locals, not
    module-state captures."""
    out: Set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        out.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            out.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node is not fn:
                out.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                out.add((a.asname or a.name).split(".")[0])
    return out


class JitRetraceRule(Rule):
    name = "jit-retrace"
    escape = "jit-ok"
    severity = "warning"
    description = ("jax.jit functions must not capture mutable module "
                   "state; jitted call sites must not build container "
                   "literals per call")

    def applies_to(self, mod) -> bool:
        return "tests" not in mod.parts

    def check(self, mod, table) -> Iterator[Site]:
        mutable_here = {q.rsplit(".", 1)[-1]: q
                        for q in table.mutable_state.get(mod.module, ())}
        # names imported from other scanned modules that are mutable there
        imported_mutable: Set[str] = set()
        for alias, full in mod.imports.items():
            owner, _, leaf = full.rpartition(".")
            if owner and full in table.mutable_state.get(owner, ()):
                imported_mutable.add(alias)

        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and table._jit_decorated(mod, node):
                yield from self._check_closure(mod, node, mutable_here,
                                               imported_mutable)
            elif isinstance(node, ast.Call) and \
                    table.is_jitted_call(mod, node):
                yield from self._check_call_site(node)

    def _check_closure(self, mod, fn, mutable_here, imported_mutable
                       ) -> Iterator[Site]:
        bound = _bound_names(fn)
        seen: Set[str] = set()
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Name)
                    and isinstance(node.ctx, ast.Load)):
                continue
            name = node.id
            if name in bound or name in seen:
                continue
            if name in mutable_here or name in imported_mutable:
                seen.add(name)
                yield self.at(node, (
                    f"jit closure captures mutable module state `{name}`: "
                    f"its contents are baked into the trace as constants — "
                    f"later mutation is silently invisible (stale-constant "
                    f"class).  Pass it as an argument or annotate "
                    f"`# lint: jit-ok(reason)` if it is frozen after "
                    f"import"))

    def _check_call_site(self, node: ast.Call) -> Iterator[Site]:
        args = list(node.args) + [kw.value for kw in node.keywords]
        for arg in args:
            if isinstance(arg, ast.Starred):
                arg = arg.value
            if isinstance(arg, CONTAINER_LITERALS):
                fname = call_name(node) or "<jitted>"
                yield self.at(arg, (
                    f"container literal built per call at jit boundary "
                    f"`{fname}(...)`: each distinct structure retraces "
                    f"and recompiles — hoist it, convert to an array, or "
                    f"annotate `# lint: jit-ok(reason)` if its shape is "
                    f"fixed"))
                break
