"""Rule 5 — lock-discipline: a lock-set race detector for serving classes.

Two bug classes motivated this rule:

* the PR 5 plan-cache race — concurrent misses on one structure ran
  racing measured trials and could elect *different* near-tied kernels,
  mixing plans (and bitwise results) within one stream;
* the PR 6 half-taken-work window — ``quiesce()`` could observe the gap
  between "bucket popped" and "worker executing" unless pop and
  busy-marking share one critical section.

Analysis, per class in ``serving/`` modules that starts a worker thread
(``threading.Thread(target=self.<m>)``):

1. lock attributes = ``self.X`` assigned ``threading.Lock()`` /
   ``RLock()`` / ``Condition(...)`` in ``__init__``;
2. for every method, every ``self.<attr>`` access is recorded with the
   lexical lock set (``with self.X:`` nesting) at the access, writes
   distinguished (assignments, augmented assignments, subscript stores,
   and mutator method calls like ``.append``/``.update``);
3. the self-call graph propagates held locks: a method called while
   holding L is analyzed as holding L (RLock/Condition reentry is the
   repo's idiom);
4. worker-reachable accesses (closure from the thread targets) are paired
   against submit/flush-path accesses (closure from the public methods);
   a pair touching the same non-lock attribute, at least one side a
   write, with *disjoint* lock sets, is a finding on the unguarded line.

Attributes only ever written in ``__init__`` (pre-thread) are immutable
configuration and exempt.  Escapes: ``# lint: unlocked-ok(reason)`` at
the access.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, FrozenSet, Iterator, List, Optional, Set, Tuple

from . import Rule, Site
from ..engine import call_name, last_segment

LOCK_CTORS = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
MUTATOR_METHODS = {"append", "extend", "insert", "remove", "pop", "clear",
                   "update", "setdefault", "popitem", "add", "discard",
                   "appendleft", "popleft"}
CONTAINER_CTORS = {"list", "dict", "set", "deque", "defaultdict",
                   "OrderedDict", "Counter"}


@dataclasses.dataclass(frozen=True)
class Access:
    attr: str
    write: bool
    locks: FrozenSet[str]
    method: str
    lineno: int
    col: int
    end_lineno: int


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


class _MethodScan(ast.NodeVisitor):
    """Collect self-attribute accesses + self-calls with lexical locksets."""

    def __init__(self, lock_attrs: Set[str], method: str,
                 container_attrs: Optional[Set[str]] = None):
        self.lock_attrs = lock_attrs
        self.container_attrs = container_attrs or set()
        self.method = method
        self.lockset: Tuple[str, ...] = ()
        self.accesses: List[Access] = []
        #: (callee, lockset-at-callsite)
        self.calls: List[Tuple[str, FrozenSet[str]]] = []

    def _record(self, node, attr: str, write: bool) -> None:
        if attr in self.lock_attrs:
            return
        self.accesses.append(Access(
            attr=attr, write=write, locks=frozenset(self.lockset),
            method=self.method, lineno=node.lineno, col=node.col_offset,
            end_lineno=getattr(node, "end_lineno", None) or node.lineno))

    def visit_With(self, node: ast.With) -> None:
        held = []
        for item in node.items:
            attr = _self_attr(item.context_expr)
            if attr in self.lock_attrs:
                held.append(attr)
        for item in node.items:
            self.visit(item.context_expr)
        self.lockset = self.lockset + tuple(held)
        for stmt in node.body:
            self.visit(stmt)
        self.lockset = self.lockset[:len(self.lockset) - len(held)]

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr is not None:
            self._record(node, attr,
                         isinstance(node.ctx, (ast.Store, ast.Del)))
        self.generic_visit(node)

    def visit_Subscript(self, node: ast.Subscript) -> None:
        # self.x[k] = v mutates self.x even though the Attribute ctx is Load
        attr = _self_attr(node.value)
        if attr is not None and isinstance(node.ctx, (ast.Store, ast.Del)):
            self._record(node, attr, True)
            self.visit(node.slice)
            return
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        attr = _self_attr(node.target)
        if attr is not None:
            self._record(node, attr, True)
        elif isinstance(node.target, ast.Subscript):
            inner = _self_attr(node.target.value)
            if inner is not None:
                self._record(node, inner, True)
        self.visit(node.value)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            recv_attr = _self_attr(func.value)
            if recv_attr is not None and func.attr in MUTATOR_METHODS \
                    and recv_attr in self.container_attrs:
                # self.x.append(...) mutates a plain container attribute;
                # method calls on non-container sub-objects (a Batcher, an
                # LRUCache) are NOT writes here — such objects own their
                # internal synchronization
                self._record(func.value, recv_attr, True)
            target = _self_attr(func)
            if target is not None:
                self.calls.append((target, frozenset(self.lockset)))
        self.generic_visit(node)


class _ClassAnalysis:
    def __init__(self, rule, mod, cls: ast.ClassDef):
        self.rule = rule
        self.mod = mod
        self.cls = cls
        self.methods: Dict[str, ast.AST] = {
            n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        self.lock_attrs = self._find_locks()
        self.container_attrs = self._find_container_attrs()
        self.worker_roots = self._find_thread_targets()
        self.scans: Dict[str, _MethodScan] = {}
        for name, fn in self.methods.items():
            scan = _MethodScan(self.lock_attrs, name, self.container_attrs)
            for stmt in fn.body:
                scan.visit(stmt)
            self.scans[name] = scan
        self.init_only = self._init_only_attrs()

    def _find_locks(self) -> Set[str]:
        out: Set[str] = set()
        init = self.methods.get("__init__")
        if init is None:
            return out
        for node in ast.walk(init):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    attr = _self_attr(tgt)
                    if attr is None:
                        continue
                    if isinstance(node.value, ast.Call) and \
                            last_segment(call_name(node.value)) in LOCK_CTORS:
                        out.add(attr)
        return out

    def _find_container_attrs(self) -> Set[str]:
        """Attributes initialized to plain containers in ``__init__`` —
        the ones whose mutator-method calls (.append/.update/...) count
        as writes.  Sub-objects built from other constructors are assumed
        to own their internal synchronization."""
        out: Set[str] = set()
        init = self.methods.get("__init__")
        if init is None:
            return out
        for node in ast.walk(init):
            targets: List[ast.AST] = []
            value: Optional[ast.AST] = None
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            if value is None:
                continue
            is_container = isinstance(value, (ast.List, ast.Dict, ast.Set,
                                              ast.ListComp, ast.DictComp,
                                              ast.SetComp))
            if isinstance(value, ast.Call) and \
                    last_segment(call_name(value)) in CONTAINER_CTORS:
                is_container = True
            if not is_container:
                continue
            for tgt in targets:
                attr = _self_attr(tgt)
                if attr is not None:
                    out.add(attr)
        return out

    def _find_thread_targets(self) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(self.cls):
            if isinstance(node, ast.Call) and \
                    last_segment(call_name(node)) == "Thread":
                for kw in node.keywords:
                    if kw.arg == "target":
                        attr = _self_attr(kw.value)
                        if attr is not None:
                            out.add(attr)
        return out

    def _init_only_attrs(self) -> Set[str]:
        """Attributes written in __init__ and never written elsewhere."""
        written_init: Set[str] = set()
        written_later: Set[str] = set()
        for name, scan in self.scans.items():
            for acc in scan.accesses:
                if acc.write:
                    (written_init if name == "__init__"
                     else written_later).add(acc.attr)
        return written_init - written_later

    def _closure(self, roots: Set[str]) -> List[Access]:
        """Accesses reachable from ``roots`` with propagated held locks.

        Visits each (method, heldset) pair once; held locks at a callsite
        extend the callee's lexical locksets (reentrant-lock idiom).
        """
        out: List[Access] = []
        seen: Set[Tuple[str, FrozenSet[str]]] = set()
        stack: List[Tuple[str, FrozenSet[str]]] = [
            (r, frozenset()) for r in roots if r in self.scans]
        while stack:
            name, held = stack.pop()
            if (name, held) in seen or len(seen) > 512:
                continue
            seen.add((name, held))
            scan = self.scans[name]
            for acc in scan.accesses:
                out.append(dataclasses.replace(
                    acc, locks=acc.locks | held))
            for callee, at_locks in scan.calls:
                if callee in self.scans and callee != "__init__":
                    stack.append((callee, held | at_locks))
        return out

    def findings(self) -> Iterator[Site]:
        if not self.worker_roots or not self.lock_attrs:
            return
        public_roots = {name for name in self.methods
                        if not name.startswith("_")
                        and name not in self.worker_roots}
        worker = self._closure(self.worker_roots)
        submit = self._closure(public_roots)
        reported: Set[Tuple[int, str]] = set()
        for a1 in worker:
            if a1.attr in self.init_only:
                continue
            for a2 in submit:
                if a2.attr != a1.attr or not (a1.write or a2.write):
                    continue
                if a1.locks & a2.locks:
                    continue
                for acc, other in ((a1, a2), (a2, a1)):
                    key = (acc.lineno, acc.attr)
                    if key in reported:
                        continue
                    reported.add(key)
                    held = (", ".join(sorted(acc.locks))
                            or "no lock")
                    other_held = (", ".join(sorted(other.locks))
                                  or "no lock")
                    yield (acc.lineno, acc.col, acc.end_lineno,
                           f"`self.{acc.attr}` {'written' if acc.write else 'read'} "
                           f"in `{acc.method}` holding {held}, but the "
                           f"{'worker' if other is a1 else 'submit/flush'} "
                           f"path accesses it in `{other.method}` holding "
                           f"{other_held} (line {other.lineno}): disjoint "
                           f"lock sets between the worker thread and the "
                           f"submit/flush path — the PR 5 plan-race / "
                           f"PR 6 half-taken-work class.  Guard both sides "
                           f"with one Lock/Condition or annotate "
                           f"`# lint: unlocked-ok(reason)`")


class LockDisciplineRule(Rule):
    name = "lock-discipline"
    escape = "unlocked-ok"
    severity = "error"
    description = ("attributes shared between a serving worker thread and "
                   "the submit/flush path must share a lock")

    def applies_to(self, mod) -> bool:
        return mod.in_dir("serving") and "tests" not in mod.parts

    def check(self, mod, table) -> Iterator[Site]:
        for node in mod.tree.body:
            if isinstance(node, ast.ClassDef):
                yield from _ClassAnalysis(self, mod, node).findings()
