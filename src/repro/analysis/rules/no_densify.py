"""Rule 1 — no-densify: dense materialization is banned on hot paths.

The paper's central discipline (and PR 2/3's hard-won one): the tile and
distributed pipelines must never round-trip through a dense array — a
single ``to_dense()`` on a hot path silently turns the masked product's
O(flops(M)) work into O(m*n) and its memory into a dense allocation.

Flags calls to ``to_dense``/``todense``/``toarray`` in files under
``core/``, ``kernels/``, or ``serving/``.  Allowlisted: ``ref.py``
reference implementations, ``tests``, and sites annotated
``# lint: densify-ok(reason)``.  Defining ``to_dense`` (formats do) is
fine — only *calling* it densifies.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterator

from . import Rule, Site

HOT_DIRS = ("core", "kernels", "serving")
DENSIFY_CALLS = {"to_dense", "todense", "toarray"}
ALLOWED_BASENAMES = {"ref.py"}


class NoDensifyRule(Rule):
    name = "no-densify"
    escape = "densify-ok"
    severity = "error"
    description = ("no to_dense()/todense()/toarray() calls on core/, "
                   "kernels/, or serving/ hot paths")

    def applies_to(self, mod) -> bool:
        if mod.basename in ALLOWED_BASENAMES:
            return False
        if "tests" in Path(mod.relpath).parts:
            return False
        return any(mod.in_dir(d) for d in HOT_DIRS)

    def check(self, mod, table) -> Iterator[Site]:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            attr = (func.attr if isinstance(func, ast.Attribute)
                    else func.id if isinstance(func, ast.Name) else None)
            if attr in DENSIFY_CALLS:
                yield self.at(node, (
                    f"dense materialization `{attr}()` on a hot path "
                    f"({'/'.join(p for p in mod.parts[:-1])}); masked "
                    f"products must stay sparse end-to-end — move it to a "
                    f"ref/test path or annotate "
                    f"`# lint: densify-ok(reason)`"))
