"""Rule 4 — plan-cache-key: structure-keyed cache keys carry the token.

PR 4's stale-plan bug class: a cache keyed only by operand *structure*
keeps serving entries decided under retired cost-model constants after a
calibration profile activates.  Every cache key derived from planner
structure signatures must therefore incorporate
``planner.cost_model_token()`` — or carry an explicit justification that
the cached value is invariant to the cost model
(``# lint: plan-key-ok(reason)``; the burst gather programs and the
ring's host prep are the canonical structure-pure cases).

Detection (per function, intraprocedural taint):

* *tainted* expressions contain a call to ``structure_signature`` /
  ``content_fingerprint`` / any function the symbol table discovered to
  return structure-derived keys, or reference a local previously assigned
  from one;
* a tainted expression is *token-carrying* when it (or a local folded
  into it) contains a ``cost_model_token()`` call;
* a finding is a cache accessor call — ``X.get(k)`` / ``X.put(k, v)`` /
  ``X.peek(k)`` / ``X.setdefault(k, d)`` on a module-level cache object
  or a ``self.`` attribute, or a ``*cache_get(k)`` / ``*cache_put(k, v)``
  helper — whose key is tainted but not token-carrying.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Optional, Set

from . import Rule, Site

ACCESSOR_METHODS = {"get", "put", "peek", "setdefault"}
TOKEN_FUNCS = {"cost_model_token"}


def _contains_call(expr: ast.AST, names: Set[str]) -> bool:
    from ..engine import call_name, last_segment
    for n in ast.walk(expr):
        if isinstance(n, ast.Call):
            seg = last_segment(call_name(n))
            if seg in names:
                return True
    return False


def _referenced_locals(expr: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(expr)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


class _FunctionScan:
    """Taint pass over one function body (nested defs get their own)."""

    def __init__(self, rule, mod, table, fn):
        self.rule = rule
        self.mod = mod
        self.table = table
        self.fn = fn
        self.tainted: Set[str] = set()
        self.token_ok: Set[str] = set()

    def _expr_taint(self, expr: ast.AST):
        tainted = (_contains_call(expr, self.table.taint_fns)
                   or bool(_referenced_locals(expr) & self.tainted))
        has_token = (_contains_call(expr, TOKEN_FUNCS)
                     or bool(_referenced_locals(expr) & self.token_ok))
        return tainted, has_token

    def _is_cache_receiver(self, recv: ast.AST) -> bool:
        # self.<attr> or a module-level cache object (LRUCache instance /
        # registered dict) — local transient dicts are NOT caches
        if isinstance(recv, ast.Attribute):
            base = recv.value
            return isinstance(base, ast.Name) and base.id == "self"
        if isinstance(recv, ast.Name):
            qual = self.mod.qualify(recv.id)
            return qual in self.table.cache_vars
        return False

    def _shallow_nodes(self):
        """This function's nodes in source order, NOT descending into
        nested defs (each nested function gets its own scan — taint is
        per-scope, and descending twice would double-report)."""
        out = []
        stack = list(ast.iter_child_nodes(self.fn))
        while stack:
            n = stack.pop()
            out.append(n)
            if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                stack.extend(ast.iter_child_nodes(n))
        out.sort(key=lambda n: (getattr(n, "lineno", 0),
                                getattr(n, "col_offset", 0)))
        return out

    def run(self) -> Iterator[Site]:
        for node in self._shallow_nodes():
            if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                    isinstance(node.targets[0], ast.Name):
                tainted, has_token = self._expr_taint(node.value)
                if tainted:
                    self.tainted.add(node.targets[0].id)
                if has_token:
                    self.token_ok.add(node.targets[0].id)
            elif isinstance(node, ast.Call):
                yield from self._check_call(node)

    def _check_call(self, node: ast.Call) -> Iterator[Site]:
        from ..engine import call_name, last_segment
        key_arg: Optional[ast.AST] = None
        func = node.func
        if isinstance(func, ast.Attribute) and \
                func.attr in ACCESSOR_METHODS and node.args:
            if not self._is_cache_receiver(func.value):
                return
            key_arg = node.args[0]
        else:
            seg = last_segment(call_name(node)) or ""
            if (seg.endswith("cache_get") or seg.endswith("cache_put")) \
                    and node.args:
                key_arg = node.args[0]
        if key_arg is None:
            return
        tainted, has_token = self._expr_taint(key_arg)
        if tainted and not has_token:
            yield self.rule.at(node, (
                "cache access keyed by planner structure signatures "
                "without cost_model_token(): after a calibration profile "
                "activates (or an in-place retune), this cache would keep "
                "serving entries decided under the OLD cost model (the "
                "PR 4 stale-plan class) — add cost_model_token() to the "
                "key, or annotate `# lint: plan-key-ok(reason)` if the "
                "cached value is provably cost-model-invariant"))


class PlanCacheKeyRule(Rule):
    name = "plan-cache-key"
    escape = "plan-key-ok"
    severity = "error"
    description = ("cache keys built from structure signatures must "
                   "include cost_model_token() (stale-plan guard)")

    def applies_to(self, mod) -> bool:
        return "tests" not in mod.parts

    def check(self, mod, table) -> Iterator[Site]:
        funcs: Dict[int, ast.AST] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                funcs[node.lineno] = node
        for fn in funcs.values():
            yield from _FunctionScan(self, mod, table, fn).run()
