"""``python -m repro.autotune`` — serving-knob autotuning entry point.

Thin shim over :mod:`repro.tuning.autotune` (mirrors ``repro.tune`` /
``repro.tuning.cli``): replay a recorded traffic trace deterministically,
search the ``QueryEngine`` knob grid, pin the winner under
``results/profiles/``.
"""
from repro.tuning.autotune import main

if __name__ == "__main__":
    raise SystemExit(main())
