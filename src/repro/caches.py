"""Process-wide cache registry: every module-level cache, bounded and
introspectable.

The serving layer (and long-running processes generally) must not grow
memory without bound as the structure stream drifts, so every cache in the
package — the planner's plan cache, the distributed ring's host-prep cache,
the compiled shard_map programs, the serving result cache — is either an
``LRUCache`` from this module or registered here with clear/size handles:

    from repro import caches
    caches.cache_info()            # {name: {size, capacity, hits, misses}}
    caches.clear_all()             # one switch empties every cache
    caches.set_capacity("planner-plans", 512)

Capacities are configurable per cache at runtime (``set_capacity``) or at
import via environment variables (each cache names its own, e.g.
``REPRO_PLAN_CACHE_CAP``); shrinking evicts LRU-first immediately.
"""
from __future__ import annotations

import os
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Optional

_registry_lock = threading.Lock()
_registry: "OrderedDict[str, Dict[str, Callable]]" = OrderedDict()


def register(name: str, *, clear: Callable[[], None],
             size: Callable[[], int],
             capacity: Optional[Callable[[], int]] = None,
             set_capacity: Optional[Callable[[int], None]] = None,
             stats: Optional[Callable[[], Dict[str, int]]] = None) -> None:
    """Register (or replace) a cache's management handles under ``name``."""
    with _registry_lock:
        _registry[name] = dict(clear=clear, size=size, capacity=capacity,
                               set_capacity=set_capacity, stats=stats)


def register_lru(name: str, fn) -> None:
    """Register a ``functools.lru_cache``-wrapped function (fixed capacity)."""
    register(name, clear=fn.cache_clear,
             size=lambda: fn.cache_info().currsize,
             capacity=lambda: fn.cache_info().maxsize,
             stats=lambda: {"hits": fn.cache_info().hits,
                            "misses": fn.cache_info().misses})


def unregister(name: str) -> None:
    with _registry_lock:
        _registry.pop(name, None)


def clear_all() -> None:
    """Empty every registered cache (plans, ring prep, compiled programs,
    serving results).  Compiled programs recompile on next use; everything
    else rebuilds from the operands — correctness never depends on a cache.
    """
    with _registry_lock:
        handles = list(_registry.values())
    for h in handles:
        h["clear"]()


def cache_info() -> Dict[str, Dict[str, int]]:
    """Size/capacity/hit-miss snapshot of every registered cache."""
    with _registry_lock:
        handles = list(_registry.items())
    out = {}
    for name, h in handles:
        row = {"size": int(h["size"]())}
        if h["capacity"] is not None:
            cap = h["capacity"]()
            row["capacity"] = -1 if cap is None else int(cap)
        if h["stats"] is not None:
            row.update(h["stats"]())
        out[name] = row
    return out


def set_capacity(name: str, capacity: int) -> None:
    with _registry_lock:
        h = _registry.get(name)
    if h is None:
        raise KeyError(f"no cache registered as {name!r}; "
                       f"known: {sorted(_registry)}")
    if h["set_capacity"] is None:
        raise ValueError(f"cache {name!r} has a fixed capacity")
    h["set_capacity"](int(capacity))


def env_capacity(var: str, default: int) -> int:
    """Capacity from the environment (``var``), falling back to ``default``."""
    raw = os.environ.get(var, "")
    try:
        return int(raw) if raw else default
    except ValueError as e:
        raise ValueError(f"{var} must be an integer, got {raw!r}") from e


class LRUCache:
    """Thread-safe bounded LRU mapping with hit/miss stats.

    Self-registers under ``name`` (env var ``env_var``, when given, sets the
    initial capacity).  The unit of accounting is the entry — callers cache
    similarly-sized objects per cache, so entry count bounds memory.
    """

    def __init__(self, name: str, capacity: int,
                 env_var: Optional[str] = None):
        if env_var is not None:
            capacity = env_capacity(env_var, capacity)
        if capacity < 1:
            raise ValueError(f"{name}: capacity must be >= 1, got {capacity}")
        self.name = name
        self._capacity = capacity
        self._data: "OrderedDict[Any, Any]" = OrderedDict()
        self._lock = threading.RLock()
        self._hits = 0
        self._misses = 0
        register(name, clear=self.clear, size=self.__len__,
                 capacity=lambda: self._capacity,
                 set_capacity=self.set_capacity, stats=self.stats)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)

    @property
    def capacity(self) -> int:
        return self._capacity

    def set_capacity(self, capacity: int) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        with self._lock:
            self._capacity = capacity
            while len(self._data) > capacity:
                self._data.popitem(last=False)

    def get(self, key, default=None):
        """Lookup; a hit refreshes recency.  Misses count only here (``peek``
        does not touch stats), so hit-rate reflects real traffic."""
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._hits += 1
                return self._data[key]
            self._misses += 1
            return default

    def peek(self, key, default=None):
        with self._lock:
            return self._data.get(key, default)

    def put(self, key, value) -> None:
        with self._lock:
            self._data[key] = value
            self._data.move_to_end(key)
            while len(self._data) > self._capacity:
                self._data.popitem(last=False)

    def pop(self, key, default=None):
        """Remove and return one entry (scoped invalidation: evicting a
        stale key must not flush the rest of the cache)."""
        with self._lock:
            return self._data.pop(key, default)

    def clear(self) -> None:
        with self._lock:
            self._data.clear()
            self._hits = 0
            self._misses = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self._hits, "misses": self._misses}

    def info(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self._hits, "misses": self._misses,
                    "size": len(self._data), "capacity": self._capacity}
