"""Version portability shims for the jax API surface this repo uses.

The codebase targets the current jax API (``jax.shard_map`` with
``check_vma``, ``jax.set_mesh``); older jaxlib builds (<= 0.4.x, like the
pinned container toolchain) expose the same functionality under
``jax.experimental.shard_map`` (with ``check_rep``) and via ``Mesh`` as a
context manager.  Importing through this module keeps every call site
version-agnostic:

    from repro.compat import shard_map, set_mesh
"""
from __future__ import annotations

import contextlib

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with per-shard semantics checking disabled.

    On new jax this is ``jax.shard_map(..., check_vma=False)``; on old jax,
    ``jax.experimental.shard_map.shard_map(..., check_rep=False)``.  The
    check is disabled in both because the collectives in this repo
    (ppermute rings, psum trees) are hand-scheduled and the checker's
    replication inference rejects some valid programs.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def axis_size(axis_name):
    """Size of a named mesh axis inside shard_map.

    Old jax has no ``jax.lax.axis_size``; ``psum(1, axis)`` is the classic
    spelling and folds to a constant for a known mesh.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def get_abstract_mesh():
    """The ambient mesh, or None.

    Old jax tracks the global mesh (installed by ``with mesh:``) on
    ``pxla.thread_resources`` instead of ``jax.sharding``.  Without this
    fallback, mesh-sniffing callers (e.g. the expert-parallel MoE switch)
    silently saw "no mesh" and degraded to their dense paths.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax.interpreters import pxla
    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


@contextlib.contextmanager
def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    New jax: ``jax.set_mesh``.  Old jax: ``Mesh`` itself is the context
    manager (the classic global-mesh idiom).
    """
    if hasattr(jax, "set_mesh"):
        ctx = jax.set_mesh(mesh)
        # jax.set_mesh is itself a context manager on current jax
        with ctx:
            yield mesh
        return
    with mesh:
        yield mesh
