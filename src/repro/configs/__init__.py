from .base import (ModelConfig, MoECfg, MLACfg, SSMCfg, XLSTMCfg, ShapeCfg,
                   SHAPES, ARCH_IDS, ARCH_ALIASES, get_config,
                   cell_is_runnable)
