"""Architecture config schema + registry.

One ``<arch>.py`` per assigned architecture defines ``CONFIG`` (exact paper/
HF numbers) and ``SMOKE`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    router_scale: bool = False       # normalize top-k weights
    ep: bool = True                  # expert-parallel shard_map path when a
                                     # mesh with a "model" axis is ambient
    capacity_factor: float = 1.5     # EP per-rank capacity vs perfect balance


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class XLSTMCfg:
    slstm_every: int = 8             # one sLSTM block per this many layers
    head_dim: int = 0                # 0 -> d_model // n_heads
    proj_factor: float = 2.0         # mLSTM up-projection
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    # attention / mask pattern (the paper's technique parameters)
    attn_impl: str = "block_masked"  # dense_masked | block_masked | flash_pallas
    attn_block: int = 128
    kv_replicated: bool = False      # replicate wk/wv + K/V activations:
                                     # kills per-layer KV all-gathers when
                                     # n_kv_heads < TP (see §Perf)
    window: int = 0                  # sliding window; 0 = full
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # family extensions
    moe: Optional[MoECfg] = None
    first_k_dense: int = 0           # leading dense-FFN layers in MoE stacks
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    hybrid_attn_every: int = 0       # zamba2: shared attn block cadence
    xlstm: Optional[XLSTMCfg] = None
    # enc-dec
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # vlm
    img_tokens: int = 0
    d_frontend: int = 0
    # numerics / scale
    dtype: str = "bfloat16"
    remat: str = "full"              # none | dots | full
    sub_quadratic: bool = False      # supports long_500k decode
    max_seq: int = 8192

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# shape grid (assigned): every LM arch x these four shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "llama3_2_3b", "llama3_2_1b", "stablelm_3b", "starcoder2_7b",
    "xlstm_1_3b", "zamba2_7b", "moonshot_v1_16b_a3b", "deepseek_v2_lite_16b",
    "seamless_m4t_large_v2", "internvl2_2b",
)

# public --arch ids (hyphenated) -> module names
ARCH_ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ARCH_ALIASES.update({
    "llama3.2-3b": "llama3_2_3b",
    "llama3.2-1b": "llama3_2_1b",
    "xlstm-1.3b": "xlstm_1_3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
})


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod_name = ARCH_ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def cell_is_runnable(cfg: ModelConfig, shape: ShapeCfg) -> Tuple[bool, str]:
    """Whether (arch x shape) is a defined cell (spec rules)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k decode requires "
                       "sub-quadratic attention (skip noted in DESIGN.md)")
    return True, ""
