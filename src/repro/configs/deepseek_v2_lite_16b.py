"""deepseek-v2-lite-16b [moe] 27L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6, MLA kv_lora=512, 2 shared experts
[arXiv:2405.04434; hf].

(The assignment line mentions both "64e" and "160 routed"; DeepSeek-V2-Lite
ground truth is 64 routed + 2 shared, top-6 — we follow 64e.)  First layer
uses a dense FFN (d_ff=10944) per the HF config; expert FFN d_ff=1408."""
from .base import ModelConfig, MoECfg, MLACfg

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", n_layers=27, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=10944, vocab_size=102400,
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2,
               d_ff_shared=1408, router_scale=True),
    mla=MLACfg(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
               v_head_dim=128),
    first_k_dense=1, norm="rmsnorm", act="swiglu",
    attn_impl="block_masked", sub_quadratic=False,
)

SMOKE = CONFIG.replace(
    name="deepseek-v2-lite-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512,
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
               d_ff_shared=32, router_scale=True),
    mla=MLACfg(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
               v_head_dim=16),
    first_k_dense=1, attn_block=16, dtype="float32", remat="none",
)
