"""internvl2-2b [vlm] 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT + InternLM2  [arXiv:2404.16821; hf].

Backbone only: the InternViT frontend is a STUB (input_specs provides
precomputed patch embeddings, 256 tokens x d_frontend=1024).  The image
prefix is bidirectional within itself -> a dense-prefix block mask, the
general structured-mask path of the paper's technique."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92553,
    img_tokens=256, d_frontend=1024, rope_theta=1000000.0,
    norm="rmsnorm", act="swiglu", attn_impl="block_masked",
    sub_quadratic=False,
)

SMOKE = CONFIG.replace(
    name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, img_tokens=16, d_frontend=32,
    attn_block=16, dtype="float32", remat="none",
)
