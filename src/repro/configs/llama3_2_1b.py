"""llama3.2-1b [dense] 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256  [hf:meta-llama/Llama-3.2-1B; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=32, n_kv_heads=8, d_ff=8192, vocab_size=128256,
    head_dim=64, rope_theta=500000.0, norm="rmsnorm", act="swiglu",
    attn_impl="block_masked", sub_quadratic=False, tie_embeddings=True,
)

SMOKE = CONFIG.replace(
    name="llama3.2-1b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16, attn_block=16,
    dtype="float32", remat="none",
)
