"""moonshot-v1-16b-a3b [moe] 48L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight
[hf:moonshotai/Moonlight-16B-A3B; hf]."""
from .base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=5632, vocab_size=163840,
    moe=MoECfg(n_experts=64, top_k=6, d_ff_expert=1408,
               router_scale=True),
    first_k_dense=1, norm="rmsnorm", act="swiglu",
    attn_impl="block_masked", sub_quadratic=False,
)

SMOKE = CONFIG.replace(
    name="moonshot-smoke", n_layers=3, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512,
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32, router_scale=True),
    first_k_dense=1, attn_block=16, dtype="float32", remat="none",
)
