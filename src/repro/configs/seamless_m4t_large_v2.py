"""seamless-m4t-large-v2 [audio] 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — enc-dec, multimodal  [arXiv:2308.11596; hf].

Backbone only: 24 encoder + 24 decoder layers; the speech frontend is a
STUB (input_specs provides precomputed frame embeddings, d_frontend=1024).
Encoder attention is bidirectional (mask fully dense -> plain-product fast
path); decoder self-attention is causal block-masked; cross-attention dense.
Encoder-only part has no decode; decode shapes exercise the decoder."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio", n_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192, vocab_size=256206,
    enc_dec=True, n_enc_layers=24, n_dec_layers=24, d_frontend=1024,
    norm="layernorm", act="gelu", attn_impl="block_masked",
    sub_quadratic=False,
)

SMOKE = CONFIG.replace(
    name="seamless-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, n_enc_layers=2, n_dec_layers=2,
    d_frontend=32, attn_block=16, dtype="float32", remat="none",
)
