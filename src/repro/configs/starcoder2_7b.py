"""starcoder2-7b [dense] 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE, 4k sliding window  [arXiv:2402.19173; hf].

The sliding window makes its attention mask a banded block-sparse mask —
the paper's technique gives the full S/W saving here, and long_500k decode
is sub-quadratic (ring-buffered cache of one window)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv_heads=4, d_ff=18432, vocab_size=49152,
    window=4096, norm="layernorm", act="gelu", qkv_bias=True,
    rope_theta=100000.0, attn_impl="block_masked", sub_quadratic=True,
)

SMOKE = CONFIG.replace(
    name="starcoder2-7b-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=512, window=32, attn_block=16,
    dtype="float32", remat="none",
)
