"""xlstm-1.3b [ssm] 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks  [arXiv:2405.04517; unverified].

48 layers in super-blocks of (7 mLSTM + 1 sLSTM); chunkwise-parallel mLSTM
training path, O(1)-state decode (long_500k runs)."""
from .base import ModelConfig, XLSTMCfg

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    xlstm=XLSTMCfg(slstm_every=8, head_dim=512, chunk=64),
    norm="rmsnorm", sub_quadratic=True,
)

SMOKE = CONFIG.replace(
    name="xlstm-1.3b-smoke", n_layers=4, d_model=64, n_heads=2,
    n_kv_heads=2, vocab_size=512,
    xlstm=XLSTMCfg(slstm_every=2, head_dim=32, chunk=8),
    dtype="float32", remat="none",
)
