"""zamba2-7b [hybrid] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; unverified].

81 Mamba2 layers; ONE shared attention+MLP block (a single weight set)
applied after every 6th Mamba layer — Zamba's parameter-sharing design.
SSM majority makes long_500k decode O(1)-state (runs)."""
from .base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab_size=32000,
    ssm=SSMCfg(d_state=64, head_dim=64, expand=2, chunk=128),
    hybrid_attn_every=6, norm="rmsnorm", act="swiglu",
    attn_impl="block_masked", sub_quadratic=True,
)

SMOKE = CONFIG.replace(
    name="zamba2-7b-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=512,
    ssm=SSMCfg(d_state=16, head_dim=16, expand=2, chunk=8),
    hybrid_attn_every=2, attn_block=16, dtype="float32", remat="none",
)
