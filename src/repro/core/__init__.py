# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from .distributed import (distributed_masked_spgemm, ring_masked_matmul,
                          ring_sparse_masked_spgemm,
                          row_parallel_masked_spgemm)
from .masked_spgemm import (ALGORITHMS, MaskedSpGEMMResult, dense_oracle,
                            masked_spgemm, masked_spgemm_batched)
from .planner import (DistPlan, Plan, PlanStats, clear_plan_cache,
                      collect_stats, cost_model_token, decide,
                      decide_distributed, distributed_costs, plan,
                      plan_batch, plan_cache_info, plan_distributed,
                      rank_algorithms)

__all__ = [
    "ALGORITHMS", "MaskedSpGEMMResult", "dense_oracle", "masked_spgemm",
    "masked_spgemm_batched", "distributed_masked_spgemm",
    "ring_masked_matmul", "ring_sparse_masked_spgemm",
    "row_parallel_masked_spgemm", "DistPlan", "Plan", "PlanStats",
    "clear_plan_cache", "collect_stats", "cost_model_token", "decide",
    "decide_distributed", "distributed_costs", "plan", "plan_batch",
    "plan_cache_info", "plan_distributed", "rank_algorithms",
]
