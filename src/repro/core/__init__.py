# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from .masked_spgemm import (ALGORITHMS, MaskedSpGEMMResult, dense_oracle,
                            masked_spgemm, masked_spgemm_batched)
from .planner import (Plan, PlanStats, clear_plan_cache, collect_stats,
                      decide, plan, plan_batch, plan_cache_info,
                      rank_algorithms)

__all__ = [
    "ALGORITHMS", "MaskedSpGEMMResult", "dense_oracle", "masked_spgemm",
    "masked_spgemm_batched", "Plan", "PlanStats", "clear_plan_cache",
    "collect_stats", "decide", "plan", "plan_batch", "plan_cache_info",
    "rank_algorithms",
]
