"""Element-level Masked SpGEVM accumulators (paper Sec. 5), in JAX.

Each accumulator implements the paper's interface

    SETALLOWED(key) / INSERT(key, value) / REMOVE(key)

with the three states NOTALLOWED / ALLOWED / SET, specialized as a row-level
masked SpGEVM  v = m (.)  (u^T B)  over an arbitrary semiring.

Vectorization notes (faithfulness vs. the CPU paper):
  * The paper's scalar inner loop over a row of B is vectorized: one B-row is
    processed as a whole (the state transitions applied are identical because
    column ids within a CSR row are unique).
  * MCA/Heap use sorted-merge primitives.  ``searchsorted`` is the vectorized
    equivalent of the paper's sequential 2-way merge (same information flow,
    log-factor instead of linear scan); the Heap's multiway merge is realized
    as sort + segmented reduction, the standard data-parallel equivalent of a
    priority-queue merge.
  * INSERT's lambda deferral ("only evaluate the product if it will not be
    discarded") becomes predication: products are computed vector-wide and
    masked, which on SIMD hardware is the same optimization.

All functions operate on a single row and are ``vmap``-ed by the driver in
``masked_spgemm.py``.  Static widths: pm = mask-row pad, wa = A-row pad,
wb = B-row pad.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .semiring import Semiring

NOTALLOWED, ALLOWED, SET = 0, 1, 2


def _b_row(B_cols, B_vals, B_lens, row, kdim):
    """Fetch one padded row of B, masking padding and out-of-range rows."""
    safe = jnp.minimum(row, kdim - 1)
    cols = B_cols[safe]
    vals = B_vals[safe]
    valid = (jnp.arange(cols.shape[0]) < B_lens[safe]) & (row < kdim)
    return cols, vals, valid


# ---------------------------------------------------------------------------
# MSA: dense values[n] + states[n]  (paper Sec. 5.2)
# ---------------------------------------------------------------------------


def msa_row(m_cols, a_cols, a_vals, a_len, B_cols, B_vals, B_lens,
            n: int, kdim: int, sr: Semiring, complement: bool = False):
    """Masked SpGEVM with the Masked Sparse Accumulator.

    Returns (vals, present) aligned to mask slots when ``complement=False``;
    dense (n,) row otherwise (complemented output is not mask-aligned).
    """
    values = jnp.full((n + 1,), sr.zero, dtype=B_vals.dtype)
    if complement:
        states = jnp.full((n + 1,), ALLOWED, dtype=jnp.int8)
        states = states.at[m_cols].set(NOTALLOWED)  # SETNOTALLOWED
        states = states.at[n].set(NOTALLOWED)       # scratch slot
    else:
        states = jnp.full((n + 1,), NOTALLOWED, dtype=jnp.int8)
        states = states.at[m_cols].set(ALLOWED)     # SETALLOWED; pads hit slot n
        states = states.at[n].set(NOTALLOWED)

    def insert_row(k, carry):
        values, states = carry
        uk = a_vals[k]
        bcols, bvals, bvalid = _b_row(B_cols, B_vals, B_lens, a_cols[k], kdim)
        bvalid = bvalid & (k < a_len)
        st = states[bcols]
        allowed = (st >= ALLOWED) & bvalid
        prod = sr.mul(uk, bvals)                      # predicated lambda
        new = jnp.where(allowed, sr.add(values[bcols], prod), values[bcols])
        values = values.at[bcols].set(new)            # cols unique within row
        states = states.at[bcols].set(jnp.where(allowed, SET, st).astype(jnp.int8))
        return values, states

    values, states = jax.lax.fori_loop(0, a_cols.shape[0], insert_row,
                                       (values, states))
    if complement:
        present = states[:n] == SET
        return jnp.where(present, values[:n], sr.zero), present
    # gather in mask order (REMOVE per mask nonzero) -> stable output
    out = values[m_cols]
    present = (states[m_cols] == SET) & (m_cols < n)
    return jnp.where(present, out, sr.zero), present


# ---------------------------------------------------------------------------
# Hash: open addressing, linear probing, load factor 0.25 (paper Sec. 5.3)
# ---------------------------------------------------------------------------


def _hash_size(pm: int, load: float = 0.25) -> int:
    t = 1
    need = max(4, int(pm / load))
    while t < need:
        t <<= 1
    return t


def _probe(keys, queries, table_size):
    """Vectorized linear probing: slot of each query (or slot of first EMPTY).

    Returns (slots, found).  EMPTY = -1.
    """
    h = (queries.astype(jnp.uint32) * jnp.uint32(2654435761)) & jnp.uint32(table_size - 1)
    slots = h.astype(jnp.int32)

    def cond(c):
        _, done = c
        return ~jnp.all(done)

    def body(c):
        slots, done = c
        at = keys[slots]
        hit = (at == queries) | (at == -1)
        new_done = done | hit
        slots = jnp.where(new_done, slots, (slots + 1) & (table_size - 1))
        return slots, new_done

    slots, _ = jax.lax.while_loop(
        cond, body, (slots, jnp.zeros_like(queries, dtype=bool)))
    found = keys[slots] == queries
    return slots, found


def hash_row(m_cols, a_cols, a_vals, a_len, B_cols, B_vals, B_lens,
             n: int, kdim: int, sr: Semiring, table_size: int = 0):
    """Masked SpGEVM with the hash accumulator (non-complemented mask)."""
    pm = m_cols.shape[0]
    T = table_size or _hash_size(pm)
    keys = jnp.full((T,), -1, dtype=jnp.int32)
    values = jnp.full((T,), sr.zero, dtype=B_vals.dtype)
    states = jnp.full((T,), NOTALLOWED, dtype=jnp.int8)

    # SETALLOWED for every mask nonzero (sequential inserts, like the paper)
    def set_allowed(i, carry):
        keys, states = carry
        c = m_cols[i]
        valid = c < n
        slots, _ = _probe(keys, jnp.array([c], jnp.int32), T)
        s = slots[0]
        keys = jnp.where(valid, keys.at[s].set(c), keys)
        states = jnp.where(valid, states.at[s].set(ALLOWED), states)
        return keys, states

    keys, states = jax.lax.fori_loop(0, pm, set_allowed, (keys, states))

    def insert_row(k, carry):
        values, states = carry
        uk = a_vals[k]
        bcols, bvals, bvalid = _b_row(B_cols, B_vals, B_lens, a_cols[k], kdim)
        bvalid = bvalid & (k < a_len)
        slots, found = _probe(keys, bcols.astype(jnp.int32), T)
        allowed = found & bvalid & (states[slots] >= ALLOWED)
        prod = sr.mul(uk, bvals)
        new = jnp.where(allowed, sr.add(values[slots], prod), values[slots])
        values = values.at[slots].set(new)
        states = states.at[slots].set(
            jnp.where(allowed, SET, states[slots]).astype(jnp.int8))
        return values, states

    values, states = jax.lax.fori_loop(0, a_cols.shape[0], insert_row,
                                       (values, states))
    # REMOVE in mask order
    slots, found = _probe(keys, m_cols.astype(jnp.int32), T)
    present = found & (states[slots] == SET) & (m_cols < n)
    return jnp.where(present, values[slots], sr.zero), present


# ---------------------------------------------------------------------------
# MCA: compressed accumulator indexed by mask rank (paper Sec. 5.4; novel)
# ---------------------------------------------------------------------------


def mca_row(m_cols, a_cols, a_vals, a_len, B_cols, B_vals, B_lens,
            n: int, kdim: int, sr: Semiring):
    """Masked SpGEVM with the Mask Compressed Accumulator.

    Accumulator arrays have length nnz(m) (= pm padded); keys are the *ranks*
    of mask nonzeros.  Only ALLOWED/SET states exist.  No complement support
    (faithful to the paper).  ``searchsorted`` plays the role of the sorted
    mask/B-row merge.
    """
    pm = m_cols.shape[0]
    # one scratch slot at index pm absorbs every non-hit scatter: a clamped
    # miss must never alias a hit slot (duplicate-index .at[].set order is
    # unspecified and would otherwise drop accumulations)
    values = jnp.full((pm + 1,), sr.zero, dtype=B_vals.dtype)
    states = jnp.zeros((pm + 1,), dtype=jnp.int8)  # 0 = ALLOWED, 1 = SET

    def insert_row(k, carry):
        values, states = carry
        uk = a_vals[k]
        bcols, bvals, bvalid = _b_row(B_cols, B_vals, B_lens, a_cols[k], kdim)
        bvalid = bvalid & (k < a_len)
        idx = jnp.searchsorted(m_cols, bcols).astype(jnp.int32)
        idxc = jnp.minimum(idx, pm - 1)
        hit = (m_cols[idxc] == bcols) & (bcols < n) & bvalid & (idx < pm)
        tgt = jnp.where(hit, idxc, pm)
        prod = sr.mul(uk, bvals)
        new = jnp.where(hit, sr.add(values[idxc], prod), sr.zero)
        values = values.at[tgt].set(new)
        states = states.at[tgt].set(jnp.where(hit, 1, 0).astype(jnp.int8))
        return values, states

    values, states = jax.lax.fori_loop(0, a_cols.shape[0], insert_row,
                                       (values, states))
    present = (states[:pm] == 1) & (m_cols < n)
    return jnp.where(present, values[:pm], sr.zero), present


# ---------------------------------------------------------------------------
# Heap: multiway merge of scaled B-rows (paper Sec. 5.5)
# ---------------------------------------------------------------------------


def _segmented_reduce_sorted(cols, vals, sr: Semiring, n: int):
    """Combine values of equal, sorted cols: returns (cols, vals, is_tail).

    ``is_tail[i]`` marks the last element of each equal-col run; vals at the
    tail hold the run's semiring-sum (matches the paper's "accumulate into
    the last inserted output entry" logic, Alg. 4 lines 14-18).
    """
    newseg = jnp.concatenate([jnp.ones((1,), bool), cols[1:] != cols[:-1]])

    def combine(a, b):
        (va, sa), (vb, sb) = a, b
        v = jnp.where(sb, vb, sr.add(va, vb))
        return v, sa | sb  # segment flag must OR both sides (associativity)

    vals_scan, _ = jax.lax.associative_scan(combine, (vals, newseg))
    is_tail = jnp.concatenate([cols[1:] != cols[:-1], jnp.ones((1,), bool)])
    is_tail = is_tail & (cols < n)
    return cols, vals_scan, is_tail


def heap_row(m_cols, a_cols, a_vals, a_len, B_cols, B_vals, B_lens,
             n: int, kdim: int, sr: Semiring, n_inspect: int = 1,
             complement: bool = False):
    """Masked SpGEVM via multiway merge (Heap / HeapDot).

    ``n_inspect`` mirrors the paper's NInspect: 0 pushes every element and
    filters against the mask during the merge (Heap); >=1 ("HeapDot" when
    inf) checks mask membership *before* an element enters the merge.  The
    data-parallel merge is sort + segmented semiring-reduction.
    """
    wa, wb = a_cols.shape[0], B_cols.shape[1]
    pm = m_cols.shape[0]

    def one_source(k):
        uk = a_vals[k]
        bcols, bvals, bvalid = _b_row(B_cols, B_vals, B_lens, a_cols[k], kdim)
        bvalid = bvalid & (k < a_len)
        prod = sr.mul(uk, bvals)
        if n_inspect > 0 and not complement:
            idx = jnp.minimum(jnp.searchsorted(m_cols, bcols), pm - 1)
            in_mask = (m_cols[idx] == bcols)
            bvalid = bvalid & in_mask  # inspect mask before pushing
        cols = jnp.where(bvalid, bcols, n)
        return cols, jnp.where(bvalid, prod, sr.zero)

    cols, vals = jax.vmap(one_source)(jnp.arange(wa))
    cols, vals = cols.reshape(-1), vals.reshape(-1)
    order = jnp.argsort(cols)                     # == heap-ordered extraction
    cols, vals = cols[order], vals[order]
    cols, vals, is_tail = _segmented_reduce_sorted(cols, vals, sr, n)

    if complement:
        # products for S \ m: drop merged entries whose col is in the mask
        idx = jnp.minimum(jnp.searchsorted(m_cols, cols), pm - 1)
        in_mask = (m_cols[idx] == cols)
        keep = is_tail & ~in_mask
        dense = jnp.full((n + 1,), sr.zero, dtype=vals.dtype)
        densep = jnp.zeros((n + 1,), bool)
        dense = dense.at[jnp.where(keep, cols, n)].set(vals)
        densep = densep.at[jnp.where(keep, cols, n)].set(True)
        return dense[:n], densep[:n]

    # align merged run-tails to mask slots (scatter only the hits; a slot is
    # hit by at most one run tail since mask cols are unique)
    out = jnp.full((pm + 1,), sr.zero, dtype=vals.dtype)
    present = jnp.zeros((pm + 1,), bool)
    idx = jnp.searchsorted(m_cols, cols).astype(jnp.int32)
    idxc = jnp.minimum(idx, pm - 1)
    hit = (m_cols[idxc] == cols) & is_tail
    tgt = jnp.where(hit, idxc, pm)
    out = out.at[tgt].set(vals)
    present = present.at[tgt].set(hit)
    return out[:pm], present[:pm] & (m_cols < n)


# ---------------------------------------------------------------------------
# Inner: pull-based dot products per mask nonzero (paper Sec. 4.1)
# ---------------------------------------------------------------------------


def inner_row(m_cols, a_cols, a_vals, a_len,
              Bt_cols, Bt_vals, Bt_lens, n: int, kdim: int, sr: Semiring):
    """Pull algorithm: for each mask nonzero j, sparse dot  A_i* . B_*j.

    ``Bt_*`` is B stored column-major (CSC == CSR of B^T), as the paper
    prescribes.  Intersection of the two sorted index lists via searchsorted.
    """
    wa = a_cols.shape[0]
    a_valid = jnp.arange(wa) < a_len

    def one_dot(j):
        bcols, bvals, bvalid = _b_row(Bt_cols, Bt_vals, Bt_lens, j, n)
        # locate each A-row index inside B's column-j index list
        idx = jnp.minimum(jnp.searchsorted(bcols, a_cols), bcols.shape[0] - 1)
        hit = (bcols[idx] == a_cols) & a_valid & (a_cols < kdim)
        hit = hit & bvalid[idx]
        prod = sr.mul(a_vals, bvals[idx])
        contrib = jnp.where(hit, prod, sr.zero)
        # semiring-reduce the intersection
        red = jax.lax.reduce(contrib, jnp.asarray(sr.zero, contrib.dtype),
                             sr.add, (0,))
        return red, jnp.any(hit)

    vals, present = jax.vmap(one_dot)(jnp.minimum(m_cols, n - 1))
    present = present & (m_cols < n)
    return jnp.where(present, vals, sr.zero), present


# ---------------------------------------------------------------------------
# Symbolic (counting-only) variants for the two-phase pipeline (paper Sec. 6)
# ---------------------------------------------------------------------------


def symbolic_row(m_cols, a_cols, a_len, B_cols, B_lens, n: int, kdim: int):
    """Number of output nonzeros of one masked row (structure only).

    Mirrors MCA with boolean states and no value computation -- the cheapest
    faithful symbolic pass.
    """
    pm = m_cols.shape[0]
    states = jnp.zeros((pm + 1,), bool)  # scratch slot pm absorbs misses

    def body(k, states):
        bcols = B_cols[jnp.minimum(a_cols[k], kdim - 1)]
        bvalid = (jnp.arange(bcols.shape[0]) <
                  B_lens[jnp.minimum(a_cols[k], kdim - 1)])
        bvalid = bvalid & (a_cols[k] < kdim) & (k < a_len)
        idx = jnp.minimum(jnp.searchsorted(m_cols, bcols), pm - 1)
        hit = (m_cols[idx] == bcols) & (bcols < n) & bvalid
        return states.at[jnp.where(hit, idx, pm)].set(True)

    states = jax.lax.fori_loop(0, a_cols.shape[0], body, states)
    return jnp.sum((states[:pm] & (m_cols < n)).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Cost hooks (planner): per-algorithm work models over padded row widths
# ---------------------------------------------------------------------------
#
# The planner (``planner.py``) chooses among the accumulators by evaluating
# these models on cheap structural statistics.  The models describe THIS
# vectorized implementation, not the paper's scalar CPU loops: every row is
# padded to the static widths wa/wb/pm, so padded products (not true flops)
# are what the hardware executes.  Units: estimated milliseconds per 1024
# output rows on the calibration host; only the *ranking* matters, and the
# constants are tunable (see ROADMAP "Open items" for the re-calibration
# procedure against BENCH_density / the rmat suite).

#: Calibration constants — SHIPPED CPU defaults, fit to
#: benchmarks/bench_density.py (n=1024 ER grid) plus skewed R-MAT and
#: dense-mask probes.  On other backends don't hand-edit: ``python -m
#: repro.tune`` measures the kernels and refits these (and TILE_COST /
#: DIST_COST / the tile gates) into a CalibrationProfile, and
#: ``repro.tuning.activate`` installs it here in place.  The planner keys
#: its plan caches on a fingerprint of these tables, so any change —
#: activation or manual mutation — invalidates previously cached plans.
COST_CONSTANTS = {
    # dense (n+1)-wide state init/gather + wa sequential scatter rounds
    "msa": dict(base=12.0, per_n=0.035, per_flop=0.25, per_mask=0.5),
    # table build is a sequential probe loop over mask nonzeros; probing
    # inside the flop loop is a while-loop per batch of wb queries
    "hash": dict(base=40.0, per_flop=0.30, per_mask=1.5, per_slot=0.01),
    # wa merge rounds of wb searchsorted lookups into the pm-long mask row
    "mca": dict(base=45.0, per_merge=0.045),
    # sort of the wa*wb expansion + segmented reduce + mask alignment
    "heap": dict(base=25.0, per_sort=0.05, per_mask=1.0),
    "heapdot": dict(base=25.0, per_sort=0.05, per_mask=1.0, per_inspect=0.01),
    # one vmapped sparse dot per mask nonzero (no sequential flop loop);
    # the large base is the host-side B^T transpose+pad paid every call
    "inner": dict(base=51.0, per_dot=0.0157),
}


def _log2(x: float) -> float:
    import math
    return math.log2(max(2.0, float(x)))


# Each model is LINEAR in its constants: cost = sum_k c[k] * feature_k.
# The feature functions below are that decomposition, shared between the
# hooks (dot with COST_CONSTANTS) and the calibration fit in
# ``repro.tuning.fit`` (least squares over the same features) — one
# functional form, two readers, no way to drift apart.


def _msa_features(*, n, wa, wb, wbt, pm):
    # dense (n+1)-wide state init/gather + wa sequential scatter rounds
    return {"base": 1.0, "per_n": float(n + 1), "per_flop": float(wa * wb),
            "per_mask": float(pm)}


def _hash_features(*, n, wa, wb, wbt, pm):
    # table build is a sequential probe loop over mask nonzeros; probing
    # inside the flop loop is a while-loop per batch of wb queries
    return {"base": 1.0, "per_flop": float(wa * wb), "per_mask": float(pm),
            "per_slot": float(_hash_size(max(1, pm)))}


def _mca_features(*, n, wa, wb, wbt, pm):
    # wa merge rounds of wb searchsorted lookups into the pm-long mask row
    return {"base": 1.0, "per_merge": wa * wb * _log2(pm + 2)}


def _heap_features(*, n, wa, wb, wbt, pm):
    # sort of the wa*wb expansion + segmented reduce + mask alignment
    e = wa * wb
    return {"base": 1.0, "per_sort": e * _log2(e + 2), "per_mask": float(pm)}


def _heapdot_features(*, n, wa, wb, wbt, pm):
    e = wa * wb
    return {"base": 1.0, "per_sort": e * _log2(e + 2), "per_mask": float(pm),
            "per_inspect": e * _log2(pm + 2)}


def _inner_features(*, n, wa, wb, wbt, pm):
    # one vmapped sparse dot per mask nonzero (no sequential flop loop);
    # the base is the host-side B^T transpose+pad paid every call
    return {"base": 1.0, "per_dot": pm * wa * _log2(wbt + 2)}


#: algorithm name -> feature decomposition of its cost model
COST_FEATURES = {
    "msa": _msa_features,
    "hash": _hash_features,
    "mca": _mca_features,
    "heap": _heap_features,
    "heapdot": _heapdot_features,
    "inner": _inner_features,
}


def _make_cost_hook(name):
    features = COST_FEATURES[name]

    def hook(*, n, wa, wb, wbt, pm):
        c = COST_CONSTANTS[name]
        f = features(n=n, wa=wa, wb=wb, wbt=wbt, pm=pm)
        return sum(c[k] * f[k] for k in f)

    hook.__name__ = f"{name}_cost"
    return hook


#: algorithm name -> cost hook; keys mirror masked_spgemm.ALGORITHMS
COST_HOOKS = {name: _make_cost_hook(name) for name in COST_FEATURES}

# named aliases, kept for direct callers
msa_cost = COST_HOOKS["msa"]
hash_cost = COST_HOOKS["hash"]
mca_cost = COST_HOOKS["mca"]
heap_cost = COST_HOOKS["heap"]
heapdot_cost = COST_HOOKS["heapdot"]
inner_cost = COST_HOOKS["inner"]

#: algorithms whose row kernels accept ``complement=True`` (paper Sec. 8.4:
#: hash/MCA/inner require an explicit mask)
SUPPORTS_COMPLEMENT = frozenset({"msa", "heap", "heapdot"})
