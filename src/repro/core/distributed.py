"""Distributed Masked SpGEMM under ``shard_map`` (beyond-paper scale-out).

The paper is a shared-memory study; its row-parallel decomposition extends
naturally across a mesh:

* ``row_parallel_masked_spgemm`` — 1D: rows of A and M are sharded over the
  mesh's data axes; B is replicated.  Zero communication in the numeric
  phase (the paper's OpenMP loop, across pods).  This is the right regime
  for nnz(B) small vs aggregate memory — typical graph masks.

* ``ring_sparse_masked_spgemm`` — 1.5D sparse ring-SUMMA on BCSR operands
  when B is too large to replicate: A/M row-block-panels are sharded, B's
  *occupied* BCSR K-slabs rotate around the ring via ``jax.lax.ppermute``
  (each panel = ``(nnzb_slab, bs, bs)`` value+pattern blocks, padded to the
  ring-wide max so every rotation has one static shape).  Each stage
  replays a host-built K-slab worklist on the block executors (Pallas on
  TPU, chunked XLA elsewhere) — no dense ``(k, n)`` or ``(m, n)`` array
  exists anywhere on this path, which is what makes it usable at scales
  where ``ring_masked_matmul``'s dense operands would not fit.

* ``ring_masked_matmul`` — the dense 1.5D ring (tile-granular skipping),
  kept for dense-operand workloads and as the bench baseline the sparse
  ring is measured against.

``distributed_masked_spgemm`` is the driver-level entry point: it takes
host CSR operands plus a mesh and elects row-parallel vs the sparse ring
via the planner's distributed cost model (replication bytes vs ring volume
vs per-stage tile cost), mirroring ``masked_spgemm(algorithm="auto")`` on
one device.  The model's ``DIST_COST`` constants are per-backend
calibration data: ``python -m repro.tune --only dist`` refits them from
measured ring/row probes (forced host devices stand in for a real
network, so refit on the actual mesh before trusting auto at scale).

All device programs are pure ``shard_map``: they lower and compile for any
mesh (including the 512-chip production mesh) and are exercised by the
dry-run and the forced-multi-device CPU harness in ``tests/``.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import caches
from repro import obs
from repro.compat import shard_map

from .formats import CSR, PaddedCSR, bcsr_row_panels, padded_from_csr
from .masked_spgemm import MaskedSpGEMMResult, _row_fn
from .semiring import Semiring, PLUS_TIMES


# ---------------------------------------------------------------------------
# 1D row-parallel: the paper's decomposition across the mesh
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=64)
def _row_parallel_program(mesh: Mesh, axes: Tuple[str, ...], algorithm: str,
                          n: int, kdim: int, semiring: Semiring,
                          complement: bool, n_inspect: Optional[int]):
    """Compiled row-parallel program, cached so repeated calls (the
    serving loop, timed bench iterations) never re-trace or re-compile —
    the jit cache keys the remaining variation (operand shapes/widths)."""
    row = _row_fn(algorithm, n, kdim, semiring, complement, n_inspect)
    spec = P(axes)

    def local(mc, ac, av, al, Bc, Bv, Bl):
        f = jax.vmap(lambda mcr, acr, avr, alr:
                     row(mcr, acr, avr, alr, Bc, Bv, Bl))
        return f(mc, ac, av, al)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(spec, spec, spec, spec, P(), P(), P()),
        out_specs=(spec, spec),
    ))


def row_parallel_masked_spgemm(A: PaddedCSR, B: PaddedCSR, M: PaddedCSR,
                               mesh: Mesh, *, algorithm: str = "msa",
                               semiring: Semiring = PLUS_TIMES,
                               complement: bool = False,
                               n_inspect: Optional[int] = None,
                               axes: Sequence[str] = ("data",)):
    """C = M (.) (A B), rows of A/M sharded over ``axes``, B replicated.

    Returns (vals, present) mask-aligned, sharded like the mask rows.
    For ``algorithm="inner"`` pass B already transposed (PaddedCSR of B^T,
    the same contract as the single-device driver); output shape comes
    from the mask, so a transposed B never skews it.
    """
    n = M.shape[1]
    kdim = A.shape[1]
    shard = _row_parallel_program(mesh, tuple(axes), algorithm, n, kdim,
                                  semiring, complement, n_inspect)
    return shard(M.cols, A.cols, A.vals, A.lens, B.cols, B.vals, B.lens)


# ---------------------------------------------------------------------------
# 1.5D ring-SUMMA masked matmul (tile-granular, dense panels)
# ---------------------------------------------------------------------------


def ring_masked_matmul(a, b, mask, mesh: Mesh, *, axis: str = "data",
                       block: int = 128, precision=None):
    """C = mask (.) (A B) with A row-sharded and B K-sharded over ``axis``.

    a: (m, k) sharded P(axis, None); b: (k, n) sharded P(axis, None);
    mask: (m, n) {0,1} sharded P(axis, None).

    Tile-granular skipping, per stage: each shard computes its mask's
    block-level occupancy once (any nonzero per ``block x block`` tile);
    inside every ring stage the local product is issued per output column
    panel, and panels whose tiles are all disallowed skip their MXU work
    through ``lax.cond`` (the dot is never executed, every stage).  After
    the loop, disallowed output tiles are zeroed at block granularity and
    the element mask applied once.  The ppermute for stage s+1 is issued
    *before* stage s's local compute so XLA's async collectives overlap
    communication with the MXU work; the last stage is peeled so the HLO
    contains exactly nsteps-1 collective-permutes of one B panel each
    (the nsteps-th rotation would only restore the starting layout).

    Returns (m, n) sharded P(axis, None).
    """
    shard = _ring_dense_program(mesh, axis, block, precision)
    return shard(a, b, mask)


@functools.lru_cache(maxsize=64)
def _ring_dense_program(mesh: Mesh, axis: str, block: int, precision):
    """Compiled dense-ring program (cached: see _row_parallel_program)."""
    nsteps = mesh.shape[axis]

    def local(a_blk, b_blk, m_blk):
        # a_blk: (m/p, k); b_blk: (k/p, n); m_blk: (m/p, n)
        idx = jax.lax.axis_index(axis)
        k_per, n = b_blk.shape
        m_loc = a_blk.shape[0]
        tm, tn = min(block, m_loc), min(block, n)
        pad_m, pad_n = -m_loc % tm, -n % tn
        mp, np_ = m_loc + pad_m, n + pad_n
        tiles_m, tiles_n = mp // tm, np_ // tn

        # block-level occupancy of this shard's mask rows (computed once);
        # padded columns/rows are zero -> their tiles are never scheduled
        m_pad = jnp.pad(m_blk != 0, ((0, pad_m), (0, pad_n)))
        occ = m_pad.reshape(tiles_m, tm, tiles_n, tn).any(axis=(1, 3))
        col_needed = occ.any(axis=0)            # (tiles_n,)
        a_pad = jnp.pad(a_blk, ((0, pad_m), (0, 0)))
        b_pad = jnp.pad(b_blk, ((0, 0), (0, pad_n)))

        def compute(s, acc, panel):
            src = (idx - s) % nsteps          # whose panel we now hold
            a_slice = jax.lax.dynamic_slice_in_dim(a_pad, src * k_per, k_per,
                                                   axis=1)

            def col_panel(tj, acc):
                panel_j = jax.lax.dynamic_slice_in_dim(panel, tj * tn, tn,
                                                       axis=1)
                contrib = jax.lax.cond(
                    col_needed[tj],
                    lambda: jnp.dot(a_slice, panel_j,
                                    preferred_element_type=jnp.float32,
                                    precision=precision),
                    lambda: jnp.zeros((mp, tn), jnp.float32))
                cur = jax.lax.dynamic_slice_in_dim(acc, tj * tn, tn, axis=1)
                return jax.lax.dynamic_update_slice_in_dim(
                    acc, cur + contrib, tj * tn, axis=1)

            return jax.lax.fori_loop(0, tiles_n, col_panel, acc)

        def stage(s, carry):
            acc, panel = carry
            # prefetch next panel first -> XLA overlaps with the matmul
            nxt = jax.lax.ppermute(
                panel, axis,
                [(i, (i + 1) % nsteps) for i in range(nsteps)])
            acc = compute(s, acc, panel)
            return acc, nxt

        acc = jnp.zeros((mp, np_), jnp.float32)
        # last stage peeled: its prefetched panel would be dropped, so only
        # nsteps-1 rotations are transmitted
        acc, panel = jax.lax.fori_loop(0, nsteps - 1, stage, (acc, b_pad))
        acc = compute(nsteps - 1, acc, panel)
        # zero disallowed tiles at block granularity, then the element mask
        occ_elem = jnp.repeat(jnp.repeat(occ, tm, axis=0), tn, axis=1)
        acc = jnp.where(occ_elem, acc, 0.0)[:m_loc, :n]
        return jnp.where(m_blk != 0, acc, 0.0).astype(a_blk.dtype)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None)),
        out_specs=P(axis, None),
    ))


# ---------------------------------------------------------------------------
# 1.5D sparse ring-SUMMA on BCSR panels (densify-free distributed tile route)
# ---------------------------------------------------------------------------


def _ring_stage_xla(out, a_blocks, b_blocks, rank, pa, pb, flags, *, bs):
    """One ring stage on the chunked-XLA executor: gather, batched matmul,
    segment-add into the running panel accumulator.  Chunked like
    ``ops._block_spgemm_xla`` so peak memory stays O(chunk * bs^2)."""
    from repro.kernels.masked_matmul.ops import _XLA_CHUNK_ELEMS
    ws = int(rank.shape[0])
    chunk = max(1, _XLA_CHUNK_ELEMS // (bs * bs))
    for s0 in range(0, ws, chunk):
        e = min(ws, s0 + chunk)
        real = ((flags[s0:e] >> 1) & 1).astype(jnp.float32)
        prods = jnp.einsum("wij,wjk->wik",
                           a_blocks[pa[s0:e]].astype(jnp.float32),
                           b_blocks[pb[s0:e]].astype(jnp.float32),
                           preferred_element_type=jnp.float32)
        out = out.at[rank[s0:e]].add(prods * real[:, None, None])
    return out


def _ring_stage_pallas(out, a_blocks, b_blocks, rank, pa, pb, flags, *,
                       bs, interpret):
    """One ring stage on the Pallas executor: the worklist covers every
    output rank (zero-fill + padding-rank entries from
    ``build_ring_schedules``), so the kernel's output is fully defined and
    adds into the running accumulator."""
    from repro.kernels.masked_matmul.kernel import block_spgemm_kernel
    stage = block_spgemm_kernel(a_blocks, b_blocks, rank, pa, pb, flags,
                                out.shape[0], bs=bs, interpret=interpret)
    return out + stage


@functools.lru_cache(maxsize=64)
def _ring_sparse_program(mesh: Mesh, axis: str, p: int, bs: int,
                         wm_blocks: int, pm: int, rows_loc: int,
                         backend: str, interpret: Optional[bool]):
    """Compiled sparse-ring program (cached: see _row_parallel_program).
    Panel/worklist lengths vary per problem and are handled by the jit
    cache; only the quantities baked into the trace are keys here.

    The mask-aligned extraction runs inside the shard program: every mask
    element lives in exactly one row-panel, so each device scatters its own
    elements into its ``(rows_loc, pm)`` output shard — no cross-device
    gather of block panels ever happens.
    """
    if backend == "xla":
        apply_stage = functools.partial(_ring_stage_xla, bs=bs)
    else:
        apply_stage = functools.partial(_ring_stage_pallas, bs=bs,
                                        interpret=interpret)

    def local(av, ap, bv, bp, sc, loc, roff, coff, rowl, slot):
        av, ap, bv, bp, sc = av[0], ap[0], bv[0], bp[0], sc[0]
        loc, roff, coff, rowl, slot = (x[0] for x in
                                       (loc, roff, coff, rowl, slot))
        panel = jnp.stack([bv, bp])        # values+pattern rotate together

        def compute(s, vals, cnts, pan):
            row = jax.lax.dynamic_index_in_dim(sc, s, 0, keepdims=False)
            rank, pa, pb, flags = row[0], row[1], row[2], row[3]
            vals = apply_stage(vals, av, pan[0], rank, pa, pb, flags)
            cnts = apply_stage(cnts, ap, pan[1], rank, pa, pb, flags)
            return vals, cnts

        def stage(s, carry):
            vals, cnts, pan = carry
            # prefetch the next panel first -> XLA overlaps the collective
            # with this stage's block products
            nxt = jax.lax.ppermute(
                pan, axis, [(i, (i + 1) % p) for i in range(p)])
            vals, cnts = compute(s, vals, cnts, pan)
            return vals, cnts, nxt

        vals = jnp.zeros((wm_blocks, bs, bs), jnp.float32)
        cnts = jnp.zeros((wm_blocks, bs, bs), jnp.float32)
        # the last stage is peeled: its prefetched panel would be dropped,
        # so only p-1 panel rotations are ever transmitted
        vals, cnts, panel = jax.lax.fori_loop(0, p - 1, stage,
                                              (vals, cnts, panel))
        vals, cnts = compute(p - 1, vals, cnts, panel)
        # panel-local extraction (padding entries carry rowl == rows_loc,
        # dropped by the out-of-bounds scatter mode)
        out_v = jnp.zeros((rows_loc, pm), jnp.float32)
        out_p = jnp.zeros((rows_loc, pm), bool)
        out_v = out_v.at[rowl, slot].set(vals[loc, roff, coff], mode="drop")
        out_p = out_p.at[rowl, slot].set(cnts[loc, roff, coff] > 0,
                                         mode="drop")
        # row-sharded over the axis: global result is (p * rows_loc, pm)
        return out_v, out_p

    spec = P(axis)
    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(spec,) * 10,
        out_specs=(spec, spec)))


def _panel_scatter(x: CSR, bs: int, p: int) -> Tuple[np.ndarray, ...]:
    """Per-entry scatter coordinates into a (p, W, bs, bs) stacked panel
    array plus the panel block structure.

    Returns ``(indptr_pad, indices, panel, local, r, c, w)``: entry e of
    ``x`` lands in ``stacked[panel[e], local[e], r[e], c[e]]``; ``w`` is
    the max panel nnzb (the ring-wide pad).  Pure structure — values are
    scattered per call.
    """
    m, n = x.shape
    nb = -(-n // bs)
    mb = -(-m // bs)
    mb_pad = -(-mb // p) * p
    rows = np.repeat(np.arange(m, dtype=np.int64), np.diff(x.indptr))
    key = (rows // bs) * nb + x.indices // bs
    uniq, inv = np.unique(key, return_inverse=True)
    ubr, ubc = uniq // nb, uniq % nb
    indptr = np.zeros(mb_pad + 1, dtype=np.int64)
    np.add.at(indptr, ubr + 1, 1)
    indptr = np.cumsum(indptr)
    rows_per = mb_pad // p
    panel_of_block = ubr // rows_per
    local_of_block = np.arange(len(uniq)) - indptr[panel_of_block * rows_per]
    w = max(1, int(np.bincount(panel_of_block, minlength=p).max(initial=0)))
    return (indptr, ubc.astype(np.int64), panel_of_block[inv],
            local_of_block[inv], rows % bs, x.indices % bs, w)


def _struct_panels(indptr: np.ndarray, indices: np.ndarray, p: int, bs: int,
                   ncols: int):
    """Structure-only BCSR row panels (blocks empty; schedule construction
    never reads them)."""
    from .formats import BCSR
    full = BCSR(indptr, indices, np.zeros((0, bs, bs), np.float32),
                ((len(indptr) - 1) * bs, ncols), bs)
    return bcsr_row_panels(full, p)


#: host-prep cache for the sparse ring, keyed on operand *structure*
#: (CRC signatures) + block size + ring size: schedules, scatter
#: coordinates, and extraction addressing are all structure-pure, so
#: repeated structures (the serving case; every plan-cache hit) skip
#: straight to the value scatter + device program.  Capacity:
#: $REPRO_RING_PREP_CAP or ``repro.caches.set_capacity("ring-prep", n)``.
_ring_prep_cache = caches.LRUCache("ring-prep", 32,
                                   env_var="REPRO_RING_PREP_CAP")


def _ring_prep(A: CSR, B: CSR, M: CSR, bs: int, p: int,
               wm: Optional[int]) -> dict:
    from repro.core.planner import structure_signature
    from repro.kernels.masked_matmul.ops import build_ring_schedules

    key = (structure_signature(A), structure_signature(B),
           structure_signature(M), bs, p, wm)
    # host prep is pure structure arithmetic (panelization, scatter maps,
    # ring schedules) — it embeds no cost-model decision, so a
    # calibration change cannot stale it; deliberately token-free
    hit = _ring_prep_cache.get(key)  # lint: plan-key-ok(structure-pure prep)
    if hit is not None:
        return hit

    m, k = A.shape
    n = B.shape[1]
    a_ptr, a_idx, a_pan, a_loc, a_r, a_c, wa = _panel_scatter(A, bs, p)
    b_ptr, b_idx, b_pan, b_loc, b_r, b_c, wb = _panel_scatter(B, bs, p)
    m_ptr, m_idx, m_pan, m_loc, m_r, m_c, wmb = _panel_scatter(M, bs, p)

    A_panels = _struct_panels(a_ptr, a_idx, p, bs, k)
    B_slabs = _struct_panels(b_ptr, b_idx, p, bs, n)
    M_panels = _struct_panels(m_ptr, m_idx, p, bs, n)
    sched = build_ring_schedules(A_panels, B_slabs, M_panels, out_pad=wmb)

    # stored-entry pattern panels are structure-constant: build once
    a_pat = np.zeros((p, wa, bs, bs), np.float32)
    a_pat[a_pan, a_loc, a_r, a_c] = 1.0
    b_pat = np.zeros((p, wb, bs, bs), np.float32)
    b_pat[b_pan, b_loc, b_r, b_c] = 1.0

    # extraction: group mask elements by owning panel; each device
    # scatters its own elements into its (rows_loc, pm) output shard.
    # Padding entries point at row rows_loc -> dropped by scatter mode.
    mr = np.repeat(np.arange(m, dtype=np.int64), np.diff(M.indptr))
    slots = np.arange(M.nnz, dtype=np.int64) - M.indptr[mr]
    M_p = padded_from_csr(M, wm)
    rows_per = (len(m_ptr) - 1) // p
    rows_loc = rows_per * bs
    counts = np.bincount(m_pan, minlength=p)
    max_e = max(1, int(counts.max(initial=0)))
    order = np.argsort(m_pan, kind="stable")
    j = np.arange(M.nnz) - np.concatenate(
        [[0], np.cumsum(counts)[:-1]])[m_pan[order]]
    pan_o = m_pan[order]

    def panelized(values, fill):
        out = np.full((p, max_e), fill, np.int32)
        out[pan_o, j] = values[order]
        return out

    prep = dict(
        a_scatter=(a_pan, a_loc, a_r, a_c, wa), a_pat=a_pat,
        b_scatter=(b_pan, b_loc, b_r, b_c, wb), b_pat=b_pat,
        sched=sched, wm_blocks=wmb, rows_loc=rows_loc,
        ex_loc=panelized(m_loc, 0),
        ex_roff=panelized(mr % bs, 0),
        ex_coff=panelized(m_c, 0),
        ex_rowl=panelized(mr - m_pan * rows_loc, rows_loc),
        ex_slot=panelized(slots, 0),
        mask_cols=M_p.cols, pm=M_p.width)
    _ring_prep_cache.put(key, prep)  # lint: plan-key-ok(structure-pure prep)
    return prep


def clear_ring_prep_cache() -> None:
    _ring_prep_cache.clear()


def ring_prep_cache_info() -> dict:
    return _ring_prep_cache.info()


def ring_sparse_masked_spgemm(A: CSR, B: CSR, M: CSR, mesh: Mesh, *,
                              axis: str = "data",
                              block_size: Optional[int] = None,
                              backend: Optional[str] = None,
                              interpret: Optional[bool] = None,
                              wm: Optional[int] = None) -> MaskedSpGEMMResult:
    """C = M (.) (A B) on a sparse BCSR ring: A/M row-panels sharded over
    ``axis``, B's occupied K-slabs rotating via ``ppermute``.

    Densify-free end to end: CSR operands scatter into occupied blocks,
    every device holds only its row-panel of A/M and one rotating B slab
    (values + stored-entry pattern, padded to the ring max so ``ppermute``
    sees one static shape), and each stage replays a host-built K-slab
    worklist on the block executor.  ``present`` comes from a structural
    counting replay sharing the same schedules, so results are bitwise the
    single-device ``masked_spgemm`` semantics, including cancellation and
    explicitly stored zeros.

    Host prep (schedules, scatter coordinates, extraction addressing) is
    pure structure and cached by structural signature — repeated
    structures, the serving case, pay only the value scatter and the
    compiled device program.

    Only ``plus_times`` with an explicit mask is supported (the executors
    accumulate with a dense dot) — ``distributed_masked_spgemm`` routes
    unsupported products to the row-parallel path.
    """
    from repro.kernels.masked_matmul.ops import on_tpu

    m, k = A.shape
    k2, n = B.shape
    assert k == k2, (A.shape, B.shape)
    assert M.shape == (m, n), (M.shape, (m, n))
    p = int(mesh.shape[axis])

    if M.nnz == 0:
        M_p = padded_from_csr(M, wm)
        z = jnp.zeros((m, M_p.width), jnp.float32)
        return MaskedSpGEMMResult(z, jnp.zeros((m, M_p.width), bool),
                                  M_p.cols, (m, n))
    if block_size is None:
        from .planner import ring_block_candidates
        block_size = ring_block_candidates(m, k, n)[0]
    bs = block_size
    if backend is None:
        backend = "pallas" if (interpret or on_tpu()) else "xla"
    it = None
    if backend == "pallas":
        it = (not on_tpu()) if interpret is None else interpret
    elif backend != "xla":
        raise ValueError(f"unknown backend {backend!r}")

    prep = _ring_prep(A, B, M, bs, p, wm)
    a_pan, a_loc, a_r, a_c, wa = prep["a_scatter"]
    b_pan, b_loc, b_r, b_c, wb = prep["b_scatter"]
    wm_blocks = prep["wm_blocks"]
    a_vals = np.zeros((p, wa, bs, bs), np.float32)
    a_vals[a_pan, a_loc, a_r, a_c] = A.data
    b_vals = np.zeros((p, wb, bs, bs), np.float32)
    b_vals[b_pan, b_loc, b_r, b_c] = B.data

    run = _ring_sparse_program(mesh, axis, p, bs, wm_blocks, prep["pm"],
                               prep["rows_loc"], backend, it)
    vals, present = run(a_vals, prep["a_pat"], b_vals, prep["b_pat"],
                        prep["sched"], prep["ex_loc"], prep["ex_roff"],
                        prep["ex_coff"], prep["ex_rowl"], prep["ex_slot"])
    return MaskedSpGEMMResult(vals[:m], present[:m], prep["mask_cols"],
                              (m, n))


# ---------------------------------------------------------------------------
# Driver-level entry point: route election across the mesh
# ---------------------------------------------------------------------------


def distributed_masked_spgemm(A: CSR, B: CSR, M: CSR, mesh: Mesh, *,
                              algorithm: str = "auto", axis: str = "data",
                              semiring: Semiring = PLUS_TIMES,
                              complement: bool = False,
                              block_size: Optional[int] = None,
                              row_algorithm: Optional[str] = None,
                              backend: Optional[str] = None,
                              interpret: Optional[bool] = None
                              ) -> MaskedSpGEMMResult:
    """C = M (.) (A B) across ``mesh``: the distributed counterpart of
    ``masked_spgemm``.

    ``algorithm``:
      * ``"auto"`` — extend the planner's decision to the mesh: the
        distributed cost model weighs replicating B (row-parallel, zero
        numeric-phase communication) against rotating B's occupied BCSR
        K-slabs around the ring (sparse ring-SUMMA, memory O(nnzb/p) per
        device), plus each route's compute cost.
      * ``"row"``  — force the 1D row-parallel path (B replicated).
      * ``"ring"`` — force the sparse BCSR ring (plus_times, explicit mask).

    Host CSR operands only; returns a mask-aligned ``MaskedSpGEMMResult``
    identical (bitwise, under exact values) to single-device
    ``masked_spgemm`` on the same operands.
    """
    if not isinstance(A, CSR) or not isinstance(B, CSR) \
            or not isinstance(M, CSR):
        raise NotImplementedError(
            "distributed_masked_spgemm needs host CSR operands")
    if complement:
        raise NotImplementedError(
            "complemented masks are not mask-bounded; shard "
            "row_parallel_masked_spgemm directly for that regime")
    if algorithm not in ("auto", "row", "ring"):
        raise ValueError(f"unknown distributed algorithm {algorithm!r}")

    from repro.kernels.masked_matmul.ops import tile_path_supported
    ring_ok = tile_path_supported(semiring.name, complement)
    p = int(mesh.shape[axis])

    if algorithm == "ring" and not ring_ok:
        raise NotImplementedError(
            "sparse ring requires plus_times and an explicit mask")
    if algorithm == "auto":
        from .planner import plan_distributed
        dplan = plan_distributed(A, B, M, p, complement=complement,
                                 semiring=semiring)
        algorithm = dplan.route
        if block_size is None and dplan.tile_block:
            block_size = dplan.tile_block
        if row_algorithm is None:
            row_algorithm = dplan.row_algorithm

    if algorithm == "ring":
        with obs.span("spgemm.dist", route="ring", p=p,
                      block=block_size or 0):
            return ring_sparse_masked_spgemm(
                A, B, M, mesh, axis=axis, block_size=block_size,
                backend=backend, interpret=interpret)

    # row-parallel: replicate B, shard A/M rows, run the row kernels
    if row_algorithm is None:
        from .planner import decide, collect_stats
        stats = collect_stats(A, B, M, complement=complement,
                              semiring=semiring)
        dec = decide(stats, allow_tile=False)
        row_algorithm = dec.algorithm
    m, n = M.shape
    with obs.span("spgemm.dist", route="row", p=p,
                  algorithm=row_algorithm):
        with obs.span("spgemm.host_prep", algorithm=row_algorithm):
            if row_algorithm == "inner":
                B_p = padded_from_csr(B.transpose())
            else:
                B_p = padded_from_csr(B)
            A_p = padded_from_csr(A)
            M_p = padded_from_csr(M)
            A_p, M_p = pad_rows_to(p, A_p, M_p)
        vals, present = row_parallel_masked_spgemm(
            A_p, B_p, M_p, mesh, algorithm=row_algorithm,
            semiring=semiring, complement=complement, axes=(axis,))
    return MaskedSpGEMMResult(vals[:m], present[:m], M_p.cols[:m], (m, n))


# ---------------------------------------------------------------------------
# helpers for building sharded problems
# ---------------------------------------------------------------------------


# the compiled shard_map programs are lru_cache-bounded; registering them
# lets ``repro.caches.clear_all()`` drop compiled state in one sweep
caches.register_lru("dist-row-program", _row_parallel_program)
caches.register_lru("dist-dense-ring-program", _ring_dense_program)
caches.register_lru("dist-sparse-ring-program", _ring_sparse_program)


def pad_rows_to(mesh_axis_size: int, *mats: PaddedCSR) -> Tuple[PaddedCSR, ...]:
    """Pad row count to a multiple of the mesh axis so shards are equal."""
    out = []
    for p in mats:
        m, n = p.shape
        target = -(-m // mesh_axis_size) * mesh_axis_size
        if target == m:
            out.append(p)
            continue
        pad = target - m
        cols = jnp.concatenate(
            [p.cols, jnp.full((pad, p.width), n, jnp.int32)])
        vals = jnp.concatenate([p.vals, jnp.zeros((pad, p.width),
                                                  p.vals.dtype)])
        lens = jnp.concatenate([p.lens, jnp.zeros((pad,), jnp.int32)])
        out.append(PaddedCSR(cols, vals, lens, (target, n)))
    return tuple(out)
