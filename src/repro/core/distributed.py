"""Distributed Masked SpGEMM under ``shard_map`` (beyond-paper scale-out).

The paper is a shared-memory study; its row-parallel decomposition extends
naturally across a mesh:

* ``row_parallel_masked_spgemm`` — 1D: rows of A and M are sharded over the
  mesh's data axes; B is replicated.  Zero communication in the numeric
  phase (the paper's OpenMP loop, across pods).  This is the right regime
  for nnz(B) small vs aggregate memory — typical graph masks.

* ``ring_masked_matmul`` — 1.5D ring-SUMMA for tile-granular masked products
  when B is too large to replicate: A is row-sharded, B is K-sharded; B
  panels rotate around the ring via ``jax.lax.ppermute`` while each stage
  accumulates the partial masked product for the tiles its mask admits.
  The ppermute for stage s+1 is issued *before* stage s's local compute so
  XLA's async collectives overlap communication with the MXU work.

Both are pure ``shard_map`` programs: they lower and compile for any mesh
(including the 512-chip production mesh) and are exercised by the dry-run.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from .formats import CSR, PaddedCSR, padded_from_csr
from .masked_spgemm import _row_fn
from .semiring import Semiring, PLUS_TIMES


# ---------------------------------------------------------------------------
# 1D row-parallel: the paper's decomposition across the mesh
# ---------------------------------------------------------------------------


def row_parallel_masked_spgemm(A: PaddedCSR, B: PaddedCSR, M: PaddedCSR,
                               mesh: Mesh, *, algorithm: str = "msa",
                               semiring: Semiring = PLUS_TIMES,
                               complement: bool = False,
                               n_inspect: Optional[int] = None,
                               axes: Sequence[str] = ("data",)):
    """C = M (.) (A B), rows of A/M sharded over ``axes``, B replicated.

    Returns (vals, present) mask-aligned, sharded like the mask rows.
    """
    m, n = A.shape[0], B.shape[1]
    kdim = A.shape[1]
    row = _row_fn(algorithm, n, kdim, semiring, complement, n_inspect)
    spec = P(tuple(axes))

    def local(mc, ac, av, al, Bc, Bv, Bl):
        f = jax.vmap(lambda mcr, acr, avr, alr:
                     row(mcr, acr, avr, alr, Bc, Bv, Bl))
        return f(mc, ac, av, al)

    shard = shard_map(
        local, mesh=mesh,
        in_specs=(spec, spec, spec, spec, P(), P(), P()),
        out_specs=(spec, spec),
    )
    return shard(M.cols, A.cols, A.vals, A.lens, B.cols, B.vals, B.lens)


# ---------------------------------------------------------------------------
# 1.5D ring-SUMMA masked matmul (tile-granular, dense panels)
# ---------------------------------------------------------------------------


def ring_masked_matmul(a, b, mask, mesh: Mesh, *, axis: str = "data",
                       block: int = 128, precision=None):
    """C = mask (.) (A B) with A row-sharded and B K-sharded over ``axis``.

    a: (m, k) sharded P(axis, None); b: (k, n) sharded P(axis, None);
    mask: (m, n) {0,1} sharded P(axis, None).

    Tile-granular skipping, per stage: each shard computes its mask's
    block-level occupancy once (any nonzero per ``block x block`` tile);
    inside every ring stage the local product is issued per output column
    panel, and panels whose tiles are all disallowed skip their MXU work
    through ``lax.cond`` (the dot is never executed, every stage).  After
    the loop, disallowed output tiles are zeroed at block granularity and
    the element mask applied once.  The ppermute for stage s+1 is issued
    *before* stage s's local compute so XLA's async collectives overlap
    communication with the MXU work; the HLO contains exactly nsteps
    collective-permutes of one B panel each.

    Returns (m, n) sharded P(axis, None).
    """
    nsteps = mesh.shape[axis]

    def local(a_blk, b_blk, m_blk):
        # a_blk: (m/p, k); b_blk: (k/p, n); m_blk: (m/p, n)
        idx = jax.lax.axis_index(axis)
        k_per, n = b_blk.shape
        m_loc = a_blk.shape[0]
        tm, tn = min(block, m_loc), min(block, n)
        pad_m, pad_n = -m_loc % tm, -n % tn
        mp, np_ = m_loc + pad_m, n + pad_n
        tiles_m, tiles_n = mp // tm, np_ // tn

        # block-level occupancy of this shard's mask rows (computed once);
        # padded columns/rows are zero -> their tiles are never scheduled
        m_pad = jnp.pad(m_blk != 0, ((0, pad_m), (0, pad_n)))
        occ = m_pad.reshape(tiles_m, tm, tiles_n, tn).any(axis=(1, 3))
        col_needed = occ.any(axis=0)            # (tiles_n,)
        a_pad = jnp.pad(a_blk, ((0, pad_m), (0, 0)))
        b_pad = jnp.pad(b_blk, ((0, 0), (0, pad_n)))

        def stage(s, carry):
            acc, panel = carry
            # prefetch next panel first -> XLA overlaps with the matmul
            nxt = jax.lax.ppermute(
                panel, axis,
                [(i, (i + 1) % nsteps) for i in range(nsteps)])
            src = (idx - s) % nsteps          # whose panel we now hold
            a_slice = jax.lax.dynamic_slice_in_dim(a_pad, src * k_per, k_per,
                                                   axis=1)

            def col_panel(tj, acc):
                panel_j = jax.lax.dynamic_slice_in_dim(panel, tj * tn, tn,
                                                       axis=1)
                contrib = jax.lax.cond(
                    col_needed[tj],
                    lambda: jnp.dot(a_slice, panel_j,
                                    preferred_element_type=jnp.float32,
                                    precision=precision),
                    lambda: jnp.zeros((mp, tn), jnp.float32))
                cur = jax.lax.dynamic_slice_in_dim(acc, tj * tn, tn, axis=1)
                return jax.lax.dynamic_update_slice_in_dim(
                    acc, cur + contrib, tj * tn, axis=1)

            acc = jax.lax.fori_loop(0, tiles_n, col_panel, acc)
            return acc, nxt

        acc = jnp.zeros((mp, np_), jnp.float32)
        acc, _ = jax.lax.fori_loop(0, nsteps, stage, (acc, b_pad))
        # zero disallowed tiles at block granularity, then the element mask
        occ_elem = jnp.repeat(jnp.repeat(occ, tm, axis=0), tn, axis=1)
        acc = jnp.where(occ_elem, acc, 0.0)[:m_loc, :n]
        return jnp.where(m_blk != 0, acc, 0.0).astype(a_blk.dtype)

    shard = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(axis, None)),
        out_specs=P(axis, None),
    )
    return shard(a, b, mask)


# ---------------------------------------------------------------------------
# helpers for building sharded problems
# ---------------------------------------------------------------------------


def pad_rows_to(mesh_axis_size: int, *mats: PaddedCSR) -> Tuple[PaddedCSR, ...]:
    """Pad row count to a multiple of the mesh axis so shards are equal."""
    out = []
    for p in mats:
        m, n = p.shape
        target = -(-m // mesh_axis_size) * mesh_axis_size
        if target == m:
            out.append(p)
            continue
        pad = target - m
        cols = jnp.concatenate(
            [p.cols, jnp.full((pad, p.width), n, jnp.int32)])
        vals = jnp.concatenate([p.vals, jnp.zeros((pad, p.width),
                                                  p.vals.dtype)])
        lens = jnp.concatenate([p.lens, jnp.zeros((pad,), jnp.int32)])
        out.append(PaddedCSR(cols, vals, lens, (target, n)))
    return tuple(out)
