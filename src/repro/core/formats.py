"""Sparse matrix storage formats.

The paper (Milakovic et al., "Parallel Algorithms for Masked Sparse
Matrix-Matrix Products", 2021) uses element-level CSR/CSC on CPUs.  JAX/TPU
needs static shapes and tile-granular compute, so we provide three layers:

  * ``CSR`` / ``CSC``          -- host-side (numpy) element formats, used to
                                  build problems and as ground truth.
  * ``PaddedCSR`` (ELL-like)   -- device-friendly element format: every row is
                                  padded to a static width so the paper's
                                  row-parallel algorithms can be ``vmap``-ed.
  * ``BCSR`` / ``BCSC``        -- Block-CSR with MXU-aligned dense tiles; the
                                  TPU-native adaptation of the paper's
                                  algorithms operates on these.

All element formats keep column indices sorted within each row (the paper
assumes sorted inputs for MCA and Heap).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# --------------------------------------------------------------------------
# Host-side element CSR/CSC (numpy; problem setup + oracles)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class CSR:
    """Host-side CSR. indptr:(m+1,) indices:(nnz,) data:(nnz,) shape:(m,n)."""

    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[i], self.indptr[i + 1]
        return self.indices[s:e], self.data[s:e]

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype)
        for i in range(self.shape[0]):
            cols, vals = self.row(i)
            out[i, cols] = vals
        return out

    def transpose(self) -> "CSR":
        """CSR of the transpose (== CSC view of self)."""
        return csr_from_coo(
            self.indices,
            _expand_rows(self.indptr),
            self.data,
            (self.shape[1], self.shape[0]),
        )

    def sorted_rows(self) -> "CSR":
        rows = _expand_rows(self.indptr)
        order = np.lexsort((self.indices, rows))
        return CSR(self.indptr, self.indices[order], self.data[order],
                   self.shape)


def _expand_rows(indptr: np.ndarray) -> np.ndarray:
    """Row index of every nonzero, from indptr."""
    counts = np.diff(indptr)
    return np.repeat(np.arange(len(counts), dtype=np.int64), counts)


def csr_from_coo(rows, cols, vals, shape, sum_dups: bool = True) -> CSR:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if sum_dups and len(rows):
        key = rows * shape[1] + cols
        uniq, inv = np.unique(key, return_inverse=True)
        new_vals = np.zeros(len(uniq), dtype=vals.dtype)
        np.add.at(new_vals, inv, vals)
        rows, cols, vals = uniq // shape[1], uniq % shape[1], new_vals
    indptr = np.zeros(shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr, cols.astype(np.int64), vals, shape)


def csr_from_dense(a: np.ndarray) -> CSR:
    rows, cols = np.nonzero(a)
    return csr_from_coo(rows, cols, a[rows, cols], a.shape, sum_dups=False)


# --------------------------------------------------------------------------
# Edge-batch deltas: incremental CSR updates for dynamic graphs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CSRDelta:
    """A batch of edge mutations against one CSR operand.

    Records are applied in order (last write to a coordinate wins):
    ``delete[e]`` removes ``(rows[e], cols[e])`` if present (``vals[e]`` is
    ignored), otherwise the record upserts — overwriting an existing entry's
    value or inserting a new structural nonzero.
    """

    rows: np.ndarray      # (e,) int64
    cols: np.ndarray      # (e,) int64
    vals: np.ndarray      # (e,) value per record (ignored for deletes)
    delete: np.ndarray    # (e,) bool

    def __post_init__(self):
        object.__setattr__(self, "rows", np.asarray(self.rows, np.int64))
        object.__setattr__(self, "cols", np.asarray(self.cols, np.int64))
        object.__setattr__(self, "vals", np.asarray(self.vals))
        object.__setattr__(self, "delete", np.asarray(self.delete, bool))
        n = len(self.rows)
        if not (len(self.cols) == len(self.vals) == len(self.delete) == n):
            raise ValueError("CSRDelta fields must have equal length")

    @classmethod
    def upserts(cls, rows, cols, vals) -> "CSRDelta":
        rows = np.asarray(rows, np.int64)
        return cls(rows, cols, vals, np.zeros(len(rows), bool))

    @classmethod
    def deletes(cls, rows, cols) -> "CSRDelta":
        rows = np.asarray(rows, np.int64)
        return cls(rows, cols, np.zeros(len(rows), np.float32),
                   np.ones(len(rows), bool))

    @classmethod
    def concat(cls, deltas: Sequence["CSRDelta"]) -> "CSRDelta":
        return cls(np.concatenate([d.rows for d in deltas]),
                   np.concatenate([d.cols for d in deltas]),
                   np.concatenate([d.vals for d in deltas]),
                   np.concatenate([d.delete for d in deltas]))

    @property
    def changed_rows(self) -> np.ndarray:
        return np.unique(self.rows)

    def __len__(self) -> int:
        return len(self.rows)


@dataclasses.dataclass(frozen=True)
class DeltaResult:
    """Outcome of ``apply_csr_delta``: the post-delta CSR, which rows
    changed, whether the sparsity structure survived (values-only delta),
    and the incrementally-maintained delta signature."""

    csr: CSR
    changed_rows: np.ndarray   # sorted unique rows any record touched
    values_only: bool          # True iff no row's column set changed
    signature: tuple           # incremental_signature(csr), updated in O(Δ)


_ISIG_MASK = (1 << 64) - 1


def _row_sig(i: int, cols: np.ndarray) -> int:
    """Salted 64-bit hash of one row's column set (order-insensitive XOR
    combination across rows stays collision-resistant because the row index
    salts the CRC and a splitmix finalizer spreads it to 64 bits)."""
    crc = zlib.crc32(np.ascontiguousarray(cols, dtype=np.int64).tobytes(),
                     zlib.crc32(np.int64(i).tobytes()))
    z = (crc + 0x9E3779B97F4A7C15) & _ISIG_MASK
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _ISIG_MASK
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _ISIG_MASK
    return (z ^ (z >> 31)) & _ISIG_MASK


def incremental_signature(x: CSR) -> tuple:
    """Delta-maintainable structural identity: XOR of salted per-row hashes.

    Unlike ``planner.structure_signature`` (a whole-array CRC that any
    change recomputes from scratch), this form updates in O(changed rows):
    ``new = old ^ H(old changed rows) ^ H(new changed rows)``.  Equal
    signatures => equal sparsity structure (up to hash collision).
    """
    acc = 0
    for i in range(x.shape[0]):
        s, e = x.indptr[i], x.indptr[i + 1]
        acc ^= _row_sig(i, x.indices[s:e])
    return ("icsr", x.shape, x.nnz, acc)


def apply_csr_delta(a: CSR, delta: CSRDelta,
                    old_signature: Optional[tuple] = None) -> DeltaResult:
    """Apply an edge batch functionally: a new CSR sharing the unchanged
    rows' entries, the changed-row set, and the delta signature updated
    incrementally from ``old_signature`` (recomputed when absent).
    """
    m, n = a.shape
    if len(delta) and (delta.rows.min() < 0 or delta.rows.max() >= m
                       or delta.cols.min() < 0 or delta.cols.max() >= n):
        raise ValueError(f"delta coordinates outside shape {a.shape}")
    changed = delta.changed_rows
    if old_signature is not None and old_signature[:2] != ("icsr", a.shape):
        raise ValueError("old_signature does not match the operand")

    # per changed row: fold the record stream into the existing entries
    new_rows_cols: dict = {}
    new_rows_vals: dict = {}
    values_only = True
    for r in changed:
        cols0, vals0 = a.row(int(r))
        entries = dict(zip(cols0.tolist(), vals0.tolist()))
        sel = delta.rows == r
        for c, v, dele in zip(delta.cols[sel].tolist(),
                              delta.vals[sel].tolist(),
                              delta.delete[sel].tolist()):
            if dele:
                entries.pop(c, None)
            else:
                entries[c] = v
        cols1 = np.fromiter(sorted(entries), dtype=np.int64,
                            count=len(entries))
        new_rows_cols[int(r)] = cols1
        new_rows_vals[int(r)] = np.array([entries[c] for c in cols1],
                                         dtype=a.data.dtype)
        if values_only and not np.array_equal(cols0, cols1):
            values_only = False

    er = _expand_rows(a.indptr)
    keep = ~np.isin(er, changed)
    all_rows = np.concatenate(
        [er[keep]] + [np.full(len(new_rows_cols[int(r)]), r, np.int64)
                      for r in changed])
    all_cols = np.concatenate(
        [a.indices[keep]] + [new_rows_cols[int(r)] for r in changed])
    all_vals = np.concatenate(
        [a.data[keep]] + [new_rows_vals[int(r)] for r in changed])
    out = csr_from_coo(all_rows, all_cols, all_vals, a.shape, sum_dups=False)
    out.data = out.data.astype(a.data.dtype, copy=False)

    if old_signature is not None:
        acc = old_signature[3]
        for r in changed:
            acc ^= _row_sig(int(r), a.row(int(r))[0])
            acc ^= _row_sig(int(r), new_rows_cols[int(r)])
        sig = ("icsr", a.shape, out.nnz, acc)
    else:
        sig = incremental_signature(out)
    return DeltaResult(csr=out, changed_rows=changed,
                       values_only=values_only, signature=sig)


def bcsr_apply_delta(b: BCSR, new: CSR, changed_rows: np.ndarray) -> BCSR:
    """Update a BCSR mirror of ``new`` after a delta touching
    ``changed_rows``: only the affected block rows' occupancy and blocks
    are rebuilt; every other block row's device blocks are reused.
    """
    bs = b.block_size
    if (b.shape != new.shape):
        raise ValueError("BCSR/CSR shape mismatch")
    changed_rows = np.asarray(changed_rows, np.int64)
    if len(changed_rows) == 0:
        return b
    affected = set(np.unique(changed_rows // bs).tolist())
    mb = b.block_rows

    seg_indices = []   # per block row: occupied block-col indices
    seg_blocks = []    # per block row: host or device (nnzb_i, bs, bs)
    host_blocks = isinstance(b.blocks, np.ndarray)
    for br in range(mb):
        if br not in affected:
            s, e = int(b.indptr[br]), int(b.indptr[br + 1])
            seg_indices.append(b.indices[s:e])
            seg_blocks.append(b.blocks[s:e])
            continue
        lo, hi = br * bs, min((br + 1) * bs, new.shape[0])
        s, e = int(new.indptr[lo]), int(new.indptr[hi])
        rows = _expand_rows(new.indptr)[s:e] - lo
        cols = new.indices[s:e]
        vals = new.data[s:e]
        bcols = np.unique(cols // bs) if len(cols) else \
            np.zeros(0, np.int64)
        blocks = np.zeros((len(bcols), bs, bs),
                          dtype=np.asarray(vals).dtype)
        if len(cols):
            pos = np.searchsorted(bcols, cols // bs)
            blocks[pos, rows, cols % bs] = vals
        seg_indices.append(bcols)
        seg_blocks.append(blocks if host_blocks else jnp.asarray(blocks))

    counts = np.array([len(ix) for ix in seg_indices], np.int64)
    indptr = np.zeros(mb + 1, np.int64)
    indptr[1:] = np.cumsum(counts)
    indices = (np.concatenate(seg_indices) if counts.sum()
               else np.zeros(0, np.int64))
    xp = np if isinstance(b.blocks, np.ndarray) else jnp
    nonempty = [blk for blk in seg_blocks if blk.shape[0]]
    blocks = xp.concatenate(nonempty) if nonempty else b.blocks[:0]
    return BCSR(indptr, indices.astype(np.int64), blocks, b.shape, bs)


# --------------------------------------------------------------------------
# Device-side PaddedCSR (ELL): rows padded to a static width
# --------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PaddedCSR:
    """ELL-style padded rows: cols:(m, w) int32, vals:(m, w), lens:(m,) int32.

    Padding columns hold ``ncols`` (an out-of-range sentinel that sorts after
    every real column, which keeps merge-based algorithms branch-free).
    """

    cols: Array  # (m, w) int32, sorted ascending per row, pad = ncols
    vals: Array  # (m, w)
    lens: Array  # (m,) int32
    shape: Tuple[int, int]  # static

    def tree_flatten(self):
        return (self.cols, self.vals, self.lens), self.shape

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux)

    @property
    def width(self) -> int:
        return self.cols.shape[1]

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    def valid(self) -> Array:
        return self.cols < self.shape[1]

    def to_dense(self) -> Array:
        m, n = self.shape
        out = jnp.zeros((m, n + 1), dtype=self.vals.dtype)
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], self.cols.shape)
        out = out.at[rows, self.cols].add(jnp.where(self.valid(), self.vals, 0))
        return out[:, :n]


def padded_from_csr(a: CSR, width: Optional[int] = None, dtype=jnp.float32) -> PaddedCSR:
    a = a.sorted_rows()
    m, n = a.shape
    row_nnz = a.row_nnz()
    w = int(width if width is not None else max(1, int(row_nnz.max(initial=0))))
    cols = np.full((m, w), n, dtype=np.int32)
    vals = np.zeros((m, w), dtype=np.float32)
    # vectorized scatter: slot of entry e is its offset within its row;
    # entries beyond the requested width are dropped (same as the old
    # per-row loop, without the per-row Python cost)
    rows = _expand_rows(a.indptr)
    slots = np.arange(a.nnz, dtype=np.int64) - a.indptr[rows]
    keep = slots < w
    cols[rows[keep], slots[keep]] = a.indices[keep]
    vals[rows[keep], slots[keep]] = a.data[keep]
    return PaddedCSR(
        jnp.asarray(cols), jnp.asarray(vals, dtype=dtype),
        jnp.asarray(np.minimum(row_nnz, w), dtype=jnp.int32), (m, n)
    )


def padded_from_dense(a: np.ndarray, width: Optional[int] = None) -> PaddedCSR:
    return padded_from_csr(csr_from_dense(np.asarray(a)), width)


# --------------------------------------------------------------------------
# Block-CSR: the TPU-native format.  Tiles are dense (bs x bs) blocks.
# --------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BCSR:
    """Block-CSR: indptr:(Mb+1,), indices:(nnzb,), blocks:(nnzb, bs, bs).

    ``indptr``/``indices`` live on host (numpy) because they drive schedule
    construction (the symbolic phase); ``blocks`` is a device array.
    """

    indptr: np.ndarray  # host
    indices: np.ndarray  # host, sorted per block-row
    blocks: Array  # (nnzb, bs, bs) device
    shape: Tuple[int, int]  # element shape
    block_size: int

    def tree_flatten(self):
        return (self.blocks,), (self.indptr.tobytes(), self.indices.tobytes(),
                                len(self.indptr), len(self.indices),
                                self.shape, self.block_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        pb, ib, np_len, ni_len, shape, bs = aux
        indptr = np.frombuffer(pb, dtype=np.int64, count=np_len)
        indices = np.frombuffer(ib, dtype=np.int64, count=ni_len)
        return cls(indptr, indices, children[0], shape, bs)

    @property
    def nnzb(self) -> int:
        return int(self.indices.shape[0])

    @property
    def block_rows(self) -> int:
        return len(self.indptr) - 1

    @property
    def block_cols(self) -> int:
        return -(-self.shape[1] // self.block_size)

    def block_row(self, i: int) -> np.ndarray:
        return self.indices[self.indptr[i]: self.indptr[i + 1]]

    def to_dense(self) -> np.ndarray:
        bs = self.block_size
        mb, nb = self.block_rows, self.block_cols
        out = np.zeros((mb * bs, nb * bs), dtype=np.asarray(self.blocks).dtype)
        blocks = np.asarray(self.blocks)
        for i in range(mb):
            for p in range(self.indptr[i], self.indptr[i + 1]):
                j = self.indices[p]
                out[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = blocks[p]
        return out[: self.shape[0], : self.shape[1]]


def bcsr_from_dense(a: np.ndarray, block_size: int, prune_zero: bool = True) -> BCSR:
    a = np.asarray(a)
    m, n = a.shape
    bs = block_size
    mb, nb = -(-m // bs), -(-n // bs)
    padded = np.zeros((mb * bs, nb * bs), dtype=a.dtype)
    padded[:m, :n] = a
    tiles = padded.reshape(mb, bs, nb, bs).transpose(0, 2, 1, 3)
    nz = np.abs(tiles).sum(axis=(2, 3)) != 0 if prune_zero else np.ones((mb, nb), bool)
    rows, cols = np.nonzero(nz)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    indptr = np.zeros(mb + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    blocks = tiles[rows, cols] if len(rows) else np.zeros((0, bs, bs), a.dtype)
    return BCSR(indptr, cols.astype(np.int64), jnp.asarray(blocks), (m, n), bs)


def bcsr_from_csr(a: CSR, block_size: int, dtype=None) -> BCSR:
    """Direct CSR -> BCSR: scatter entries into only the occupied blocks.

    Never materializes the dense matrix — memory is O(nnzb * bs^2), bounded
    by the input's block structure, which is what makes the tile path usable
    at scales where an (m, n) densify would not fit.  Rows/cols beyond the
    last full block are padded into partial edge blocks (zero filled), same
    layout as ``bcsr_from_dense``.  Assumes ``a`` has no duplicate entries
    (every ``csr_from_coo``-built CSR satisfies this).
    """
    bs = block_size
    m, n = a.shape
    mb, nb = -(-m // bs), -(-n // bs)
    rows = _expand_rows(a.indptr)
    cols = a.indices
    key = (rows // bs) * nb + cols // bs
    uniq, inv = np.unique(key, return_inverse=True)
    blocks = np.zeros((len(uniq), bs, bs), dtype=a.data.dtype)
    blocks[inv, rows % bs, cols % bs] = a.data
    ubr, ubc = uniq // nb, uniq % nb
    indptr = np.zeros(mb + 1, dtype=np.int64)
    np.add.at(indptr, ubr + 1, 1)
    dev = jnp.asarray(blocks) if dtype is None else jnp.asarray(blocks, dtype)
    return BCSR(np.cumsum(indptr), ubc.astype(np.int64), dev, (m, n), bs)


def bcsr_to_csr(a: BCSR, prune_zero: bool = True) -> CSR:
    """Inverse of ``bcsr_from_csr``: element CSR of the stored blocks.

    With ``prune_zero`` (default) only numerically nonzero elements are
    kept — the result-extraction contract of the tile pipeline, where the
    output's element structure is the nonzeros the masked product actually
    produced.  Elements in the zero-padded edge region (beyond ``shape``)
    are always dropped.
    """
    bs = a.block_size
    m, n = a.shape
    blocks = np.asarray(a.blocks)
    brow = np.repeat(np.arange(a.block_rows, dtype=np.int64),
                     np.diff(a.indptr))
    if prune_zero:
        p, r, c = np.nonzero(blocks)
    else:
        p, r, c = (x.ravel() for x in np.indices(blocks.shape))
    rows = brow[p] * bs + r
    cols = a.indices[p] * bs + c
    keep = (rows < m) & (cols < n)
    return csr_from_coo(rows[keep], cols[keep], blocks[p, r, c][keep],
                        (m, n), sum_dups=False)


def bcsr_block_positions(a: BCSR, bi: np.ndarray, bj: np.ndarray
                         ) -> np.ndarray:
    """Positions in ``a.blocks`` of blocks (bi[t], bj[t]); -1 when absent.

    Relies on the BCSR invariant that blocks are stored in row-major
    (block-row, block-col) order, so a single searchsorted resolves every
    query.
    """
    nb = a.block_cols
    brow = np.repeat(np.arange(a.block_rows, dtype=np.int64),
                     np.diff(a.indptr))
    keys = brow * nb + a.indices
    q = np.asarray(bi, dtype=np.int64) * nb + np.asarray(bj, dtype=np.int64)
    pos = np.searchsorted(keys, q)
    pos_c = np.minimum(pos, max(0, len(keys) - 1))
    ok = (pos < len(keys)) & (keys[pos_c] == q) if len(keys) else \
        np.zeros(len(q), dtype=bool)
    return np.where(ok, pos, -1)


def bcsr_structure_transpose(a: BCSR) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Column-major view of the block structure: (indptr_T, rows_T, pos_T).

    ``pos_T[p]`` is the position in ``a.blocks`` of the p-th block when
    traversing column-by-column.  Used to build pull-based schedules.
    """
    mb = a.block_rows
    nb = a.block_cols
    rows = np.repeat(np.arange(mb, dtype=np.int64), np.diff(a.indptr))
    cols = a.indices
    pos = np.arange(a.nnzb, dtype=np.int64)
    order = np.lexsort((rows, cols))
    rows_t, cols_t, pos_t = rows[order], cols[order], pos[order]
    indptr_t = np.zeros(nb + 1, dtype=np.int64)
    np.add.at(indptr_t, cols_t + 1, 1)
    return np.cumsum(indptr_t), rows_t, pos_t


# --------------------------------------------------------------------------
# BCSR panel helpers (distributed ring-SUMMA: row-panels and K-slabs)
# --------------------------------------------------------------------------


def bcsr_pad_block_rows(a: BCSR, target_block_rows: int) -> BCSR:
    """Append empty block rows so ``a`` has exactly ``target_block_rows``.

    The element shape grows with the padding (the new rows are structurally
    empty), so downstream panel splits see equal shards.
    """
    mb = a.block_rows
    if target_block_rows < mb:
        raise ValueError(f"cannot shrink {mb} block rows to "
                         f"{target_block_rows}")
    if target_block_rows == mb:
        return a
    indptr = np.concatenate([
        a.indptr,
        np.full(target_block_rows - mb, a.indptr[-1], dtype=a.indptr.dtype)])
    return BCSR(indptr, a.indices, a.blocks,
                (target_block_rows * a.block_size, a.shape[1]), a.block_size)


def bcsr_row_panels(a: BCSR, nparts: int) -> Tuple[BCSR, ...]:
    """Split ``a`` into ``nparts`` equal block-row panels.

    Requires ``a.block_rows % nparts == 0`` (pad first via
    ``bcsr_pad_block_rows``).  Each panel's ``indptr`` is rebased to start
    at 0 and its ``blocks`` is the contiguous device slice of the parent's
    blocks, so panel-local schedule positions index the panel directly.
    """
    mb = a.block_rows
    if mb % nparts:
        raise ValueError(f"{mb} block rows do not split into {nparts} panels")
    rows_per = mb // nparts
    out = []
    for d in range(nparts):
        lo, hi = d * rows_per, (d + 1) * rows_per
        s, e = int(a.indptr[lo]), int(a.indptr[hi])
        out.append(BCSR(a.indptr[lo:hi + 1] - a.indptr[lo],
                        a.indices[s:e], a.blocks[s:e],
                        (rows_per * a.block_size, a.shape[1]),
                        a.block_size))
    return tuple(out)


def bcsr_concat_row_panels(panels: Sequence[BCSR]) -> BCSR:
    """Inverse of ``bcsr_row_panels``: stack block-row panels vertically."""
    if not panels:
        raise ValueError("no panels")
    bs = panels[0].block_size
    ncols = panels[0].shape[1]
    indptrs = [panels[0].indptr]
    offset = panels[0].indptr[-1]
    for p in panels[1:]:
        assert p.block_size == bs and p.shape[1] == ncols
        indptrs.append(p.indptr[1:] + offset)
        offset = offset + p.indptr[-1]
    xp = np if all(isinstance(p.blocks, np.ndarray) for p in panels) else jnp
    blocks = (xp.concatenate([p.blocks for p in panels])
              if sum(p.nnzb for p in panels)
              else panels[0].blocks[:0])
    return BCSR(np.concatenate(indptrs),
                np.concatenate([p.indices for p in panels]),
                blocks,
                (sum(p.shape[0] for p in panels), ncols), bs)


def pad_panel_blocks(blocks: Array, target_nnzb: int) -> Array:
    """Pad a (nnzb, bs, bs) block array with zero blocks to ``target_nnzb``
    (>= 1), giving every ring participant one static ``ppermute`` shape.
    Works on device or host (numpy) blocks without changing residency."""
    xp = np if isinstance(blocks, np.ndarray) else jnp
    nnzb = blocks.shape[0]
    target = max(1, target_nnzb)
    if nnzb == target:
        return blocks
    pad = xp.zeros((target - nnzb,) + tuple(blocks.shape[1:]), blocks.dtype)
    return xp.concatenate([blocks, pad]) if nnzb else pad


# --------------------------------------------------------------------------
# Random sparse generators (paper Sec. 7: Erdos-Renyi and R-MAT/Graph500)
# --------------------------------------------------------------------------


def erdos_renyi(n: int, avg_degree: float, seed: int = 0,
                values: str = "uniform") -> CSR:
    """ER(n, d): each row has ~Poisson(d) nonzeros at uniform columns."""
    rng = np.random.default_rng(seed)
    nnz = rng.poisson(avg_degree, size=n)
    rows = np.repeat(np.arange(n, dtype=np.int64), nnz)
    cols = rng.integers(0, n, size=int(nnz.sum()), dtype=np.int64)
    if values == "ones":
        vals = np.ones(len(rows), dtype=np.float32)
    else:
        vals = rng.uniform(0.5, 1.5, size=len(rows)).astype(np.float32)
    return csr_from_coo(rows, cols, vals, (n, n))


def rmat(scale: int, edge_factor: int = 16, seed: int = 0,
         a: float = 0.57, b: float = 0.19, c: float = 0.19,
         symmetric: bool = True, remove_self_loops: bool = True) -> CSR:
    """R-MAT generator with Graph500 parameters (a,b,c,d)=(.57,.19,.19,.05)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    rows = np.zeros(m, dtype=np.int64)
    cols = np.zeros(m, dtype=np.int64)
    for lvl in range(scale):
        r = rng.random(m)
        # quadrant probabilities with noise, Graph500-style
        ab = a + b
        abc = a + b + c
        go_right = ((r >= a) & (r < ab)) | (r >= abc)
        go_down = r >= ab
        rows |= go_down.astype(np.int64) << lvl
        cols |= go_right.astype(np.int64) << lvl
    if remove_self_loops:
        keep = rows != cols
        rows, cols = rows[keep], cols[keep]
    if symmetric:
        rows, cols = np.concatenate([rows, cols]), np.concatenate([cols, rows])
    vals = np.ones(len(rows), dtype=np.float32)
    out = csr_from_coo(rows, cols, vals, (n, n))
    out.data[:] = 1.0  # binarize: duplicate edges must not create weights
    return out


def random_mask_like(a: CSR, keep_prob: float, seed: int = 0) -> CSR:
    """Random subsample of a's pattern (mask values are irrelevant)."""
    rng = np.random.default_rng(seed)
    keep = rng.random(a.nnz) < keep_prob
    rows = _expand_rows(a.indptr)[keep]
    return csr_from_coo(rows, a.indices[keep], np.ones(keep.sum(), np.float32),
                        a.shape, sum_dups=False)


def er_mask(n: int, d: float, seed: int) -> CSR:
    """ER-pattern mask: ~Poisson(d) ones per row at uniform columns.

    The mask family of the paper's Fig. 7 density sweep; shared by the
    benchmarks and the calibration probes so both measure the same
    distribution.
    """
    rng = np.random.default_rng(seed)
    nnz = rng.poisson(d, size=n)
    rows = np.repeat(np.arange(n, dtype=np.int64), nnz)
    cols = rng.integers(0, n, size=int(nnz.sum()), dtype=np.int64)
    return csr_from_coo(rows, cols, np.ones(len(rows), np.float32), (n, n))


def block_sparse(n: int, bs: int, tile_density: float,
                 within_density: float, seed: int,
                 mask: bool = False) -> np.ndarray:
    """Block-structured sparse matrix as a DENSE (n, n) float32 array:
    (bs x bs) tiles occupied w.p. ``tile_density``, elements inside an
    occupied tile w.p. ``within_density``; integer values in [1, 5)
    unless ``mask`` (then 0/1).

    The tile/ring routes' calibration family; shared by bench_tile,
    bench_dist, and the tuning probes — the draw order is part of the
    committed grids' identity, so change it only with a regeneration.
    """
    rng = np.random.default_rng(seed)
    nb = n // bs
    tiles = rng.random((nb, nb)) < tile_density
    if not tiles.any():
        tiles[0, 0] = True
    dense = np.kron(tiles, np.ones((bs, bs))) * (rng.random((n, n))
                                                 < within_density)
    if mask:
        return dense.astype(np.float32)
    return (dense * rng.integers(1, 5, (n, n))).astype(np.float32)


def tril(a: CSR, strict: bool = True) -> CSR:
    rows = _expand_rows(a.indptr)
    keep = a.indices < rows if strict else a.indices <= rows
    return csr_from_coo(rows[keep], a.indices[keep], a.data[keep], a.shape,
                        sum_dups=False)
