"""Row-parallel Masked SpGEMM drivers (paper Sec. 5-6).

``masked_spgemm`` computes  C = M (.) (A B)  (or the complemented variant)
by vmapping the row-level accumulator kernels over rows of A/M, exactly like
the paper's OpenMP parallel-for over output rows.  One- vs two-phase:

  * 1P: numeric pass only; the output is allocated at the mask's size
        (output pattern is a subset of the mask pattern), matching the
        paper's observation that the mask bounds the output.
  * 2P: a symbolic pass first computes per-row output nnz; the numeric pass
        then writes into an exactly-sized allocation.  Here the symbolic
        pass is real work (it is timed by the benchmark harness) while the
        "allocation" difference shows up as the tighter padded width.

Outputs are returned mask-aligned: ``vals[i, p]`` / ``present[i, p]`` refer
to the p-th nonzero slot of mask row i (stable, sorted by construction).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from . import accumulators as acc
from .formats import (CSR, PaddedCSR, padded_from_csr, csr_from_coo,
                      bcsr_from_csr, bcsr_block_positions, _expand_rows)
from .semiring import Semiring, PLUS_TIMES

#: the vmapped row kernels; the BCSR tile route ("tile") dispatches through
#: the Pallas/XLA block executors instead and is planner- or caller-elected
ALGORITHMS = ("msa", "hash", "mca", "heap", "heapdot", "inner")


@dataclasses.dataclass(frozen=True)
class MaskedSpGEMMResult:
    vals: jax.Array      # (m, pm) mask-aligned values
    present: jax.Array   # (m, pm) bool
    mask_cols: jax.Array  # (m, pm) int32 column ids (pad = n)
    shape: Tuple[int, int]

    def to_dense(self):
        m, n = self.shape
        rows = jnp.broadcast_to(jnp.arange(m)[:, None], self.mask_cols.shape)
        out = jnp.zeros((m, n + 1), self.vals.dtype)
        cols = jnp.where(self.present, self.mask_cols, n)
        out = out.at[rows, cols].set(jnp.where(self.present, self.vals, 0))
        return out[:, :n]

    def to_csr(self) -> CSR:
        present = np.asarray(self.present)
        rows, slots = np.nonzero(present)
        cols = np.asarray(self.mask_cols)[rows, slots]
        vals = np.asarray(self.vals)[rows, slots]
        return csr_from_coo(rows, cols, vals, self.shape, sum_dups=False)

    @property
    def nnz(self):
        return jnp.sum(self.present.astype(jnp.int32))


def _row_fn(algorithm: str, n: int, kdim: int, sr: Semiring,
            complement: bool, n_inspect: int):
    if algorithm == "msa":
        def f(mc, ac, av, al, Bc, Bv, Bl):
            return acc.msa_row(mc, ac, av, al, Bc, Bv, Bl, n, kdim, sr,
                               complement=complement)
    elif algorithm == "hash":
        if complement:
            raise NotImplementedError(
                "hash complement: use msa (dense states) per paper Sec. 5.2")
        def f(mc, ac, av, al, Bc, Bv, Bl):
            return acc.hash_row(mc, ac, av, al, Bc, Bv, Bl, n, kdim, sr)
    elif algorithm == "mca":
        if complement:
            raise NotImplementedError("MCA does not support complemented "
                                      "masks (paper Sec. 8.4)")
        def f(mc, ac, av, al, Bc, Bv, Bl):
            return acc.mca_row(mc, ac, av, al, Bc, Bv, Bl, n, kdim, sr)
    elif algorithm in ("heap", "heapdot"):
        ni = 1 if algorithm == "heap" else (0 if complement else 10 ** 9)
        ni = n_inspect if n_inspect is not None else ni
        def f(mc, ac, av, al, Bc, Bv, Bl):
            return acc.heap_row(mc, ac, av, al, Bc, Bv, Bl, n, kdim, sr,
                                n_inspect=ni, complement=complement)
    elif algorithm == "inner":
        if complement:
            raise NotImplementedError("inner requires an explicit mask")
        def f(mc, ac, av, al, Btc, Btv, Btl):
            return acc.inner_row(mc, ac, av, al, Btc, Btv, Btl, n, kdim, sr)
    else:
        raise ValueError(f"unknown algorithm {algorithm!r}")
    return f


@functools.partial(
    jax.jit,
    static_argnames=("algorithm", "sr", "complement", "n_inspect", "shape",
                     "kdim"))
def _masked_spgemm_padded(M: PaddedCSR, A: PaddedCSR, B_or_Bt: PaddedCSR,
                          *, algorithm: str, sr: Semiring, complement: bool,
                          n_inspect: Optional[int], shape, kdim):
    n = shape[1]
    row = _row_fn(algorithm, n, kdim, sr, complement, n_inspect)
    f = jax.vmap(
        lambda mc, ac, av, al: row(mc, ac, av, al, B_or_Bt.cols,
                                   B_or_Bt.vals, B_or_Bt.lens))
    return f(M.cols, A.cols, A.vals, A.lens)


def masked_spgemm(A, B, M, *, algorithm: str = "auto",
                  semiring: Semiring = PLUS_TIMES, complement: bool = False,
                  two_phase: bool = False, n_inspect: Optional[int] = None,
                  widths: Optional[Tuple[int, int, int]] = None,
                  tile_block: Optional[int] = None, plan=None):
    """C = M (.) (A B)   [or  C = (not M) (.) (A B)].

    A, B, M: host CSR (or PaddedCSR already on device).  Returns a
    MaskedSpGEMMResult (mask-aligned) for the normal mask; for the
    complemented mask returns (dense_vals, dense_present) since the output
    is not a subset of the mask pattern.

    ``algorithm="auto"`` (the default) consults the planner: cheap
    structural statistics pick the cheapest kernel per the paper's Sec. 7-8
    guidelines, memoized by structural signature (plus the active
    cost-model token — retuning or activating a calibration profile via
    ``repro.tuning`` / ``python -m repro.tune`` re-plans everything) so
    repeated shapes skip re-planning.  When the plan elects the BCSR tile route
    (``plan.algorithm == "tile"``), the product executes on the block
    executors (Pallas on TPU, compiled XLA elsewhere) end to end — no
    densify anywhere on that path.  ``algorithm="tile"`` forces the tile
    route (``tile_block`` picks the block size; plus_times, explicit mask,
    host-CSR operands only).  A precomputed ``plan`` (from
    ``planner.plan``) overrides ``algorithm`` and ``widths``.
    """
    m, k = A.shape
    k2, n = B.shape
    assert k == k2, (A.shape, B.shape)
    if two_phase and algorithm == "tile":
        # the tile route's symbolic phase is the host schedule build; a 2P
        # padded-width pass has no meaning there, and silently ignoring the
        # request would misreport what was measured
        raise NotImplementedError(
            "two_phase is not supported by the tile route (its symbolic "
            "phase is the host schedule build); use a row algorithm")
    if plan is None and algorithm == "auto":
        from .planner import plan as _plan
        plan = _plan(A, B, M, complement=complement, semiring=semiring)
    if plan is not None:
        algorithm = plan.algorithm
        if algorithm == "tile" and two_phase:
            # an auto-elected tile route cannot honor two_phase: fall back
            # to the cheapest row kernel from the same plan's ranking
            algorithm = next(name for name, _ in plan.costs
                             if name != "tile")
            s = plan.stats
            if widths is None:
                widths = (s.wa, s.wbt if algorithm == "inner" else s.wb,
                          s.pm)
        if widths is None:
            widths = plan.widths
        if n_inspect is None:
            n_inspect = plan.n_inspect
        if tile_block is None and plan.tile_block:
            tile_block = plan.tile_block
    wa, wb, wm = widths or (None, None, None)

    if algorithm == "tile":
        from repro.kernels.masked_matmul.ops import tile_path_supported
        if not tile_path_supported(semiring.name, complement):
            raise NotImplementedError(
                "tile route requires plus_times and an explicit mask")
        if not (isinstance(A, CSR) and isinstance(B, CSR)
                and isinstance(M, CSR)):
            raise NotImplementedError("tile route needs host CSR operands")
        return _masked_spgemm_tile(A, B, M, block_size=tile_block, wm=wm)

    with obs.span("spgemm.host_prep", algorithm=algorithm):
        A_p = A if isinstance(A, PaddedCSR) else padded_from_csr(A, wa)
        M_p = M if isinstance(M, PaddedCSR) else padded_from_csr(M, wm)
        if algorithm == "inner":
            Bt = B.transpose() if isinstance(B, CSR) else B
            B_p = (Bt if isinstance(Bt, PaddedCSR)
                   else padded_from_csr(Bt, wb))
        else:
            B_p = (B if isinstance(B, PaddedCSR)
                   else padded_from_csr(B, wb))

    if two_phase:
        # symbolic pass: exact output structure (counts); in this padded
        # setting its product is the tight numeric width.  The symbolic pass
        # always walks B row-major, so Inner (which multiplies against B^T)
        # pads a row-major copy just for this phase.
        if algorithm == "inner":
            B_sym = B if isinstance(B, PaddedCSR) else padded_from_csr(B, wb)
        else:
            B_sym = B_p
        counts = symbolic_phase(A_p, M_p, B_sym, shape=(m, n), kdim=k)
        _ = counts.block_until_ready()

    with obs.span("spgemm.row", algorithm=algorithm, m=m, n=n):
        vals, present = _masked_spgemm_padded(
            M_p, A_p, B_p, algorithm=algorithm, sr=semiring,
            complement=complement, n_inspect=n_inspect, shape=(m, n),
            kdim=k)
    if complement:
        return vals, present
    return MaskedSpGEMMResult(vals, present, M_p.cols, (m, n))


@functools.partial(jax.jit, static_argnames=("shape", "kdim"))
def symbolic_phase(A: PaddedCSR, M: PaddedCSR, B: Optional[PaddedCSR], *,
                   shape, kdim):
    """Two-phase symbolic pass: per-row output nnz (paper Sec. 6)."""
    n = shape[1]
    f = jax.vmap(lambda mc, ac, al: acc.symbolic_row(
        mc, ac, al, B.cols, B.lens, n, kdim))
    return f(M.cols, A.cols, A.lens)


# ---------------------------------------------------------------------------
# BCSR tile route: block executors end-to-end, densify-free
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("m", "pm"))
def _tile_gather(c_blocks, s_blocks, pos, roff, coff, rows, slots, *, m, pm):
    """Gather per-mask-element values/structure out of the block result and
    scatter them into the mask-aligned (m, pm) layout."""
    vals_flat = c_blocks[pos, roff, coff]
    cnt_flat = s_blocks[pos, roff, coff]
    vals = jnp.zeros((m, pm), c_blocks.dtype)
    vals = vals.at[rows, slots].set(vals_flat, mode="drop")
    present = jnp.zeros((m, pm), bool)
    present = present.at[rows, slots].set(cnt_flat > 0, mode="drop")
    return vals, present


def _masked_spgemm_tile(A: CSR, B: CSR, M: CSR, *,
                        block_size: Optional[int] = None,
                        wm: Optional[int] = None,
                        interpret=None, backend=None) -> MaskedSpGEMMResult:
    """Execute C = M (.) (A B) on the BCSR tile pipeline.

    Densify-free end to end: CSR operands scatter into occupied blocks
    (``bcsr_from_csr``), the vectorized host schedule replays on the block
    executor, and the result is gathered straight from the output blocks
    into the same mask-aligned layout the row kernels produce.  ``present``
    comes from a structural counting replay of the same schedule, so it is
    exact element-level structure — bitwise the row kernels' semantics,
    including numeric-cancellation cases.
    """
    from repro.kernels.masked_matmul.ops import block_spgemm_with_structure

    m, k = A.shape
    _, n = B.shape
    if M.nnz == 0:
        M_p = padded_from_csr(M, wm)
        z = jnp.zeros((m, M_p.width), jnp.float32)
        return MaskedSpGEMMResult(z, jnp.zeros((m, M_p.width), bool),
                                  M_p.cols, (m, n))
    if block_size is None:
        from .planner import ring_block_candidates
        block_size = ring_block_candidates(m, k, n)[0]
    bs = block_size
    with obs.span("spgemm.tile", block=bs, m=m, n=n):
        with obs.span("spgemm.host_prep", algorithm="tile"):
            Ab = bcsr_from_csr(A, bs)
            Bb = bcsr_from_csr(B, bs)
            Mb = bcsr_from_csr(M, bs)

            def pattern(x: CSR):
                """Stored-entry pattern blocks: 1.0 per CSR entry (an
                explicitly stored 0.0 is structural to the row kernels)."""
                ones = CSR(x.indptr, x.indices,
                           np.ones(x.nnz, np.float32), x.shape)
                return bcsr_from_csr(ones, bs).blocks

            a_pat, b_pat = pattern(A), pattern(B)
        Cb, Sb = block_spgemm_with_structure(
            Ab, Bb, Mb, a_pattern=a_pat, b_pattern=b_pat,
            interpret=interpret, backend=backend)
        return gather_mask_aligned(M, Mb, Cb.blocks, Sb.blocks, n=n, wm=wm)


def gather_mask_aligned(M: CSR, Mb_struct, c_blocks, s_blocks, *, n: int,
                        wm: Optional[int] = None) -> MaskedSpGEMMResult:
    """Extract a mask-aligned result from block-granular values/counts.

    ``c_blocks``/``s_blocks`` are ``(nnzb, bs, bs)`` device arrays laid out
    in ``Mb_struct``'s block order (the 1P allocation: output structure ==
    mask block structure).  The distributed ring does NOT come through
    here — its extraction is panel-local inside the shard program.
    """
    m = M.shape[0]
    bs = Mb_struct.block_size
    M_p = padded_from_csr(M, wm)
    pm = M_p.width
    # host-side addressing: every mask element lives in a mask block by
    # construction
    mr = _expand_rows(M.indptr)
    mc = M.indices
    pos = bcsr_block_positions(Mb_struct, mr // bs, mc // bs)
    slots = np.arange(M.nnz, dtype=np.int64) - M.indptr[mr]
    vals, present = _tile_gather(
        c_blocks, s_blocks, jnp.asarray(pos), jnp.asarray(mr % bs),
        jnp.asarray(mc % bs), jnp.asarray(mr), jnp.asarray(slots),
        m=m, pm=pm)
    return MaskedSpGEMMResult(vals, present, M_p.cols, (m, n))


# ---------------------------------------------------------------------------
# Batched driver: one plan + one compiled program for same-shape operands
# ---------------------------------------------------------------------------


def _stack_padded(mats, width: int) -> PaddedCSR:
    """Pad each CSR to ``width`` and stack into a batched PaddedCSR whose
    leaves carry a leading batch dim (vmap slices it back off).

    Host-CSR batches are padded into ONE host array per leaf and
    transferred once — stacking per-element device arrays costs a
    dispatch per element, which is exactly the overhead batching exists
    to remove (the serving engine's hot path)."""
    if all(isinstance(m, CSR) for m in mats):
        b = len(mats)
        m_rows, n = mats[0].shape
        cols = np.full((b, m_rows, width), n, dtype=np.int32)
        vals = np.zeros((b, m_rows, width), dtype=np.float32)
        lens = np.zeros((b, m_rows), dtype=np.int32)
        for i, mat in enumerate(mats):
            mat = mat.sorted_rows()
            rows = _expand_rows(mat.indptr)
            slots = np.arange(mat.nnz, dtype=np.int64) - mat.indptr[rows]
            keep = slots < width
            cols[i, rows[keep], slots[keep]] = mat.indices[keep]
            vals[i, rows[keep], slots[keep]] = mat.data[keep]
            lens[i] = np.minimum(mat.row_nnz(), width)
        return PaddedCSR(jnp.asarray(cols), jnp.asarray(vals),
                         jnp.asarray(lens), (m_rows, n))
    padded = [m if isinstance(m, PaddedCSR) else padded_from_csr(m, width)
              for m in mats]
    return PaddedCSR(
        jnp.stack([p.cols for p in padded]),
        jnp.stack([p.vals for p in padded]),
        jnp.stack([p.lens for p in padded]),
        padded[0].shape)


def masked_spgemm_batched(As, B, Ms, *, algorithm: str = "auto",
                          semiring: Semiring = PLUS_TIMES,
                          complement: bool = False, plan=None):
    """Batch of C_i = M_i (.) (A_i B) with ONE plan and ONE compiled program.

    ``As``/``Ms``: equal-length sequences of same-shape operands (CSR or
    PaddedCSR); ``B`` is shared.  This is the multi-source traversal case
    (betweenness centrality): per-batch structures differ, but one plan —
    with pad widths widened to the batch maxima — serves every element, so
    the device sees a single vmapped program instead of len(As) dispatches.

    Returns a list of MaskedSpGEMMResult (mask case), or stacked dense
    ``(vals, present)`` of shape (batch, m, n) under ``complement``.
    """
    As, Ms = list(As), list(Ms)
    if len(As) != len(Ms) or not As:
        raise ValueError("As/Ms must be equal-length, non-empty")
    m, k = As[0].shape
    _, n = B.shape
    if plan is None and algorithm == "auto":
        from .planner import plan_batch
        plan = plan_batch(As, B, Ms, complement=complement,
                          semiring=semiring)
    if plan is not None and plan.algorithm == "tile":
        # a tile-elected plan (the serving engine hands these in) executes
        # each element on the block executors: the compiled executor is
        # shared across the batch (jit cache), the plan across every call
        from repro.kernels.masked_matmul.ops import tile_path_supported
        if not tile_path_supported(semiring.name, complement):
            raise NotImplementedError(
                "tile route requires plus_times and an explicit mask")
        return [_masked_spgemm_tile(a, B, mm,
                                    block_size=plan.tile_block or None,
                                    wm=plan.widths[2])
                for a, mm in zip(As, Ms)]
    if plan is not None:
        algorithm = plan.algorithm
        wa, wb, wm = plan.widths
    else:
        wa = max(1, max(int(np.diff(a.indptr).max(initial=0)) for a in As))
        wm = max(1, max(int(np.diff(mm.indptr).max(initial=0)) for mm in Ms))
        wb = None

    A_b = _stack_padded(As, wa)
    M_b = _stack_padded(Ms, wm)
    if algorithm == "inner":
        Bt = B.transpose() if isinstance(B, CSR) else B
        B_p = Bt if isinstance(Bt, PaddedCSR) else padded_from_csr(Bt, wb)
    else:
        B_p = B if isinstance(B, PaddedCSR) else padded_from_csr(B, wb)

    run = jax.vmap(lambda Mp, Ap: _masked_spgemm_padded(
        Mp, Ap, B_p, algorithm=algorithm, sr=semiring,
        complement=complement, n_inspect=None, shape=(m, n), kdim=k))
    vals, present = run(M_b, A_b)
    if complement:
        return vals, present
    return [MaskedSpGEMMResult(vals[i], present[i], M_b.cols[i], (m, n))
            for i in range(len(As))]


# ---------------------------------------------------------------------------
# Dense oracle (tests): structural semantics under a semiring
# ---------------------------------------------------------------------------


def dense_oracle(a, b, m, *, semiring: Semiring = PLUS_TIMES,
                 complement: bool = False):
    """Reference masked product on dense arrays.

    Returns (vals, present): present = structural nonzero AND mask allows;
    vals = semiring matmul where present (zero elsewhere).
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    m = jnp.asarray(m)
    structure = ((jnp.abs(a) > 0).astype(jnp.float32)
                 @ (jnp.abs(b) > 0).astype(jnp.float32)) > 0
    allowed = (m == 0) if complement else (m != 0)
    present = structure & allowed
    vals = semiring.matmul(a, b)
    return jnp.where(present, vals, semiring.zero), present
