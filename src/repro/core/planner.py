"""Adaptive algorithm planner for Masked SpGEMM (paper Sec. 7-8).

The paper's headline result is that no single Masked-SpGEMM algorithm wins
everywhere: "matrix and mask density, mask structure and cache behavior play
a vital role".  This module turns those guidelines into an explicit,
deterministic decision function:

    stats  = collect_stats(A, B, M, ...)      # cheap structural statistics
    plan   = decide(stats)                    # pure: stats -> Plan
    result = masked_spgemm(A, B, M)           # algorithm="auto" runs both

``plan()`` memoizes Plans in an LRU cache keyed on a structural signature
(shapes + nnz + CRC of the index arrays), so repeated shapes — the serving /
batched case — skip re-planning entirely.  ``decide`` ranks algorithms with
the per-algorithm cost hooks exported by ``accumulators.py``; the hooks
model THIS vectorized implementation (padded-width products, sequential
``fori_loop`` rounds, vmapped dots), which is what actually executes, rather
than the paper's scalar CPU cost model.  The regime structure is the same as
the paper's:

  * Inner wins when the mask is sparser than the (padded) product — one
    vmapped dot per mask nonzero beats any push-style flop loop.
  * MCA wins when the mask is much denser than the inputs (compressed
    accumulator, log-factor merges).
  * MSA wins for complemented masks (dense states; hash/MCA/inner cannot
    complement per Sec. 8.4) and small n; Heap takes over for extremely
    sparse inputs when n is too large for MSA's dense state init.

A sampled symbolic probe estimates flops and the compression ratio
(flops / nnz(output)); it feeds the Plan's tile-path eligibility (dense
block occupancy makes the Pallas ``masked_matmul`` / ``block_spgemm``
kernels profitable) and is recorded for benchmark diagnostics.

When the model ranks two candidates within ``TRIAL_RATIO`` of each other
the tie is resolved empirically: ``plan()`` times the contenders once on
the real operands and caches the winner (autotuning; the cost model cannot
distinguish near-ties reliably across machine/load conditions).  The pure
``decide`` path never measures — only ``plan`` does, and only on a cache
miss for large non-complemented problems.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import zlib
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro import caches
from repro import obs
from repro.tuning import profile as tuning_profile

from . import accumulators as acc
from .formats import CSR, PaddedCSR
from .semiring import Semiring, PLUS_TIMES

#: candidate algorithms, in cost-hook order
CANDIDATES = tuple(acc.COST_HOOKS)

#: rows sampled by the symbolic probe
PROBE_ROWS = 64
#: per-row flop budget above which the probe falls back to upper bounds
PROBE_FLOP_CAP = 1 << 16

#: candidates whose modeled cost is within this factor of the best are
#: resolved by a one-shot measured trial on the real operands (the model
#: cannot distinguish near-ties reliably across load/cache conditions;
#: measuring once and caching the winner can)
TRIAL_RATIO = 1.25
#: at most this many candidates enter a trial
TRIAL_MAX_CANDIDATES = 3
#: timed repetitions per trial candidate (plus one warmup/compile call);
#: the minimum is kept (robust to additive noise)
TRIAL_ITERS = 3
#: problems smaller than this are too fast for a meaningful trial (and any
#: choice is fine); the modeled ranking is used directly
TRIAL_MIN_ROWS = 256

#: minimum input density for the tile path: dense (bs x bs) tiles compute
#: bs^3 flops regardless of occupancy, so sparse operands would be mostly
#: padding.  Re-tuned against benchmarks/bench_tile.py (tile_grid.json):
#: 0.05 sits between the grid's losing uniform-ER controls (~0.8% density,
#: tile 2-4.5x slower) and its winning dense-block points (>= 9% density,
#: tile 9-50x faster); at the old 0.02 only the cost model kept marginal
#: uniform-sparse operands out of the tile route
TILE_MIN_DENSITY = 0.05
#: minimum expected nonzeros per (bs x bs) tile for a block size to be
#: worth scheduling (bench_tile: winning regimes all sit far above this;
#: between 2 and 4 the grid's marginal points flip from ~par to >10% loss)
TILE_MIN_OCCUPANCY = 4.0
#: block sizes the tile path will consider, largest first (MXU-aligned on
#: TPU; the XLA executor on CPU accepts any of these)
TILE_BLOCK_SIZES = (128, 32, 8)
#: minimum fraction of mask nonzeros the symbolic probe must see hit by
#: the product for the tile path to stay eligible
TILE_MIN_HIT_RATE = 0.05

#: tile-route cost model constants (ms), CPU-calibrated against
#: benchmarks/bench_tile.py like COST_CONSTANTS: host covers the
#: bcsr_from_csr scatters + vectorized schedule build (per element/worklist
#: entry), mac the batched block products of the two device replays
#: (values + structure), gather the per-mask-element result extraction.
#: Like every constant table in this module, these are the SHIPPED CPU
#: defaults: ``repro.tuning.activate(profile)`` overwrites them in place
#: from a fitted CalibrationProfile (``python -m repro.tune``), and the
#: plan caches key on ``cost_model_token()`` so retuning never serves a
#: plan decided under old constants.
TILE_COST = dict(base=3.0, per_host=2.5e-4, per_mac=1.6e-7,
                 per_gather=3.0e-4)


# ---------------------------------------------------------------------------
# Statistics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanStats:
    """Cheap structural statistics driving the decision function.

    Widths are the padded row widths the vmapped row kernels will actually
    execute (``wa``/``wb`` = max row nnz of A/B, ``wbt`` = max *column* nnz
    of B = row width of B^T for Inner, ``pm`` = max mask-row nnz).
    ``flops`` / ``out_nnz`` come from the sampled symbolic probe, scaled to
    the full matrix; ``compression`` is their ratio (paper Sec. 7).
    """

    m: int
    k: int
    n: int
    nnz_a: int
    nnz_b: int
    nnz_m: int
    wa: int
    wb: int
    wbt: int
    pm: int
    complement: bool
    semiring: str = "plus_times"
    flops: float = 0.0
    out_nnz: float = 0.0
    #: False when B is device-resident row-major (PaddedCSR): Inner needs
    #: B^T and a padded B cannot be transposed without a host round-trip,
    #: so it must not be auto-selected (the driver would misread B as B^T)
    b_transposable: bool = True

    @property
    def compression(self) -> float:
        return self.flops / max(1.0, self.out_nnz)

    @property
    def mask_density(self) -> float:
        return self.nnz_m / max(1, self.m * self.n)


@dataclasses.dataclass(frozen=True)
class Plan:
    """Executable decision: which kernel, with which static parameters."""

    algorithm: str
    widths: Tuple[int, int, int]  # (wa, wb_or_wbt, wm) pad widths
    two_phase: bool
    n_inspect: Optional[int]
    tile_eligible: bool
    tile_block: int               # suggested BCSR block size (0 = n/a)
    costs: Tuple[Tuple[str, float], ...]
    stats: PlanStats
    trialed: Tuple[str, ...] = ()  # candidates resolved by measured trial

    def cost(self, algorithm: str) -> float:
        return dict(self.costs)[algorithm]


def _max_row_nnz(x: CSR) -> int:
    return max(1, int(np.diff(x.indptr).max(initial=0)))


def _max_col_nnz(x: CSR) -> int:
    if x.nnz == 0:
        return 1
    return max(1, int(np.bincount(x.indices, minlength=x.shape[1]).max()))


def _probe_rows(m: int, sample: int) -> np.ndarray:
    if m <= sample:
        return np.arange(m)
    return np.unique(np.linspace(0, m - 1, sample).astype(np.int64))


def symbolic_probe(A: CSR, B: CSR, M: CSR, *, complement: bool = False,
                   sample: int = PROBE_ROWS) -> Tuple[float, float]:
    """Sampled symbolic pass: (est. flops, est. nnz of the masked output).

    Walks ``sample`` evenly spaced rows; for each, flops_i is the exact
    Gustavson flop count and out_i the exact masked output nnz (union of the
    touched B rows intersected with — or minus, under complement — the mask
    row).  Rows whose flop count exceeds ``PROBE_FLOP_CAP`` fall back to the
    mask-row upper bound instead of materializing the union.
    """
    m, n = M.shape
    rows = _probe_rows(m, sample)
    b_nnz = B.row_nnz()
    flops = 0.0
    out = 0.0
    for i in rows:
        a_cols, _ = A.row(int(i))
        f_i = float(b_nnz[a_cols].sum()) if len(a_cols) else 0.0
        flops += f_i
        m_cols, _ = M.row(int(i))
        if f_i == 0.0:
            continue
        if f_i > PROBE_FLOP_CAP:
            out += float(n - len(m_cols)) if complement else float(len(m_cols))
            continue
        touched = np.unique(np.concatenate(
            [B.indices[B.indptr[j]: B.indptr[j + 1]] for j in a_cols]))
        if complement:
            out += float(len(touched) - np.isin(touched, m_cols).sum())
        else:
            out += float(np.isin(m_cols, touched).sum())
    scale = m / max(1, len(rows))
    return flops * scale, out * scale


def collect_stats(A: CSR, B: CSR, M: CSR, *, complement: bool = False,
                  semiring: Semiring = PLUS_TIMES,
                  probe: bool = True) -> PlanStats:
    """Gather the planner's statistics from host CSR operands."""
    m, k = A.shape
    _, n = B.shape
    flops, out_nnz = (symbolic_probe(A, B, M, complement=complement)
                      if probe else (0.0, 0.0))
    return PlanStats(
        m=m, k=k, n=n, nnz_a=A.nnz, nnz_b=B.nnz, nnz_m=M.nnz,
        wa=_max_row_nnz(A), wb=_max_row_nnz(B), wbt=_max_col_nnz(B),
        pm=_max_row_nnz(M), complement=complement, semiring=semiring.name,
        flops=flops, out_nnz=out_nnz)


# ---------------------------------------------------------------------------
# Decision function (pure, deterministic, testable)
# ---------------------------------------------------------------------------


def rank_algorithms(stats: PlanStats) -> Tuple[Tuple[str, float], ...]:
    """Per-algorithm cost estimates (ms for the whole product), cheapest
    first.  Pure function of ``stats``."""
    candidates = [a for a in CANDIDATES
                  if not stats.complement or a in acc.SUPPORTS_COMPLEMENT]
    if not stats.b_transposable:
        candidates = [a for a in candidates if a != "inner"]
    scale = stats.m / 1024.0
    costs = []
    for name in candidates:
        per_row = acc.COST_HOOKS[name](
            n=stats.n, wa=stats.wa, wb=stats.wb, wbt=stats.wbt, pm=stats.pm)
        costs.append((name, per_row * scale))
    return tuple(sorted(costs, key=lambda kv: (kv[1], kv[0])))


def _tile_path(stats: PlanStats) -> Tuple[bool, int]:
    """Eligibility of the Pallas tile kernels (masked_matmul/block_spgemm).

    Requires the plus_times semiring and an explicit mask (the tile kernels
    accumulate with a dense MXU dot), MXU-alignable dims, and enough expected
    nonzeros per tile that dense blocks are not mostly padding.
    """
    from repro.kernels.masked_matmul.ops import tile_path_supported
    if not tile_path_supported(stats.semiring, stats.complement):
        return False, 0
    dens_a = stats.nnz_a / max(1, stats.m * stats.k)
    dens_b = stats.nnz_b / max(1, stats.k * stats.n)
    if min(dens_a, dens_b) < TILE_MIN_DENSITY:
        return False, 0
    # symbolic-probe gate: a mask that almost never hits the product makes
    # dense output tiles pointless (most scheduled tiles would be zero)
    if stats.flops > 0 and stats.out_nnz < TILE_MIN_HIT_RATE * stats.nnz_m:
        return False, 0
    for bs in TILE_BLOCK_SIZES:
        if stats.m % bs or stats.n % bs or stats.k % bs:
            continue
        occ = min(dens_a, dens_b) * bs * bs
        if occ >= TILE_MIN_OCCUPANCY:
            return True, bs
    return False, 0


def _block_occupancy(dens: float, bs: int) -> float:
    """P(a bs x bs block holds >= 1 nonzero) under uniform sparsity."""
    return float(-np.expm1(bs * bs * np.log1p(-min(dens, 1 - 1e-12))))


def _block_counts(stats: PlanStats, bs: int
                  ) -> Tuple[float, float, float]:
    """Random-occupancy block expectations shared by the tile and ring
    models: ``(m_blocks, b_blocks, pair)`` — expected occupied output/mask
    blocks, occupied B blocks, and expected worklist entries per mask
    block (block-row/block-col intersection)."""
    m, k, n = stats.m, stats.k, stats.n
    dens_a = stats.nnz_a / max(1, m * k)
    dens_b = stats.nnz_b / max(1, k * n)
    dens_m = stats.nnz_m / max(1, m * n)
    mb, kb, nb = -(-m // bs), -(-k // bs), -(-n // bs)
    p_a = _block_occupancy(dens_a, bs)
    p_b = _block_occupancy(dens_b, bs)
    p_m = _block_occupancy(dens_m, bs)
    return mb * nb * p_m, kb * nb * p_b, kb * p_a * p_b


def _tile_feature_dict(stats: PlanStats, worklist: float, bs: int,
                       mac_div: float) -> Dict[str, float]:
    """The host/mac/gather decomposition both block routes execute, as a
    TILE_COST feature vector (``mac_div`` splits the MACs across ring
    devices; 1 on a single device)."""
    return {
        "base": 1.0,
        "per_host": float(stats.nnz_a + stats.nnz_b + stats.nnz_m
                          + worklist),
        "per_mac": 2.0 * worklist * bs ** 3 / mac_div,  # values + structure
        "per_gather": float(stats.nnz_m),
    }


def tile_cost_features(stats: PlanStats, bs: int) -> Dict[str, float]:
    """Feature vector of the tile-route model: ``tile_cost`` is the dot
    product of this with ``TILE_COST`` (the calibration fit solves the
    same linear form for the constants, so model and fit cannot drift).
    """
    m_blocks, _, pair = _block_counts(stats, bs)
    return _tile_feature_dict(stats, m_blocks * pair, bs, 1.0)


def tile_cost(stats: PlanStats, bs: int) -> float:
    """Modeled total ms of the BCSR tile route at block size ``bs``.
    Units match the row-kernel hooks (total ms at stats scale) so the
    planner can rank them side by side."""
    f = tile_cost_features(stats, bs)
    return sum(TILE_COST[k] * f[k] for k in f)


def decide(stats: PlanStats, *, allow_tile: bool = True) -> Plan:
    """Pure decision function: statistics -> Plan (paper Sec. 7-8 encoded in
    the accumulator cost hooks, plus the TPU-native tile route).

    ``allow_tile=False`` keeps the tile route out of the ranking (it still
    reports eligibility) — used by callers that can only execute the
    vmapped row kernels, like the batched driver.
    """
    costs = rank_algorithms(stats)
    tile_eligible, tile_block = _tile_path(stats)
    # the tile route enters the ranking only when the stats carry a real
    # symbolic probe (flops > 0): width-only stats (device-resident or
    # hand-built) lack the occupancy evidence the gate relies on
    if allow_tile and tile_eligible and stats.flops > 0:
        costs = tuple(sorted(
            costs + (("tile", tile_cost(stats, tile_block)),),
            key=lambda kv: (kv[1], kv[0])))
    algorithm = costs[0][0]
    wb = stats.wbt if algorithm == "inner" else stats.wb
    return Plan(
        algorithm=algorithm,
        widths=(stats.wa, wb, stats.pm),
        two_phase=False,           # 1P: the mask bounds the allocation
        n_inspect=None,            # per-algorithm default
        tile_eligible=tile_eligible,
        tile_block=tile_block,
        costs=costs,
        stats=stats)


# ---------------------------------------------------------------------------
# Distributed decision: row-parallel (replicate B) vs sparse ring-SUMMA
# ---------------------------------------------------------------------------

#: distributed cost-model constants (ms), CPU-calibrated against
#: benchmarks/bench_dist.py (dist_grid.json) on the forced-host-device
#: mesh: ``per_bcast_elem`` models replicating padded B to every device
#: (the row route's setup traffic), ``per_ring_byte`` the ppermute volume
#: of one rotating value+pattern slab panel per stage, ``stage_base`` the
#: fixed per-stage dispatch overhead of the ring program.  Re-tune with
#: ``python -m benchmarks.run --only dist`` (see ROADMAP).
DIST_COST = dict(per_bcast_elem=1.5e-6, per_ring_byte=2.0e-7,
                 stage_base=0.15)


@dataclasses.dataclass(frozen=True)
class DistPlan:
    """Executable distributed decision for ``distributed_masked_spgemm``."""

    route: str                    # "row" | "ring"
    p: int                        # ring/mesh axis size
    tile_block: int               # BCSR block size for the ring (0 = n/a)
    row_algorithm: str            # row kernel if route == "row"
    costs: Tuple[Tuple[str, float], ...]
    stats: PlanStats

    def cost(self, route: str) -> float:
        return dict(self.costs)[route]


def ring_cost_features(stats: PlanStats, p: int, bs: int
                       ) -> Tuple[Dict[str, float], Dict[str, float]]:
    """``(tile_features, comm_features)`` of the sparse-ring model:
    ``ring_cost`` dots the first with ``TILE_COST`` and the second with
    ``DIST_COST`` (the calibration fit reuses both).

    The tile part is the tile route's host/mac/gather decomposition with
    the MACs split ``p`` ways; the comm part is ``p`` ppermute stages of
    the padded value+pattern B slab panel.
    """
    m_blocks, b_blocks, pair = _block_counts(stats, bs)
    worklist = m_blocks * pair + p * m_blocks  # + zero-fills/stage
    tile_f = _tile_feature_dict(stats, worklist, bs, float(p))
    # one padded slab panel (values + pattern blocks) moves per rotation;
    # both ring implementations peel the final stage, so p stages transmit
    # only p - 1 rotations (none at p = 1)
    slab_bytes = (b_blocks / p) * bs * bs * 4.0 * 2.0
    comm_f = {"per_ring_byte": slab_bytes * (p - 1),
              "stage_base": float(p)}
    return tile_f, comm_f


def ring_cost(stats: PlanStats, p: int, bs: int) -> float:
    """Modeled total ms of the sparse BCSR ring at ``p`` devices, block
    size ``bs``."""
    tile_f, comm_f = ring_cost_features(stats, p, bs)
    return (sum(TILE_COST[k] * tile_f[k] for k in tile_f)
            + sum(DIST_COST[k] * comm_f[k] for k in comm_f))


def ring_block_candidates(m: int, k: int, n: int) -> Tuple[int, ...]:
    """BCSR block sizes the ring/tile routes may use for an (m, k, n)
    product, largest first — the single source the planner's cost scan and
    the executors' defaults share."""
    lo = max(8, min(m, k, n))
    return tuple(bs for bs in TILE_BLOCK_SIZES if bs <= lo) \
        or (TILE_BLOCK_SIZES[-1],)


def row_replication_elems(stats: PlanStats, row_alg: str) -> float:
    """Elements of B the row route replicates to every device: padded B
    (k x wb) for the row-major kernels, padded B^T (n x wbt) when the
    elected row kernel is Inner.  Shared with the calibration fit (the
    ``per_bcast_elem`` feature)."""
    return float(stats.n * stats.wbt if row_alg == "inner"
                 else stats.k * stats.wb)


def _distributed_decision(stats: PlanStats, p: int
                          ) -> Tuple[Tuple[Tuple[str, float], ...], str, int]:
    """(costs, row_algorithm, ring tile_block) — each modeled exactly once.
    """
    from repro.kernels.masked_matmul.ops import tile_path_supported
    row_alg, row_compute = rank_algorithms(stats)[0]
    costs = [("row", row_compute / p + DIST_COST["per_bcast_elem"]
              * row_replication_elems(stats, row_alg))]
    tile_block = 0
    if tile_path_supported(stats.semiring, stats.complement):
        by_bs = {bs: ring_cost(stats, p, bs)
                 for bs in ring_block_candidates(stats.m, stats.k, stats.n)}
        tile_block = min(by_bs, key=by_bs.get)
        costs.append(("ring", by_bs[tile_block]))
    return (tuple(sorted(costs, key=lambda kv: (kv[1], kv[0]))),
            row_alg, tile_block)


def distributed_costs(stats: PlanStats, p: int
                      ) -> Tuple[Tuple[str, float], ...]:
    """(route, modeled ms) pairs for the mesh, cheapest first.  The ring
    entry reports the best block size's cost; when the tile kernels cannot
    express the product only the row route is listed."""
    return _distributed_decision(stats, p)[0]


def decide_distributed(stats: PlanStats, p: int) -> DistPlan:
    """Pure distributed decision: statistics + mesh size -> DistPlan."""
    costs, row_alg, tile_block = _distributed_decision(stats, p)
    return DistPlan(
        route=costs[0][0], p=p, tile_block=tile_block,
        row_algorithm=row_alg, costs=costs, stats=stats)


def plan_distributed(A: CSR, B: CSR, M: CSR, p: int, *,
                     complement: bool = False,
                     semiring: Semiring = PLUS_TIMES,
                     use_cache: bool = True) -> DistPlan:
    """Cached distributed decision: the mesh counterpart of ``plan``.

    Keyed on the operands' structural signatures + ring size, sharing the
    planner's LRU — repeated structures (the serving case) skip the
    symbolic probe and the cost model entirely.
    """
    key = None
    if use_cache:
        key = (structure_signature(A), structure_signature(B),
               structure_signature(M), p, complement, semiring.name, "dist",
               cost_model_token())
        hit = _cache_get(key)
        if hit is not None:
            return hit
    stats = collect_stats(A, B, M, complement=complement, semiring=semiring)
    d = decide_distributed(stats, p)
    if use_cache:
        _cache_put(key, d)
    return d


# ---------------------------------------------------------------------------
# Measured trial: resolve modeled near-ties empirically (cached with the plan)
# ---------------------------------------------------------------------------


def _trial_candidates(p: Plan) -> Tuple[str, ...]:
    best_cost = p.costs[0][1]
    cand = tuple(name for name, c in p.costs[:TRIAL_MAX_CANDIDATES]
                 if c <= best_cost * TRIAL_RATIO)
    return cand if len(cand) >= 2 else ()


#: measured-trial winners memoized by coarse shape class, so iterative
#: algorithms (k-truss, BC) whose operand structure drifts every iteration
#: pay for at most one trial per shape class, not one per iteration
_trial_winners: Dict[tuple, str] = {}
_TRIAL_MEMO_CAPACITY = 256
caches.register("planner-trials",
                clear=_trial_winners.clear,
                size=lambda: len(_trial_winners),
                capacity=lambda: _TRIAL_MEMO_CAPACITY)


def _shape_class(s: PlanStats) -> tuple:
    b = int.bit_length  # log2 buckets: widths within 2x share a class
    return (s.m, s.k, s.n, b(s.wa), b(s.wb), b(s.wbt), b(s.pm),
            s.semiring, s.complement)


def _refine_with_trial(A: CSR, B: CSR, M: CSR, p: Plan,
                       semiring: Semiring) -> Plan:
    """Time the near-tied candidates once on the real operands and keep the
    winner.  Plans are cached by structure, so the trial is a one-time cost
    amortized over every later call with the same shapes (the serving
    case); clearly-ranked plans never pay it."""
    import time
    from .masked_spgemm import masked_spgemm  # deferred: no import cycle

    cand = _trial_candidates(p)
    if not cand:
        return p
    s = p.stats
    memo_key = _shape_class(s)
    with _cache_lock:
        winner = _trial_winners.get(memo_key)
    if winner is not None and winner in cand:
        wb = s.wbt if winner == "inner" else s.wb
        return dataclasses.replace(p, algorithm=winner,
                                   widths=(s.wa, wb, s.pm), trialed=cand)

    def make(name):
        widths = (s.wa, s.wbt if name == "inner" else s.wb, s.pm)
        tb = p.tile_block if name == "tile" else None

        def call():
            out = masked_spgemm(A, B, M, algorithm=name, semiring=semiring,
                                widths=widths, tile_block=tb)
            out.vals.block_until_ready()

        return call

    calls = {name: make(name) for name in cand}
    for call in calls.values():        # compile + warm
        call()
    # interleaved rounds, min per candidate: drift in machine conditions
    # during the trial hits every candidate alike
    timed = {name: float("inf") for name in cand}
    for _ in range(TRIAL_ITERS):
        for name, call in calls.items():
            t0 = time.perf_counter()
            call()
            timed[name] = min(timed[name], time.perf_counter() - t0)
    winner = min(timed, key=timed.get)
    with _cache_lock:
        if len(_trial_winners) >= _TRIAL_MEMO_CAPACITY:
            _trial_winners.clear()
        _trial_winners[memo_key] = winner
    wb = s.wbt if winner == "inner" else s.wb
    return dataclasses.replace(p, algorithm=winner,
                               widths=(s.wa, wb, s.pm), trialed=cand)


# ---------------------------------------------------------------------------
# Plan cache (structural-signature LRU)
# ---------------------------------------------------------------------------

#: default plan-cache entries; override with $REPRO_PLAN_CACHE_CAP or
#: ``repro.caches.set_capacity("planner-plans", n)``
_CACHE_CAPACITY = 128
_cache = caches.LRUCache("planner-plans", _CACHE_CAPACITY,
                         env_var="REPRO_PLAN_CACHE_CAP")
_cache_lock = threading.Lock()


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def cost_model_token() -> str:
    """Identity of the cost model every cached Plan was decided under.

    Combines the active calibration profile's version token with a
    fingerprint of the LIVE constant tables, so both ``repro.tuning.
    activate`` and the legacy hand-retune workflow (mutating
    ``COST_CONSTANTS`` / ``TILE_COST`` / ``DIST_COST`` / the gates in
    place) change every plan-cache key — a plan decided under old
    constants is never served after a retune.
    """
    fp = tuning_profile.fingerprint_tables(
        acc.COST_CONSTANTS, TILE_COST,
        {"min_density": TILE_MIN_DENSITY,
         "min_occupancy": TILE_MIN_OCCUPANCY,
         "min_hit_rate": TILE_MIN_HIT_RATE},
        DIST_COST)
    return f"{tuning_profile.active_version()}-{fp}"


def structure_signature(x) -> tuple:
    """Structural identity of an operand: equal signatures => equal sparsity
    structure (up to CRC collision), values ignored.

    Memoized on the CSR instance: ``indptr``/``indices`` are never mutated
    in place anywhere in the repo (deltas build new CSRs), so the signature
    is stable for the object's lifetime.  The delta path recomputes it for
    the same operands many times per update — hashing the mask's index
    arrays each time would dominate an O(changed rows) patch.
    """
    if isinstance(x, CSR):
        sig = getattr(x, "_structure_sig", None)
        if sig is None:
            sig = ("csr", x.shape, x.nnz, _crc(x.indptr), _crc(x.indices))
            x._structure_sig = sig
        return sig
    if isinstance(x, PaddedCSR):
        # device-resident: identify by the host-visible static structure
        # only (no device sync); callers wanting exact reuse pass a Plan
        return ("padded", x.shape, x.width)
    raise TypeError(f"unsupported operand type {type(x)!r}")


def plan_cache_info() -> Dict[str, int]:
    return _cache.info()


def clear_plan_cache() -> None:
    _cache.clear()
    with _cache_lock:
        _trial_winners.clear()


def _cache_get(key) -> Optional[Plan]:
    return _cache.get(key)


def _cache_put(key, p: Plan) -> None:
    _cache.put(key, p)


#: serializes plan construction per key stripe: concurrent misses on the
#: SAME structure (async serving submitters racing the worker) must
#: resolve to ONE plan — the measured trial is load-dependent, so two
#: racing trials can elect different near-tied kernels and the stream
#: would mix plans that the one-shot path (reading the finally-cached
#: plan) never saw.  Striped so one structure's trial (tens of ms) does
#: not convoy unrelated structures' planning.
_PLAN_LOCK_STRIPES = 16
_plan_build_locks = tuple(threading.Lock()
                          for _ in range(_PLAN_LOCK_STRIPES))


def _plan_build_lock(key) -> threading.Lock:
    return _plan_build_locks[hash(key) % _PLAN_LOCK_STRIPES]


def plan(A, B, M, *, complement: bool = False,
         semiring: Semiring = PLUS_TIMES, use_cache: bool = True) -> Plan:
    """Plan C = M (.) (A B): cached decision on structural signatures.

    ``A``/``B``/``M`` are host ``CSR`` (the common entry); ``PaddedCSR``
    operands are planned from their static widths without a probe.
    """
    def build() -> Plan:
        if isinstance(A, CSR) and isinstance(B, CSR) and isinstance(M, CSR):
            stats = collect_stats(A, B, M, complement=complement,
                                  semiring=semiring)
        else:  # device-resident operands: widths are already static
            m, k = A.shape
            _, n = B.shape
            stats = PlanStats(
                m=m, k=k, n=n,
                nnz_a=m * A.width if isinstance(A, PaddedCSR) else A.nnz,
                nnz_b=(B.shape[0] * B.width if isinstance(B, PaddedCSR)
                       else B.nnz),
                nnz_m=m * M.width if isinstance(M, PaddedCSR) else M.nnz,
                wa=A.width if isinstance(A, PaddedCSR) else _max_row_nnz(A),
                wb=B.width if isinstance(B, PaddedCSR) else _max_row_nnz(B),
                wbt=B.width if isinstance(B, PaddedCSR) else _max_col_nnz(B),
                pm=M.width if isinstance(M, PaddedCSR) else _max_row_nnz(M),
                complement=complement, semiring=semiring.name,
                b_transposable=not isinstance(B, PaddedCSR))
        p = decide(stats)
        if (not complement and stats.m >= TRIAL_MIN_ROWS
                and isinstance(A, CSR) and isinstance(B, CSR)
                and isinstance(M, CSR)):
            p = _refine_with_trial(A, B, M, p, semiring)
        return p

    def traced_build() -> Plan:
        # the cold path only: cache hits must stay span-free (they are
        # the serving steady state and the disabled-cost contract's
        # hottest call site)
        with obs.span("plan.build") as sp:
            p = build()
            if obs.enabled():
                sp.set(algorithm=p.algorithm, explain=explain_cached(p))
        return p

    if not use_cache:
        return traced_build()
    key = (structure_signature(A), structure_signature(B),
           structure_signature(M), complement, semiring.name,
           cost_model_token())
    hit = _cache_get(key)
    if hit is not None:
        return hit
    # double-checked build: concurrent misses on one structure (async
    # serving) must all observe the SAME plan — racing measured trials can
    # elect different near-tied kernels
    with _plan_build_lock(key):
        hit = _cache.peek(key)
        if hit is not None:
            return hit
        p = traced_build()
        _cache_put(key, p)
    return p


#: relative drift in nnz / pad widths a revalidation tolerates before
#: falling back to a cold plan: small deltas move the cost-model inputs a
#: little, and the hooks' rankings are stable well past 25%; re-planning
#: inside the band would thrash (delta -> cold plan -> delta -> cold plan)
#: for exactly the streams the delta path exists for
REVALIDATE_HYSTERESIS = 0.25


def _within_band(new: float, old: float, band: float) -> bool:
    lo = old / (1.0 + band)
    hi = old * (1.0 + band)
    return lo <= max(new, 1e-12) <= hi if old > 0 else new <= 1


def revalidate(old: Plan, A: CSR, B: CSR, M: CSR, *,
               complement: bool = False,
               semiring: Semiring = PLUS_TIMES,
               use_cache: bool = True) -> Tuple[Plan, bool]:
    """Cheap plan refresh after a delta: ``(plan, survived)``.

    Re-checks the elected kernel's cost-model inputs (pad widths, nnz,
    tile-gate densities) against the post-delta operands WITHOUT the
    symbolic probe or a measured trial.  While every input stays inside
    the ``REVALIDATE_HYSTERESIS`` band and the elected kernel is still
    ranked within ``TRIAL_RATIO`` of the cheapest, the old plan survives —
    widths widened to cover the new operands, re-stamped into the plan
    cache under the post-delta structure signatures with the same
    ``cost_model_token()``.  Anything else falls back to a cold ``plan()``
    (``survived=False``).
    """
    def cold() -> Tuple[Plan, bool]:
        obs.event("plan.revalidate", survived=False,
                  algorithm=old.algorithm)
        return (plan(A, B, M, complement=complement, semiring=semiring,
                     use_cache=use_cache), False)

    if not (isinstance(A, CSR) and isinstance(B, CSR) and isinstance(M, CSR)):
        return cold()
    s0 = old.stats
    if ((s0.m, s0.k, s0.n) != (A.shape[0], A.shape[1], B.shape[1])
            or s0.complement != complement or s0.semiring != semiring.name):
        return cold()

    s1 = collect_stats(A, B, M, complement=complement, semiring=semiring,
                       probe=False)
    band = REVALIDATE_HYSTERESIS
    drifted = not all((
        _within_band(s1.nnz_a, s0.nnz_a, band),
        _within_band(s1.nnz_b, s0.nnz_b, band),
        _within_band(s1.nnz_m, s0.nnz_m, band),
        _within_band(s1.wa, s0.wa, band),
        _within_band(s1.wb, s0.wb, band),
        _within_band(s1.wbt, s0.wbt, band),
        _within_band(s1.pm, s0.pm, band),
    ))
    if drifted:
        return cold()

    # carry the probe estimates forward, scaled by the nnz drift (the only
    # consumer below is the tile gate's hit-rate test; the row-kernel cost
    # hooks read widths alone) — a re-probe is exactly what we are avoiding
    fa = s1.nnz_a / max(1, s0.nnz_a)
    fb = s1.nnz_b / max(1, s0.nnz_b)
    fm = s1.nnz_m / max(1, s0.nnz_m)
    s1 = dataclasses.replace(s1, flops=s0.flops * fa * fb,
                             out_nnz=s0.out_nnz * fm)

    costs = rank_algorithms(s1)
    tile_eligible, tile_block = _tile_path(s1)
    if tile_eligible and s1.flops > 0:
        costs = tuple(sorted(costs + (("tile", tile_cost(s1, tile_block)),),
                             key=lambda kv: (kv[1], kv[0])))
    by_name = dict(costs)
    if old.algorithm == "tile":
        if not tile_eligible:
            return cold()
    elif (old.algorithm not in by_name
          or by_name[old.algorithm] > costs[0][1] * TRIAL_RATIO):
        return cold()

    wb = s1.wbt if old.algorithm == "inner" else s1.wb
    kept = dataclasses.replace(
        old, widths=(s1.wa, wb, s1.pm), stats=s1, costs=costs,
        tile_eligible=tile_eligible,
        tile_block=tile_block if tile_eligible else old.tile_block)
    if use_cache:
        key = (structure_signature(A), structure_signature(B),
               structure_signature(M), complement, semiring.name,
               cost_model_token())
        _cache_put(key, kept)
    obs.event("plan.revalidate", survived=True, algorithm=kept.algorithm)
    return kept, True


def explain(p) -> Dict:
    """Why the planner elected what it elected, as one JSON-safe record.

    Works for both :class:`Plan` and :class:`DistPlan`.  Returns the
    elected algorithm/route, every candidate's modeled cost (ms), the
    per-candidate COST_FEATURES decomposition the linear model dotted
    with its fitted constants (so a reader can recompute each cost from
    the record), the driving statistics, and the ``cost_model_token()``
    identifying the calibration the decision was made under.  Attached
    to every ``plan.build`` span, this is what lets production traces
    yield modeled-vs-measured residuals for ``repro.tune``.
    """
    s = p.stats
    stats_d = {f.name: (getattr(s, f.name))
               for f in dataclasses.fields(PlanStats)}
    stats_d["compression"] = float(s.compression)
    stats_d["mask_density"] = float(s.mask_density)
    costs = {name: float(c) for name, c in p.costs}
    scale = s.m / 1024.0
    features: Dict[str, Dict[str, float]] = {}
    for name in costs:
        if name in acc.COST_FEATURES:
            feats = acc.COST_FEATURES[name](
                n=s.n, wa=s.wa, wb=s.wb, wbt=s.wbt, pm=s.pm)
            features[name] = {k: float(v) for k, v in feats.items()}
    out: Dict = {
        "costs_ms": costs,
        "cost_scale_rows": float(scale),
        "features": features,
        "stats": stats_d,
        "cost_model_token": cost_model_token(),
    }
    if isinstance(p, DistPlan):
        out["elected"] = p.route
        out["route"] = p.route
        out["p"] = p.p
        out["row_algorithm"] = p.row_algorithm
        if p.tile_block:
            tile_f, comm_f = ring_cost_features(s, p.p, p.tile_block)
            features["ring"] = {
                **{k: float(v) for k, v in tile_f.items()},
                **{k: float(v) for k, v in comm_f.items()}}
        out["elected_cost_ms"] = costs.get(p.route)
    else:
        out["elected"] = p.algorithm
        out["algorithm"] = p.algorithm
        out["widths"] = list(p.widths)
        out["two_phase"] = p.two_phase
        out["tile"] = {"eligible": p.tile_eligible,
                       "block": p.tile_block}
        out["trialed"] = list(p.trialed)
        if "tile" in costs and p.tile_block:
            features["tile"] = {
                k: float(v)
                for k, v in tile_cost_features(s, p.tile_block).items()}
        out["elected_cost_ms"] = costs.get(p.algorithm)
    return out


#: memo for per-bucket span attachment — explain() costs ~100us (feature
#: recomputation), far above the ~5us span budget, and serving re-emits
#: it on every bucket execution of the same immutable plan.  Registered
#: in the bounded ``repro.caches`` registry with an env-configurable cap
#: so long-lived engines cycling many plans cannot grow it unboundedly.
_explain_memo = caches.LRUCache("planner-explain", 256,
                                env_var="REPRO_EXPLAIN_MEMO_CAP")


def explain_cached(p) -> Dict:
    """Memoized :func:`explain` keyed by plan identity.  Safe because
    plans are frozen and the memo entry pins the plan object (its id
    cannot be recycled while the record is servable); the cost-model
    token cannot drift under a live plan — re-planning on token change
    produces a fresh object."""
    hit = _explain_memo.get(id(p))
    if hit is not None and hit[0] is p:
        return hit[1]
    info = explain(p)
    _explain_memo.put(id(p), (p, info))
    return info


def feature_regime(p) -> str:
    """Coarse log-bucketed feature signature of a plan's operands — the
    drift detector's per-regime key.

    The paper's finding (and PR 4's fitted constants) is that the right
    kernel swings with size, row widths and densities; a cost model can
    be calibrated in one regime and stale in another.  Buckets are
    log2 for sizes/widths and log10 for densities, coarse enough that
    one serving workload lands in a handful of regimes (bounded drift
    state) yet fine enough to separate the paper's density sweeps.
    Works for row, tile and distributed plans — anything carrying
    ``PlanStats``.
    """
    s = p.stats

    def b2(x) -> int:
        return int(math.log2(max(1, int(x))))

    def b10(d: float) -> int:
        return int(math.floor(math.log10(max(d, 1e-9))))

    dens_a = s.nnz_a / max(1, s.m * s.k)
    dens_m = s.nnz_m / max(1, s.m * s.n)
    return (f"m{b2(s.m)}n{b2(s.n)}w{b2(s.pm)}"
            f"da{b10(dens_a)}dm{b10(dens_m)}")


def plan_batch(As: Sequence[CSR], B, Ms: Sequence[CSR], *,
               complement: bool = False,
               semiring: Semiring = PLUS_TIMES,
               allow_tile: bool = False) -> Plan:
    """One Plan for a batch of same-shape operands sharing B.

    Statistics come from the first (A, M) pair; pad widths are widened to
    the batch maxima so a single compiled program fits every element.  The
    cache key covers the whole batch's structure.  ``allow_tile=True`` lets
    the tile route into the ranking: the batched driver now serves it
    per-element on the shared block executor (the serving engine's case);
    the default keeps batches on the single vmapped row program.
    """
    if not As or len(As) != len(Ms):
        raise ValueError("batch needs equal-length non-empty As/Ms")
    key = (tuple(structure_signature(a) for a in As),
           structure_signature(B),
           tuple(structure_signature(m) for m in Ms),
           complement, semiring.name, "batch", allow_tile,
           cost_model_token())
    hit = _cache_get(key)
    if hit is not None:
        return hit

    def width(x):
        return x.width if isinstance(x, PaddedCSR) else _max_row_nnz(x)

    if (isinstance(As[0], CSR) and isinstance(B, CSR)
            and isinstance(Ms[0], CSR)):
        stats = collect_stats(As[0], B, Ms[0], complement=complement,
                              semiring=semiring)
    else:
        m, k = As[0].shape
        _, n = B.shape
        stats = PlanStats(
            m=m, k=k, n=n, nnz_a=m * width(As[0]),
            nnz_b=B.shape[0] * width(B), nnz_m=m * width(Ms[0]),
            wa=width(As[0]), wb=width(B),
            wbt=width(B) if isinstance(B, PaddedCSR) else _max_col_nnz(B),
            pm=width(Ms[0]), complement=complement, semiring=semiring.name)
    stats = dataclasses.replace(
        stats, wa=max(width(a) for a in As), pm=max(width(m) for m in Ms),
        b_transposable=not isinstance(B, PaddedCSR))
    # one vmapped row program serves the whole batch; the tile route only
    # enters when the caller can execute it per element (serving engine)
    p = decide(stats, allow_tile=allow_tile)

    _cache_put(key, p)
    return p


# A fitted calibration profile named by $REPRO_TUNE_PROFILE is installed
# as soon as the planner exists (this module's tables are the ones it
# overwrites), so benchmarks, CI jobs, and the distributed bench's child
# interpreters all run under the same fitted constants without code
# changes.  Errors propagate: a calibration that silently failed to apply
# would invalidate every measurement made under it.
tuning_profile.activate_from_env()
