"""GraphBLAS-style semirings for masked sparse products.

The paper's algorithms are defined over an arbitrary semiring (Sec. 2); the
graph apps use PLUS_TIMES (triangle counting / k-truss support counts) and
PLUS_FIRST / boolean semirings (BFS-like traversals in betweenness
centrality).  A semiring is (add, mul, zero); ``add`` must be associative and
commutative with identity ``zero``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    add: Callable
    mul: Callable
    zero: float

    def matmul(self, a, b):
        """Dense *structural* matmul under this semiring: (m,k) x (k,n).

        Entries equal to literal 0 in a/b are treated as structurally absent
        (contributing the semiring zero, not mul(0, .)), matching sparse
        semantics where only stored nonzeros generate products.
        """
        if self.name == "plus_times":
            return a @ b
        # generic (slow) path: broadcast over k, mask absent products
        both = (a != 0)[:, :, None] & (b != 0)[None, :, :]
        prod = jnp.where(both, self.mul(a[:, :, None], b[None, :, :]),
                         self.zero)  # (m, k, n)
        out = prod[:, 0, :]
        k = prod.shape[1]
        for i in range(1, k):
            out = self.add(out, prod[:, i, :])
        return out


PLUS_TIMES = Semiring("plus_times", jnp.add, jnp.multiply, 0.0)
# OR-AND over {0,1} floats
OR_AND = Semiring("or_and", lambda x, y: jnp.maximum(x, y),
                  lambda x, y: jnp.minimum(jnp.sign(jnp.abs(x)), jnp.sign(jnp.abs(y))), 0.0)
# min-plus (tropical): zero is +inf
MIN_PLUS = Semiring("min_plus", jnp.minimum, jnp.add, jnp.inf)
# plus_first: mul(a, b) = a  (used for frontier expansion where B is pattern)
PLUS_FIRST = Semiring("plus_first", jnp.add, lambda x, y: x, 0.0)
# plus_second: mul(a, b) = b
PLUS_SECOND = Semiring("plus_second", jnp.add, lambda x, y: y, 0.0)

REGISTRY = {s.name: s for s in
            (PLUS_TIMES, OR_AND, MIN_PLUS, PLUS_FIRST, PLUS_SECOND)}
