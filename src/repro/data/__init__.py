from .pipeline import SyntheticLM, batch_for
