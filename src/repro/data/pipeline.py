"""Deterministic, stateless, elastic data pipeline.

Every batch is a pure function of (global_step) — no iterator state, no
files.  Consequences the large-scale runbook relies on:

* **exact restart**: resuming from a checkpoint at step k replays exactly
  the batches >= k (fault tolerance without data-state checkpoints);
* **elastic resharding**: a host only materializes its slice of the global
  batch; when the healthy-device set changes, the new mesh just maps
  different slices — the global stream is unchanged.

The synthetic LM stream is a mixture of Zipf-distributed unigrams and
copy/induction segments so small models show real learning signal (loss
drops well below the unigram entropy) in the end-to-end example.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    copy_frac: float = 0.5           # fraction of induction-copy segments
    segment: int = 32

    def global_batch_at(self, step: int) -> np.ndarray:
        """(global_batch, seq_len+1) int32 — deterministic in step."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        b, s = self.global_batch, self.seq_len + 1
        # zipf unigrams (clipped to vocab)
        base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
        base = (base - 1) % self.vocab_size
        # induction segments: periodic copies of a short motif
        n_seg = s // self.segment
        for i in range(b):
            if rng.random() < self.copy_frac and n_seg >= 2:
                motif = rng.integers(0, self.vocab_size, self.segment)
                reps = np.tile(motif, n_seg + 1)[:s]
                base[i] = reps
        return base.astype(np.int32)

    def shard_at(self, step: int, shard: int, n_shards: int) -> np.ndarray:
        """This host's rows of the global batch (elastic-safe slicing)."""
        g = self.global_batch_at(step)
        per = self.global_batch // n_shards
        return g[shard * per:(shard + 1) * per]


def batch_for(cfg: ModelConfig, pipe: SyntheticLM, step: int,
              rng_seed: int = 0) -> Dict[str, Any]:
    """Assemble the model-family batch dict from the token stream."""
    raw = pipe.global_batch_at(step)
    tokens, labels = raw[:, :-1], raw[:, 1:]
    out = {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}
    rng = np.random.default_rng(np.random.SeedSequence([rng_seed, 7, step]))
    if cfg.family == "vlm":
        s_txt = tokens.shape[1] - cfg.img_tokens
        out["tokens"] = out["tokens"][:, :s_txt]
        out["labels"] = out["labels"][:, :s_txt]
        out["patches"] = jnp.asarray(
            rng.standard_normal((tokens.shape[0], cfg.img_tokens,
                                 cfg.d_frontend)),
            cfg.activation_dtype) * 0.2
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.standard_normal((tokens.shape[0], tokens.shape[1],
                                 cfg.d_frontend or cfg.d_model)),
            cfg.activation_dtype) * 0.2
    return out
