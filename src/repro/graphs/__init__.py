"""Graph applications from the paper's evaluation (§7-8): Triangle Counting,
k-truss, and Betweenness Centrality, written against the Masked SpGEMM
primitive exactly as a GraphBLAS user would."""
from .triangle_counting import triangle_count
from .ktruss import ktruss
from .betweenness import betweenness_centrality
