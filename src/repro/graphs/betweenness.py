"""Batched (multi-source) Betweenness Centrality via Masked SpGEMM
(paper §8.4; Brandes [8] in GraphBLAS form [11]).

The forward sweep uses the *complemented* mask (avoid re-discovering visited
vertices) — the paper's motivating use of mask complement:

    F_{d+1} = ¬Visited ⊙ (F_d @ A)

and the backward sweep uses a normal masked SpGEMM per depth:

    W = Sigma_{d-1} ⊙ (W @ Aᵀ)

Only MSA (and Heap) support the complement (MCA cannot, §8.4) — callers pick
``algorithm`` accordingly; the backward mask is unrestricted.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.formats import CSR, csr_from_dense
from repro.core.masked_spgemm import masked_spgemm
from repro.core.semiring import PLUS_TIMES


def betweenness_centrality(adj: CSR, sources: Optional[Sequence[int]] = None,
                           *, algorithm: str = "msa",
                           backward_algorithm: Optional[str] = None,
                           two_phase: bool = False
                           ) -> Tuple[np.ndarray, float, int]:
    """Returns (bc values (n,), masked-spgemm seconds, #spgemm calls).

    ``adj``: symmetric 0/1 adjacency (undirected), no self-loops.
    ``sources``: batch of source vertices (default: all).
    Unnormalized, endpoints excluded, each unordered pair counted once.
    """
    n = adj.shape[0]
    At = adj.transpose()
    sources = np.arange(n) if sources is None else np.asarray(sources)
    b = len(sources)
    backward_algorithm = backward_algorithm or (
        algorithm if algorithm not in ("mca",) else "msa")

    spgemm_time = 0.0
    calls = 0

    # ---- forward: BFS wave with #shortest-paths accumulation -------------
    num_sp = np.zeros((b, n), np.float32)
    num_sp[np.arange(b), sources] = 1.0
    frontier = num_sp.copy()
    sigmas = []                                   # per-depth path counts
    while True:
        f_csr = csr_from_dense(frontier)
        if f_csr.nnz == 0:
            break
        visited_mask = csr_from_dense((num_sp != 0).astype(np.float32))
        t0 = time.perf_counter()
        vals, present = masked_spgemm(f_csr, adj, visited_mask,
                                      algorithm=algorithm,
                                      semiring=PLUS_TIMES, complement=True,
                                      two_phase=two_phase)
        spgemm_time += time.perf_counter() - t0
        calls += 1
        frontier = np.where(np.asarray(present), np.asarray(vals), 0.0)
        if not frontier.any():
            break
        sigmas.append(frontier.copy())
        num_sp += frontier

    # ---- backward: dependency accumulation -------------------------------
    bcu = np.ones((b, n), np.float32)
    inv_sp = np.where(num_sp != 0, 1.0 / np.maximum(num_sp, 1e-30), 0.0)
    for d in range(len(sigmas) - 1, 0, -1):
        w = np.where(sigmas[d] != 0, bcu * inv_sp, 0.0)
        w_csr = csr_from_dense(w)
        mask = csr_from_dense((sigmas[d - 1] != 0).astype(np.float32))
        t0 = time.perf_counter()
        out = masked_spgemm(w_csr, At, mask, algorithm=backward_algorithm,
                            semiring=PLUS_TIMES, two_phase=two_phase)
        spgemm_time += time.perf_counter() - t0
        calls += 1
        w_next = np.asarray(out.to_dense())
        bcu += w_next * num_sp
    # depth-0 wave (sources' own row) contributes no centrality

    bc = (bcu - 1.0).sum(axis=0)
    bc[sources] -= 0.0                            # endpoints already excluded
    return bc / 2.0, spgemm_time, calls


def bc_teps(adj: CSR, seconds: float, batch: int) -> float:
    """Paper §8.4 metric: batch_size * num_edges / total_time."""
    return batch * adj.nnz / max(seconds, 1e-12)
