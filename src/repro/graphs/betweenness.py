"""Batched (multi-source) Betweenness Centrality via Masked SpGEMM
(paper §8.4; Brandes [8] in GraphBLAS form [11]).

The forward sweep uses the *complemented* mask (avoid re-discovering visited
vertices) — the paper's motivating use of mask complement:

    F_{d+1} = ¬Visited ⊙ (F_d @ A)

and the backward sweep uses a normal masked SpGEMM per depth:

    W = Sigma_{d-1} ⊙ (W @ Aᵀ)

Only MSA (and Heap) support the complement (MCA cannot, §8.4) — callers pick
``algorithm`` accordingly; the backward mask is unrestricted.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.core.formats import CSR, csr_from_dense
from repro.core.masked_spgemm import masked_spgemm, masked_spgemm_batched
from repro.core.semiring import PLUS_TIMES


def _chunk_rows(dense: np.ndarray, chunks: int):
    """Split a (b, n) operand row-wise into ``chunks`` equal CSR pieces
    (the last is zero-padded), for the batched one-plan driver."""
    b, n = dense.shape
    size = -(-b // chunks)
    out = []
    for c in range(chunks):
        piece = np.zeros((size, n), dense.dtype)
        rows = dense[c * size:(c + 1) * size]
        piece[: len(rows)] = rows
        out.append(csr_from_dense(piece))
    return out, size


def betweenness_centrality(adj: CSR, sources: Optional[Sequence[int]] = None,
                           *, algorithm: str = "auto",
                           backward_algorithm: Optional[str] = None,
                           two_phase: bool = False, source_chunks: int = 1,
                           engine=None) -> Tuple[np.ndarray, float, int]:
    """Returns (bc values (n,), masked-spgemm seconds, #spgemm calls).

    ``adj``: symmetric 0/1 adjacency (undirected), no self-loops.
    ``sources``: batch of source vertices (default: all).
    ``source_chunks`` > 1 splits the source batch into that many same-shape
    chunks per sweep and runs them through ``masked_spgemm_batched``: one
    plan and one vmapped program per depth instead of a dispatch per chunk
    (the paper's multi-source batching, Sec. 8.4).
    Unnormalized, endpoints excluded, each unordered pair counted once.

    ``engine``: a ``repro.serving.QueryEngine`` — BC becomes a serving
    client: each chunk is submitted as a query and the engine's batcher
    reassembles the per-depth batch (same shapes, shared B), so BC traffic
    coexists with — and batches against — other streams hitting the same
    engine.  Results are equivalent to the direct driver up to float
    summation order: the engine plans per chunk where the direct path
    plans the whole batch once, and near-tied plans may elect different
    (equally correct) kernels whose accumulation orders differ in the
    last ulp.
    """
    if two_phase and source_chunks > 1:
        raise ValueError("two_phase is not supported by the batched "
                         "(source_chunks > 1) driver")
    if engine is not None and two_phase:
        raise ValueError("two_phase is not supported by the serving engine")
    n = adj.shape[0]
    At = adj.transpose()
    sources = np.arange(n) if sources is None else np.asarray(sources)
    b = len(sources)
    # the forward sweep runs under complement=True; hash/mca/inner cannot
    # complement (paper Sec. 8.4) and would raise mid-sweep — coerce them to
    # msa up front ("auto" plans the complement itself; msa/heap* pass
    # through).  The backward sweep has a normal mask, so the caller's
    # algorithm is fine there; its default only avoids inheriting a
    # forward-coerced choice where the original works.
    complement_capable = ("auto", "msa", "heap", "heapdot")
    forward_algorithm = (algorithm if algorithm in complement_capable
                         else "msa")
    backward_algorithm = backward_algorithm or algorithm

    spgemm_time = 0.0
    calls = 0

    def _serve_batch(As_, B_, Ms_, algo, complement):
        """Run one per-depth chunk batch through the serving engine: one
        ticket per chunk; the engine's batcher re-fuses the same-shape
        tickets into one plan + one vmapped program."""
        forced = None if algo == "auto" else algo
        tickets = [engine.submit(a, B_, mm, complement=complement,
                                 algorithm=forced)
                   for a, mm in zip(As_, Ms_)]
        engine.flush()
        outs = [t.result() for t in tickets]
        if complement:
            return (np.stack([np.asarray(v) for v, _ in outs]),
                    np.stack([np.asarray(p) for _, p in outs]))
        return outs

    def _serve_one(A_, B_, M_, algo, complement):
        forced = None if algo == "auto" else algo
        return engine.submit(A_, B_, M_, complement=complement,
                             algorithm=forced).result()

    # ---- forward: BFS wave with #shortest-paths accumulation -------------
    num_sp = np.zeros((b, n), np.float32)
    num_sp[np.arange(b), sources] = 1.0
    frontier = num_sp.copy()
    sigmas = []                                   # per-depth path counts
    while True:
        if not frontier.any():
            break
        visited = (num_sp != 0).astype(np.float32)
        # host-side format conversion is untimed (as before this PR): the
        # timed quantity feeding bc_teps is masked-spgemm device time only
        if source_chunks > 1:
            f_chunks, _ = _chunk_rows(frontier, source_chunks)
            v_chunks, _ = _chunk_rows(visited, source_chunks)
            t0 = time.perf_counter()
            if engine is not None:
                vals, present = _serve_batch(f_chunks, adj, v_chunks,
                                             forward_algorithm, True)
            else:
                vals, present = masked_spgemm_batched(
                    f_chunks, adj, v_chunks, algorithm=forward_algorithm,
                    semiring=PLUS_TIMES, complement=True)
            spgemm_time += time.perf_counter() - t0
            vals = np.asarray(vals).reshape(-1, n)[:b]
            present = np.asarray(present).reshape(-1, n)[:b]
        else:
            f_csr = csr_from_dense(frontier)
            visited_mask = csr_from_dense(visited)
            t0 = time.perf_counter()
            if engine is not None:
                vals, present = _serve_one(f_csr, adj, visited_mask,
                                           forward_algorithm, True)
            else:
                vals, present = masked_spgemm(f_csr, adj, visited_mask,
                                              algorithm=forward_algorithm,
                                              semiring=PLUS_TIMES,
                                              complement=True,
                                              two_phase=two_phase)
            spgemm_time += time.perf_counter() - t0
            vals, present = np.asarray(vals), np.asarray(present)
        calls += 1
        frontier = np.where(present, vals, 0.0)
        if not frontier.any():
            break
        sigmas.append(frontier.copy())
        num_sp += frontier

    # ---- backward: dependency accumulation -------------------------------
    bcu = np.ones((b, n), np.float32)
    inv_sp = np.where(num_sp != 0, 1.0 / np.maximum(num_sp, 1e-30), 0.0)
    for d in range(len(sigmas) - 1, 0, -1):
        w = np.where(sigmas[d] != 0, bcu * inv_sp, 0.0)
        mask_dense = (sigmas[d - 1] != 0).astype(np.float32)
        if source_chunks > 1:
            w_chunks, _ = _chunk_rows(w, source_chunks)
            m_chunks, _ = _chunk_rows(mask_dense, source_chunks)
            t0 = time.perf_counter()
            if engine is not None:
                outs = _serve_batch(w_chunks, At, m_chunks,
                                    backward_algorithm, False)
            else:
                outs = masked_spgemm_batched(w_chunks, At, m_chunks,
                                             algorithm=backward_algorithm,
                                             semiring=PLUS_TIMES)
            spgemm_time += time.perf_counter() - t0
            w_next = np.concatenate(
                [np.asarray(o.to_dense()) for o in outs])[:b]
        else:
            w_csr = csr_from_dense(w)
            mask = csr_from_dense(mask_dense)
            t0 = time.perf_counter()
            if engine is not None:
                out = _serve_one(w_csr, At, mask, backward_algorithm, False)
            else:
                out = masked_spgemm(w_csr, At, mask,
                                    algorithm=backward_algorithm,
                                    semiring=PLUS_TIMES,
                                    two_phase=two_phase)
            spgemm_time += time.perf_counter() - t0
            w_next = np.asarray(out.to_dense())
        calls += 1
        bcu += w_next * num_sp
    # depth-0 wave (sources' own row) contributes no centrality

    bc = (bcu - 1.0).sum(axis=0)
    return bc / 2.0, spgemm_time, calls


def bc_teps(adj: CSR, seconds: float, batch: int) -> float:
    """Paper §8.4 metric: batch_size * num_edges / total_time."""
    return batch * adj.nnz / max(seconds, 1e-12)
