"""k-truss via iterated Masked SpGEMM (paper §8.3).

The k-truss is the maximal subgraph in which every edge is supported by at
least k-2 triangles.  Each iteration computes every edge's support with one
Masked SpGEMM  S = A .* (A @ A)  (support of edge (i,j) = common neighbors),
prunes under-supported edges, and repeats until a fixed point.
"""
from __future__ import annotations

import time
from typing import Tuple

import numpy as np

from repro.core.formats import CSR, csr_from_coo, _expand_rows
from repro.core.masked_spgemm import masked_spgemm
from repro.core.semiring import PLUS_TIMES


def ktruss(adj: CSR, k: int, *, algorithm: str = "auto",
           two_phase: bool = False, max_iter: int = 100
           ) -> Tuple[CSR, float, int, int]:
    """Returns (truss_adjacency, masked_spgemm_seconds, iterations, flops).

    ``adj``: symmetric 0/1 adjacency, no self-loops.  Only the Masked
    SpGEMM calls are timed; flops is the summed flops(A@A) restricted to
    surviving structure per iteration (the paper's GFLOPS denominator).
    """
    a = adj
    support_needed = k - 2
    spgemm_time = 0.0
    flops = 0
    for it in range(max_iter):
        if a.nnz == 0:
            return a, spgemm_time, it, flops
        t0 = time.perf_counter()
        out = masked_spgemm(a, a, a, algorithm=algorithm,
                            semiring=PLUS_TIMES, two_phase=two_phase)
        spgemm_time += time.perf_counter() - t0
        row_nnz = a.row_nnz()
        flops += int(2 * row_nnz[a.indices].sum())

        present = np.asarray(out.present)
        vals = np.asarray(out.vals)
        rows, slots = np.nonzero(present)
        cols = np.asarray(out.mask_cols)[rows, slots]
        support = vals[rows, slots]
        keep = support >= support_needed
        if keep.sum() == len(_expand_rows(a.indptr)):
            return a, spgemm_time, it + 1, flops
        pruned = csr_from_coo(rows[keep], cols[keep],
                              np.ones(int(keep.sum()), np.float32), a.shape,
                              sum_dups=False)
        if pruned.nnz == a.nnz:
            return pruned, spgemm_time, it + 1, flops
        a = pruned
    return a, spgemm_time, max_iter, flops
