"""Triangle Counting via Masked SpGEMM (paper §8.2).

With vertices relabelled in non-increasing degree order and L the strictly
lower-triangular part of the adjacency matrix, the triangle count is

    #tri = sum( L .* (L @ L) )

(one masked SpGEMM plus a reduction).  (L@L)_{ij} counts k with j < k < i
adjacent to both; masking by L_{ij} keeps each triangle exactly once.
"""
from __future__ import annotations

import time
from typing import Tuple

import numpy as np

from repro.core.formats import CSR, csr_from_coo, tril, _expand_rows
from repro.core.masked_spgemm import masked_spgemm
from repro.core.semiring import PLUS_TIMES


def degree_relabel(a: CSR) -> CSR:
    """Relabel vertices in non-increasing degree order (paper: [29])."""
    deg = a.row_nnz()
    order = np.argsort(-deg, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order))
    rows = rank[_expand_rows(a.indptr)]
    cols = rank[a.indices]
    return csr_from_coo(rows, cols, a.data, a.shape, sum_dups=False)


def triangle_count(adj: CSR, *, algorithm: str = "auto",
                   relabel: bool = True, two_phase: bool = False,
                   widths=None) -> Tuple[int, float]:
    """Returns (#triangles, masked-spgemm seconds).

    ``adj`` must be a symmetric 0/1 adjacency matrix without self-loops.
    Only the Masked SpGEMM is timed (as in the paper's §8.2).
    """
    a = degree_relabel(adj) if relabel else adj
    L = tril(a, strict=True)
    t0 = time.perf_counter()
    out = masked_spgemm(L, L, L, algorithm=algorithm, semiring=PLUS_TIMES,
                        two_phase=two_phase, widths=widths)
    total = float(np.asarray(out.vals[out.present].sum()))
    dt = time.perf_counter() - t0
    return int(round(total)), dt


def tc_flops(adj: CSR) -> int:
    """flops(L@L) = 2 * sum_k nnz(L_k*) over nonzeros L_ik (paper metric)."""
    L = tril(degree_relabel(adj), strict=True)
    row_nnz = L.row_nnz()
    return int(2 * row_nnz[L.indices].sum())
