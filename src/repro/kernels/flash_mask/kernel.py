"""Pull-based block-masked flash attention (Pallas TPU).

This kernel IS the paper's pull algorithm (§4.1) at MXU-tile granularity:
for each *allowed* output tile (q-block), stream the k-dimension tiles
(KV blocks) that the mask admits, and never touch the rest.  The host-built
worklist of (q_block, kv_block) pairs is the mask's block structure; the
streaming softmax is the semiring-style accumulation.  Fully-masked tiles
cost zero flops AND zero memory traffic — the central saving the paper
measures (Fig. 1).

Worklist layout: flat (P,) arrays qi, ki, flags — sorted by qi so the
sequential TPU grid can keep one q-block's accumulator in VMEM.
flags bit0 = first visit of qi (init accumulators), bit1 = last visit
(normalize + flush).

Scratch is (bq, LANES)/(bq, D) f32 in VMEM; running max m and normalizer l
are replicated across the 128-lane minor dimension (Mosaic-friendly).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
NEG_INF = -1e30


def _body(qi_ref, ki_ref, flags_ref, q_ref, k_ref, v_ref, o_ref,
          m_ref, l_ref, acc_ref, *, bq, bk, scale, causal, window, prefix,
          q_offset):
    w = pl.program_id(0)
    first = flags_ref[w] & 1
    last = (flags_ref[w] >> 1) & 1

    @pl.when(first == 1)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (bq, d)
    k = k_ref[0]                                     # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    # parametric element mask inside the tile
    qg = qi_ref[w] * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + q_offset
    kg = ki_ref[w] * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= kg <= qg
    if window > 0:
        ok &= ((qg - kg) < window) | (kg < prefix)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[:, :1]                            # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)       # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                  # (bq, 1)
    p = jnp.exp(s - m_new)                           # (bq, bk)
    p = jnp.where(ok, p, 0.0)
    l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32),
        preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(last == 1)
    def _flush():
        l = l_ref[:, :1]
        o = jnp.where(l > 0, acc_ref[...] / jnp.maximum(l, 1e-30), 0.0)
        o_ref[...] = o.astype(o_ref.dtype)[None]


def build_schedule(s_q: int, s_k: int, *, bq: int, bk: int, causal: bool,
                   window: int, prefix: int, q_offset: int):
    """Host-side symbolic phase: the (q_block, kv_block) worklist.

    A pair enters the worklist iff ANY element of its tile is allowed —
    tile-granular mask structure, exactly BCSR-of-the-mask.  Cost of this
    merge is O(#blocks), done once per (shape, pattern) and cached.
    """
    nq, nk = s_q // bq, s_k // bk
    i = np.arange(nq)[:, None]
    j = np.arange(nk)[None, :]
    q_lo, q_hi = i * bq + q_offset, (i + 1) * bq - 1 + q_offset
    k_lo, k_hi = j * bk, (j + 1) * bk - 1
    # interval test: the tile holds diffs (q-k) in [q_lo-k_hi, q_hi-k_lo]
    ok = np.ones((nq, nk), bool)
    if causal:
        ok &= k_lo <= q_hi
    if window > 0:
        in_win = (q_lo - k_hi) < window
        if causal:
            in_win &= (q_hi - k_lo) >= 0
        else:
            in_win &= (k_lo - q_hi) < window
        ok &= in_win | np.broadcast_to(k_lo < prefix, in_win.shape)
    # degenerate rows (can't happen for our patterns): keep one tile so the
    # accumulator init/flush protocol stays intact
    ok[~ok.any(axis=1), 0] = True

    qi, ki, flags = [], [], []
    for row in range(nq):
        cols = np.nonzero(ok[row])[0]
        f = np.zeros(len(cols), np.int32)
        f[0] |= 1
        f[-1] |= 2
        qi.extend([row] * len(cols)); ki.extend(cols); flags.extend(f)
    return (np.asarray(qi, np.int32), np.asarray(ki, np.int32),
            np.asarray(flags, np.int32))


def flash_mask_kernel(q, k, v, qi, ki, flags, *, bq, bk, scale, causal,
                      window, prefix, q_offset, interpret=False):
    """Single-head masked flash attention. q: (S, D); k, v: (T, D)."""
    s_q, d = q.shape
    P = qi.shape[0]
    body = functools.partial(_body, bq=bq, bk=bk, scale=scale, causal=causal,
                             window=window, prefix=prefix, q_offset=q_offset)
    return pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(P,),
            in_specs=[
                pl.BlockSpec((1, bq, d),
                             lambda w, qi_r, ki_r, f_r: (qi_r[w], 0, 0)),
                pl.BlockSpec((1, bk, d),
                             lambda w, qi_r, ki_r, f_r: (ki_r[w], 0, 0)),
                pl.BlockSpec((1, bk, d),
                             lambda w, qi_r, ki_r, f_r: (ki_r[w], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bq, d),
                                   lambda w, qi_r, ki_r, f_r: (qi_r[w], 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, LANES), jnp.float32),  # running max
                pltpu.VMEM((bq, LANES), jnp.float32),  # normalizer
                pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s_q // bq, bq, d), q.dtype),
        interpret=interpret,
    )(qi, ki, flags,
      q.reshape(s_q // bq, bq, d),
      k.reshape(k.shape[0] // bk, bk, d),
      v.reshape(v.shape[0] // bk, bk, d)).reshape(s_q, d)
