"""Public masked-attention op: schedule cache + batch/head vmap + GQA.

``flash_mask_attention`` is the runtime TPU path (Pallas; interpret=True on
CPU).  The jnp fallbacks used for lowering/dry-run live in
``repro.models.attention`` (they express the same block-skipping at XLA level
so the roofline reflects the technique).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro import caches

from .kernel import flash_mask_kernel, build_schedule


@functools.lru_cache(maxsize=caches.env_capacity("REPRO_FLASH_SCHED_CAP",
                                                 256))
def _sched(s_q, s_k, bq, bk, causal, window, prefix, q_offset):
    qi, ki, flags = build_schedule(s_q, s_k, bq=bq, bk=bk, causal=causal,
                                   window=window, prefix=prefix,
                                   q_offset=q_offset)
    return jnp.asarray(qi), jnp.asarray(ki), jnp.asarray(flags)


caches.register_lru("flash-sched", _sched)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "prefix", "q_offset",
                              "scale", "bq", "bk", "interpret"))
def flash_mask_attention(q, k, v, *, causal=True, window=0, prefix=0,
                         q_offset=0, scale=None, bq=128, bk=128,
                         interpret=None):
    """Masked multi-head attention, GQA-aware.

    q: (B, Hq, S, D);  k, v: (B, Hkv, T, D) with Hq % Hkv == 0.
    Returns (B, Hq, S, D) in q.dtype.
    """
    b, hq, s_q, d = q.shape
    _, hkv, s_k, _ = k.shape
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    bq_ = min(bq, s_q)
    bk_ = min(bk, s_k)
    interpret = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    qi, ki, flags = _sched(s_q, s_k, bq_, bk_, causal, window, prefix,
                           q_offset)

    def one(qh, kh, vh):  # (S, D), (T, D), (T, D)
        return flash_mask_kernel(qh, kh, vh, qi, ki, flags, bq=bq_, bk=bk_,
                                 scale=scale, causal=causal, window=window,
                                 prefix=prefix, q_offset=q_offset,
                                 interpret=interpret)

    qg = q.reshape(b, hkv, g, s_q, d)
    f = jax.vmap(jax.vmap(jax.vmap(one, in_axes=(0, None, None)),
                          in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    out = f(qg, k, v)                      # (B, Hkv, G, S, D)
    return out.reshape(b, hq, s_q, d)
