"""Pure-jnp oracle for block-masked flash attention.

The mask family is parametric (causal / sliding-window / dense-prefix),
covering every attention pattern used by the assigned architectures:

    allowed(q, k) = causal_ok(q, k) AND (window_ok(q, k) OR k < prefix)

with absolute query position  q_abs = q + q_offset  (q_offset > 0 during
decode, where queries sit at the end of a longer KV history).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mask_allowed(s_q: int, s_k: int, *, causal: bool, window: int,
                 prefix: int, q_offset: int):
    """(s_q, s_k) bool array of the parametric mask."""
    q = np.arange(s_q)[:, None] + q_offset
    k = np.arange(s_k)[None, :]
    ok = np.ones((s_q, s_k), bool)
    if causal:
        ok &= k <= q
    if window > 0:
        ok &= ((q - k) < window) | (k < prefix)
    return ok


def flash_mask_ref(q, k, v, *, causal=True, window=0, prefix=0,
                   q_offset=0, scale=None):
    """Dense masked attention oracle. q: (S, D); k, v: (T, D)."""
    s_q, d = q.shape
    s_k = k.shape[0]
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    ok = jnp.asarray(mask_allowed(s_q, s_k, causal=causal, window=window,
                                  prefix=prefix, q_offset=q_offset))
    s = jnp.where(ok, s, -jnp.inf)
    # fully-masked rows -> zero output (mirrors the kernel's l==0 guard)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True, initial=-jnp.inf,
                            where=ok))
    p = jnp.where(ok, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.dot(p, v.astype(jnp.float32))
    return jnp.where(l > 0, o / jnp.maximum(l, 1e-30), 0.0)
