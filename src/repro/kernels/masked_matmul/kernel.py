"""Pallas TPU kernels for masked tile products (the paper's technique at MXU
granularity).

Two kernels:

* ``masked_matmul_kernel`` — tile-MCA SDDMM: dense A (M,K) x dense B (K,N),
  computing ONLY the output tiles allowed by the mask's block structure.
  The accumulator is exactly the paper's MCA: its length is nnzb(M) tiles,
  indexed by mask-block *rank* (the output array's leading dim), and only the
  states ALLOWED (tile scheduled) / SET (tile computed) exist.  NOTALLOWED
  tiles are never even scheduled — the paper's "skip masked-out flops".

* ``block_spgemm_kernel`` — BCSR x BCSR masked product replaying a host-built
  worklist (the paper's Heap merge performed once at schedule-construction
  time, §6's symbolic phase made free by the mask bound).

TPU notes: the grid is executed sequentially per core, so accumulating into
the same output block across consecutive grid steps (out index_map revisits)
is the canonical Mosaic reduction pattern.  Blocks are MXU-aligned; VMEM
footprint per step is bm*bk + bk*bn + bm*bn words.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Tile-MCA SDDMM:  C[r] = A[bi[r], :] @ B[:, bj[r]]   for each mask block r
# ---------------------------------------------------------------------------


def _masked_matmul_body(bi_ref, bj_ref, a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def masked_matmul_kernel(a, b, bi, bj, *, bm, bn, bk, out_dtype=jnp.float32,
                         interpret=False):
    """C_tiles[r] = (A @ B) tile (bi[r], bj[r]); only allowed tiles computed.

    a: (M, K), b: (K, N); M % bm == 0, N % bn == 0, K % bk == 0.
    bi, bj: (nnzb,) int32 mask block coordinates.
    Returns (nnzb, bm, bn) out_dtype.
    """
    nnzb = bi.shape[0]
    K = a.shape[1]
    grid = (nnzb, K // bk)
    return pl.pallas_call(
        _masked_matmul_body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda r, k, bi_r, bj_r: (bi_r[r], k)),
                pl.BlockSpec((bk, bn), lambda r, k, bi_r, bj_r: (k, bj_r[r])),
            ],
            out_specs=pl.BlockSpec((1, bm, bn),
                                   lambda r, k, bi_r, bj_r: (r, 0, 0)),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nnzb, bm, bn), out_dtype),
        interpret=interpret,
    )(bi, bj, a, b)


# ---------------------------------------------------------------------------
# BCSR x BCSR masked SpGEMM: replay a host-built (rank, posA, posB) worklist
# ---------------------------------------------------------------------------


def _block_spgemm_body(rank_ref, pa_ref, pb_ref, flags_ref,
                       a_ref, b_ref, o_ref, acc_ref):
    w = pl.program_id(0)
    first = flags_ref[w] & 1
    real = (flags_ref[w] >> 1) & 1
    last = (flags_ref[w] >> 2) & 1

    @pl.when(first == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(real == 1)
    def _mac():
        acc_ref[...] += jnp.dot(a_ref[0], b_ref[0],
                                preferred_element_type=jnp.float32)

    @pl.when(last == 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)[None]


def block_spgemm_kernel(a_blocks, b_blocks, rank, pa, pb, flags, nnzb_out,
                        *, bs, out_dtype=jnp.float32, interpret=False):
    """Masked BCSR product from a worklist.

    a_blocks: (nnzb_a, bs, bs); b_blocks: (nnzb_b, bs, bs).
    rank/pa/pb: (W,) int32 — output block rank and A/B block positions.
    flags: (W,) int32 bitfield — 1=first visit of rank, 2=real product
      (0 -> zero-fill entry for a mask block with no contribution),
      4=last visit of rank (flush accumulator to HBM).
    The worklist MUST be sorted by rank (sequential-grid accumulation).
    Returns (nnzb_out, bs, bs).
    """
    W = rank.shape[0]
    return pl.pallas_call(
        _block_spgemm_body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(W,),
            in_specs=[
                pl.BlockSpec((1, bs, bs),
                             lambda w, r_r, pa_r, pb_r, f_r: (pa_r[w], 0, 0)),
                pl.BlockSpec((1, bs, bs),
                             lambda w, r_r, pa_r, pb_r, f_r: (pb_r[w], 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, bs, bs),
                                   lambda w, r_r, pa_r, pb_r, f_r:
                                   (r_r[w], 0, 0)),
            scratch_shapes=[pltpu.VMEM((bs, bs), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nnzb_out, bs, bs), out_dtype),
        interpret=interpret,
    )(rank, pa, pb, flags, a_blocks, b_blocks)
