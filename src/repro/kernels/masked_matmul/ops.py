"""Jit'd wrappers + host-side schedule builders for the masked tile kernels.

The schedule builder is the TPU incarnation of the paper's symbolic phase:
because the mask's block structure bounds the output (paper §6, the 1P
insight), the output allocation and the worklist are fully determined on the
host before any device compute — so the device program is a single static
numeric phase.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BCSR
from .kernel import masked_matmul_kernel, block_spgemm_kernel

_ON_TPU = None


def on_tpu() -> bool:
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.default_backend() == "tpu"
    return _ON_TPU


def tile_path_supported(semiring_name: str, complement: bool) -> bool:
    """Whether the Pallas tile kernels can express this product.

    Both kernels accumulate with a dense MXU dot, so only the plus_times
    semiring is representable, and the mask must be explicit (a complement's
    output is not bounded by the mask's block structure).  The planner
    (``repro.core.planner``) consults this plus an occupancy estimate to set
    ``Plan.tile_eligible``.
    """
    return semiring_name == "plus_times" and not complement


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def masked_matmul(a, b, bi, bj, *, bm, bn, bk, interpret=None):
    """Tile-MCA SDDMM: only mask-allowed output tiles are computed."""
    interpret = (not on_tpu()) if interpret is None else interpret
    return masked_matmul_kernel(a, b, bi, bj, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)


# ---------------------------------------------------------------------------
# BCSR x BCSR schedule (host)
# ---------------------------------------------------------------------------


def build_spgemm_schedule(A: BCSR, B: BCSR, M: BCSR
                          ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray]:
    """Worklist (rank, posA, posB, flags) for C = M (.) (A B) on block
    structures.

    This is the paper's Heap merge done once on the host: for every mask
    block (i, j) [rank r in M's CSR order], intersect A's block-row i with
    B's block-column j.  Mask blocks with no contribution get a single
    zero-fill entry (flags real-bit = 0) so the kernel's output is fully
    defined.
    """
    # B column-major view for the intersection
    from repro.core.formats import bcsr_structure_transpose
    bt_indptr, bt_rows, bt_pos = bcsr_structure_transpose(B)

    rank, pa, pb, flags = [], [], [], []
    r = 0
    for i in range(M.block_rows):
        a_cols = A.block_row(i)
        a_pos = np.arange(A.indptr[i], A.indptr[i + 1])
        for j in M.block_row(i):
            b_rows = bt_rows[bt_indptr[j]: bt_indptr[j + 1]]
            b_pos = bt_pos[bt_indptr[j]: bt_indptr[j + 1]]
            # sorted intersection of a_cols (A block-row i) and b_rows
            ks, ai, bix = np.intersect1d(a_cols, b_rows,
                                         return_indices=True)
            if len(ks) == 0:
                rank.append(r); pa.append(0); pb.append(0)
                flags.append(1 | 4)  # first+last, not real -> zero fill
            else:
                for t in range(len(ks)):
                    f = 2
                    if t == 0:
                        f |= 1
                    if t == len(ks) - 1:
                        f |= 4
                    rank.append(r)
                    pa.append(int(a_pos[ai[t]]))
                    pb.append(int(b_pos[bix[t]]))
                    flags.append(f)
            r += 1
    return (np.asarray(rank, np.int32), np.asarray(pa, np.int32),
            np.asarray(pb, np.int32), np.asarray(flags, np.int32))


@functools.partial(jax.jit,
                   static_argnames=("nnzb_out", "bs", "interpret"))
def _block_spgemm_jit(a_blocks, b_blocks, rank, pa, pb, flags, *,
                      nnzb_out, bs, interpret):
    return block_spgemm_kernel(a_blocks, b_blocks, rank, pa, pb, flags,
                               nnzb_out, bs=bs, interpret=interpret)


def block_spgemm(A: BCSR, B: BCSR, M: BCSR, *, interpret=None) -> BCSR:
    """C = M (.) (A B) at tile granularity.  Output structure == M structure
    (the 1P allocation); zero blocks are kept (callers may prune)."""
    assert A.block_size == B.block_size == M.block_size
    bs = A.block_size
    rank, pa, pb, flags = build_spgemm_schedule(A, B, M)
    interpret = (not on_tpu()) if interpret is None else interpret
    blocks = _block_spgemm_jit(
        A.blocks, B.blocks, jnp.asarray(rank), jnp.asarray(pa),
        jnp.asarray(pb), jnp.asarray(flags),
        nnzb_out=M.nnzb, bs=bs, interpret=interpret)
    return BCSR(M.indptr.copy(), M.indices.copy(), blocks,
                (M.shape[0], B.shape[1]), bs)


def block_spgemm_from_csr(A, B, M, *, block_size: int,
                          interpret=None) -> BCSR:
    """Tile path from host CSR operands (the ``Plan.tile_eligible`` route).

    Densifies per tile via ``bcsr_from_dense`` — callers should only take
    this route when the planner's occupancy estimate says dense tiles pay
    off (``Plan.tile_block`` gives the block size it checked).
    """
    from repro.core.formats import bcsr_from_dense
    Ab = bcsr_from_dense(A.to_dense(), block_size)
    Bb = bcsr_from_dense(B.to_dense(), block_size)
    Mb = bcsr_from_dense(M.to_dense(), block_size)
    return block_spgemm(Ab, Bb, Mb, interpret=interpret)
