"""Jit'd wrappers + host-side schedule builders for the masked tile kernels.

The schedule builder is the TPU incarnation of the paper's symbolic phase:
because the mask's block structure bounds the output (paper §6, the 1P
insight), the output allocation and the worklist are fully determined on the
host before any device compute — so the device program is a single static
numeric phase.  The builder is pure vectorized numpy (segment ops over the
CSR structures); the per-block Python loops of the original demo would
dominate end-to-end time and defeat the point of a free symbolic phase.

Two executors replay the worklist:

* ``backend="pallas"`` — the Mosaic kernels in ``kernel.py`` (sequential
  grid, VMEM accumulator).  The real TPU path; ``interpret=True`` emulates
  it on CPU for tests.
* ``backend="xla"``    — gather + batched matmul + segment-sum, compiled by
  XLA.  The fast path on CPU/GPU where Pallas interpret mode would be pure
  Python overhead.

``backend=None`` picks pallas on TPU and xla elsewhere, re-queried per call
(the backend can change mid-process, e.g. tests forcing CPU after a TPU
probe — caching the first answer forever ran compiled-mode kernels in the
wrong mode).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BCSR
from .kernel import masked_matmul_kernel, block_spgemm_kernel

Schedule = Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]


def on_tpu() -> bool:
    """Whether the *current* default backend is TPU (never cached here:
    ``jax.default_backend()`` is already memoized by jax and invalidated
    when the platform changes, so a module-global cache could only be
    stale, never faster)."""
    return jax.default_backend() == "tpu"


def tile_path_supported(semiring_name: str, complement: bool) -> bool:
    """Whether the tile kernels can express this product.

    Both executors accumulate with a dense MXU dot, so only the plus_times
    semiring is representable, and the mask must be explicit (a complement's
    output is not bounded by the mask's block structure).  The planner
    (``repro.core.planner``) consults this plus an occupancy estimate to set
    ``Plan.tile_eligible``.
    """
    return semiring_name == "plus_times" and not complement


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def masked_matmul(a, b, bi, bj, *, bm, bn, bk, interpret=None):
    """Tile-MCA SDDMM: only mask-allowed output tiles are computed."""
    interpret = (not on_tpu()) if interpret is None else interpret
    return masked_matmul_kernel(a, b, bi, bj, bm=bm, bn=bn, bk=bk,
                                interpret=interpret)


# ---------------------------------------------------------------------------
# BCSR x BCSR schedule (host, vectorized)
# ---------------------------------------------------------------------------


def _empty_schedule() -> Schedule:
    z = np.zeros(0, np.int32)
    return z, z.copy(), z.copy(), z.copy()


def build_spgemm_schedule(A: BCSR, B: BCSR, M: BCSR) -> Schedule:
    """Worklist (rank, posA, posB, flags) for C = M (.) (A B) on block
    structures.

    For every mask block (i, j) [rank r in M's CSR order], the worklist
    holds one entry per block k with A[i, k] and B[k, j] both present, in
    ascending k; mask blocks with no contribution get a single zero-fill
    entry (flags real-bit = 0) so the kernel's output is fully defined.
    ``flags`` bits: 1 = first visit of rank, 2 = real product, 4 = last
    visit of rank.

    Implementation is pure vectorized numpy: the candidate set (every
    (mask block, A block) pair sharing a block row) is expanded with
    segment ops, then matched against B's column-major structure with one
    searchsorted over composite (block-col, block-row) keys.  Work and
    memory are O(sum over mask blocks of nnzb(A block-row)) — the same
    asymptotics the per-block Python loop had, minus the interpreter.
    """
    if M.nnzb == 0:
        return _empty_schedule()

    from repro.core.formats import bcsr_structure_transpose
    bt_indptr, bt_rows, bt_pos = bcsr_structure_transpose(B)

    nnzb_m = M.nnzb
    mi = np.repeat(np.arange(M.block_rows, dtype=np.int64),
                   np.diff(M.indptr))                  # mask block-row per rank
    mj = M.indices                                     # mask block-col per rank

    # expand: one candidate per (rank, A block in block-row mi[rank])
    a_cnt = np.diff(A.indptr)
    counts = a_cnt[mi]
    total = int(counts.sum())
    rep_r = np.repeat(np.arange(nnzb_m, dtype=np.int64), counts)
    starts = np.zeros(nnzb_m, dtype=np.int64)
    np.cumsum(counts[:-1], out=starts[1:])
    within = np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
    a_pos = A.indptr[mi[rep_r]] + within
    k = A.indices[a_pos]

    # match candidates against B's column-major structure: bt is sorted by
    # (block-col, block-row), so composite keys are globally sorted and one
    # searchsorted resolves every candidate
    kb = B.block_rows
    bt_cols = np.repeat(np.arange(B.block_cols, dtype=np.int64),
                        np.diff(bt_indptr))
    bt_key = bt_cols * kb + bt_rows
    cand_key = mj[rep_r] * kb + k
    if len(bt_key):
        pos = np.searchsorted(bt_key, cand_key)
        pos_c = np.minimum(pos, len(bt_key) - 1)
        hit = (pos < len(bt_key)) & (bt_key[pos_c] == cand_key)
    else:
        hit = np.zeros(total, dtype=bool)

    rank = rep_r[hit]                 # nondecreasing: rep_r was, filter keeps
    pa = a_pos[hit]
    pb = bt_pos[np.minimum(pos[hit], max(0, len(bt_key) - 1))] \
        if len(bt_key) else np.zeros(0, np.int64)
    real = np.ones(len(rank), dtype=np.int32)

    # zero-fill entries for mask blocks with no contribution
    per_rank = np.bincount(rank, minlength=nnzb_m)
    empty = np.nonzero(per_rank == 0)[0]
    if len(empty):
        rank = np.concatenate([rank, empty])
        pa = np.concatenate([pa, np.zeros(len(empty), np.int64)])
        pb = np.concatenate([pb, np.zeros(len(empty), np.int64)])
        real = np.concatenate([real, np.zeros(len(empty), np.int32)])
        order = np.argsort(rank, kind="stable")
        rank, pa, pb, real = rank[order], pa[order], pb[order], real[order]

    first = np.empty(len(rank), dtype=bool)
    first[:1] = True
    np.not_equal(rank[1:], rank[:-1], out=first[1:])
    last = np.empty(len(rank), dtype=bool)
    last[-1:] = True
    np.not_equal(rank[1:], rank[:-1], out=last[:-1])
    flags = first * 1 + real * 2 + last * 4
    return (rank.astype(np.int32), pa.astype(np.int32),
            pb.astype(np.int32), flags.astype(np.int32))


# ---------------------------------------------------------------------------
# K-slab schedules (distributed ring-SUMMA): one worklist per ring stage
# ---------------------------------------------------------------------------


def build_spgemm_schedule_slab(A: BCSR, B_slab: BCSR, M: BCSR,
                               k0_blocks: int) -> Schedule:
    """Worklist for C = M (.) (A[:, slab] @ B_slab), one ring stage.

    ``B_slab`` holds block rows [k0_blocks, k0_blocks + B_slab.block_rows)
    of the full B, rebased to start at 0 (its ``pb`` positions index the
    slab's own blocks).  ``pa`` positions index the full panel ``A.blocks``.
    Zero-fill semantics match ``build_spgemm_schedule``: every mask block
    gets at least one entry, so a per-stage executor's output is fully
    defined even for stages whose slab contributes nothing.
    """
    rows_slab = B_slab.block_rows
    in_slab = (A.indices >= k0_blocks) & (A.indices < k0_blocks + rows_slab)
    pos_map = np.nonzero(in_slab)[0]
    brow = np.repeat(np.arange(A.block_rows, dtype=np.int64),
                     np.diff(A.indptr))[in_slab]
    indptr_sub = np.zeros(A.block_rows + 1, dtype=np.int64)
    np.add.at(indptr_sub, brow + 1, 1)
    A_sub = BCSR(np.cumsum(indptr_sub), A.indices[in_slab] - k0_blocks,
                 A.blocks, (A.shape[0], rows_slab * A.block_size),
                 A.block_size)
    rank, pa, pb, flags = build_spgemm_schedule(A_sub, B_slab, M)
    # remap pa from slab-filtered positions back to the full panel's blocks
    # (zero-fill entries keep position 0 — they never contribute)
    real = (flags >> 1) & 1
    if len(pos_map):
        pa = np.where(real == 1, pos_map[np.minimum(pa, len(pos_map) - 1)],
                      0).astype(np.int32)
    else:
        pa = np.zeros_like(pa)
    return rank, pa, pb, flags


def build_ring_schedules(A_panels, B_slabs, M_panels, *, out_pad: int
                         ) -> np.ndarray:
    """Stacked per-device, per-stage worklists for the sparse ring.

    Returns int32 ``(p, p, 4, Ws)``: ``[d, s]`` is the worklist
    ``(rank, pa, pb, flags)`` device ``d`` replays at ring stage ``s``,
    when it holds B K-slab ``(d - s) % p``.  All worklists are padded to
    one static length ``Ws``:

    * ranks ``[nnzb(M_panel), out_pad)`` (the ring-wide output padding) get
      zero-fill entries (flags first|last, real off) so per-stage executors
      that require every output rank to be written stay fully defined;
    * trailing padding entries carry ``rank = out_pad - 1`` with all flags
      off (no write, no contribution) so rank-sortedness is preserved.
    """
    p = len(A_panels)
    assert len(B_slabs) == len(M_panels) == p
    slab_rows = B_slabs[0].block_rows
    scheds = {}
    ws = 1
    for d in range(p):
        for s in range(p):
            src = (d - s) % p
            rank, pa, pb, flags = build_spgemm_schedule_slab(
                A_panels[d], B_slabs[src], M_panels[d], src * slab_rows)
            nloc = M_panels[d].nnzb
            if out_pad > nloc:
                extra = np.arange(nloc, out_pad, dtype=np.int32)
                z = np.zeros(len(extra), np.int32)
                rank = np.concatenate([rank, extra])
                pa = np.concatenate([pa, z])
                pb = np.concatenate([pb, z])
                flags = np.concatenate([flags, np.full(len(extra), 5,
                                                       np.int32)])
            scheds[d, s] = (rank, pa, pb, flags)
            ws = max(ws, len(rank))
    out = np.zeros((p, p, 4, ws), np.int32)
    out[:, :, 0, :] = max(0, out_pad - 1)
    for (d, s), parts in scheds.items():
        L = len(parts[0])
        for i, arr in enumerate(parts):
            out[d, s, i, :L] = arr
    return out


# ---------------------------------------------------------------------------
# Worklist executors
# ---------------------------------------------------------------------------


@functools.partial(jax.jit,
                   static_argnames=("nnzb_out", "bs", "interpret"))
def _block_spgemm_pallas(a_blocks, b_blocks, rank, pa, pb, flags, *,
                         nnzb_out, bs, interpret):
    return block_spgemm_kernel(a_blocks, b_blocks, rank, pa, pb, flags,
                               nnzb_out, bs=bs, interpret=interpret)


@jax.jit
def _xla_chunk_add(out, a_blocks, b_blocks, rank, pa, pb, flags):
    """One worklist chunk: gather, batched matmul, segment-add into ``out``.
    Zero-fill entries (real-bit off) gather block 0 but contribute
    nothing."""
    real = ((flags >> 1) & 1).astype(jnp.float32)
    prods = jnp.einsum("wij,wjk->wik",
                       a_blocks[pa].astype(jnp.float32),
                       b_blocks[pb].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    return out.at[rank].add(prods * real[:, None, None])


#: peak f32 elements the XLA executor materializes per worklist chunk
#: (~64 MB); bounds device memory at O(chunk * bs^2) instead of O(W * bs^2)
#: for huge worklists, where one unchunked einsum could out-allocate the
#: very densify this pipeline removed
_XLA_CHUNK_ELEMS = 1 << 24


def _block_spgemm_xla(a_blocks, b_blocks, rank, pa, pb, flags, *,
                      nnzb_out, bs):
    """XLA replay of the worklist, chunked to bound peak memory.

    Chunks are independent partial sums into the same output (the rank
    segment-add is associative), so first/last flags are irrelevant here —
    only the real-bit is consulted.  The tail chunk is padded with
    real-bit-off entries to keep exactly one compiled chunk shape.
    """
    W = int(rank.shape[0])
    chunk = max(1, _XLA_CHUNK_ELEMS // (bs * bs))
    out = jnp.zeros((nnzb_out, bs, bs), jnp.float32)
    if W <= chunk:
        return _xla_chunk_add(out, a_blocks, b_blocks, rank, pa, pb, flags)
    pad = -W % chunk
    if pad:
        z = jnp.zeros(pad, rank.dtype)
        rank, pa, pb = (jnp.concatenate([x, z]) for x in (rank, pa, pb))
        flags = jnp.concatenate([flags, z])
    for s in range(0, W + pad, chunk):
        e = s + chunk
        out = _xla_chunk_add(out, a_blocks, b_blocks, rank[s:e], pa[s:e],
                             pb[s:e], flags[s:e])
    return out


def _run_schedule(A: BCSR, B: BCSR, M: BCSR, schedule: Schedule,
                  blocks_a, blocks_b, *, interpret, backend):
    bs = A.block_size
    if backend is None:
        # interpret=True requests the pallas path (tests exercise the kernel
        # in interpret mode on CPU); interpret=False only means "compiled
        # mode *if* pallas runs at all" — off-TPU it must still pick xla,
        # never compiled-mode Mosaic on a host platform
        backend = "pallas" if (interpret or on_tpu()) else "xla"
    # an empty operand leaves only zero-fill entries in the worklist, but
    # those still address block 0 — give them one zero block to read
    if blocks_a.shape[0] == 0:
        blocks_a = jnp.zeros((1, bs, bs), blocks_a.dtype)
    if blocks_b.shape[0] == 0:
        blocks_b = jnp.zeros((1, bs, bs), blocks_b.dtype)
    rank, pa, pb, flags = (jnp.asarray(x) for x in schedule)
    if backend == "pallas":
        interpret = (not on_tpu()) if interpret is None else interpret
        return _block_spgemm_pallas(blocks_a, blocks_b, rank, pa, pb, flags,
                                    nnzb_out=M.nnzb, bs=bs,
                                    interpret=interpret)
    if backend == "xla":
        return _block_spgemm_xla(blocks_a, blocks_b, rank, pa, pb, flags,
                                 nnzb_out=M.nnzb, bs=bs)
    raise ValueError(f"unknown backend {backend!r}")


def block_spgemm(A: BCSR, B: BCSR, M: BCSR, *, interpret=None,
                 backend: Optional[str] = None,
                 schedule: Optional[Schedule] = None) -> BCSR:
    """C = M (.) (A B) at tile granularity.  Output structure == M structure
    (the 1P allocation); zero blocks are kept (callers may prune via
    ``bcsr_to_csr``).

    An all-empty mask is a defined degenerate case: the worklist is empty
    and an empty BCSR is returned without launching a kernel.  Pass a
    precomputed ``schedule`` to amortize the symbolic phase across several
    numeric replays (e.g. a values pass and a structure pass).
    """
    assert A.block_size == B.block_size == M.block_size
    bs = A.block_size
    if M.nnzb == 0:
        return BCSR(M.indptr.copy(), M.indices.copy(),
                    jnp.zeros((0, bs, bs), jnp.float32),
                    (M.shape[0], B.shape[1]), bs)
    if schedule is None:
        schedule = build_spgemm_schedule(A, B, M)
    blocks = _run_schedule(A, B, M, schedule, A.blocks, B.blocks,
                           interpret=interpret, backend=backend)
    return BCSR(M.indptr.copy(), M.indices.copy(), blocks,
                (M.shape[0], B.shape[1]), bs)


def block_spgemm_with_structure(A: BCSR, B: BCSR, M: BCSR, *,
                                a_pattern=None, b_pattern=None,
                                interpret=None,
                                backend: Optional[str] = None
                                ) -> Tuple[BCSR, BCSR]:
    """(values, structural-counts) pair sharing ONE schedule build.

    The second BCSR replays the same worklist over the operands' 0/1
    patterns; its entries count structural contributions, so ``count > 0``
    is exact element-level presence — identical to the row kernels'
    structural semantics even when numeric cancellation produces a stored
    0.0 in the values pass.  ``a_pattern``/``b_pattern`` are optional
    (nnzb, bs, bs) 0/1 block arrays marking the operands' *stored entries*
    (the row kernels treat an explicitly stored 0.0 as structural); when
    omitted, value-nonzeroness of the blocks is used, which cannot tell a
    stored zero from block padding.
    """
    assert A.block_size == B.block_size == M.block_size
    bs = A.block_size
    shape = (M.shape[0], B.shape[1])
    if M.nnzb == 0:
        empty = jnp.zeros((0, bs, bs), jnp.float32)
        return (BCSR(M.indptr.copy(), M.indices.copy(), empty, shape, bs),
                BCSR(M.indptr.copy(), M.indices.copy(), empty, shape, bs))
    schedule = build_spgemm_schedule(A, B, M)
    vals = _run_schedule(A, B, M, schedule, A.blocks, B.blocks,
                         interpret=interpret, backend=backend)
    if a_pattern is None:
        a_pattern = (A.blocks != 0).astype(jnp.float32)
    if b_pattern is None:
        b_pattern = (B.blocks != 0).astype(jnp.float32)
    struct = _run_schedule(A, B, M, schedule, a_pattern, b_pattern,
                           interpret=interpret, backend=backend)
    return (BCSR(M.indptr.copy(), M.indices.copy(), vals, shape, bs),
            BCSR(M.indptr.copy(), M.indices.copy(), struct, shape, bs))


def block_spgemm_from_csr(A, B, M, *, block_size: int, interpret=None,
                          backend: Optional[str] = None) -> BCSR:
    """Tile path from host CSR operands (the ``Plan.tile_eligible`` route).

    Densify-free: operands are scattered straight into their occupied
    blocks (``bcsr_from_csr``), so memory stays O(occupied blocks) instead
    of O(m*n) — the property that makes this route usable at scales where
    the original demo's ``to_dense`` re-blocking could not run.
    """
    from repro.core.formats import bcsr_from_csr
    Ab = bcsr_from_csr(A, block_size)
    Bb = bcsr_from_csr(B, block_size)
    Mb = bcsr_from_csr(M, block_size)
    return block_spgemm(Ab, Bb, Mb, interpret=interpret, backend=backend)
