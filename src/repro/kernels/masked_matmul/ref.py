"""Pure-jnp oracles for the masked tile kernels.

These are the ground truth the Pallas kernels (interpret=True on CPU, Mosaic
on TPU) are validated against, shape-for-shape.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def masked_matmul_ref(a, b, bi, bj, *, bm, bn):
    """Tile-MCA SDDMM oracle: dense C = A @ B, then gather allowed tiles.

    a: (M, K), b: (K, N), bi/bj: (nnzb,) block coords of allowed tiles.
    Returns (nnzb, bm, bn) float32.
    """
    c = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    out = []
    for i, j in zip(np.asarray(bi), np.asarray(bj)):
        out.append(c[i * bm:(i + 1) * bm, j * bn:(j + 1) * bn])
    return jnp.stack(out) if out else jnp.zeros((0, bm, bn), jnp.float32)


def block_spgemm_ref(a_dense, b_dense, mask_bi, mask_bj, *, bs):
    """BCSR x BCSR masked SpGEMM oracle, tile-granular mask.

    Returns (nnzb_m, bs, bs) float32: the dense product gathered at the mask's
    allowed blocks (blocks the product never touches come out zero — paper
    Fig. 1's "mask entry with no output").
    """
    c = jnp.dot(a_dense.astype(jnp.float32), b_dense.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    out = []
    for i, j in zip(np.asarray(mask_bi), np.asarray(mask_bj)):
        out.append(c[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs])
    return jnp.stack(out) if out else jnp.zeros((0, bs, bs), jnp.float32)
