import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (device count locks at
# first backend init).  Everything below is ordinary code.

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from typing import Dict  # noqa: E402

import jax  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs.base import (ARCH_IDS, SHAPES, get_config,  # noqa: E402
                                cell_is_runnable)
from repro.compat import set_mesh  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (train_input_specs,  # noqa: E402
                                decode_input_specs)
from repro.models.common import shardings_for  # noqa: E402

DP = ("pod", "data")

# ---------------------------------------------------------------------------
# HLO collective-traffic accounting (per-device bytes, from the partitioned
# module text;  §Roofline uses: term = bytes_per_device / link_bw)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device collective traffic by op kind (output-shape proxy;
    all-reduce counted 2x for the ring reduce-scatter+all-gather)."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        sig, kind = m.group(1), m.group(2)
        b = _shape_bytes(sig)
        if kind == "all-reduce":
            b *= 2
        out[kind] = out.get(kind, 0) + b
    return out


# ---------------------------------------------------------------------------
# cache/batch sharding specs
# ---------------------------------------------------------------------------

_CACHE_RULES = {
    "k": (DP, "model", None, None), "v": (DP, "model", None, None),
    # MLA latent caches: context-parallel (T over model) — each rank holds
    # 1/TP of the sequence; softmax/contraction reductions over T are the
    # small (b,h) flash-statistics collectives (§Perf C3)
    "kv_c": (DP, "model", None), "k_rope": (DP, "model", None),
    "S": (DP, "model", None, None), "conv": (DP, None, "model"),
    "C": (DP, None, "model", None), "n": (DP, None, "model"),
    "m": (DP, None), "c": (DP, None, "model"), "h": (DP, None, "model"),
}


def cache_specs(cache_shapes):
    def one(path, leaf):
        name = None
        for part in reversed(path):
            key = getattr(part, "key", None)
            if isinstance(key, str) and key in _CACHE_RULES:
                name = key
                break
        base = _CACHE_RULES.get(name, (DP,))
        lead = leaf.ndim - len(base)
        return P(*([None] * lead), *base)
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# ---------------------------------------------------------------------------
# lowering one cell
# ---------------------------------------------------------------------------


def reduced_depth_cfgs(cfg):
    """Two reduced-depth configs (cfg1, cfg2, l1, l2, l_full) preserving the
    stack pattern, for layer-extrapolated cost accounting (scan bodies are
    counted once by HloCostAnalysis; unrolled reduced-depth lowerings give
    the exact per-layer delta, and layers are homogeneous by construction).
    """
    fam = cfg.family
    if fam == "audio":
        # vary encoder+decoder pairs together
        c1 = cfg.replace(n_enc_layers=1, n_dec_layers=1)
        c2 = cfg.replace(n_enc_layers=2, n_dec_layers=2)
        return c1, c2, 1, 2, cfg.n_enc_layers
    if fam == "ssm" and cfg.xlstm is not None:
        r = cfg.xlstm.slstm_every
        return (cfg.replace(n_layers=r), cfg.replace(n_layers=2 * r),
                r, 2 * r, cfg.n_layers)
    if fam == "hybrid":
        e = cfg.hybrid_attn_every
        return (cfg.replace(n_layers=e), cfg.replace(n_layers=2 * e),
                e, 2 * e, cfg.n_layers)
    if cfg.moe is not None:
        kd = cfg.first_k_dense
        return (cfg.replace(n_layers=kd + 1), cfg.replace(n_layers=kd + 2),
                kd + 1, kd + 2, cfg.n_layers)
    return cfg.replace(n_layers=1), cfg.replace(n_layers=2), 1, 2, \
        cfg.n_layers


def account_cell(arch: str, shape_name: str, multi_pod: bool,
                 attn_impl: str = None):
    """Exact per-device cost metrics via reduced-depth unrolled lowerings:
        metric(L_full) = m1 + (m2 - m1) * (L_full - l1) / (l2 - l1)
    Returns a result dict shaped like lower_cell's, accounting="extrapolated".
    """
    cfg0 = _PATCHED_CFG.get(arch) or get_config(arch)
    c1, c2, l1, l2, l_full = reduced_depth_cfgs(cfg0)
    outer_patch = _PATCHED_CFG.get(arch)

    def metrics(res):
        ca = res.get("cost_analysis", {})
        coll = res.get("collective_bytes_per_device", {})
        return (float(ca.get("flops", float("nan"))),
                float(ca.get("bytes accessed", float("nan"))),
                float(sum(v for v in coll.values()
                          if isinstance(v, (int, float)))))

    results = []
    for c in (c1, c2):
        _PATCHED_CFG[arch] = c
        try:
            results.append(lower_cell(arch, shape_name, multi_pod,
                                      attn_impl=attn_impl, unroll=True))
        finally:
            if outer_patch is not None:
                _PATCHED_CFG[arch] = outer_patch
            else:
                _PATCHED_CFG.pop(arch, None)
        if results[-1]["status"] != "ok":
            return results[-1]
    m1 = metrics(results[0])
    m2 = metrics(results[1])
    scale = (l_full - l1) / (l2 - l1)
    flops, byts, coll = (a + (b - a) * scale for a, b in zip(m1, m2))
    out = dict(results[0])
    out["accounting"] = "extrapolated"
    out["depths"] = {"l1": l1, "l2": l2, "l_full": l_full}
    out["cost_analysis"] = {"flops": flops, "bytes accessed": byts}
    out["collective_bytes_per_device"] = {"total": coll}
    out["samples"] = {"l1": m1, "l2": m2}
    return out


# not a memo: a config-override side channel for reduced-depth probe
# cells, written/restored in try/finally and bounded by the arch table
_PATCHED_CFG = {}  # lint: cache-ok(override channel, not a cache)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               attn_impl: str = None, zero1: bool = True,
               microbatches: int = 1, unroll: bool = False):
    if unroll:
        os.environ["REPRO_UNROLL"] = "1"   # exact cost accounting (pscan)
    else:
        os.environ.pop("REPRO_UNROLL", None)
    from repro.models import transformer as T
    from repro.optim.adamw import AdamW
    from repro.serve.decode import make_serve_step
    from repro.train.train_step import (init_state,
                                        state_specs,
                                        batch_specs,
                                        make_train_step)

    cfg = _PATCHED_CFG.get(arch) or get_config(arch)
    if attn_impl:
        cfg = cfg.replace(attn_impl=attn_impl)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    opt = AdamW()
    t0 = time.time()
    with set_mesh(mesh):
        if shape.kind in ("train", "prefill"):
            state_shapes = jax.eval_shape(
                lambda: init_state(cfg, jax.random.PRNGKey(0), opt))
            sspec = state_specs(cfg, state_shapes, zero1=zero1)
            bshapes = train_input_specs(cfg, shape)
            bspec = batch_specs(bshapes)
            ssh = shardings_for(mesh, sspec, state_shapes)
            bsh = shardings_for(mesh, bspec, bshapes)
            if shape.kind == "train":
                fn = make_train_step(cfg, opt, microbatches=microbatches)
                jitted = jax.jit(fn,
                                 in_shardings=(ssh, bsh),
                                 out_shardings=(ssh, None),
                                 donate_argnums=(0,))
                lowered = jitted.lower(state_shapes, bshapes)
            else:  # prefill: forward only (inference)
                def fwd(params, batch):
                    return T.forward(params, cfg, batch)
                jitted = jax.jit(fwd, in_shardings=(ssh.params, bsh))
                lowered = jitted.lower(state_shapes.params, bshapes)
        else:  # decode
            params_shapes = jax.eval_shape(
                lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
            # serve weights in activation dtype
            params_shapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    s.shape, cfg.activation_dtype), params_shapes)
            from repro.models.common import make_param_specs
            pspec = make_param_specs(params_shapes)
            dspecs = decode_input_specs(cfg, shape)
            cspec = cache_specs(dspecs["cache"])
            serve = make_serve_step(cfg)
            args = [params_shapes, dspecs["token"], dspecs["cache"],
                    dspecs["pos"]]
            csh = shardings_for(mesh, cspec, dspecs["cache"])
            in_sh = [shardings_for(mesh, pspec, params_shapes),
                     shardings_for(mesh, P(DP), dspecs["token"]),
                     csh,
                     shardings_for(mesh, P(DP), dspecs["pos"])]
            if cfg.family == "audio":
                args.append(dspecs["encoder_out"])
                in_sh.append(shardings_for(mesh, P(DP, None, None),
                                           dspecs["encoder_out"]))
            jitted = jax.jit(serve, in_shardings=tuple(in_sh),
                             out_shardings=(None, csh),
                             donate_argnums=(2,))
            lowered = jitted.lower(*args)

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[k] = getattr(ma, k, None)
    except Exception as e:          # pragma: no cover
        mem["error"] = str(e)
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        cost = {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "optimal_seconds")
                    or k.startswith("bytes accessed"))}
    except Exception as e:          # pragma: no cover
        cost = {"error": str(e)}
    try:
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        n_coll_ops = {k: hlo.count(f" {k}(") + hlo.count(f" {k}-start(")
                      for k in ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute")}
    except Exception as e:          # pragma: no cover
        coll, n_coll_ops = {"error": str(e)}, {}

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": 512 if multi_pod else 256,
        "kind": shape.kind,
        "attn_impl": attn_impl or get_config(arch).attn_impl,
        "unrolled": unroll,
        "status": "ok",
        "seconds_lower": round(t_lower, 1),
        "seconds_compile": round(t_compile, 1),
        "memory_analysis": mem,
        "cost_analysis": cost,
        "collective_bytes_per_device": coll,
        "collective_op_counts": n_coll_ops,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll scans for exact cost accounting")
    ap.add_argument("--account", action="store_true",
                    help="layer-extrapolated exact accounting (fast)")
    ap.add_argument("--patch", default="",
                    help="config overrides, e.g. "
                         "kv_replicated=true,moe.ep=false,remat=dots")
    ap.add_argument("--all", action="store_true",
                    help="sweep every (arch x shape) in subprocesses")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.all:
        os.makedirs(args.out, exist_ok=True)
        for arch in ARCH_IDS:
            for shape in SHAPES:
                suffix = ".acct" if args.account else (
                    ".unroll" if args.unroll else "")
                name = f"{arch}.{shape}.{args.mesh}{suffix}"
                path = os.path.join(args.out, name + ".json")
                if os.path.exists(path):
                    print("skip (exists):", name)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", args.mesh,
                       "--out", args.out]
                if args.unroll:
                    cmd += ["--unroll", "--tag", "unroll"]
                if args.account:
                    cmd += ["--account", "--tag", "acct"]
                print(">>", name, flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=7200)
                if r.returncode != 0:
                    with open(os.path.join(args.out, name + ".err"),
                              "w") as f:
                        f.write(r.stdout + "\n" + r.stderr)
                    print("FAILED:", name, r.stderr.splitlines()[-1:],
                          flush=True)
        return

    assert args.arch and args.shape
    if args.patch:
        import dataclasses
        cfg = get_config(args.arch)
        sub_kw = {"moe": {}, "ssm": {}, "xlstm": {}, "mla": {}}
        top_kw = {}
        for kv in args.patch.split(","):
            k, v = kv.split("=")
            v = {"true": True, "false": False}.get(
                v, int(v) if v.lstrip("-").isdigit() else
                (float(v) if v.replace(".", "").lstrip("-").isdigit()
                 else v))
            pre = k.split(".", 1)
            if len(pre) == 2 and pre[0] in sub_kw:
                sub_kw[pre[0]][pre[1]] = v
            else:
                top_kw[k] = v
        for name, kw in sub_kw.items():
            if kw:
                top_kw[name] = dataclasses.replace(getattr(cfg, name), **kw)
        _PATCHED_CFG[args.arch] = cfg.replace(**top_kw)
    if args.account:
        res = account_cell(args.arch, args.shape, args.mesh == "multipod",
                           attn_impl=args.attn_impl)
    else:
        res = lower_cell(args.arch, args.shape, args.mesh == "multipod",
                         attn_impl=args.attn_impl, zero1=not args.no_zero1,
                         microbatches=args.microbatches, unroll=args.unroll)
    os.makedirs(args.out, exist_ok=True)
    tag = f".{args.tag}" if args.tag else ""
    name = f"{args.arch}.{args.shape}.{args.mesh}{tag}.json"
    with open(os.path.join(args.out, name), "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps(res, indent=2))


if __name__ == "__main__":
    main()
