"""Elastic / fault-tolerant orchestration.

Large-scale runbook (1000+ nodes):

* **failure detection** — the coordinator watches per-step heartbeats; a
  missing heartbeat marks the worker's devices unhealthy.
* **restart** — remaining hosts relaunch with the same entry point; the
  mesh is rebuilt by ``make_elastic_mesh(n_healthy)`` (TP kept, DP shrunk),
  the checkpoint is topology-independent (full arrays), and the stateless
  data stream replays from the checkpointed step — no training state is
  lost beyond the last checkpoint interval.
* **stragglers** — two mitigations: (i) checkpoint writes are async
  (device->host copy off the step path); (ii) the deterministic stream
  lets any host compute any shard, so a rebalanced mesh assignment needs
  no data movement.

This module implements the single-process simulation of that story used
by tests/test_fault_tolerance.py: a "failure" kills the process between
steps; the relaunch resumes on a smaller device set and must reproduce
exactly the same training trajectory as an uninterrupted run (bitwise on
the loss stream, because data is stateless and checkpointing captures the
full state).
"""
from __future__ import annotations

import dataclasses
import os
import time

from repro.launch.mesh import make_elastic_mesh


@dataclasses.dataclass
class Heartbeat:
    """File-based heartbeat: workers touch, the coordinator checks age."""
    path: str
    timeout_s: float = 60.0

    def beat(self):
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    def healthy(self) -> bool:
        try:
            with open(self.path) as f:
                return time.time() - float(f.read()) < self.timeout_s
        except (OSError, ValueError):
            return False


def plan_restart(n_healthy: int, *, model_parallel: int = 16):
    """Mesh + step plan for a degraded restart."""
    mesh = make_elastic_mesh(n_healthy, model_parallel=model_parallel)
    return {
        "mesh_shape": dict(mesh.shape),
        "dp": mesh.shape.get("data", 1),
        "tp": mesh.shape.get("model", 1),
    }
