"""Production mesh construction.

A FUNCTION (not a module constant) so importing never touches jax device
state; the dry-run forces 512 host devices before calling this, real
launches see the actual TPU topology.
"""
from __future__ import annotations


import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(n_devices: int, *, model_parallel: int = 16):
    """Largest (data, model) mesh for a degraded device set (elastic
    restart after failures): keeps TP fixed, shrinks DP."""
    tp = model_parallel
    while tp > 1 and n_devices % tp:
        tp //= 2
    dp = n_devices // tp
    return jax.make_mesh((dp, tp), ("data", "model"))
