"""Model input stand-ins: ShapeDtypeStructs for the dry-run, concrete
arrays for smoke tests.  One source of truth for every (arch x shape) cell.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCfg


def train_input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    """ShapeDtypeStruct pytree for one train/prefill step's batch."""
    b, s = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
    specs = {"tokens": tok, "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        s_txt = s - cfg.img_tokens
        specs["tokens"] = jax.ShapeDtypeStruct((b, s_txt), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s_txt), jnp.int32)
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.img_tokens, cfg.d_frontend), cfg.activation_dtype)
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_frontend or cfg.d_model), cfg.activation_dtype)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    """serve_step inputs: one new token against a seq_len KV/state cache."""
    from repro.models.transformer import init_cache
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    specs = {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": cache,
    }
    if cfg.family == "audio":
        specs["encoder_out"] = jax.ShapeDtypeStruct(
            (b, s, cfg.d_model), cfg.activation_dtype)
    return specs


def concrete_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0
                   ) -> Dict[str, Any]:
    """Small real batch for smoke tests / examples."""
    rng = np.random.default_rng(seed)
    tok = rng.integers(0, cfg.vocab_size, (batch, seq)).astype(np.int32)
    out = {"tokens": jnp.asarray(tok),
           "labels": jnp.asarray(np.roll(tok, -1, axis=1))}
    if cfg.family == "vlm":
        s_txt = seq - cfg.img_tokens
        out["tokens"] = out["tokens"][:, :s_txt]
        out["labels"] = out["labels"][:, :s_txt]
        out["patches"] = jnp.asarray(
            rng.standard_normal((batch, cfg.img_tokens, cfg.d_frontend)),
            cfg.activation_dtype) * 0.2
    if cfg.family == "audio":
        out["frames"] = jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_frontend or cfg.d_model)),
            cfg.activation_dtype) * 0.2
    return out
