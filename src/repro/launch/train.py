"""Training launcher: config -> mesh -> data -> jitted step -> checkpoints.

Usage (CPU example, smoke config):
  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real cluster the same entry point runs under the production mesh
(--mesh pod|multipod); on this CPU container it uses whatever devices
exist.  Fault tolerance: every --ckpt-every steps an atomic checkpoint is
published; on restart the launcher resumes from LATEST automatically, and
the stateless data pipeline replays the exact remaining batches.
"""
from __future__ import annotations

import argparse
import time

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import set_mesh
from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM, batch_for
from repro.launch.mesh import make_production_mesh, make_elastic_mesh
from repro.models.common import shardings_for
from repro.optim.adamw import AdamW
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import init_state, state_specs, make_train_step


def run(arch: str, *, smoke: bool, steps: int, batch: int, seq: int,
        ckpt_dir: str = "", ckpt_every: int = 50, lr: float = 3e-4,
        mesh_kind: str = "auto", microbatches: int = 1, log_every: int = 10,
        seed: int = 0, max_seconds: float = 0.0):
    cfg = get_config(arch, smoke=smoke)
    if mesh_kind == "auto":
        n = jax.device_count()
        mesh = make_elastic_mesh(n, model_parallel=min(4, n))
    else:
        mesh = make_production_mesh(multi_pod=mesh_kind == "multipod")

    opt = AdamW(lr=lr, warmup=min(20, steps // 5 + 1), total_steps=steps)
    pipe = SyntheticLM(cfg.vocab_size, seq, batch, seed=seed)

    with set_mesh(mesh):
        state = init_state(cfg, jax.random.PRNGKey(seed), opt)
        sshapes = jax.eval_shape(lambda: state)
        sspec = state_specs(cfg, sshapes, zero1=True)
        ssh = shardings_for(mesh, sspec, sshapes)
        state = jax.device_put(state, ssh)

        start_step = 0
        mgr = None
        if ckpt_dir:
            mgr = CheckpointManager(ckpt_dir)
            last = mgr.latest_step()
            if last is not None:
                state = mgr.restore(last, sshapes, ssh)
                start_step = last
                print(f"[train] resumed from step {last}")

        step_fn = jax.jit(
            make_train_step(cfg, opt, microbatches=microbatches),
            in_shardings=(ssh, None),
            out_shardings=(ssh, None),
            donate_argnums=(0,))

        losses = []
        t_start = time.time()
        for step in range(start_step, steps):
            data = batch_for(cfg, pipe, step)
            state, metrics = step_fn(state, data)
            losses.append(float(metrics["loss"]))
            if step % log_every == 0 or step == steps - 1:
                dt = time.time() - t_start
                print(f"[train] step {step} loss {losses[-1]:.4f} "
                      f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)",
                      flush=True)
            if mgr and (step + 1) % ckpt_every == 0:
                mgr.save(step + 1, state, asynchronous=True)
            if max_seconds and time.time() - t_start > max_seconds:
                print(f"[train] time budget reached at step {step}")
                break
        if mgr:
            mgr.wait()
            mgr.save(min(step + 1, steps), state)
    return losses, state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="auto",
                    choices=["auto", "pod", "multipod"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-seconds", type=float, default=0.0)
    args = ap.parse_args()
    run(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
        seq=args.seq, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        lr=args.lr, mesh_kind=args.mesh, microbatches=args.microbatches,
        seed=args.seed, max_seconds=args.max_seconds)


if __name__ == "__main__":
    main()
