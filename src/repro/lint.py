"""``python -m repro.lint`` — run the invariant linter (``repro.analysis``).

Checks the six repo-specific correctness rules (no-densify,
clock-discipline, cache-registry, plan-cache-key, lock-discipline,
jit-retrace — ``--list-rules`` for details) over ``src/repro`` by
default, against the committed baseline at ``lint-baseline.json``.

    python -m repro.lint                         # text report, exit != 0
                                                 # on any non-baselined
                                                 # finding
    python -m repro.lint --format=json           # machine-readable (CI)
    python -m repro.lint --only clock-discipline,lock-discipline
    python -m repro.lint path/to/tree            # lint another tree
    python -m repro.lint --write-baseline        # accept current findings

Intentional escapes live in code, one annotation per rule with a
mandatory reason, e.g. ``# lint: clock-ok(duration measurement)``; the
baseline is for findings outside the zero-tolerance dirs (policy: no
baselined findings under ``serving/`` or ``core/`` — enforced by
``tests/test_lint.py``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import Baseline, LintEngine, rule_names
from repro.analysis.findings import split_by_baseline
from repro.analysis.rules import RULES


def default_root() -> Path:
    """The installed ``repro`` package tree (src/repro in a checkout)."""
    return Path(__file__).resolve().parent


def default_baseline_path() -> Path:
    """``lint-baseline.json`` at the checkout root (may not exist)."""
    return default_root().parent.parent / "lint-baseline.json"


def _list_rules() -> str:
    rows = []
    for r in RULES:
        escape = f"# lint: {r.escape}(reason)" if r.escape else "-"
        rows.append(f"  {r.name:18s} [{r.severity}] escape: {escape}\n"
                    f"      {r.description}")
    return "rules:\n" + "\n".join(rows)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="Invariant linter: enforce the repo's hard-won "
                    "correctness rules.")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/trees to lint (default: the repro package)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="baseline suppression file (default: "
                         "lint-baseline.json at the checkout root, when "
                         "present; 'none' disables)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--only", default=None, metavar="RULE[,RULE]",
                    help="run only these rules")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the current findings to the baseline file "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0

    only = None
    if args.only:
        only = [s.strip() for s in args.only.split(",") if s.strip()]
        unknown = set(only) - set(rule_names())
        if unknown:
            print(f"unknown rule(s): {', '.join(sorted(unknown))}; "
                  f"valid: {', '.join(rule_names())}", file=sys.stderr)
            return 2

    roots = [Path(p) for p in (args.paths or [default_root()])]
    for root in roots:
        if not root.exists():
            print(f"no such path: {root}", file=sys.stderr)
            return 2

    findings = []
    for root in roots:
        findings.extend(LintEngine(root).run(only=only))

    baseline_path = None
    if args.baseline != "none":
        baseline_path = (Path(args.baseline) if args.baseline
                         else default_baseline_path())

    if args.write_baseline:
        if baseline_path is None:
            print("--write-baseline needs a baseline path", file=sys.stderr)
            return 2
        Baseline.from_findings(findings).save(baseline_path)
        print(f"wrote {len(findings)} finding(s) to {baseline_path}")
        return 0

    if baseline_path is not None and baseline_path.exists():
        baseline = Baseline.load(baseline_path)
    else:
        baseline = Baseline()
    new, suppressed = split_by_baseline(findings, baseline)

    if args.format == "json":
        print(json.dumps({
            "version": 1,
            "roots": [str(r) for r in roots],
            "rules": only or rule_names(),
            "baseline": str(baseline_path) if baseline_path else None,
            "counts": {"total": len(findings), "new": len(new),
                       "baselined": len(suppressed)},
            "findings": [dict(f.to_dict(), baselined=False) for f in new]
            + [dict(f.to_dict(), baselined=True) for f in suppressed],
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        if suppressed:
            print(f"({len(suppressed)} baselined finding(s) suppressed)")
        if new:
            print(f"\n{len(new)} non-baselined finding(s).")
        else:
            print("clean: 0 non-baselined findings "
                  f"({len(findings)} total, {len(suppressed)} baselined).")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
