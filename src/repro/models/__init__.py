"""Model stack: the assigned architectures, built on the paper's masked
tile-product machinery for every attention/SSM score computation."""
