"""Attention as Masked SpGEMM (paper technique inside the LM stack).

``scores = M (.) (Q Kᵀ)`` is a masked matrix product with a *structured*
mask (causal / sliding-window / dense-prefix).  Three implementations:

* ``dense_masked`` — the paper's Fig.-1 strawman: compute ALL scores, then
  mask.  Quadratic flops regardless of mask.  Baseline for §Perf.
* ``block_masked`` — the paper's pull algorithm at MXU-tile granularity,
  expressed in XLA: a host-built tile worklist (only mask-admitted tiles),
  load-balanced by pairing long rows with short rows (folded-causal), then
  executed as a scan of uniform gather+matmul+streaming-softmax chunks.
  The HLO flop count shows the saving (≈2x for causal, S/W for windows) —
  this is what the dry-run rooflines measure.
* Pallas runtime kernel (``repro.kernels.flash_mask``) — same worklist, VMEM
  streaming, for real TPU execution.

``decode_attention`` is the serve-time single-token path over a (possibly
ring-buffered) KV cache.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import caches

from .common import pscan

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parametric mask (shared with kernels/flash_mask)
# ---------------------------------------------------------------------------


def allowed_fn(qpos, kpos, *, causal: bool, window: int, prefix: int):
    ok = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), jnp.bool_)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= ((qpos - kpos) < window) | (kpos < prefix)
    if prefix > 0 and window == 0:
        # prefix-LM: bidirectional within the prefix
        ok |= (kpos < prefix) & (qpos < prefix)
    return ok


# ---------------------------------------------------------------------------
# dense baseline (plain product + mask)
# ---------------------------------------------------------------------------


def dense_masked_attention(q, k, v, *, causal=True, window=0, prefix=0,
                           q_offset=0, scale=None):
    """q: (B, Hq, S, D); k, v: (B, Hkv, T, D).  Full quadratic scores."""
    b, hq, s_q, d = q.shape
    _, hkv, s_k, _ = k.shape
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, hkv, g, s_q, d)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s_q)[:, None] + q_offset
    kpos = jnp.arange(s_k)[None, :]
    ok = allowed_fn(qpos, kpos, causal=causal, window=window, prefix=prefix)
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, hq, s_q, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# block-masked (paper pull algorithm, balanced worklist, XLA)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=caches.env_capacity("REPRO_ATTN_SCHED_CAP",
                                                 256))
def _balanced_schedule(s_q: int, s_k: int, bq: int, bk: int, causal: bool,
                       window: int, prefix: int, q_offset: int,
                       chunk: int = 8):
    """Host symbolic phase: per-q-block tile lists, folded into G groups of
    2 rows with near-equal total work, padded to a common chunked length.

    Returns numpy arrays:
      q_ids  (G, 2)  row ids of the two members
      kv_ids (G, E)  gathered kv block per entry (pad: 0)
      member (G, E)  0/1 member index per entry
      valid  (G, E)  entry is real
    """
    nq, nk = s_q // bq, s_k // bk
    i = np.arange(nq)[:, None]
    j = np.arange(nk)[None, :]
    q_lo, q_hi = i * bq + q_offset, (i + 1) * bq - 1 + q_offset
    k_lo, k_hi = j * bk, (j + 1) * bk - 1
    ok = np.ones((nq, nk), bool)
    if causal:
        ok &= k_lo <= q_hi
    if window > 0:
        in_win = (q_lo - k_hi) < window
        if causal:
            in_win &= (q_hi - k_lo) >= 0
        else:
            in_win &= (k_lo - q_hi) < window
        ok &= in_win | np.broadcast_to(k_lo < prefix, in_win.shape)
    if prefix > 0 and window == 0:
        ok |= (k_lo < prefix) & (q_lo < prefix).reshape(-1, 1)
    ok[~ok.any(axis=1), 0] = True

    lists = [np.nonzero(ok[r])[0] for r in range(nq)]
    order = np.argsort([-len(l) for l in lists], kind="stable")
    if nq % 2:                      # odd: last group has one member
        order = np.concatenate([order, [order[-1]]])
    half = len(order) // 2
    groups = [(order[t], order[len(order) - 1 - t]) for t in range(half)]

    raw_e = max(len(lists[a]) + (len(lists[b]) if b != a else 0)
                for a, b in groups)
    steps = max(1, -(-raw_e // chunk))
    E = steps * (-(-raw_e // steps))
    G = len(groups)
    q_ids = np.zeros((G, 2), np.int32)
    scatter_ids = np.full((G, 2), nq, np.int32)   # nq == dropped write
    kv_ids = np.zeros((G, E), np.int32)
    member = np.zeros((G, E), np.int32)
    valid = np.zeros((G, E), bool)
    seen = set()
    for g, (a, b) in enumerate(groups):
        q_ids[g] = (a, b)
        for slot, row in ((0, int(a)), (1, int(b))):
            if row not in seen:        # duplicated rows write exactly once
                seen.add(row)
                scatter_ids[g, slot] = row
        ents = [(0, int(x)) for x in lists[a]]
        if b != a:
            ents += [(1, int(x)) for x in lists[b]]
        for e, (m, kvb) in enumerate(ents):
            member[g, e] = m
            kv_ids[g, e] = kvb
            valid[g, e] = True
    return q_ids, scatter_ids, kv_ids, member, valid, E // steps


caches.register_lru("attention-block-schedule", _balanced_schedule)


def block_masked_attention(q, k, v, *, causal=True, window=0, prefix=0,
                           q_offset=0, scale=None, bq=128, bk=128):
    """Pull-based masked attention: only mask-admitted tiles are computed.

    q: (B, Hq, S, D); k, v: (B, Hkv, T, D).  Returns (B, Hq, S, D).
    """
    b, hq, s_q, d = q.shape
    _, hkv, s_k, _ = k.shape
    g_rep = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    bq_, bk_ = min(bq, s_q), min(bk, s_k)
    if s_q % bq_ or s_k % bk_:
        return dense_masked_attention(q, k, v, causal=causal, window=window,
                                      prefix=prefix, q_offset=q_offset,
                                      scale=scale)
    if not causal and window == 0:
        # mask fully dense -> the plain product IS the masked product
        return dense_masked_attention(q, k, v, causal=False, window=0,
                                      prefix=0, q_offset=q_offset,
                                      scale=scale)

    q_ids, scatter_ids, kv_ids, member, valid, chunk = _balanced_schedule(
        s_q, s_k, bq_, bk_, causal, window, prefix, q_offset)
    G, E = kv_ids.shape
    steps = E // chunk
    q_ids_j = jnp.asarray(q_ids)
    scatter_j = jnp.asarray(scatter_ids)
    kv_c = jnp.asarray(kv_ids.reshape(G, steps, chunk))
    mem_c = jnp.asarray(member.reshape(G, steps, chunk))
    val_c = jnp.asarray(valid.reshape(G, steps, chunk))

    dv = v.shape[-1]

    def one_head(qh, kh, vh):
        # qh: (S, Dqk) one query head; kh: (T, Dqk); vh: (T, Dv)
        qb = qh.reshape(s_q // bq_, bq_, d)
        kb = kh.reshape(s_k // bk_, bk_, d)
        vb = vh.reshape(s_k // bk_, bk_, dv)

        def one_group(qid2, kv_s, mem_s, val_s):
            qg = qb[qid2]                        # (2, bq, d)

            def step(carry, xs):
                m_run, l_run, acc = carry        # (2,bq),(2,bq),(2,bq,d)
                kv_e, mem_e, val_e = xs          # (chunk,) each
                ke = kb[kv_e]                    # (c, bk, d)
                ve = vb[kv_e]
                qe = qg[mem_e]                   # (c, bq, d)
                # native-dtype operands + f32 accumulation: bf16 inputs
                # must NOT be copied up to f32 (2x HBM traffic, §Perf A2)
                s = jnp.einsum("cqd,ckd->cqk", qe, ke,
                               preferred_element_type=jnp.float32) * scale
                qrow = qid2[mem_e]               # (c,)
                qp = (qrow[:, None] * bq_ + jnp.arange(bq_)[None, :]
                      + q_offset)                # (c, bq)
                kp = kv_e[:, None] * bk_ + jnp.arange(bk_)[None, :]  # (c, bk)
                ok = allowed_fn(qp[:, :, None], kp[:, None, :],
                                causal=causal, window=window, prefix=prefix)
                ok &= val_e[:, None, None]
                s = jnp.where(ok, s, NEG_INF)
                # per-entry partials
                m_e = jnp.max(s, axis=-1)                    # (c, bq)
                p = jnp.where(ok, jnp.exp(s - m_e[..., None]), 0.0)
                l_e = jnp.sum(p, axis=-1)                    # (c, bq)
                p_mm = p.astype(jnp.promote_types(ve.dtype, jnp.bfloat16))
                o_e = jnp.einsum("cqk,ckd->cqd", p_mm, ve,
                                 preferred_element_type=jnp.float32)
                # combine the chunk's entries into the 2 members
                sel = jax.nn.one_hot(mem_e, 2, dtype=jnp.float32)  # (c, 2)
                m_e = jnp.where(l_e > 0, m_e, NEG_INF)
                m_grp = jnp.max(
                    jnp.where(sel.T[:, :, None] > 0, m_e[None], NEG_INF),
                    axis=1)                                   # (2, bq)
                m_new = jnp.maximum(m_run, m_grp)
                w_e = jnp.exp(m_e - m_new[mem_e]) * (l_e > 0)  # (c, bq)
                l_add = jnp.einsum("cm,cq->mq", sel, w_e * l_e)
                o_add = jnp.einsum("cm,cqd->mqd", sel,
                                   w_e[..., None] * o_e)
                alpha = jnp.exp(m_run - m_new)
                l_new = l_run * alpha + l_add
                acc_new = acc * alpha[..., None] + o_add
                return (m_new, l_new, acc_new), None

            init = (jnp.full((2, bq_), NEG_INF, jnp.float32),
                    jnp.zeros((2, bq_), jnp.float32),
                    jnp.zeros((2, bq_, dv), jnp.float32))
            (m_run, l_run, acc), _ = pscan(
                step, init, (kv_s, mem_s, val_s))
            # where-guarded denominator: with maximum(l, tiny), backward
            # computes 1/l^2 = inf (f32 overflow) and 0*inf = NaN for
            # fully-masked members
            l_safe = jnp.where(l_run > 0, l_run, 1.0)[..., None]
            return jnp.where(l_run[..., None] > 0, acc / l_safe, 0.0)

        out_g = jax.vmap(one_group)(q_ids_j, kv_c, mem_c, val_c)
        # scatter rows back; duplicate members carry a drop sentinel
        out = jnp.zeros((s_q // bq_, bq_, dv), jnp.float32)
        out = out.at[scatter_j.reshape(-1)].set(
            out_g.reshape(-1, bq_, dv), mode="drop")
        return out.reshape(s_q, dv)

    qg = q.reshape(b, hkv, g_rep, s_q, d)
    f = jax.vmap(jax.vmap(jax.vmap(one_head, in_axes=(0, None, None)),
                          in_axes=(0, 0, 0)), in_axes=(0, 0, 0))
    out = f(qg, k, v)
    return out.reshape(b, hq, s_q, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# dispatcher + decode
# ---------------------------------------------------------------------------


def attention(q, k, v, *, impl="block_masked", causal=True, window=0,
              prefix=0, q_offset=0, scale=None, block=128):
    if impl == "dense_masked":
        return dense_masked_attention(q, k, v, causal=causal, window=window,
                                      prefix=prefix, q_offset=q_offset,
                                      scale=scale)
    if impl == "block_masked":
        return block_masked_attention(q, k, v, causal=causal, window=window,
                                      prefix=prefix, q_offset=q_offset,
                                      scale=scale, bq=block, bk=block)
    if impl == "flash_pallas":
        from repro.kernels.flash_mask.ops import flash_mask_attention
        return flash_mask_attention(q, k, v, causal=causal, window=window,
                                    prefix=prefix, q_offset=q_offset,
                                    scale=scale, bq=block, bk=block)
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0, prefix=0,
                     scale=None):
    """One-token decode. q: (B, Hq, D); caches: (B, Hkv, T, D).

    ``cache_len``: (B,) int32 — valid prefix length (query position is
    cache_len - 1 after the cache insert).  Ring-buffered caches pass the
    physical layout; masking is by validity only.
    """
    b, hq, d = q.shape
    _, hkv, t, _ = k_cache.shape
    g = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(t)[None, :]
    ok = pos < cache_len[:, None]                      # (B, T)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, v_cache.shape[-1]).astype(q.dtype)
