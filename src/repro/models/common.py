"""Shared model utilities: sharding helpers, norms, RoPE, initializers."""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

DP = ("pod", "data")     # data-parallel axes (logical); absent axes dropped
TP = "model"             # tensor/expert-parallel axis


def _mesh_axis_names():
    try:
        from repro.compat import get_abstract_mesh
        mesh = get_abstract_mesh()
        return tuple(mesh.axis_names) if mesh is not None else ()
    except Exception:
        return ()


def _filter_spec(entries, axis_names) -> P:
    out = []
    for e in entries:
        if e is None:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a in axis_names)
            out.append(kept if kept else None)
        else:
            out.append(e if e in axis_names else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x, *entries):
    """with_sharding_constraint that degrades to a no-op off-mesh.

    Axis names not present in the current mesh are dropped, so the same
    model code runs in single-device smoke tests, the 16x16 pod, and the
    2x16x16 multi-pod mesh.
    """
    names = _mesh_axis_names()
    if not names:
        return x
    spec = _filter_spec(entries, names)
    return jax.lax.with_sharding_constraint(x, spec)


def shard_dp(x):
    """Batch-leading activation: (B, ...) -> shard batch over DP axes."""
    return shard(x, DP)


def filter_pspec(spec, mesh):
    """Drop axis names a given mesh doesn't have (pod vs single-pod)."""
    return _filter_spec(tuple(spec), tuple(mesh.axis_names))


def fit_spec(spec, shape, mesh) -> P:
    """Make a PartitionSpec legal for a concrete (shape, mesh):

    * axis names missing from the mesh are dropped (pod on single-pod);
    * an entry whose mesh-axis product does not divide its dim is moved to
      the next free dim that divides (later dims first), else dropped.

    jit input shardings require exact divisibility, unlike internal
    with_sharding_constraint (which pads) — this is the one place sharding
    legality is decided, so every jit boundary routes through here.
    """
    sizes = dict(mesh.shape)

    def norm(e):
        if e is None:
            return None
        if isinstance(e, (tuple, list)):
            kept = tuple(a for a in e if a in sizes)
            return kept if kept else None
        return e if e in sizes else None

    def axsize(e):
        if isinstance(e, tuple):
            n = 1
            for a in e:
                n *= sizes[a]
            return n
        return sizes[e]

    entries = [norm(e) for e in tuple(spec)]
    entries += [None] * (len(shape) - len(entries))
    out = [None] * len(shape)
    for i, e in enumerate(entries):
        if e is None:
            continue
        n = axsize(e)
        if n <= 1:
            continue
        for j in [i] + list(range(i + 1, len(shape))) + \
                list(range(i - 1, -1, -1)):
            if out[j] is None and shape[j] % n == 0 and shape[j] >= n:
                out[j] = e
                break
    return P(*out)


def shardings_for(mesh, spec_tree, shape_tree):
    """NamedSharding pytree: fit_spec applied leaf-wise."""
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda s, l: NamedSharding(mesh, fit_spec(s, l.shape, mesh)),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


_UNROLL_CAP = 48


def pscan(f, init, xs, length=None):
    """lax.scan that fully unrolls when REPRO_UNROLL=1 (roofline mode).

    XLA's HloCostAnalysis visits a while-loop body ONCE, so flop/byte/
    collective counts of scanned layers are undercounted by the trip count.
    The accounting pass therefore lowers reduced-depth configs with this
    unrolled form (layer scans and attention entry scans unroll; trip
    counts above _UNROLL_CAP — SSM/mLSTM cross-chunk state scans, sLSTM's
    per-token scan — stay rolled: their bodies are the cheap state-decay
    updates, a few percent of layer flops, noted in EXPERIMENTS.md).
    """
    import os as _os
    unroll: Any = 1
    if _os.environ.get("REPRO_UNROLL") == "1":
        n = length
        if n is None and xs is not None:
            n = jax.tree.leaves(xs)[0].shape[0]
        cap = int(_os.environ.get("REPRO_UNROLL_CAP", _UNROLL_CAP))
        if n is not None and n <= cap:
            unroll = True
    return jax.lax.scan(f, init, xs, length=length, unroll=unroll)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale + bias).astype(x.dtype)


def rope(x, positions, theta: float = 10000.0):
    """Rotary embedding. x: (..., S, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype=jnp.float32, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = (scale if scale is not None else 1.0) / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# parameter sharding specs by path convention
# ---------------------------------------------------------------------------

_RULES = (
    # (substring, ndim -> spec entries applied to the TRAILING dims)
    ("embed",   {2: (TP, None)}),                    # (V, D) vocab on TP
    ("lm_head", {2: (None, TP)}),                    # (D, V)
    ("patch_proj", {2: (None, None)}),
    ("wq",      {2: (None, TP)}),
    ("wk_rep",  {2: (None, None)}),
    ("wv_rep",  {2: (None, None)}),
    # MLA latent projections are small and feed the shared low-rank cache:
    # TP-sharding them propagates r-sharding into the cache and forces a
    # full-cache all-gather per layer per decode step (§Perf cell C)
    ("wkv_a",   {2: (None, None)}),
    ("wk_rope", {2: (None, None)}),
    ("wk",      {2: (None, TP)}),
    ("wv",      {2: (None, TP)}),
    ("wkv",     {2: (None, TP)}),
    ("wo",      {2: (TP, None)}),
    ("w_gate",  {2: (None, TP)}),
    ("w_up",    {2: (None, TP)}),
    ("w_down",  {2: (TP, None)}),
    ("experts", {3: (TP, None, None)}),              # (E, d, f) experts on TP
    ("router",  {2: (None, None)}),
    ("in_proj", {2: (None, TP)}),                    # ssm/xlstm big in-proj
    ("out_proj", {2: (TP, None)}),
    ("conv",    {2: (None, None), 3: (None, None, None)}),
)


def spec_for(path: str, ndim: int, stacked: bool) -> P:
    """Sharding spec for a parameter, by name convention.

    ``stacked`` marks scan-stacked params (leading layer dim -> None).
    """
    trailing = ndim - (1 if stacked else 0)
    entries: Tuple = ()
    for needle, table in _RULES:
        if needle in path and trailing in table:
            entries = table[trailing]
            break
    else:
        entries = (None,) * trailing
    full = ((None,) if stacked else ()) + tuple(entries)
    return P(*full)


def tree_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def make_param_specs(params, stacked_prefixes: Sequence[str] = ("layers",
                                                                "blocks")):
    """Pytree of PartitionSpecs parallel to ``params`` (path-convention)."""
    def one(path, leaf):
        p = jax.tree_util.keystr(path)
        stacked = any(s in p for s in stacked_prefixes) and leaf.ndim >= 2
        return spec_for(p, leaf.ndim, stacked)
    return jax.tree_util.tree_map_with_path(one, params)
