"""Per-layer building blocks: GQA attention, MLA, MLP, MoE.

Every block is an (init, apply) pair over plain dicts so layers can be
stacked with ``jax.vmap(init)`` and scanned with ``jax.lax.scan`` (compile
time independent of depth).  Decode variants take/update caches.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.configs.base import ModelConfig, MoECfg, MLACfg
from .attention import attention, decode_attention
from .common import dense_init, rms_norm, layer_norm, rope, shard, DP, TP


def _norm(cfg: ModelConfig, params, x, name):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, params[f"{name}_scale"])
    return layer_norm(x, params[f"{name}_scale"], params[f"{name}_bias"])


def init_norm(cfg: ModelConfig, name):
    p = {f"{name}_scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.norm == "layernorm":
        p[f"{name}_bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# GQA attention (RoPE), train + decode
# ---------------------------------------------------------------------------


def _kv_names(cfg: ModelConfig):
    # name-swap selects the sharding rule (common._RULES is path-keyed)
    return ("wk_rep", "wv_rep") if cfg.kv_replicated else ("wk", "wv")


def init_attn(key, cfg: ModelConfig):
    hd = cfg.hd
    nk, nv = _kv_names(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads * hd)),
        nk: dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads * hd)),
        nv: dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads * hd)),
        "wo": dense_init(ks[3], (cfg.n_heads * hd, cfg.d_model)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), jnp.float32)
    return p


def _qkv(params, cfg: ModelConfig, x, positions):
    b, s, _ = x.shape
    hd = cfg.hd
    nk, nv = _kv_names(cfg)
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params[nk].astype(x.dtype)
    v = x @ params[nv].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    q = rope(q, positions[:, None, :], cfg.rope_theta)
    k = rope(k, positions[:, None, :], cfg.rope_theta)
    q = shard(q, DP, TP, None, None)
    kv_tp = None if cfg.kv_replicated else TP
    k = shard(k, DP, kv_tp, None, None)
    v = shard(v, DP, kv_tp, None, None)
    return q, k, v


def apply_attn(params, cfg: ModelConfig, x, positions, *, causal=True,
               prefix=0, q_offset=0, window=None):
    """x: (B, S, D) -> (B, S, D)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, cfg, x, positions)
    window = cfg.window if window is None else window
    out = attention(q, k, v, impl=cfg.attn_impl, causal=causal,
                    window=window, prefix=prefix, q_offset=q_offset,
                    block=cfg.attn_block)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    return out @ params["wo"].astype(x.dtype)


def apply_cross_attn(params, cfg: ModelConfig, x, positions, kv_src,
                     src_positions):
    """Cross attention: q from x, k/v from kv_src (dense mask path)."""
    b, s, _ = x.shape
    hd = cfg.hd
    nk, nv = _kv_names(cfg)
    q = (x @ params["wq"].astype(x.dtype)).reshape(
        b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (kv_src @ params[nk].astype(x.dtype)).reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = (kv_src @ params[nv].astype(x.dtype)).reshape(
        b, kv_src.shape[1], cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    out = attention(q, k, v, impl="dense_masked", causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    return out @ params["wo"].astype(x.dtype)


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    """Ring-buffered when windowed: physical length min(max_len, window)."""
    t = min(max_len, cfg.window) if cfg.window > 0 else max_len
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, t, cfg.hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, t, cfg.hd), dtype),
    }


def apply_attn_decode(params, cfg: ModelConfig, x, cache, pos):
    """One-token decode. x: (B, 1, D); pos: (B,) absolute position.

    Returns (out (B, 1, D), new_cache).
    """
    b = x.shape[0]
    hd = cfg.hd
    nk, nv = _kv_names(cfg)
    q = (x[:, 0] @ params["wq"].astype(x.dtype))
    k = (x[:, 0] @ params[nk].astype(x.dtype))
    v = (x[:, 0] @ params[nv].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, cfg.n_heads, hd)
    k = k.reshape(b, cfg.n_kv_heads, hd)
    v = v.reshape(b, cfg.n_kv_heads, hd)
    q = rope(q[:, :, None, :], pos[:, None, None], cfg.rope_theta)[:, :, 0]
    k = rope(k[:, :, None, :], pos[:, None, None], cfg.rope_theta)[:, :, 0]
    t = cache["k"].shape[2]
    slot = jnp.where(jnp.asarray(cfg.window > 0), pos % t,
                     jnp.minimum(pos, t - 1))
    bidx = jnp.arange(b)
    k_cache = cache["k"].at[bidx, :, slot].set(k.astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, :, slot].set(v.astype(cache["v"].dtype))
    valid = jnp.minimum(pos + 1, t)
    out = decode_attention(q, k_cache, v_cache, valid)
    out = out.reshape(b, 1, cfg.n_heads * hd)
    return out @ params["wo"].astype(x.dtype), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank KV cache, weight-absorbed decode
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    m: MLACfg = cfg.mla
    ks = jax.random.split(key, 6)
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads * qk)),
        "wkv_a": dense_init(ks[1], (cfg.d_model, m.kv_lora_rank)),
        "wk_rope": dense_init(ks[2], (cfg.d_model, m.qk_rope_dim)),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank,
                                   cfg.n_heads * m.qk_nope_dim)),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank,
                                   cfg.n_heads * m.v_head_dim)),
        "wo": dense_init(ks[5], (cfg.n_heads * m.v_head_dim, cfg.d_model)),
    }


def apply_mla(params, cfg: ModelConfig, x, positions, *, causal=True):
    m: MLACfg = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = (x @ params["wq"].astype(x.dtype)).reshape(
        b, s, h, m.qk_nope_dim + m.qk_rope_dim).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope, positions[:, None, :], cfg.rope_theta)
    kv_c = x @ params["wkv_a"].astype(x.dtype)              # (B, S, r)
    k_rope = rope((x @ params["wk_rope"].astype(x.dtype))[:, None],
                  positions[:, None, :], cfg.rope_theta)    # (B, 1, S, dr)
    k_nope = (kv_c @ params["wk_b"].astype(x.dtype)).reshape(
        b, s, h, m.qk_nope_dim).transpose(0, 2, 1, 3)
    v = (kv_c @ params["wv_b"].astype(x.dtype)).reshape(
        b, s, h, m.v_head_dim).transpose(0, 2, 1, 3)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, h, s, m.qk_rope_dim))],
        axis=-1)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    out = attention(qf, kf, v, impl=cfg.attn_impl, causal=causal,
                    scale=scale, block=cfg.attn_block)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    return out @ params["wo"].astype(x.dtype)


def mla_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    return {
        "kv_c": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    }


def apply_mla_decode(params, cfg: ModelConfig, x, cache, pos):
    """Weight-absorbed MLA decode: attention runs in the latent space, so
    the cache is rank-(kv_lora+rope) per token instead of 2*H*hd."""
    m: MLACfg = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    q = (x[:, 0] @ params["wq"].astype(x.dtype)).reshape(
        b, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = rope(q_rope[:, :, None], pos[:, None, None],
                  cfg.rope_theta)[:, :, 0]
    kv_c_new = x[:, 0] @ params["wkv_a"].astype(x.dtype)     # (B, r)
    k_rope_new = rope((x[:, 0] @ params["wk_rope"].astype(x.dtype))
                      [:, None, None], pos[:, None, None],
                      cfg.rope_theta)[:, 0, 0]
    t = cache["kv_c"].shape[1]
    bidx = jnp.arange(b)
    slot = jnp.minimum(pos, t - 1)
    kv_c_new = shard(kv_c_new, DP, None)
    k_rope_new = shard(k_rope_new, DP, None)
    kv_c = cache["kv_c"].at[bidx, slot].set(
        kv_c_new.astype(cache["kv_c"].dtype))
    k_rope = cache["k_rope"].at[bidx, slot].set(
        k_rope_new.astype(cache["k_rope"].dtype))
    kv_c = shard(kv_c, DP, None, None)
    k_rope = shard(k_rope, DP, None, None)
    # absorb wk_b into q:  q_lat (B,H,r) = q_nope @ wk_b^T (per head)
    wk_b = params["wk_b"].astype(x.dtype).reshape(
        m.kv_lora_rank, h, m.qk_nope_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope, wk_b)
    # native-dtype operands + f32 accumulation: astype(f32) materializes
    # full f32 copies of the latent cache every layer (§Perf cell C2)
    s_lat = jnp.einsum("bhr,btr->bht", q_lat, kv_c,
                       preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bhd,btd->bht", q_rope, k_rope,
                        preferred_element_type=jnp.float32)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = shard((s_lat + s_rope) * scale, DP, TP, None)
    valid = (jnp.arange(t)[None, :] <= pos[:, None])
    s = jnp.where(valid[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = p.astype(jnp.promote_types(kv_c.dtype, jnp.bfloat16))
    o_lat = jnp.einsum("bht,btr->bhr", p, kv_c,
                       preferred_element_type=jnp.float32)
    wv_b = params["wv_b"].astype(x.dtype).reshape(
        m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", o_lat.astype(x.dtype), wv_b)
    out = out.reshape(b, 1, h * m.v_head_dim)
    return out @ params["wo"].astype(x.dtype), \
        {"kv_c": kv_c, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], (cfg.d_model, d_ff)),
            "w_up": dense_init(ks[1], (cfg.d_model, d_ff)),
            "w_down": dense_init(ks[2], (d_ff, cfg.d_model)),
        }
    return {
        "w_up": dense_init(ks[0], (cfg.d_model, d_ff)),
        "w_down": dense_init(ks[1], (d_ff, cfg.d_model)),
        "b_up": jnp.zeros((d_ff,), jnp.float32),
        "b_down": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def apply_mlp(params, cfg: ModelConfig, x):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) * (
            x @ params["w_up"].astype(x.dtype))
        h = shard(h, DP, None, TP)
        return h @ params["w_down"].astype(x.dtype)
    h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype)
                    + params["b_up"].astype(x.dtype), approximate=True)
    h = shard(h, DP, None, TP)
    return h @ params["w_down"].astype(x.dtype) + \
        params["b_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE: top-k routing, sort-based grouped GEMM (ragged_dot)
# ---------------------------------------------------------------------------
#
# The dispatch IS a masked product (DESIGN.md §4): the routing assignment is
# a sparse mask over (token, expert); sorting tokens by expert materializes
# the mask's worklist (the same symbolic phase as the tile kernels), and
# ragged_dot executes only the admitted products — a dropless masked SpGEMM.


def init_moe(key, cfg: ModelConfig):
    mo: MoECfg = cfg.moe
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (cfg.d_model, mo.n_experts), scale=0.1),
        "experts_gate": dense_init(ks[1], (mo.n_experts, cfg.d_model,
                                           mo.d_ff_expert)),
        "experts_up": dense_init(ks[2], (mo.n_experts, cfg.d_model,
                                         mo.d_ff_expert)),
        "experts_down": dense_init(ks[3], (mo.n_experts, mo.d_ff_expert,
                                           cfg.d_model)),
    }
    if mo.n_shared:
        p["shared"] = init_mlp(ks[4], cfg,
                               d_ff=mo.d_ff_shared * mo.n_shared)
    return p


def apply_moe(params, cfg: ModelConfig, x):
    """x: (B, S, D) -> (B, S, D).

    Two paths:
    * **EP (shard_map)** when a mesh with a "model" axis is ambient: experts
      live sharded on the model axis; every rank routes its dp-shard's
      tokens, keeps only assignments that hit its local experts (a fixed
      per-rank capacity), runs the local grouped GEMM (ragged_dot) and
      psums the combine.  The routing mask's worklist is materialized
      locally — the masked-SpGEMM schedule at expert granularity — and no
      token array is ever replicated across ranks (the GSPMD dense path
      replicated the (T·k, D) gather per rank: ~1 TB/device at train_4k).
    * **dense fallback** (no mesh / ep=False): dropless sort + ragged_dot.
    """
    from .common import _mesh_axis_names
    mo: MoECfg = cfg.moe
    names = _mesh_axis_names()
    if mo.ep and "model" in names:
        return _apply_moe_ep(params, cfg, x, names)
    return _apply_moe_dense(params, cfg, x)


def _apply_moe_dense(params, cfg: ModelConfig, x):
    mo: MoECfg = cfg.moe
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = (xt @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, mo.top_k)        # (T, k)
    if mo.router_scale:
        top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    T = xt.shape[0]
    flat_e = top_e.reshape(-1)                           # (T*k,)
    flat_w = top_w.reshape(-1)
    src = jnp.repeat(jnp.arange(T), mo.top_k)
    order = jnp.argsort(flat_e)                          # worklist by expert
    gathered = xt[src[order]]                            # (T*k, D)
    group_sizes = jnp.bincount(flat_e, length=mo.n_experts).astype(jnp.int32)

    def ragged(lhs, rhs):
        return jax.lax.ragged_dot(lhs, rhs.astype(lhs.dtype), group_sizes)

    h = jax.nn.silu(ragged(gathered, params["experts_gate"])) * \
        ragged(gathered, params["experts_up"])
    out_sorted = ragged(h, params["experts_down"])       # (T*k, D)
    # combine: unsort + weight + segment-sum back onto tokens
    contrib = out_sorted * flat_w[order][:, None].astype(out_sorted.dtype)
    out = jnp.zeros((T, d), contrib.dtype).at[src[order]].add(contrib)
    out = out.reshape(b, s, d)
    if mo.n_shared:
        out = out + apply_mlp(params["shared"], cfg, x)
    return out.astype(x.dtype)


def _apply_moe_ep(params, cfg: ModelConfig, x, axis_names):
    """Expert-parallel MoE: shard_map over (dp..., model)."""
    mo: MoECfg = cfg.moe
    b, s, d = x.shape
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    from jax.sharding import PartitionSpec as P
    dp = tuple(a for a in ("pod", "data") if a in axis_names)
    ep = mesh.shape["model"]
    if mo.n_experts % ep:
        return _apply_moe_dense(params, cfg, x)
    e_local = mo.n_experts // ep
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    if dp_size and b % dp_size:
        return _apply_moe_dense(params, cfg, x)
    t_local = max(1, (b // max(dp_size, 1)) * s)
    # fixed per-rank capacity (in token-assignments)
    cap = int(np.ceil(t_local * mo.top_k / ep * mo.capacity_factor))
    cap = min(cap, t_local * mo.top_k)

    def local(xt, router, eg, eu, ed):
        # xt: (b_loc, s, d) this dp shard (replicated over model)
        bl = xt.shape[0]
        xt = xt.reshape(bl * s, d)
        T = xt.shape[0]
        rank = jax.lax.axis_index("model")
        logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, mo.top_k)
        if mo.router_scale:
            top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)
        src = jnp.repeat(jnp.arange(T), mo.top_k)
        mine = (flat_e // e_local) == rank
        # one argsort: local assignments grouped by expert, others pushed out
        key = jnp.where(mine, flat_e, mo.n_experts)
        order = jnp.argsort(key)
        sel = order[:cap]
        valid = mine[sel]
        rows = src[sel]
        gathered = xt[rows] * valid[:, None].astype(xt.dtype)
        le = jnp.where(valid, flat_e[sel] - rank * e_local, e_local)
        group_sizes = jnp.bincount(le, length=e_local + 1)[:e_local]
        group_sizes = group_sizes.astype(jnp.int32)

        def ragged(lhs, rhs):
            return jax.lax.ragged_dot(lhs, rhs.astype(lhs.dtype),
                                      group_sizes)

        h = jax.nn.silu(ragged(gathered, eg)) * ragged(gathered, eu)
        out_rows = ragged(h, ed)
        out_rows = out_rows * (flat_w[sel][:, None] *
                               valid[:, None]).astype(out_rows.dtype)
        combined = jnp.zeros((T, d), out_rows.dtype).at[rows].add(
            out_rows, mode="drop")
        combined = jax.lax.psum(combined, "model")
        return combined.reshape(bl, s, d)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(dp if dp else None, None, None), P(),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(dp if dp else None, None, None))
    out = fn(x, params["router"],
             params["experts_gate"], params["experts_up"],
             params["experts_down"]).astype(x.dtype)
    if mo.n_shared:
        out = out + apply_mlp(params["shared"], cfg, x)
    return out


def moe_aux_loss(params, cfg: ModelConfig, x):
    """Load-balance auxiliary loss (Switch-style)."""
    mo = cfg.moe
    logits = (x.reshape(-1, x.shape[-1]) @
              params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, mo.n_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return mo.n_experts * jnp.sum(frac * imp)
