"""Mamba2 mixer via the SSD chunked-matmul algorithm (TPU-native form).

The SSD decomposition computes, per chunk of Q timesteps,

    Y_intra = (L (.) (C Bᵀ)) X          -- a *masked tile product*: L is the
                                           lower-triangular decay mask, so
                                           this is exactly the paper's
                                           C = M (.) (A B) with a structured
                                           mask at tile granularity
    Y_inter = decay-weighted C @ S_prev -- cross-chunk recurrence (scan)

which is why the paper's masked-SpGEMM machinery applies to attention-free
architectures too (DESIGN.md §5, xlstm/zamba rows).

Shapes follow the Mamba2 reference: d_inner = expand*d_model, nh heads of
head_dim p, shared B/C of state size n (ngroups=1).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SSMCfg
from .common import dense_init, rms_norm, shard, DP, TP, pscan


def _dims(cfg: ModelConfig):
    s: SSMCfg = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.head_dim
    return s, d_inner, nh


def init_ssm(key, cfg: ModelConfig):
    s, d_inner, nh = _dims(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = d_inner + 2 * s.d_state
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model,
                                      2 * d_inner + 2 * s.d_state + nh)),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_ch), scale=1.0),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[3], (d_inner, cfg.d_model)),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B, L, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for t in range(width):
        out = out + pad[:, t:t + x.shape[1]] * w[t].astype(x.dtype)
    return jax.nn.silu(out + b.astype(x.dtype))


def _split_proj(params, cfg, x):
    s, d_inner, nh = _dims(cfg)
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xs, B, C, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + s.d_state,
                 2 * d_inner + 2 * s.d_state], axis=-1)
    return z, xs, B, C, dt


def apply_ssm(params, cfg: ModelConfig, x, positions=None):
    """x: (B, L, D) -> (B, L, D) via SSD chunked scan."""
    s, d_inner, nh = _dims(cfg)
    b, L, _ = x.shape
    Q = min(s.chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    z, xs, B, C, dt = _split_proj(params, cfg, x)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"])            # (B, L, nh)
    A = -jnp.exp(params["a_log"])                        # (nh,)

    # Heads shard over TP: the O(Q^2 * nh) decay tensors below are the
    # memory hot-spot of the whole Zamba2 train step (§Perf cell B) — the
    # nh axis is the only one that splits them without breaking the masked
    # tile product's structure.  Decays are <= 1 (da < 0), so the masked
    # decay tensor is bf16-safe; products accumulate in f32.
    act = cfg.activation_dtype
    xh = xs.reshape(b, nc, Q, nh, s.head_dim).astype(jnp.float32)
    xh = shard(xh, DP, None, None, TP, None)
    Bh = B.reshape(b, nc, Q, s.d_state).astype(jnp.float32)
    Ch = C.reshape(b, nc, Q, s.d_state).astype(jnp.float32)
    dth = dt.reshape(b, nc, Q, nh)
    dth = shard(dth, DP, None, None, TP)

    da = dth * A                                         # (B, nc, Q, nh)
    cum = jnp.cumsum(da, axis=2)                         # within-chunk csum
    cum = shard(cum, DP, None, None, TP)
    # intra-chunk: masked decay product  L_ij = exp(cum_i - cum_j) (i >= j)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q,Q,nh)
    ii = jnp.arange(Q)
    tri = (ii[:, None] >= ii[None, :])                   # lower-tri mask
    Lmask = jnp.where(tri[None, None, :, :, None], jnp.exp(diff),
                      0.0).astype(act)
    Lmask = shard(Lmask, DP, None, None, None, TP)
    scores = jnp.einsum("bcqn,bckn->bcqk", Ch, Bh)       # (b,nc,Q,Q)
    gated = (scores[..., None].astype(act) * Lmask
             * dth[:, :, None, :, :].astype(act))
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", gated, xh.astype(act),
                         preferred_element_type=jnp.float32)

    # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j (x) X_j
    decay_state = jnp.exp(cum[:, :, -1:, :] - cum)       # (b,nc,Q,nh)
    wX = xh * (dth * decay_state)[..., None]             # (b,nc,Q,nh,p)
    S_c = jnp.einsum("bcqn,bcqhp->bchnp", Bh, wX)        # (b,nc,h,n,p)

    # cross-chunk recurrence (scan over chunks).  y_inter is computed
    # INSIDE the scan: materializing all nc per-chunk states S_prev
    # ((b,nc,nh,n,p) — ~1 TB/device f32 for zamba2 train) was the real
    # memory-term driver (§Perf B3); carrying one (b,nh,n,p) state and
    # emitting y per chunk keeps the live set at one chunk.
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (b,nc,nh)
    inter_decay = jnp.exp(cum)                           # (b,nc,Q,nh)

    def step(S_prev, xs_c):
        S_new, dec, Ch_c, idec_c = xs_c
        y_c = jnp.einsum("bqn,bhnp->bqhp", Ch_c, S_prev) \
            * idec_c[..., None]                          # (b,Q,nh,p)
        S_next = S_prev * dec[..., None, None] + S_new
        return S_next, y_c

    S0 = jnp.zeros((b, nh, s.d_state, s.head_dim), jnp.float32)
    _, y_inter = pscan(
        step, S0, (S_c.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2),
                   Ch.transpose(1, 0, 2, 3),
                   inter_decay.transpose(1, 0, 2, 3)))
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)           # (b,nc,Q,nh,p)

    y = (y_intra + y_inter).reshape(b, L, nh, s.head_dim)
    y = y + xh.reshape(b, L, nh, s.head_dim) * params["d_skip"][:, None]
    y = y.reshape(b, L, d_inner)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), params["norm_scale"])
    y = shard(y, DP, None, TP)
    return y @ params["out_proj"].astype(x.dtype)


# ---------------------------------------------------------------------------
# decode (single-step recurrence)
# ---------------------------------------------------------------------------


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype):
    s, d_inner, nh = _dims(cfg)
    conv_ch = d_inner + 2 * s.d_state
    return {
        "S": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_ch), dtype),
    }


def apply_ssm_decode(params, cfg: ModelConfig, x, cache, pos=None):
    """x: (B, 1, D) -> (B, 1, D); O(1)-state decode (long_500k path)."""
    s, d_inner, nh = _dims(cfg)
    b = x.shape[0]
    z, xs, B, C, dt = _split_proj(params, cfg, x)
    conv_in = jnp.concatenate([xs, B, C], axis=-1)[:, 0]  # (B, C)
    hist = jnp.concatenate([cache["conv"],
                            conv_in[:, None].astype(cache["conv"].dtype)],
                           axis=1)                        # (B, W, C)
    w = params["conv_w"].astype(x.dtype)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist.astype(x.dtype), w)
                           + params["conv_b"].astype(x.dtype))
    xs, B, C = jnp.split(conv_out, [d_inner, d_inner + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"])
    dec = jnp.exp(dt * A)                                 # (B, nh)
    xh = xs.reshape(b, nh, s.head_dim).astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    S = cache["S"] * dec[..., None, None] + jnp.einsum(
        "bn,bhp->bhnp", Bf, xh * dt[..., None])
    y = jnp.einsum("bn,bhnp->bhp", Cf, S)
    y = y + xh * params["d_skip"][:, None]
    y = y.reshape(b, 1, d_inner)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), params["norm_scale"])
    out = y @ params["out_proj"].astype(x.dtype)
    return out, {"S": S, "conv": hist[:, 1:]}
