"""Model assembly for every assigned architecture family.

Design rules:
* All per-layer params are stacked with ``jax.vmap(init)`` and applied with
  ``pscan`` -> compile time independent of depth (critical for the
  dry-run of 81-layer models on 512 partitions).
* Heterogeneous stacks are expressed as scans over *super-blocks*
  (xLSTM: r-1 mLSTM + 1 sLSTM; Zamba2: ``hybrid_attn_every`` Mamba2 layers
  + one application of the SHARED attention block — one weight set reused,
  faithful to Zamba's design).
* Every family exposes: ``init_params``, ``forward`` (logits), ``init_cache``
  and ``decode_step`` (one token), so train_step/serve_step are generic.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from . import layers as Lyr
from . import ssm as SSM
from . import xlstm as XL
from .common import (dense_init, rms_norm, shard, shard_dp, DP, TP,
                     make_param_specs, pscan)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _stack_init(init_fn, key, n, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args))(keys)


# ---------------------------------------------------------------------------
# block bodies (single layer, pre-norm residual)
# ---------------------------------------------------------------------------


def _attn_mlp_block(cfg: ModelConfig, use_moe: bool):
    def body(p, x, positions, prefix):
        h = Lyr._norm(cfg, p, x, "ln1")
        if cfg.mla is not None:
            h = Lyr.apply_mla(p["attn"], cfg, h, positions)
        else:
            h = Lyr.apply_attn(p["attn"], cfg, h, positions, prefix=prefix)
        x = x + h
        h = Lyr._norm(cfg, p, x, "ln2")
        if use_moe:
            h = Lyr.apply_moe(p["ffn"], cfg, h)
        else:
            h = Lyr.apply_mlp(p["ffn"], cfg, h)
        x = x + h
        return shard_dp(x)
    return body


def _init_attn_mlp(key, cfg: ModelConfig, use_moe: bool):
    k1, k2 = jax.random.split(key)
    p = {"attn": (Lyr.init_mla(k1, cfg) if cfg.mla is not None
                  else Lyr.init_attn(k1, cfg)),
         "ffn": (Lyr.init_moe(k2, cfg) if use_moe
                 else Lyr.init_mlp(k2, cfg))}
    p.update(Lyr.init_norm(cfg, "ln1"))
    p.update(Lyr.init_norm(cfg, "ln2"))
    return p


def _ssm_block(cfg: ModelConfig):
    def body(p, x, positions, prefix):
        h = Lyr._norm(cfg, p, x, "ln1")
        x = x + SSM.apply_ssm(p["ssm"], cfg, h)
        return shard_dp(x)
    return body


def _init_ssm_block(key, cfg: ModelConfig):
    p = {"ssm": SSM.init_ssm(key, cfg)}
    p.update(Lyr.init_norm(cfg, "ln1"))
    return p


# ---------------------------------------------------------------------------
# family: decoder-only (dense / moe / mla)
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=1.0),
    }
    params.update({f"final_{k}": v
                   for k, v in Lyr.init_norm(cfg, "ln").items()})
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size))

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        kd = cfg.first_k_dense if cfg.moe is not None else cfg.n_layers
        n_moe = cfg.n_layers - kd if cfg.moe is not None else 0
        if kd:
            params["layers_dense"] = _stack_init(
                lambda k: _init_attn_mlp(k, cfg, use_moe=False), ks[2], kd)
        if n_moe:
            params["layers_moe"] = _stack_init(
                lambda k: _init_attn_mlp(k, cfg, use_moe=True), ks[3], n_moe)
        if fam == "vlm":
            params["patch_proj"] = dense_init(
                ks[4], (cfg.d_frontend, cfg.d_model))
    elif fam == "ssm" and cfg.xlstm is not None:     # xLSTM
        r = cfg.xlstm.slstm_every
        n_super = cfg.n_layers // r
        params["layers_mlstm"] = _stack_init(
            lambda k: dict(XL.init_mlstm(k, cfg),
                           **Lyr.init_norm(cfg, "ln1")),
            ks[2], n_super * (r - 1))
        params["layers_mlstm"] = jax.tree.map(
            lambda a: a.reshape((n_super, r - 1) + a.shape[1:]),
            params["layers_mlstm"])
        params["layers_slstm"] = _stack_init(
            lambda k: dict(XL.init_slstm(k, cfg),
                           **Lyr.init_norm(cfg, "ln1")),
            ks[3], n_super)
    elif fam == "hybrid":                            # Zamba2
        params["layers_ssm"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg), ks[2], cfg.n_layers)
        params["shared_attn"] = _init_attn_mlp(ks[3], cfg, use_moe=False)
    elif fam == "audio":                             # enc-dec
        enc_cfg = cfg
        params["enc_layers"] = _stack_init(
            lambda k: _init_attn_mlp(k, enc_cfg, use_moe=False), ks[2],
            cfg.n_enc_layers)
        params["dec_layers"] = _stack_init(
            lambda k: _init_dec_block(k, cfg), ks[3], cfg.n_dec_layers)
        params.update({f"encfinal_{k}": v
                       for k, v in Lyr.init_norm(cfg, "ln").items()})
        params["frame_proj"] = dense_init(
            ks[4], (cfg.d_frontend or cfg.d_model, cfg.d_model))
    else:
        raise ValueError(f"family {cfg.family}")
    return params


def _init_dec_block(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"attn": Lyr.init_attn(k1, cfg),
         "cross": Lyr.init_attn(k2, cfg),
         "ffn": Lyr.init_mlp(k3, cfg)}
    p.update(Lyr.init_norm(cfg, "ln1"))
    p.update(Lyr.init_norm(cfg, "ln2"))
    p.update(Lyr.init_norm(cfg, "ln3"))
    return p


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _scan_blocks(cfg, body, stacked, x, positions, prefix):
    # close over positions/prefix: static args must not cross the remat
    # boundary as tracers
    fn = _remat(cfg, lambda x, p: body(p, x, positions, prefix))

    def step(x, p):
        return fn(x, p), None

    x, _ = pscan(step, x, stacked)
    return x


def _embed(params, cfg, tokens):
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    return shard_dp(x)


def _logits(params, cfg, x):
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(x.dtype)
    logits = x @ head
    return shard(logits, DP, None, TP)


def forward(params, cfg: ModelConfig, batch: Dict[str, Any]):
    """batch: tokens (B, S) [+ patches (B, P, d_frontend) for vlm;
    frames (B, S_src, d_frontend) + tokens for audio].  Returns logits."""
    fam = cfg.family
    if fam == "audio":
        return _forward_encdec(params, cfg, batch)

    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed(params, cfg, tokens)
    prefix = 0
    if fam == "vlm":
        patches = batch["patches"].astype(x.dtype)
        pe = patches @ params["patch_proj"].astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
        prefix = cfg.img_tokens
        s = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    if fam in ("dense", "moe", "vlm"):
        if "layers_dense" in params:
            x = _scan_blocks(cfg, _attn_mlp_block(cfg, False),
                             params["layers_dense"], x, positions, prefix)
        if "layers_moe" in params:
            x = _scan_blocks(cfg, _attn_mlp_block(cfg, True),
                             params["layers_moe"], x, positions, prefix)
    elif fam == "ssm" and cfg.xlstm is not None:
        def super_body(x, ps):
            p_m, p_s = ps

            def m_step(x, p):
                h = Lyr._norm(cfg, p, x, "ln1")
                return x + XL.apply_mlstm(p, cfg, h), None
            x, _ = pscan(_remat(cfg, m_step), x, p_m)
            h = Lyr._norm(cfg, p_s, x, "ln1")
            x = x + XL.apply_slstm(p_s, cfg, h)
            return shard_dp(x), None
        x, _ = pscan(super_body, x,
                            (params["layers_mlstm"], params["layers_slstm"]))
    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        shared = params["shared_attn"]
        _ssm = _ssm_block(cfg)
        _attn = _attn_mlp_block(cfg, False)
        ssm_body = _remat(cfg, lambda x, p: _ssm(p, x, positions, prefix))
        attn_body = _remat(cfg, lambda x: _attn(shared, x, positions,
                                                prefix))

        def step(carry, p):
            x, i = carry
            x = ssm_body(x, p)
            x = jax.lax.cond((i + 1) % every == 0, attn_body,
                             lambda x: x, x)
            return (x, i + 1), None
        (x, _), _ = pscan(step, (x, 0), params["layers_ssm"])
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_ln_scale"]) if cfg.norm == "rmsnorm" else \
        Lyr.layer_norm(x, params["final_ln_scale"], params["final_ln_bias"])
    return _logits(params, cfg, x)


def _forward_encdec(params, cfg: ModelConfig, batch):
    frames = batch["frames"]
    tokens = batch["tokens"]
    b, s_src, _ = frames.shape
    s_tgt = tokens.shape[1]
    enc = frames.astype(cfg.activation_dtype) @ \
        params["frame_proj"].astype(cfg.activation_dtype)
    enc = shard_dp(enc)
    pos_src = jnp.broadcast_to(jnp.arange(s_src)[None, :], (b, s_src))

    enc_body = _attn_mlp_block(cfg, False)

    def enc_step(x, p):
        # bidirectional: dense mask path
        h = Lyr._norm(cfg, p, x, "ln1")
        h = Lyr.apply_attn(p["attn"], cfg, h, pos_src, causal=False,
                           window=0)
        x = x + h
        h = Lyr._norm(cfg, p, x, "ln2")
        x = x + Lyr.apply_mlp(p["ffn"], cfg, h)
        return shard_dp(x), None

    enc, _ = pscan(_remat(cfg, enc_step), enc, params["enc_layers"])
    enc = (rms_norm(enc, params["encfinal_ln_scale"])
           if cfg.norm == "rmsnorm" else
           Lyr.layer_norm(enc, params["encfinal_ln_scale"],
                          params["encfinal_ln_bias"]))

    x = _embed(params, cfg, tokens)
    pos_tgt = jnp.broadcast_to(jnp.arange(s_tgt)[None, :], (b, s_tgt))

    def dec_step(x, p):
        h = Lyr._norm(cfg, p, x, "ln1")
        x = x + Lyr.apply_attn(p["attn"], cfg, h, pos_tgt)
        h = Lyr._norm(cfg, p, x, "ln2")
        x = x + Lyr.apply_cross_attn(p["cross"], cfg, h, pos_tgt, enc,
                                     pos_src)
        h = Lyr._norm(cfg, p, x, "ln3")
        x = x + Lyr.apply_mlp(p["ffn"], cfg, h)
        return shard_dp(x), None

    x, _ = pscan(_remat(cfg, dec_step), x, params["dec_layers"])
    x = rms_norm(x, params["final_ln_scale"]) if cfg.norm == "rmsnorm" else \
        Lyr.layer_norm(x, params["final_ln_scale"], params["final_ln_bias"])
    return _logits(params, cfg, x)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_fn(params, cfg: ModelConfig, batch):
    logits = forward(params, cfg, batch).astype(jnp.float32)
    labels = batch["labels"]
    if cfg.family == "vlm":          # image prefix produces no loss
        logits = logits[:, cfg.img_tokens:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.einsum("bsv,bsv->bs", jax.nn.one_hot(labels, cfg.vocab_size,
                                                    dtype=jnp.float32),
                      logits)
    mask = (labels >= 0).astype(jnp.float32)
    nll = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return nll


# ---------------------------------------------------------------------------
# decode (serve_step): caches stacked per scanned segment
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = cfg.activation_dtype
    fam = cfg.family

    def stack(n, make):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[make() for _ in
                                                         range(n)]) \
            if n else None

    if fam in ("dense", "moe", "vlm"):
        kd = cfg.first_k_dense if cfg.moe is not None else cfg.n_layers
        n_moe = cfg.n_layers - kd if cfg.moe is not None else 0
        mk = ((lambda: Lyr.mla_cache_init(cfg, batch, max_len, dt))
              if cfg.mla is not None else
              (lambda: Lyr.attn_cache_init(cfg, batch, max_len, dt)))
        return {"dense": stack(kd, mk), "moe": stack(n_moe, mk)}
    if fam == "ssm" and cfg.xlstm is not None:
        r = cfg.xlstm.slstm_every
        n_super = cfg.n_layers // r
        m = stack(n_super * (r - 1), lambda: XL.mlstm_cache_init(cfg, batch))
        m = jax.tree.map(lambda a: a.reshape((n_super, r - 1) + a.shape[1:]),
                         m)
        return {"mlstm": m,
                "slstm": stack(n_super, lambda: XL.slstm_cache_init(cfg,
                                                                    batch))}
    if fam == "hybrid":
        every = cfg.hybrid_attn_every
        n_attn = cfg.n_layers // every
        return {"ssm": stack(cfg.n_layers,
                             lambda: SSM.ssm_cache_init(cfg, batch, dt)),
                "attn": stack(n_attn,
                              lambda: Lyr.attn_cache_init(cfg, batch,
                                                          max_len, dt))}
    if fam == "audio":
        return {"self": stack(cfg.n_dec_layers,
                              lambda: Lyr.attn_cache_init(cfg, batch,
                                                          max_len, dt))}
    raise ValueError(fam)


def decode_step(params, cfg: ModelConfig, token, cache, pos,
                encoder_out=None):
    """One decode step.  token: (B,) int32; pos: (B,) absolute position.
    Returns (logits (B, V), new_cache)."""
    fam = cfg.family
    x = params["embed"].astype(cfg.activation_dtype)[token][:, None, :]

    if fam in ("dense", "moe", "vlm"):
        dec = (Lyr.apply_mla_decode if cfg.mla is not None
               else Lyr.apply_attn_decode)

        def seg(x, stacked, caches, use_moe):
            def step(x, pc):
                p, c = pc
                h = Lyr._norm(cfg, p, x, "ln1")
                h, c = dec(p["attn"], cfg, h, c, pos)
                x = x + h
                h = Lyr._norm(cfg, p, x, "ln2")
                x = x + (Lyr.apply_moe(p["ffn"], cfg, h) if use_moe
                         else Lyr.apply_mlp(p["ffn"], cfg, h))
                return x, c
            return pscan(step, x, (stacked, caches))

        new_cache = dict(cache)
        if cache.get("dense") is not None:
            x, new_cache["dense"] = seg(x, params["layers_dense"],
                                        cache["dense"], False)
        if cache.get("moe") is not None:
            x, new_cache["moe"] = seg(x, params["layers_moe"],
                                      cache["moe"], True)
    elif fam == "ssm" and cfg.xlstm is not None:
        def super_step(x, pcs):
            (p_m, c_m), (p_s, c_s) = pcs

            def m_step(x, pc):
                p, c = pc
                h = Lyr._norm(cfg, p, x, "ln1")
                h, c = XL.apply_mlstm_decode(p, cfg, h, c)
                return x + h, c
            x, c_m = pscan(m_step, x, (p_m, c_m))
            h = Lyr._norm(cfg, p_s, x, "ln1")
            h, c_s = XL.apply_slstm_decode(p_s, cfg, h, c_s)
            return x + h, (c_m, c_s)
        x, (cm, cs) = pscan(
            super_step, x, ((params["layers_mlstm"], cache["mlstm"]),
                            (params["layers_slstm"], cache["slstm"])))
        new_cache = {"mlstm": cm, "slstm": cs}
    elif fam == "hybrid":
        every = cfg.hybrid_attn_every
        n_attn = cfg.n_layers // every
        shared = params["shared_attn"]

        def step(carry, pc):
            x, i, ai, attn_caches = carry
            p, c = pc
            h = Lyr._norm(cfg, p, x, "ln1")
            h, c = SSM.apply_ssm_decode(p["ssm"], cfg, h, c)
            x = x + h

            def with_attn(op):
                x, ai, attn_caches = op
                ac = jax.tree.map(lambda a: a[ai], attn_caches)
                h = Lyr._norm(cfg, shared, x, "ln1")
                h, ac = Lyr.apply_attn_decode(shared["attn"], cfg, h, ac,
                                              pos)
                x = x + h
                h = Lyr._norm(cfg, shared, x, "ln2")
                x = x + Lyr.apply_mlp(shared["ffn"], cfg, h)
                attn_caches = jax.tree.map(
                    lambda full, one: jax.lax.dynamic_update_index_in_dim(
                        full, one, ai, 0), attn_caches, ac)
                return x, ai + 1, attn_caches

            x, ai, attn_caches = jax.lax.cond(
                (i + 1) % every == 0, with_attn,
                lambda op: op, (x, ai, attn_caches))
            return (x, i + 1, ai, attn_caches), c

        (x, _, _, attn_caches), ssm_caches = pscan(
            step, (x, 0, 0, cache["attn"]),
            (params["layers_ssm"], cache["ssm"]))
        new_cache = {"ssm": ssm_caches, "attn": attn_caches}
    elif fam == "audio":
        def step(x, pc):
            p, c = pc
            h = Lyr._norm(cfg, p, x, "ln1")
            h, c = Lyr.apply_attn_decode(p["attn"], cfg, h, c, pos)
            x = x + h
            h = Lyr._norm(cfg, p, x, "ln2")
            x = x + Lyr.apply_cross_attn(
                p["cross"], cfg, h, pos[:, None], encoder_out,
                jnp.arange(encoder_out.shape[1])[None, :])
            h = Lyr._norm(cfg, p, x, "ln3")
            x = x + Lyr.apply_mlp(p["ffn"], cfg, h)
            return x, c
        x, cs = pscan(step, x, (params["dec_layers"], cache["self"]))
        new_cache = {"self": cs}
    else:
        raise ValueError(fam)

    x = rms_norm(x, params["final_ln_scale"]) if cfg.norm == "rmsnorm" else \
        Lyr.layer_norm(x, params["final_ln_scale"], params["final_ln_bias"])
    return _logits(params, cfg, x)[:, 0], new_cache


def param_specs(params):
    return make_param_specs(params)
