"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

The mLSTM's chunkwise-parallel form computes, inside each chunk,

    H = (D (.) (Q Kᵀ)) V

where D is the lower-triangular exp-gate decay mask — the same masked tile
product as the paper's C = M (.) (A B) (DESIGN.md §5).  Cross-chunk state is
a (dk x dv) matrix-memory recurrence with log-space stabilization; the
chunkwise path is validated against the exact sequential recurrence in
tests/test_models.py.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, XLSTMCfg
from .common import dense_init, rms_norm, shard, DP, TP, pscan

NEG = -1e30


def _dims(cfg: ModelConfig):
    x: XLSTMCfg = cfg.xlstm
    hd = x.head_dim or (cfg.d_model // cfg.n_heads)
    return x, cfg.n_heads, hd


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig):
    xc, nh, hd = _dims(cfg)
    d_in = nh * hd
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], (cfg.d_model, d_in)),
        "wk": dense_init(ks[1], (cfg.d_model, d_in)),
        "wv": dense_init(ks[2], (cfg.d_model, d_in)),
        "w_if": dense_init(ks[3], (cfg.d_model, 2 * nh), scale=0.5),
        "b_if": jnp.concatenate([jnp.zeros((nh,)), 3.0 * jnp.ones((nh,))]),
        "w_og": dense_init(ks[4], (cfg.d_model, d_in), scale=0.5),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[5], (d_in, cfg.d_model)),
    }


def _mlstm_gates(params, cfg, x):
    xc, nh, hd = _dims(cfg)
    b, L, _ = x.shape
    q = (x @ params["wq"].astype(x.dtype)).reshape(b, L, nh, hd)
    k = (x @ params["wk"].astype(x.dtype)).reshape(b, L, nh, hd)
    v = (x @ params["wv"].astype(x.dtype)).reshape(b, L, nh, hd)
    if_pre = (x @ params["w_if"].astype(x.dtype)).astype(jnp.float32) \
        + params["b_if"]
    log_i = if_pre[..., :nh]                       # i = exp(i_pre)
    log_f = -jax.nn.softplus(-if_pre[..., nh:])    # f = sigmoid(f_pre)
    og = jax.nn.sigmoid(x @ params["w_og"].astype(x.dtype))
    return q, k, v, log_i, log_f, og


def apply_mlstm(params, cfg: ModelConfig, x, positions=None):
    """Chunkwise-parallel mLSTM. x: (B, L, D) -> (B, L, D)."""
    xc, nh, hd = _dims(cfg)
    b, L, _ = x.shape
    Q = min(xc.chunk, L)
    assert L % Q == 0
    nc = L // Q
    q, k, v, log_i, log_f, og = _mlstm_gates(params, cfg, x)
    scale = hd ** -0.5

    qh = q.reshape(b, nc, Q, nh, hd).astype(jnp.float32) * scale
    kh = k.reshape(b, nc, Q, nh, hd).astype(jnp.float32)
    vh = v.reshape(b, nc, Q, nh, hd).astype(jnp.float32)
    li = log_i.reshape(b, nc, Q, nh)
    lf = log_f.reshape(b, nc, Q, nh)

    F = jnp.cumsum(lf, axis=2)                     # within-chunk cum log f
    Ftot = F[:, :, -1, :]                          # (b,nc,nh)

    # ---- intra-chunk masked product:  D_ij = exp(F_i - F_j + li_j) --------
    logD = F[:, :, :, None, :] - F[:, :, None, :, :] + li[:, :, None, :, :]
    ii = jnp.arange(Q)
    tri = ii[:, None] >= ii[None, :]
    logD = jnp.where(tri[None, None, :, :, None], logD, NEG)
    m_intra = jnp.max(logD, axis=3)                # (b,nc,Q,nh)

    # ---- cross-chunk recurrence with stabilizer ---------------------------
    # carry: (C (b,nh,dk,dv), n (b,nh,dk), m (b,nh))
    def step(carry, xs):
        C, n, m = carry
        kh_c, vh_c, li_c, F_c, Ftot_c = xs
        # per-position source log-weights for the state update
        lw = Ftot_c[:, None, :] - F_c + li_c       # (b,Q,nh)
        m_loc = jnp.max(lw, axis=1)                # (b,nh)
        m_new = jnp.maximum(Ftot_c + m, m_loc)
        w = jnp.exp(lw - m_new[:, None, :])        # (b,Q,nh)
        decay = jnp.exp(Ftot_c + m - m_new)        # (b,nh)
        C_new = C * decay[..., None, None] + jnp.einsum(
            "bqhk,bqhv->bhkv", kh_c * w[..., None], vh_c)
        n_new = n * decay[..., None] + jnp.einsum(
            "bqhk->bhk", kh_c * w[..., None])
        return (C_new, n_new, m_new), (C, n, m)

    C0 = jnp.zeros((b, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, nh, hd), jnp.float32)
    m0 = jnp.full((b, nh), NEG, jnp.float32)
    xs = (kh.transpose(1, 0, 2, 3, 4), vh.transpose(1, 0, 2, 3, 4),
          li.transpose(1, 0, 2, 3), F.transpose(1, 0, 2, 3),
          Ftot.transpose(1, 0, 2))
    _, (C_prev, n_prev, m_prev) = pscan(step, (C0, n0, m0), xs)
    C_prev = C_prev.transpose(1, 0, 2, 3, 4)       # (b,nc,nh,dk,dv)
    n_prev = n_prev.transpose(1, 0, 2, 3)
    m_prev = m_prev.transpose(1, 0, 2)

    # combined stabilizer per position: max(intra row max, inter decay + m)
    log_inter = F + m_prev[:, :, None, :]          # (b,nc,Q,nh)
    m_row = jnp.maximum(m_intra, log_inter)

    D = jnp.exp(logD - m_row[:, :, :, None, :])
    s = jnp.einsum("bcqhd,bckhd->bcqkh", qh, kh) * D
    h_intra = jnp.einsum("bcqkh,bckhv->bcqhv", s, vh)
    l_intra = jnp.sum(s, axis=3)                   # (b,nc,Q,nh)

    w_inter = jnp.exp(log_inter - m_row)           # (b,nc,Q,nh)
    h_inter = jnp.einsum("bcqhk,bchkv->bcqhv", qh * w_inter[..., None],
                         C_prev)
    l_inter = jnp.einsum("bcqhk,bchk->bcqh", qh * w_inter[..., None], n_prev)

    l = l_intra + l_inter
    denom = jnp.maximum(jnp.abs(l), jnp.exp(-m_row))
    h = (h_intra + h_inter) / denom[..., None]

    h = h.reshape(b, L, nh * hd).astype(x.dtype) * og
    h = rms_norm(h, params["norm_scale"])
    h = shard(h, DP, None, TP)
    return h @ params["out_proj"].astype(x.dtype)


def mlstm_cache_init(cfg: ModelConfig, batch: int):
    xc, nh, hd = _dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), NEG, jnp.float32),
    }


def apply_mlstm_decode(params, cfg: ModelConfig, x, cache, pos=None):
    """Exact sequential recurrence, one step. x: (B, 1, D)."""
    xc, nh, hd = _dims(cfg)
    b = x.shape[0]
    q, k, v, log_i, log_f, og = _mlstm_gates(params, cfg, x)
    qf = q[:, 0].astype(jnp.float32) * hd ** -0.5  # (b,nh,hd)
    kf = k[:, 0].astype(jnp.float32)
    vf = v[:, 0].astype(jnp.float32)
    li, lf = log_i[:, 0], log_f[:, 0]              # (b,nh)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(lf + m, li)
    decay = jnp.exp(lf + m - m_new)
    inp = jnp.exp(li - m_new)
    C = C * decay[..., None, None] + jnp.einsum(
        "bhk,bhv->bhkv", kf * inp[..., None], vf)
    n = n * decay[..., None] + kf * inp[..., None]
    h_num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    l = jnp.einsum("bhk,bhk->bh", qf, n)
    denom = jnp.maximum(jnp.abs(l), jnp.exp(-m_new))
    h = (h_num / denom[..., None]).reshape(b, 1, nh * hd).astype(x.dtype)
    h = rms_norm(h * og, params["norm_scale"])
    out = h @ params["out_proj"].astype(x.dtype)
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (sequential scalar recurrence, block-diagonal recurrent weights)
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig):
    xc, nh, hd = _dims(cfg)
    d_in = nh * hd
    ks = jax.random.split(key, 4)
    return {
        "w_in": dense_init(ks[0], (cfg.d_model, 4 * d_in)),
        "r_blocks": dense_init(ks[1], (4, nh, hd, hd), scale=0.5),
        "b_gates": jnp.concatenate(
            [jnp.zeros((d_in,)), 3.0 * jnp.ones((d_in,)),
             jnp.zeros((2 * d_in,))]),
        "norm_scale": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[3], (d_in, cfg.d_model)),
    }


def _slstm_cell(params, cfg, x_pre, state):
    """One step. x_pre: (B, 4*d_in) input preactivations (no recurrent)."""
    xc, nh, hd = _dims(cfg)
    d_in = nh * hd
    c, n, m, h = state
    hb = h.reshape(-1, nh, hd)
    rec = jnp.einsum("bhd,ghde->bghe", hb,
                     params["r_blocks"].astype(h.dtype))  # (b,4,nh,hd)
    pre = x_pre.reshape(-1, 4, nh, hd) + rec \
        + params["b_gates"].reshape(4, nh, hd).astype(h.dtype)
    pre = pre.astype(jnp.float32)
    li = pre[:, 0]                                  # log input gate
    lf = -jax.nn.softplus(-pre[:, 1])               # log sigmoid forget
    z = jnp.tanh(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(lf + m, li)
    c_new = jnp.exp(lf + m - m_new) * c + jnp.exp(li - m_new) * z
    n_new = jnp.exp(lf + m - m_new) * n + jnp.exp(li - m_new)
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, m_new, h_new.astype(h.dtype))


def apply_slstm(params, cfg: ModelConfig, x, positions=None):
    """Sequential scan over time. x: (B, L, D)."""
    xc, nh, hd = _dims(cfg)
    b, L, _ = x.shape
    d_in = nh * hd
    x_pre = x @ params["w_in"].astype(x.dtype)      # (B, L, 4*d_in)

    def step(state, xt):
        new = _slstm_cell(params, cfg, xt, state)
        return new, new[3]

    init = slstm_cache_init(cfg, b)
    state = (init["c"], init["n"], init["m"],
             jnp.zeros((b, nh, hd), x.dtype))
    _, hs = pscan(step, state, x_pre.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(b, L, d_in)
    h = rms_norm(h, params["norm_scale"])
    h = shard(h, DP, None, TP)
    return h @ params["out_proj"].astype(x.dtype)


def slstm_cache_init(cfg: ModelConfig, batch: int):
    xc, nh, hd = _dims(cfg)
    return {
        "c": jnp.zeros((batch, nh, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh, hd), NEG, jnp.float32),
        "h": jnp.zeros((batch, nh, hd), jnp.float32),
    }


def apply_slstm_decode(params, cfg: ModelConfig, x, cache, pos=None):
    xc, nh, hd = _dims(cfg)
    b = x.shape[0]
    x_pre = (x[:, 0] @ params["w_in"].astype(x.dtype))
    state = (cache["c"], cache["n"], cache["m"],
             cache["h"].astype(x.dtype))
    c, n, m, h = _slstm_cell(params, cfg, x_pre, state)
    out = rms_norm(h.reshape(b, 1, nh * hd), params["norm_scale"])
    out = out @ params["out_proj"].astype(x.dtype)
    return out, {"c": c, "n": n, "m": m, "h": h.astype(jnp.float32)}
