"""repro.obs — structured tracing + exposition for the serving stack.

Usage sketch (quickstart §12 walks the full loop)::

    from repro import obs
    obs.configure()                      # in-memory ring; off by default
    eng = QueryEngine(expose_port=0)     # /metrics + /health
    ... serve ...
    spans = obs.current_spans()
    obs.export.save_chrome_trace("trace.json", spans)   # Perfetto
    obs.disable()

Span sites cost one global read + one branch while tracing is off, and
spans never feed scheduling or deterministic counters — enabling them
cannot change ``deterministic_snapshot()`` (pinned by
``benchmarks/bench_obs.py`` and the CI ``obs-smoke`` job).

Online health intelligence (quickstart §13) rides the same stream::

    monitor = obs.HealthMonitor()        # aggregator + SLOs + drift
    eng = QueryEngine(monitor=monitor, expose_port=0)
    with obs.tracing(monitor):
        ... serve ...
    eng.health()                         # HealthVerdict{ok|degraded|failing}

while ``python -m repro.obs.report`` renders the cross-PR trajectory of
every committed bench grid.
"""
# NB: .report is deliberately NOT imported here — it is a CLI module
# (``python -m repro.obs.report``) and pre-importing it from the package
# __init__ would trip runpy's double-import warning
from . import drift, export, health, sinks, slo  # noqa: F401
from .drift import DriftDetector
from .exposition import parse_prometheus, render_prometheus
from .export import chrome_trace, residuals, save_chrome_trace
from .health import HealthMonitor, HealthVerdict, WindowAggregator
from .sinks import InMemorySink, JsonlSpanSink, load_spans
from .slo import DEFAULT_SLOS, Objective, SLOEngine
from .spans import (
    Tracer,
    configure,
    counter,
    current_spans,
    disable,
    enabled,
    event,
    get_tracer,
    new_trace,
    span,
    tracing,
)

__all__ = [
    "DEFAULT_SLOS", "DriftDetector", "HealthMonitor", "HealthVerdict",
    "InMemorySink", "JsonlSpanSink", "Objective", "SLOEngine", "Tracer",
    "WindowAggregator", "chrome_trace", "configure", "counter",
    "current_spans", "disable", "drift", "enabled", "event", "export",
    "get_tracer", "health", "load_spans", "new_trace",
    "parse_prometheus", "render_prometheus", "residuals",
    "save_chrome_trace", "sinks", "slo", "span", "tracing",
]
