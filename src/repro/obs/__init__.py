"""repro.obs — structured tracing + exposition for the serving stack.

Usage sketch (quickstart §12 walks the full loop)::

    from repro import obs
    obs.configure()                      # in-memory ring; off by default
    eng = QueryEngine(expose_port=0)     # /metrics + /health
    ... serve ...
    spans = obs.current_spans()
    obs.export.save_chrome_trace("trace.json", spans)   # Perfetto
    obs.disable()

Span sites cost one global read + one branch while tracing is off, and
spans never feed scheduling or deterministic counters — enabling them
cannot change ``deterministic_snapshot()`` (pinned by
``benchmarks/bench_obs.py`` and the CI ``obs-smoke`` job).
"""
from . import export, sinks  # noqa: F401  (re-exported submodules)
from .exposition import parse_prometheus, render_prometheus
from .export import chrome_trace, residuals, save_chrome_trace
from .sinks import InMemorySink, JsonlSpanSink, load_spans
from .spans import (
    Tracer,
    configure,
    current_spans,
    disable,
    enabled,
    event,
    get_tracer,
    new_trace,
    span,
    tracing,
)

__all__ = [
    "InMemorySink", "JsonlSpanSink", "Tracer", "chrome_trace",
    "configure", "current_spans", "disable", "enabled", "event",
    "export", "get_tracer", "load_spans", "new_trace",
    "parse_prometheus", "render_prometheus", "residuals",
    "save_chrome_trace", "sinks", "span", "tracing",
]
