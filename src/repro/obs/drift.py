"""Online cost-model drift detection from modeled-vs-measured residuals.

The planner elects kernels from fitted cost constants
(``repro.tuning``); the paper's point — density, mask structure and
cache behavior dominate — means those constants go stale as traffic or
hardware shifts.  PR 9 made every ``serve.exec`` span carry the
planner's ``modeled_ms``; this module folds the residual
``measured / (modeled * bucket_size)`` into streaming statistics and
flags when calibration has drifted past a multiplicative **band**.

Statistics are kept per ``(family, algorithm, regime)`` key:

* ``family`` — the probe family ``repro.tune --only`` refits
  (``row`` for the row-wise kernels, ``tile``, ``dist``), so a flag
  maps directly onto the retune command that fixes it;
* ``algorithm`` — the elected kernel (msa/hash/...);
* ``regime`` — :func:`repro.core.planner.feature_regime`'s coarse
  log-bucketed feature signature, because a model can be calibrated at
  one density and wrong at another.

Residuals are folded in **log space** (a model 4x high and 4x low are
equally wrong) through two estimators: Welford's online mean/variance
(exact, all-time) and an EWMA (recent-weighted) — the EWMA drives
flagging so a one-off cold-compile outlier decays instead of
poisoning the verdict, while Welford's variance reports confidence.

Flags carry a concrete recommendation keyed by
``planner.cost_model_token()``: when the token changes (the table was
retuned or hand-edited) all statistics reset — residuals measured
against the old model say nothing about the new one.

Route discipline: ``route="burst"`` spans are skipped — the burst
executor replays a compiled program whose cost the per-query model
does not price.  Bucketed spans measure the whole bucket, so the
modeled single-query cost is scaled by the ``size`` attr.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["DriftDetector", "DriftFlag", "DriftReport", "KernelStats",
           "family_of"]

#: elected-algorithm -> ``repro.tune`` probe family
_ALGO_FAMILY = {
    "msa": "row", "hash": "row", "mca": "row", "heap": "row",
    "heapdot": "row", "inner": "row",
    "tile": "tile", "block": "tile",
    "dist": "dist", "distributed": "dist", "spsumma": "dist",
}


def family_of(algorithm: Optional[str]) -> str:
    """Map an elected algorithm to its retune probe family."""
    return _ALGO_FAMILY.get(str(algorithm), "row")


def _default_token() -> Optional[str]:
    # deferred: repro.core.planner imports repro.obs at module scope
    from repro.core import planner
    try:
        return planner.cost_model_token()
    except Exception:
        return None


class KernelStats:
    """Welford + EWMA over log residuals for one (family, algo, regime)."""

    __slots__ = ("count", "mean", "_m2", "ewma", "alpha")

    def __init__(self, alpha: float = 0.2):
        self.count = 0
        self.mean = 0.0        # Welford mean of log residuals
        self._m2 = 0.0
        self.ewma = 0.0        # recent-weighted log residual
        self.alpha = alpha

    def update(self, log_residual: float) -> None:
        self.count += 1
        delta = log_residual - self.mean
        self.mean += delta / self.count
        self._m2 += delta * (log_residual - self.mean)
        if self.count == 1:
            self.ewma = log_residual
        else:
            self.ewma += self.alpha * (log_residual - self.ewma)

    @property
    def variance(self) -> float:
        return self._m2 / (self.count - 1) if self.count > 1 else 0.0

    @property
    def stddev(self) -> float:
        return math.sqrt(self.variance)

    @property
    def mean_residual(self) -> float:
        """Geometric-mean measured/modeled ratio (1.0 = calibrated)."""
        return math.exp(self.mean)

    @property
    def ewma_residual(self) -> float:
        """Recent-weighted measured/modeled ratio."""
        return math.exp(self.ewma)


@dataclasses.dataclass(frozen=True)
class DriftFlag:
    """One (family, algorithm, regime) whose calibration drifted."""

    family: str
    algorithm: str
    regime: str
    ewma_residual: float
    mean_residual: float
    count: int
    band: float
    reason: str

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Detector summary: flags plus the retune command that fixes them."""

    flags: Tuple[DriftFlag, ...]
    families: Tuple[str, ...]
    command: str
    token: Optional[str]

    def as_dict(self) -> Dict:
        return {"flags": [f.as_dict() for f in self.flags],
                "families": list(self.families),
                "command": self.command, "token": self.token}


class DriftDetector:
    """Streams residuals into per-kernel statistics and flags drift.

    ``band`` is the flag threshold as a multiplicative factor: a key is
    flagged when its EWMA residual leaves ``[1/band, band]`` after at
    least ``min_count`` observations.  ``token_fn`` supplies the cost
    table identity (defaults to ``planner.cost_model_token``); a token
    change resets all statistics.
    """

    def __init__(self, *, band: float = 4.0, min_count: int = 8,
                 alpha: float = 0.2,
                 token_fn: Callable[[], Optional[str]] = _default_token):
        if band <= 1.0:
            raise ValueError(f"band must be > 1.0, got {band}")
        self.band = float(band)
        self.min_count = int(min_count)
        self.alpha = float(alpha)
        self._token_fn = token_fn
        self._token: Optional[str] = None
        self._stats: Dict[Tuple[str, str, str], KernelStats] = {}

    # -- ingest -------------------------------------------------------------

    def _check_token(self) -> None:
        tok = self._token_fn()
        if tok != self._token:
            if self._token is not None and self._stats:
                self._stats.clear()    # new model: old residuals are void
            self._token = tok

    def observe(self, algorithm: Optional[str], regime: Optional[str],
                residual: float) -> None:
        """Fold one normalized residual (measured/modeled ratio)."""
        if not (residual > 0.0) or not math.isfinite(residual):
            return
        self._check_token()
        key = (family_of(algorithm), str(algorithm), str(regime or "-"))
        st = self._stats.get(key)
        if st is None:
            st = self._stats[key] = KernelStats(self.alpha)
        st.update(math.log(residual))

    def observe_record(self, rec: Dict) -> None:
        """Sink-side ingest: folds a ``serve.exec`` span record carrying
        ``modeled_ms`` (other records are ignored)."""
        # cheap pre-filter: this sits on the per-record emit path, and
        # almost every record (submits, counters, queue waits) is not an
        # exec span — don't pay residual_record's dict build for those
        if rec.get("name") != "serve.exec" or "counter" in rec:
            return
        from .export import residual_record
        r = residual_record(rec)
        if r is None or r.get("route") == "burst":
            return
        self.observe(r.get("algorithm"), r.get("regime"), r["residual"])

    def ingest(self, spans: List[Dict]) -> int:
        """Fold a batch of captured span records; returns #observed."""
        before = sum(s.count for s in self._stats.values())
        for rec in spans or ():
            self.observe_record(rec)
        return sum(s.count for s in self._stats.values()) - before

    # -- reads --------------------------------------------------------------

    @property
    def token(self) -> Optional[str]:
        return self._token

    def stats(self) -> Dict[Tuple[str, str, str], KernelStats]:
        return dict(self._stats)

    def flags(self) -> List[DriftFlag]:
        log_band = math.log(self.band)
        out: List[DriftFlag] = []
        for (family, algo, regime), st in sorted(self._stats.items()):
            if st.count < self.min_count or abs(st.ewma) <= log_band:
                continue
            direction = ("measured >> modeled" if st.ewma > 0
                         else "modeled >> measured")
            out.append(DriftFlag(
                family=family, algorithm=algo, regime=regime,
                ewma_residual=st.ewma_residual,
                mean_residual=st.mean_residual, count=st.count,
                band=self.band,
                reason=(f"cost-model drift: {algo} (family {family}, "
                        f"regime {regime}) residual "
                        f"{st.ewma_residual:.3g}x over {st.count} obs "
                        f"({direction}, band {self.band:g}x)")))
        return out

    def report(self) -> DriftReport:
        flags = tuple(self.flags())
        families = tuple(sorted({f.family for f in flags}))
        command = ""
        if families:
            command = ("re-run `python -m repro.tune --only "
                       f"{','.join(families)}` (cost table "
                       f"{self._token or 'unknown'})")
        return DriftReport(flags, families, command, self._token)

    def snapshot(self) -> Dict[str, Dict]:
        """Flat per-key statistics for /metrics gauge export."""
        out: Dict[str, Dict] = {}
        for (family, algo, regime), st in sorted(self._stats.items()):
            out[f"{family}/{algo}/{regime}"] = {
                "family": family, "algorithm": algo, "regime": regime,
                "count": st.count, "mean_residual": st.mean_residual,
                "ewma_residual": st.ewma_residual,
                "log_stddev": st.stddev,
            }
        return out
