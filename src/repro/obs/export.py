"""Trace exports: Chrome trace-event / Perfetto JSON and residuals.

``chrome_trace`` turns captured span records into the Trace Event
Format both ``chrome://tracing`` and https://ui.perfetto.dev open
directly: complete ("ph": "X") events with microsecond timestamps
normalized to the earliest span, one row per emitting thread.

Counter records (from :func:`repro.obs.counter`) render as counter
("ph": "C") events, which Perfetto draws as value tracks — queue
depth, in-flight requests and cache hit-rate alongside the slices.

``residuals`` closes the paper's modeled-vs-measured loop: exec spans
carry the planner's modeled cost (``modeled_ms`` from
``planner.explain``), so a capture yields per-algorithm residual
factors that ``repro.tune`` can fold into the next calibration.  Both
``residuals`` and ``residual_summary`` accept empty, ``None`` or
plan-span-free captures and return empty results — the online drift
detector feeds them sparse windows.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

__all__ = ["chrome_trace", "residual_record", "residual_summary",
           "residuals", "save_chrome_trace"]


def _json_safe(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    return repr(v)


def chrome_trace(spans: List[Dict]) -> Dict:
    """Render span records as a Chrome trace-event JSON object."""
    spans = spans or []
    if spans:
        t_base = min(s.get("t0", 0.0) for s in spans)
    else:
        t_base = 0.0
    events = []
    for s in spans:
        if "counter" in s:                 # counter track, not a slice
            events.append({
                "name": s.get("name", "?"),
                "cat": str(s.get("name", "?")).split(".", 1)[0],
                "ph": "C",
                "ts": (s.get("t0", 0.0) - t_base) * 1e6,
                "pid": 1,
                "tid": s.get("tid", 0),
                "args": {"value": float(s["counter"])},
            })
            continue
        args = dict(s.get("attrs") or {})
        if s.get("trace") is not None:
            args["trace_id"] = s["trace"]
        if s.get("parent") is not None:
            args["parent_span"] = s["parent"]
        if s.get("error"):
            args["error"] = s["error"]
        events.append({
            "name": s.get("name", "?"),
            "cat": str(s.get("name", "?")).split(".", 1)[0],
            "ph": "X",
            "ts": (s.get("t0", 0.0) - t_base) * 1e6,
            "dur": max(s.get("dur", 0.0), 0.0) * 1e6,
            "pid": 1,
            "tid": s.get("tid", 0),
            "args": _json_safe(args),
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def save_chrome_trace(path, spans: List[Dict]) -> Dict:
    """Write a Perfetto-openable trace JSON; returns the object."""
    obj = chrome_trace(spans)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(obj, fh)
    return obj


def residual_record(rec: Dict, *,
                    span_name: str = "serve.exec") -> Optional[Dict]:
    """One span record -> residual dict, or ``None``.

    Returns ``{"algorithm", "route", "regime", "size", "modeled_ms",
    "measured_ms", "residual"}`` for an exec span carrying a usable
    modeled cost; ``None`` for anything else (wrong name, counter
    record, missing/zero/non-numeric ``modeled_ms``).  ``residual =
    measured / (modeled * size)``: bucketed exec spans measure the
    whole bucket while ``modeled_ms`` prices one query, so the modeled
    side scales by the bucket ``size`` (absent -> 1).
    """
    if not isinstance(rec, dict) or rec.get("name") != span_name:
        return None
    if "counter" in rec:
        return None
    attrs = rec.get("attrs") or {}
    try:
        modeled = float(attrs.get("modeled_ms") or 0.0)
        measured = float(rec.get("dur") or 0.0) * 1e3
        size = float(attrs.get("size") or 1.0)
    except (TypeError, ValueError):
        return None
    if modeled <= 0.0 or size <= 0.0:
        return None
    return {
        "algorithm": attrs.get("algorithm"),
        "route": attrs.get("route"),
        "regime": attrs.get("regime"),
        "size": int(size),
        "modeled_ms": modeled,
        "measured_ms": measured,
        "residual": measured / (modeled * size),
    }


def residuals(spans: Optional[List[Dict]],
              *, span_name: str = "serve.exec") -> List[Dict]:
    """Modeled-vs-measured cost residuals from exec spans.

    Returns one record per exec span that carried a modeled cost (see
    :func:`residual_record`); ``residual = 1.0`` means perfectly
    calibrated.  Feed the aggregate back to ``repro.tune`` as a
    correction factor.  Empty / ``None`` / plan-span-free input yields
    ``[]``.
    """
    out = []
    for s in spans or ():
        r = residual_record(s, span_name=span_name)
        if r is not None:
            out.append(r)
    return out


def residual_summary(spans: Optional[List[Dict]]) -> Dict[str, Dict]:
    """Per-algorithm residual aggregate: count / mean residual.
    Empty or plan-span-free input yields ``{}`` rather than raising."""
    per: Dict[Optional[str], List[float]] = {}
    for r in residuals(spans):
        per.setdefault(r["algorithm"], []).append(r["residual"])
    return {
        str(alg): {"count": len(v), "mean_residual": sum(v) / len(v)}
        for alg, v in per.items()
    }
