"""Trace exports: Chrome trace-event / Perfetto JSON and residuals.

``chrome_trace`` turns captured span records into the Trace Event
Format both ``chrome://tracing`` and https://ui.perfetto.dev open
directly: complete ("ph": "X") events with microsecond timestamps
normalized to the earliest span, one row per emitting thread.

``residuals`` closes the paper's modeled-vs-measured loop: exec spans
carry the planner's modeled cost (``modeled_ms`` from
``planner.explain``), so a capture yields per-algorithm residual
factors that ``repro.tune`` can fold into the next calibration.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

__all__ = ["chrome_trace", "residual_summary", "residuals",
           "save_chrome_trace"]


def _json_safe(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    return repr(v)


def chrome_trace(spans: List[Dict]) -> Dict:
    """Render span records as a Chrome trace-event JSON object."""
    if spans:
        t_base = min(s.get("t0", 0.0) for s in spans)
    else:
        t_base = 0.0
    events = []
    for s in spans:
        args = dict(s.get("attrs") or {})
        if s.get("trace") is not None:
            args["trace_id"] = s["trace"]
        if s.get("parent") is not None:
            args["parent_span"] = s["parent"]
        if s.get("error"):
            args["error"] = s["error"]
        events.append({
            "name": s.get("name", "?"),
            "cat": str(s.get("name", "?")).split(".", 1)[0],
            "ph": "X",
            "ts": (s.get("t0", 0.0) - t_base) * 1e6,
            "dur": max(s.get("dur", 0.0), 0.0) * 1e6,
            "pid": 1,
            "tid": s.get("tid", 0),
            "args": _json_safe(args),
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def save_chrome_trace(path, spans: List[Dict]) -> Dict:
    """Write a Perfetto-openable trace JSON; returns the object."""
    obj = chrome_trace(spans)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(obj, fh)
    return obj


def residuals(spans: List[Dict],
              *, span_name: str = "serve.exec") -> List[Dict]:
    """Modeled-vs-measured cost residuals from exec spans.

    Returns one record per exec span that carried a modeled cost:
    ``{"algorithm", "modeled_ms", "measured_ms", "residual"}`` where
    ``residual = measured / modeled`` (1.0 = perfectly calibrated).
    Feed the aggregate back to ``repro.tune`` as a correction factor.
    """
    out = []
    for s in spans:
        if s.get("name") != span_name:
            continue
        attrs = s.get("attrs") or {}
        modeled = attrs.get("modeled_ms")
        if not modeled:
            continue
        measured = s.get("dur", 0.0) * 1e3
        out.append({
            "algorithm": attrs.get("algorithm"),
            "route": attrs.get("route"),
            "modeled_ms": float(modeled),
            "measured_ms": measured,
            "residual": measured / float(modeled),
        })
    return out


def residual_summary(spans: List[Dict]) -> Dict[str, Dict]:
    """Per-algorithm residual aggregate: count / mean residual."""
    per: Dict[Optional[str], List[float]] = {}
    for r in residuals(spans):
        per.setdefault(r["algorithm"], []).append(r["residual"])
    return {
        str(alg): {"count": len(v), "mean_residual": sum(v) / len(v)}
        for alg, v in per.items()
    }
