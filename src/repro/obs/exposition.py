"""Prometheus text exposition for the serving stack.

``render_prometheus`` flattens three sources into the text format every
Prometheus-compatible scraper ingests (version 0.0.4):

* ``ServeMetrics.snapshot()`` — counters and latency percentiles as
  ``repro_serve_*`` gauges/counters;
* ``repro.caches.cache_info()`` — the process cache registry as
  ``repro_cache_*{cache="..."}`` families (the ROADMAP serving-fabric
  requirement);
* the active tracer's in-memory span ring — per-phase duration
  histograms (``repro_span_duration_seconds{phase="serve.exec"}``) with
  cumulative buckets, ``_sum`` and ``_count`` (counter-track records
  are skipped: they have no duration);
* the engine's :class:`repro.obs.health.HealthMonitor`, when attached —
  ``repro_slo_*`` burn-rate gauges per objective and window,
  ``repro_drift_*`` residual gauges per (family, kernel, regime), and
  the scalar ``repro_health_status`` (0=ok 1=degraded 2=failing) the
  fabric scrapes per worker.

``parse_prometheus`` is the matching reader used by tests and the CI
``obs-smoke`` job to assert the exposition round-trips.  The round
trip is lossless, including non-finite values (``+Inf`` buckets, NaN
quantiles from empty reservoirs) and escaped label values.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

__all__ = ["parse_prometheus", "render_prometheus", "HISTOGRAM_BUCKETS"]

#: cumulative upper bounds (seconds) for span-duration histograms —
#: microseconds through ~16s, the serving stack's realistic span range
HISTOGRAM_BUCKETS = (1e-6, 1e-5, 1e-4, 1e-3, 4e-3, 1.6e-2, 6.4e-2,
                     0.25, 1.0, 4.0, 16.0)


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isnan(f):
        return "NaN"                  # Prometheus spelling, not repr's "nan"
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


def _escape(v: str) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"') \
                 .replace("\n", "\\n")


def _unescape(v: str) -> str:
    """Inverse of :func:`_escape` (``\\\\``, ``\\"``, ``\\n``); unknown
    escapes pass through verbatim, matching Prometheus readers."""
    out: List[str] = []
    i = 0
    while i < len(v):
        ch = v[i]
        if ch == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            if nxt == "n":
                out.append("\n")
                i += 2
                continue
            if nxt in ('"', "\\"):
                out.append(nxt)
                i += 2
                continue
        out.append(ch)
        i += 1
    return "".join(out)


class _Writer:
    def __init__(self):
        self.lines: List[str] = []
        self._typed = set()

    def sample(self, name: str, value, labels: Optional[Dict] = None,
               *, kind: str = "gauge", help_text: str = "") -> None:
        if name not in self._typed:
            self._typed.add(name)
            if help_text:
                self.lines.append(f"# HELP {name} {help_text}")
            self.lines.append(f"# TYPE {name} {kind}")
        lab = ""
        if labels:
            inner = ",".join(f'{k}="{_escape(v)}"'
                             for k, v in sorted(labels.items()))
            lab = "{" + inner + "}"
        self.lines.append(f"{name}{lab} {_fmt(value)}")

    def render(self) -> str:
        return "\n".join(self.lines) + "\n"


def _serve_section(w: _Writer, engine) -> None:
    snap = engine.metrics.snapshot()
    counters = {"submitted", "completed", "failed", "result_cache_hits",
                "buckets_executed", "batched_requests", "merged_groups",
                "delta_applied", "plans_revalidated", "lanes_patched",
                "rows_invalidated"}
    for key, val in snap.items():
        if not isinstance(val, (int, float)):
            continue
        if key in counters:
            w.sample(f"repro_serve_{key}_total", val, kind="counter",
                     help_text=f"ServeMetrics.{key}")
        else:
            w.sample(f"repro_serve_{key}", val,
                     help_text=f"ServeMetrics snapshot {key}")
    w.sample("repro_serve_queue_depth", engine._pending(),
             help_text="Requests admitted but not yet served")


def _cache_section(w: _Writer) -> None:
    from repro import caches
    for name, info in sorted(caches.cache_info().items()):
        labels = {"cache": name}
        w.sample("repro_cache_size", info.get("size", 0), labels,
                 help_text="Entries currently held")
        w.sample("repro_cache_capacity", info.get("capacity", 0), labels,
                 help_text="Configured LRU capacity")
        w.sample("repro_cache_hits_total", info.get("hits", 0), labels,
                 kind="counter", help_text="Registry cache hits")
        w.sample("repro_cache_misses_total", info.get("misses", 0), labels,
                 kind="counter", help_text="Registry cache misses")


def _span_section(w: _Writer, tracer) -> None:
    spans_fn = getattr(tracer.sink, "spans", None)
    if not callable(spans_fn):
        return
    per_phase: Dict[str, List[float]] = {}
    for rec in spans_fn():
        if "counter" in rec:       # counter tracks have no duration
            continue
        per_phase.setdefault(rec.get("name", "?"), []).append(
            max(rec.get("dur", 0.0), 0.0))
    name = "repro_span_duration_seconds"
    for phase, durs in sorted(per_phase.items()):
        for le in HISTOGRAM_BUCKETS:
            count = sum(1 for d in durs if d <= le)
            w.sample(f"{name}_bucket", count,
                     {"phase": phase, "le": repr(le)}, kind="histogram",
                     help_text="Span durations by phase (ring window)")
        w.sample(f"{name}_bucket", len(durs),
                 {"phase": phase, "le": "+Inf"}, kind="histogram")
        w.sample(f"{name}_sum", sum(durs), {"phase": phase},
                 kind="histogram")
        w.sample(f"{name}_count", len(durs), {"phase": phase},
                 kind="histogram")


_STATUS_CODE = {"ok": 0.0, "degraded": 1.0, "failing": 2.0}


def _slo_section(w: _Writer, engine) -> None:
    mon = getattr(engine, "monitor", None)
    if mon is None:
        return
    for st in mon.slo_status():
        labels = {"slo": st.objective.name}
        w.sample("repro_slo_burn_rate", st.burn_short,
                 {**labels, "window": "short"},
                 help_text="Error-budget burn rate per objective/window")
        w.sample("repro_slo_burn_rate", st.burn_long,
                 {**labels, "window": "long"})
        w.sample("repro_slo_events", st.events_long, labels,
                 help_text="Relevant events in the long window")
        w.sample("repro_slo_healthy", st.status == "ok", labels,
                 help_text="1 while the objective is within budget")
    verdict = mon.verdict(engine)
    w.sample("repro_health_status", _STATUS_CODE[verdict.status],
             help_text="HealthVerdict: 0=ok 1=degraded 2=failing")


def _drift_section(w: _Writer, engine) -> None:
    mon = getattr(engine, "monitor", None)
    if mon is None or mon.drift is None:
        return
    flagged = {(f.family, f.algorithm, f.regime)
               for f in mon.drift.flags()}
    for st in mon.drift.snapshot().values():
        labels = {"family": st["family"], "algorithm": st["algorithm"],
                  "regime": st["regime"]}
        w.sample("repro_drift_ewma_residual", st["ewma_residual"], labels,
                 help_text="Recent-weighted measured/modeled cost ratio "
                           "(1.0 = calibrated)")
        w.sample("repro_drift_mean_residual", st["mean_residual"], labels,
                 help_text="Geometric-mean measured/modeled cost ratio")
        w.sample("repro_drift_observations", st["count"], labels,
                 help_text="Residuals folded for this kernel/regime")
        key = (st["family"], st["algorithm"], st["regime"])
        w.sample("repro_drift_flagged", key in flagged, labels,
                 help_text="1 when this kernel/regime is outside the "
                           "drift band")
    rep = mon.drift.report()
    w.sample("repro_drift_flagged_families", len(rep.families),
             help_text="Probe families needing a repro.tune re-run")


def render_prometheus(engine=None, tracer=None) -> str:
    """Render the full exposition.  ``engine=None`` skips the serve
    section; ``tracer=None`` uses the globally-configured tracer (and
    skips span histograms when tracing is off)."""
    from . import spans as _spans
    w = _Writer()
    if engine is not None:
        _serve_section(w, engine)
    _cache_section(w)
    if tracer is None:
        tracer = _spans.get_tracer()
    if tracer is not None:
        _span_section(w, tracer)
    if engine is not None:
        _slo_section(w, engine)
        _drift_section(w, engine)
    return w.render()


def parse_prometheus(text: str) -> Dict[Tuple[str, Tuple], float]:
    """Strict-enough parser for the exposition this module renders:
    maps ``(metric_name, sorted_label_items)`` to the sample value.
    Raises ``ValueError`` on a malformed sample line — the CI smoke
    job's "does it parse" assertion."""
    out: Dict[Tuple[str, Tuple], float] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        head, _, value = line.rpartition(" ")
        if not head:
            raise ValueError(f"malformed sample line: {raw!r}")
        labels: Tuple = ()
        name = head
        if head.endswith("}"):
            name, _, rest = head.partition("{")
            body = rest[:-1]
            items = []
            for pair in _split_labels(body):
                k, _, v = pair.partition("=")
                if len(v) < 2 or not (v.startswith('"')
                                      and v.endswith('"')):
                    raise ValueError(f"malformed label in: {raw!r}")
                items.append((k, _unescape(v[1:-1])))
            labels = tuple(sorted(items))
        if not name.replace("_", "").replace(":", "").isalnum():
            raise ValueError(f"malformed metric name in: {raw!r}")
        out[(name, labels)] = float(value)
    return out


def _split_labels(body: str) -> List[str]:
    """Split ``k="v",k2="v2"`` on commas outside quotes.

    Tracks escape state explicitly: a ``prev != "\\\\"`` heuristic
    mis-handles values *ending* in a backslash (rendered ``\\\\`` —
    the second backslash is escaped, so the closing quote that follows
    must still close the string)."""
    parts: List[str] = []
    cur: List[str] = []
    in_q = esc = False
    for ch in body:
        if in_q:
            cur.append(ch)
            if esc:
                esc = False
            elif ch == "\\":
                esc = True
            elif ch == '"':
                in_q = False
            continue
        if ch == ",":
            parts.append("".join(cur))
            cur = []
            continue
        if ch == '"':
            in_q = True
        cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts
