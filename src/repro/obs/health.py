"""Streaming health aggregation over the span stream.

:class:`WindowAggregator` is a pluggable span **sink** (the same
``emit(record)`` protocol as :class:`repro.obs.sinks.InMemorySink`):
point a tracer at it — or tee through :class:`HealthMonitor` — and it
folds every span into bounded sliding windows in O(1) memory.

The memory bound comes from **ring-buffered window shards**: the
horizon (default 60 s) is cut into ``shards`` equal slices of
``shard_s`` seconds each; a record landing at time ``t`` goes into ring
slot ``int(t / shard_s) % shards``, and a slot whose stored epoch is
stale is reset in place before reuse.  Nothing is ever scanned or
evicted — expiry is a single epoch comparison on write and on read.
Per-shard state is a handful of dicts keyed by span name plus
**bounded** duration-sample lists (``sample_cap`` per shard) for
percentile estimation, so total memory is
``O(shards * names * sample_cap)`` regardless of traffic.

Time comes from the **injectable clock** (the same
:class:`repro.serving.clock.SystemClock` /
:class:`~repro.serving.clock.VirtualClock` split the engine uses):
records are bucketed by ``clock.now()`` at emit time, so tests drive
window expiry deterministically by advancing a virtual clock — no
sleeps, no wall-clock reads, clock-discipline-lint clean.

:class:`HealthMonitor` bundles an aggregator with an
:class:`repro.obs.slo.SLOEngine` and a
:class:`repro.obs.drift.DriftDetector` and renders
:class:`HealthVerdict` — the ``ok | degraded | failing`` triple (plus
concrete reasons) that ``/health`` serves (503 on ``failing``) and the
future multi-process fabric will scrape per worker.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from . import drift as drift_mod
from . import slo as slo_mod

__all__ = ["HealthMonitor", "HealthVerdict", "WindowAggregator",
           "WindowStats", "basic_verdict"]

#: span names whose durations are sampled for percentile estimation
DEFAULT_SAMPLE_NAMES = ("serve.exec", "serve.queue_wait")

_STATUS_RANK = {"ok": 0, "degraded": 1, "failing": 2}


@dataclasses.dataclass(frozen=True)
class HealthVerdict:
    """``ok | degraded | failing`` plus the reasons that earned it."""

    status: str
    reasons: Tuple[str, ...] = ()

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def as_dict(self) -> Dict:
        return {"status": self.status, "reasons": list(self.reasons)}

    @staticmethod
    def worst(*verdicts: "HealthVerdict") -> "HealthVerdict":
        """Combine verdicts: worst status wins, reasons concatenate —
        how a fabric aggregates per-worker verdicts into one."""
        status = max((v.status for v in verdicts),
                     key=lambda s: _STATUS_RANK[s], default="ok")
        reasons: List[str] = []
        for v in verdicts:
            reasons.extend(r for r in v.reasons if r not in reasons)
        return HealthVerdict(status, tuple(reasons))


class _Shard:
    """One ring slot: aggregates for one ``shard_s``-second slice."""

    __slots__ = ("epoch", "counts", "req_counts", "dur_sums", "samples",
                 "gauges")

    def __init__(self):
        self.epoch = -1
        self.reset(-1)

    def reset(self, epoch: int) -> None:
        self.epoch = epoch
        self.counts: Dict[str, int] = {}
        self.req_counts: Dict[str, int] = {}
        self.dur_sums: Dict[str, float] = {}
        self.samples: Dict[str, List[float]] = {}
        self.gauges: Dict[str, float] = {}


class WindowStats:
    """Read-only aggregate over the shards inside one trailing window."""

    def __init__(self, seconds: float, shards: Sequence[_Shard]):
        self.seconds = seconds
        self._counts: Dict[str, int] = {}
        self._req_counts: Dict[str, int] = {}
        self._dur_sums: Dict[str, float] = {}
        self._samples: Dict[str, List[float]] = {}
        self._gauges: Dict[str, float] = {}
        # oldest -> newest so newest shard wins the gauge value
        for sh in shards:
            for k, v in sh.counts.items():
                self._counts[k] = self._counts.get(k, 0) + v
            for k, v in sh.req_counts.items():
                self._req_counts[k] = self._req_counts.get(k, 0) + v
            for k, v in sh.dur_sums.items():
                self._dur_sums[k] = self._dur_sums.get(k, 0.0) + v
            for k, v in sh.samples.items():
                self._samples.setdefault(k, []).extend(v)
            self._gauges.update(sh.gauges)

    def count(self, name: str) -> int:
        """Number of records named ``name`` in the window."""
        return self._counts.get(name, 0)

    def req_count(self, name: str) -> int:
        """Size-weighted count: a ``serve.exec`` span covering a bucket
        of 8 requests contributes 8 (its ``size`` attr), so rates stay
        per-request under batching."""
        return self._req_counts.get(name, 0)

    def dur_sum(self, name: str) -> float:
        """Total seconds spent inside spans named ``name``."""
        return self._dur_sums.get(name, 0.0)

    def samples(self, name: str) -> List[float]:
        """Bounded duration samples for ``name`` (percentile fodder)."""
        return self._samples.get(name, [])

    def gauge(self, name: str) -> Optional[float]:
        """Most recent counter-track value for ``name``, if any."""
        return self._gauges.get(name)

    def percentile(self, name: str, q: float) -> float:
        """Nearest-rank percentile (``q`` in [0, 1]) of ``name``'s
        duration samples; 0.0 with no samples."""
        xs = sorted(self._samples.get(name, ()))
        if not xs:
            return 0.0
        idx = min(len(xs) - 1, max(0, int(q * len(xs))))
        return xs[idx]

    @property
    def names(self) -> List[str]:
        return sorted(self._counts)


class WindowAggregator:
    """Span sink folding the stream into ring-buffered window shards."""

    def __init__(self, *, clock=None, horizon_s: float = 60.0,
                 shards: int = 12, sample_cap: int = 256,
                 sample_names: Sequence[str] = DEFAULT_SAMPLE_NAMES):
        if horizon_s <= 0 or shards < 2:
            raise ValueError("horizon_s must be > 0 and shards >= 2")
        if clock is None:
            # deferred: repro.serving imports repro.obs at module scope
            from repro.serving.clock import SystemClock
            clock = SystemClock()
        self.clock = clock
        self.horizon_s = float(horizon_s)
        self.shard_s = self.horizon_s / shards
        self.sample_cap = int(sample_cap)
        self.sample_names = frozenset(sample_names)
        self._ring = [_Shard() for _ in range(shards)]
        self._lock = threading.Lock()

    # -- sink protocol ------------------------------------------------------

    def emit(self, rec: Dict) -> None:
        """Fold one span/event/counter record into the current shard."""
        epoch = int(self.clock.now() / self.shard_s)
        name = rec.get("name", "?")
        with self._lock:
            sh = self._ring[epoch % len(self._ring)]
            if sh.epoch != epoch:
                sh.reset(epoch)
            if "counter" in rec:                       # counter track
                sh.gauges[name] = float(rec["counter"])
                return
            sh.counts[name] = sh.counts.get(name, 0) + 1
            attrs = rec.get("attrs") or {}
            size = attrs.get("size")
            if isinstance(size, (int, float)) and size > 0:
                sh.req_counts[name] = sh.req_counts.get(name, 0) + int(size)
            dur = rec.get("dur")
            if isinstance(dur, (int, float)):
                sh.dur_sums[name] = sh.dur_sums.get(name, 0.0) + dur
                if name in self.sample_names:
                    xs = sh.samples.setdefault(name, [])
                    if len(xs) < self.sample_cap:
                        xs.append(dur)

    # -- reads --------------------------------------------------------------

    def window(self, seconds: float) -> WindowStats:
        """Aggregate over the trailing ``seconds`` (clamped to the
        horizon).  Shard granularity means the effective window is
        ``ceil(seconds / shard_s)`` shards including the current
        partial one."""
        seconds = min(float(seconds), self.horizon_s)
        now = self.clock.now()
        cur = int(now / self.shard_s)
        span = min(max(1, math.ceil(seconds / self.shard_s)),
                   len(self._ring))
        lo = cur - span + 1
        with self._lock:
            live = sorted((sh for sh in self._ring
                           if lo <= sh.epoch <= cur),
                          key=lambda sh: sh.epoch)
            return WindowStats(seconds, live)

    def __repr__(self):
        return (f"WindowAggregator(horizon_s={self.horizon_s}, "
                f"shards={len(self._ring)}, shard_s={self.shard_s:.2f})")


def basic_verdict(engine) -> HealthVerdict:
    """Liveness-only verdict for engines without a monitor: a closed
    engine is ``failing``, a live one is ``ok``.  Window-based SLO and
    drift intelligence needs a :class:`HealthMonitor`."""
    if getattr(engine, "_stop", False):
        return HealthVerdict("failing", ("engine stopped",))
    return HealthVerdict("ok")


class HealthMonitor:
    """Aggregator + SLO engine + drift detector behind one sink.

    Use it anywhere a sink goes::

        monitor = HealthMonitor()
        engine = QueryEngine(monitor=monitor, expose_port=0)
        with obs.tracing(monitor):
            ...serve...
        engine.health()          # -> HealthVerdict

    ``inner`` optionally tees every record to a second sink (e.g. an
    :class:`~repro.obs.sinks.InMemorySink` so spans stay exportable);
    ``spans()`` delegates to it, making the monitor a drop-in
    replacement where code expects an in-memory sink.
    """

    def __init__(self, *, slos: Sequence[slo_mod.Objective] = None,
                 clock=None, horizon_s: float = 60.0, shards: int = 12,
                 sample_cap: int = 256,
                 drift: Optional[drift_mod.DriftDetector] = "default",
                 inner=None):
        self.aggregator = WindowAggregator(
            clock=clock, horizon_s=horizon_s, shards=shards,
            sample_cap=sample_cap)
        self.slo = slo_mod.SLOEngine(
            slo_mod.DEFAULT_SLOS if slos is None else slos)
        self.drift: Optional[drift_mod.DriftDetector] = (
            drift_mod.DriftDetector() if drift == "default" else drift)
        self.inner = inner

    # -- sink protocol ------------------------------------------------------

    def emit(self, rec: Dict) -> None:
        self.aggregator.emit(rec)
        if self.drift is not None:
            self.drift.observe_record(rec)
        if self.inner is not None:
            self.inner.emit(rec)

    def spans(self) -> List[Dict]:
        """Records captured by the inner sink ([] without one)."""
        if self.inner is not None and hasattr(self.inner, "spans"):
            return self.inner.spans()
        return []

    # -- verdicts -----------------------------------------------------------

    def slo_status(self) -> List[slo_mod.ObjectiveStatus]:
        return self.slo.evaluate(self.aggregator)

    def verdict(self, engine=None) -> HealthVerdict:
        """Worst-of: engine liveness, every SLO, and cost-model drift
        (drift degrades — a stale model misroutes kernels but still
        serves — it never fails the worker outright)."""
        parts: List[HealthVerdict] = []
        if engine is not None:
            parts.append(basic_verdict(engine))
        for st in self.slo_status():
            if st.status != "ok":
                parts.append(HealthVerdict(st.status, (st.reason,)))
        if self.drift is not None:
            flagged = self.drift.flags()
            if flagged:
                rep = self.drift.report()
                reasons = tuple(f.reason for f in flagged) + (
                    (rep.command,) if rep.command else ())
                parts.append(HealthVerdict("degraded", reasons))
        return HealthVerdict.worst(*parts) if parts else HealthVerdict("ok")
