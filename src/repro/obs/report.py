"""Cross-PR perf-trajectory report over the committed bench grids.

Every PR that touches a benchmark commits its full-tier grid under
``results/bench/*_grid.json``, so the git history of those files IS the
repo's performance trajectory — one **generation** per commit.  This
module loads every grid plus its history (``git log`` + ``git show``)
and renders:

* a console report: per-grid trend tables with unicode sparklines for
  every scalar metric, first->last deltas, and acceptance-flag status;
* a standalone HTML file (``--html out.html``): the same tables with
  inline-SVG sparklines, no external assets;
* machine-readable regression flags: an acceptance flag (the grid's
  ``_``-prefixed booleans, e.g. ``_health_ok``) that was True in the
  previous committed generation and is False now, or a newest
  generation that does not parse as a grid at all.

``python -m repro.obs.report --check`` exits non-zero on any regression
flag or unreadable newest generation — the CI ``health-gate`` contract.
Shallow clones degrade gracefully: with no visible history each grid
has a single generation and nothing to regress against.
"""
from __future__ import annotations

import argparse
import html as _html
import json
import os
import subprocess
from typing import Dict, List, Optional, Tuple

__all__ = ["Generation", "build_report", "flatten_metrics", "grid_flags",
           "main", "regressions", "render_console", "render_html",
           "sparkline"]

_SPARK = "▁▂▃▄▅▆▇█"


class Generation:
    """One committed (or working-tree) state of a grid file."""

    __slots__ = ("label", "payload", "error")

    def __init__(self, label: str, payload: Optional[Dict],
                 error: str = ""):
        self.label = label
        self.payload = payload
        self.error = error

    @property
    def readable(self) -> bool:
        return isinstance(self.payload, dict)

    def __repr__(self):
        state = "ok" if self.readable else f"error: {self.error}"
        return f"Generation({self.label}, {state})"


def _git(args: List[str], cwd: str) -> Tuple[int, str]:
    try:
        proc = subprocess.run(["git", *args], cwd=cwd,
                              capture_output=True, text=True, timeout=30)
        return proc.returncode, proc.stdout
    except (OSError, subprocess.SubprocessError):
        return 1, ""


def _parse_grid(text: str) -> Tuple[Optional[Dict], str]:
    try:
        payload = json.loads(text)
    except ValueError as e:
        return None, f"invalid JSON: {e}"
    if not isinstance(payload, dict):
        return None, f"grid must be a JSON object, got {type(payload).__name__}"
    for k, v in payload.items():
        if k.startswith("_") and k != "_cache_info" \
                and not isinstance(v, (bool, dict)):
            return None, f"acceptance flag {k} must be a bool, got {v!r}"
    return payload, ""


def generations(path: str, *, limit: int = 12) -> List[Generation]:
    """Oldest-first generations of one grid: committed states from git
    history plus the working tree when it differs from HEAD.  Outside a
    git checkout (or in a shallow clone with no visible history) the
    on-disk file is the only generation."""
    path = os.path.abspath(path)
    cwd = os.path.dirname(path) or "."
    out: List[Generation] = []
    rc, top = _git(["rev-parse", "--show-toplevel"], cwd)
    rel = None
    if rc == 0 and top.strip():
        rel = os.path.relpath(path, top.strip()).replace(os.sep, "/")
        rc, log = _git(["log", "--format=%h", "--follow", "--", rel],
                       top.strip())
        shas = [s for s in log.split() if s] if rc == 0 else []
        for sha in reversed(shas[:limit]):            # oldest first
            rc, blob = _git(["show", f"{sha}:{rel}"], top.strip())
            if rc != 0:
                out.append(Generation(sha, None, "git show failed"))
                continue
            payload, err = _parse_grid(blob)
            out.append(Generation(sha, payload, err))
    try:
        with open(path, encoding="utf-8") as fh:
            disk = fh.read()
    except OSError as e:
        if not out:
            out.append(Generation("worktree", None, str(e)))
        return out
    payload, err = _parse_grid(disk)
    if rel is not None and out:
        rc, head = _git(["show", f"HEAD:{rel}"],
                        os.path.dirname(os.path.abspath(path)))
        if rc == 0 and head == disk:
            return out                 # worktree == HEAD: no extra gen
    out.append(Generation("worktree", payload, err))
    return out


def flatten_metrics(payload: Dict, prefix: str = "") -> Dict[str, float]:
    """Flatten a grid to dotted scalar metrics.  Booleans, strings,
    lists and the ``_cache_info`` block are skipped — flags are handled
    by :func:`grid_flags`, and only scalars can trend."""
    out: Dict[str, float] = {}
    for k, v in sorted(payload.items()):
        if k == "_cache_info":
            continue
        key = f"{prefix}{k}"
        if isinstance(v, bool):
            continue
        if isinstance(v, (int, float)):
            out[key] = float(v)
        elif isinstance(v, dict):
            out.update(flatten_metrics(v, prefix=f"{key}."))
    return out


def grid_flags(payload: Dict) -> Dict[str, bool]:
    """The grid's top-level ``_``-prefixed acceptance booleans."""
    return {k: v for k, v in sorted(payload.items())
            if k.startswith("_") and isinstance(v, bool)}


def regressions(name: str, gens: List[Generation]) -> List[str]:
    """Machine flags for one grid: newest generation unreadable, or an
    acceptance flag that went True -> False vs the previous readable
    generation."""
    out: List[str] = []
    if not gens:
        return [f"{name}: no generations found"]
    newest = gens[-1]
    if not newest.readable:
        return [f"{name}@{newest.label}: unreadable grid ({newest.error})"]
    prior = [g for g in gens[:-1] if g.readable]
    if not prior:
        return out
    prev = prior[-1]
    prev_flags = grid_flags(prev.payload)
    for flag, val in grid_flags(newest.payload).items():
        if prev_flags.get(flag) is True and val is False:
            out.append(f"{name}: {flag} regressed True->False "
                       f"({prev.label} -> {newest.label})")
    return out


def sparkline(values: List[float]) -> str:
    """Unicode sparkline; constant series renders mid-height."""
    xs = [v for v in values if v == v]          # drop NaN
    if not xs:
        return ""
    lo, hi = min(xs), max(xs)
    if hi <= lo:
        return _SPARK[3] * len(values)
    out = []
    for v in values:
        if v != v:
            out.append(" ")
            continue
        idx = int((v - lo) / (hi - lo) * (len(_SPARK) - 1))
        out.append(_SPARK[idx])
    return "".join(out)


def _trend_rows(gens: List[Generation]) -> List[Tuple[str, List[float]]]:
    """(metric, per-generation series) with NaN filling gaps."""
    readable = [g for g in gens if g.readable]
    keys: List[str] = []
    per_gen = [flatten_metrics(g.payload) for g in readable]
    for m in per_gen:
        for k in m:
            if k not in keys:
                keys.append(k)
    return [(k, [m.get(k, float("nan")) for m in per_gen])
            for k in sorted(keys)]


def _fmt_val(v: float) -> str:
    if v != v:
        return "-"
    if v == 0:
        return "0"
    av = abs(v)
    if av >= 1e5 or av < 1e-3:
        return f"{v:.3g}"
    if float(v).is_integer() and av < 1e5:
        return str(int(v))
    return f"{v:.4g}"


def _delta(series: List[float]) -> str:
    xs = [v for v in series if v == v]
    if len(xs) < 2 or xs[0] == 0:
        return ""
    pct = (xs[-1] / xs[0] - 1.0) * 100.0
    if abs(pct) < 0.05:
        return "="
    return f"{pct:+.1f}%"


def build_report(bench_dir: str, *, limit: int = 12) -> Dict:
    """Load every ``*_grid.json`` under ``bench_dir`` with history.
    Returns ``{"grids": {name: [Generation...]}, "regressions": [...]}``.
    """
    grids: Dict[str, List[Generation]] = {}
    flagged: List[str] = []
    if not os.path.isdir(bench_dir):
        return {"grids": grids,
                "regressions": [f"bench dir not found: {bench_dir}"]}
    for fname in sorted(os.listdir(bench_dir)):
        if not fname.endswith("_grid.json"):
            continue
        name = fname[:-len("_grid.json")]
        gens = generations(os.path.join(bench_dir, fname), limit=limit)
        grids[name] = gens
        flagged.extend(regressions(name, gens))
    return {"grids": grids, "regressions": flagged}


def render_console(report: Dict, *, max_rows: int = 0) -> str:
    """Plain-text trend tables, one per grid."""
    lines: List[str] = []
    for name, gens in report["grids"].items():
        labels = [g.label for g in gens if g.readable]
        lines.append(f"== {name} ({len(labels)} generation"
                     f"{'s' if len(labels) != 1 else ''}: "
                     f"{' -> '.join(labels) or 'none readable'}) ==")
        for g in gens:
            if not g.readable:
                lines.append(f"  !! {g.label}: {g.error}")
        rows = _trend_rows(gens)
        if max_rows and len(rows) > max_rows:
            lines.append(f"  (showing {max_rows}/{len(rows)} metrics)")
            rows = rows[:max_rows]
        if rows:
            width = max(len(k) for k, _ in rows)
            for key, series in rows:
                last = next((v for v in reversed(series) if v == v),
                            float("nan"))
                lines.append(f"  {key:<{width}}  {sparkline(series):<12} "
                             f"{_fmt_val(last):>10}  {_delta(series)}")
        if gens and gens[-1].readable:
            for flag, val in grid_flags(gens[-1].payload).items():
                lines.append(f"  {flag}: {'PASS' if val else 'FAIL'}")
        lines.append("")
    if report["regressions"]:
        lines.append("REGRESSIONS:")
        lines.extend(f"  - {r}" for r in report["regressions"])
    else:
        lines.append("no regressions vs previous committed generations")
    return "\n".join(lines)


def _svg_spark(series: List[float], w: int = 120, h: int = 24) -> str:
    xs = [(i, v) for i, v in enumerate(series) if v == v]
    if len(xs) < 2:
        return f'<svg width="{w}" height="{h}"></svg>'
    lo = min(v for _, v in xs)
    hi = max(v for _, v in xs)
    rng = (hi - lo) or 1.0
    n = max(i for i, _ in xs) or 1
    pts = " ".join(
        f"{i / n * (w - 4) + 2:.1f},"
        f"{h - 3 - (v - lo) / rng * (h - 6):.1f}" for i, v in xs)
    return (f'<svg width="{w}" height="{h}">'
            f'<polyline fill="none" stroke="#2a6" stroke-width="1.5" '
            f'points="{pts}"/></svg>')


def render_html(report: Dict) -> str:
    """Standalone HTML (inline SVG sparklines, no external assets)."""
    parts = ["<!doctype html><meta charset='utf-8'>"
             "<title>repro bench trajectory</title>"
             "<style>body{font:14px monospace;margin:2em}"
             "table{border-collapse:collapse}"
             "td,th{padding:2px 10px;border-bottom:1px solid #ddd;"
             "text-align:left}.fail{color:#c22;font-weight:bold}"
             ".pass{color:#2a6}</style>",
             "<h1>repro bench trajectory</h1>"]
    regs = report["regressions"]
    if regs:
        parts.append("<h2 class=fail>regressions</h2><ul>")
        parts.extend(f"<li class=fail>{_html.escape(r)}</li>" for r in regs)
        parts.append("</ul>")
    else:
        parts.append("<p class=pass>no regressions vs previous committed "
                     "generations</p>")
    for name, gens in report["grids"].items():
        labels = " &rarr; ".join(_html.escape(g.label) for g in gens
                                 if g.readable)
        parts.append(f"<h2>{_html.escape(name)}</h2>"
                     f"<p>generations: {labels or 'none readable'}</p>")
        if gens and gens[-1].readable:
            flags = grid_flags(gens[-1].payload)
            if flags:
                parts.append("<p>" + " ".join(
                    f"<span class={'pass' if v else 'fail'}>"
                    f"{_html.escape(k)}={'PASS' if v else 'FAIL'}</span>"
                    for k, v in flags.items()) + "</p>")
        rows = _trend_rows(gens)
        if rows:
            parts.append("<table><tr><th>metric</th><th>trend</th>"
                         "<th>last</th><th>&Delta;</th></tr>")
            for key, series in rows:
                last = next((v for v in reversed(series) if v == v),
                            float("nan"))
                parts.append(
                    f"<tr><td>{_html.escape(key)}</td>"
                    f"<td>{_svg_spark(series)}</td>"
                    f"<td>{_fmt_val(last)}</td>"
                    f"<td>{_delta(series)}</td></tr>")
            parts.append("</table>")
    return "".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Cross-PR perf trajectory over committed bench "
                    "grids (console + HTML + regression flags).")
    ap.add_argument("--dir", default=os.path.join("results", "bench"),
                    help="bench grid directory (default: results/bench)")
    ap.add_argument("--html", metavar="PATH", default=None,
                    help="also write a standalone HTML report")
    ap.add_argument("--max-generations", type=int, default=12)
    ap.add_argument("--max-rows", type=int, default=0,
                    help="cap metric rows per grid in console output "
                         "(0 = all)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 on regression flags or an unreadable "
                         "newest grid (CI health-gate mode)")
    args = ap.parse_args(argv)

    report = build_report(args.dir, limit=args.max_generations)
    print(render_console(report, max_rows=args.max_rows))
    if args.html:
        with open(args.html, "w", encoding="utf-8") as fh:
            fh.write(render_html(report))
        print(f"\nhtml report: {args.html}")
    if not report["grids"]:
        print(f"no *_grid.json under {args.dir}")
        return 1 if args.check else 0
    if args.check and report["regressions"]:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
