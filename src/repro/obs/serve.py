"""``/metrics`` + ``/health`` over stdlib ``http.server``.

No third-party server dependency: a daemonized ``ThreadingHTTPServer``
bound to loopback by default, serving

* ``GET /metrics`` — the Prometheus text exposition
  (:func:`repro.obs.exposition.render_prometheus`);
* ``GET /health``  — the engine's :class:`repro.obs.health.HealthVerdict`
  as JSON (plus queue depth, quiesce/stop state, async mode); answers
  **503** with machine-readable ``reasons[]`` while the verdict is
  ``failing``, which is what load balancers and the multi-process
  fabric key ejection on.

Start it with ``QueryEngine(expose_port=0)`` (0 = ephemeral port, read
``engine.obs_server.port``), or standalone against a demo engine via
``python -m repro.obs.serve``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

__all__ = ["ObsServer", "start_server"]


def _health(engine) -> tuple:
    """``(http_status, payload)``: the engine's HealthVerdict plus the
    liveness fields earlier PRs exposed.  503 while ``failing`` — the
    contract load balancers/fabric schedulers eject workers on."""
    if hasattr(engine, "health"):
        verdict = engine.health()
    else:
        from .health import basic_verdict
        verdict = basic_verdict(engine)
    stopped = bool(getattr(engine, "_stop", False))
    payload = {
        "status": verdict.status,
        "reasons": list(verdict.reasons),
        "queue_depth": int(engine._pending()),
        "async_mode": bool(getattr(engine, "async_mode", False)),
        "stopped": stopped,
    }
    snap = engine.metrics.snapshot()
    payload["completed"] = snap["completed"]
    payload["failed"] = snap["failed"]
    return (503 if verdict.status == "failing" else 200), payload


def _make_handler(engine):
    from .exposition import render_prometheus

    class Handler(BaseHTTPRequestHandler):
        server_version = "repro-obs/1"

        def log_message(self, fmt, *args):
            pass  # exposition must not spam the serving process' stderr

        def _send(self, code: int, body: bytes, ctype: str) -> None:
            self.send_response(code)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):  # noqa: N802 (http.server API name)
            path = self.path.split("?", 1)[0]
            if path == "/metrics":
                body = render_prometheus(engine).encode("utf-8")
                self._send(200, body,
                           "text/plain; version=0.0.4; charset=utf-8")
            elif path == "/health":
                code, payload = _health(engine)
                body = (json.dumps(payload) + "\n").encode("utf-8")
                self._send(code, body, "application/json")
            else:
                self._send(404, b"not found\n", "text/plain")

    return Handler


class ObsServer:
    """Exposition endpoint bound to one engine; daemon-threaded."""

    def __init__(self, engine, *, port: int = 0, host: str = "127.0.0.1"):
        self.engine = engine
        self._httpd = ThreadingHTTPServer((host, int(port)),
                                          _make_handler(engine))
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            name=f"repro-obs-http:{self.port}", daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "ObsServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def start_server(engine, *, port: int = 0,
                 host: str = "127.0.0.1") -> ObsServer:
    return ObsServer(engine, port=port, host=host)


def _main(argv=None) -> int:
    """Demo entry: spin up an engine over a synthetic workload, serve a
    few queries with tracing on, and expose /metrics until Ctrl-C."""
    import argparse

    parser = argparse.ArgumentParser(
        prog="python -m repro.obs.serve",
        description="Expose /metrics + /health for a demo QueryEngine.")
    parser.add_argument("--port", type=int, default=9464)
    parser.add_argument("--host", default="127.0.0.1")
    parser.add_argument("--queries", type=int, default=32,
                        help="synthetic queries to serve before exposing")
    parser.add_argument("--n", type=int, default=128,
                        help="operand dimension for the demo workload")
    args = parser.parse_args(argv)

    import numpy as np

    from repro import obs
    from repro.core.formats import er_mask, erdos_renyi
    from repro.serving.engine import QueryEngine

    obs.configure()
    rng = np.random.default_rng(0)
    mats = [erdos_renyi(args.n, 4, seed=s) for s in range(3)]
    B = erdos_renyi(args.n, 4, seed=99)
    M = er_mask(args.n, max(8, args.n // 8), seed=7)
    engine = QueryEngine(expose_port=args.port)
    try:
        tickets = [engine.submit(mats[int(rng.integers(len(mats)))], B, M)
                   for _ in range(args.queries)]
        engine.flush()
        for t in tickets:
            t.result()
        print(f"served {args.queries} queries; "
              f"metrics at {engine.obs_server.url}/metrics "
              f"(Ctrl-C to stop)")
        try:
            threading.Event().wait()
        except KeyboardInterrupt:
            pass
    finally:
        engine.close()
        obs.disable()
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
