"""Span sinks: where :class:`repro.obs.spans.Tracer` records land.

Two shipped sinks cover the two deployment modes the tentpole names:

* :class:`InMemorySink` — a bounded ring for tests and the exposition
  layer's per-phase histograms.  O(1) emit, oldest spans evicted.
* :class:`JsonlSpanSink` — production capture: a thin adapter over
  ``repro.serving.trace.RotatingTraceSink``, inheriting its size-capped
  rotation (``path`` → ``path.1`` → … → ``path.N``) and seeded
  ``sample_rate`` shedding under load.

Both expose ``emit(record)``; the tracer calls nothing else.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from pathlib import Path
from typing import Dict, List, Optional

__all__ = ["InMemorySink", "JsonlSpanSink", "load_spans"]

#: header ``kind`` distinguishing span capture files from the serving
#: request traces RotatingTraceSink was built for
SPAN_TRACE_KIND = "repro-span-trace"


class InMemorySink:
    """Bounded in-memory span ring (the test / exposition default)."""

    def __init__(self, capacity: int = 4096):
        self._ring: deque = deque(maxlen=int(capacity))
        self._lock = threading.Lock()
        self.emitted = 0

    def emit(self, record: Dict) -> None:
        # lock-free on purpose: deque.append is atomic under the GIL and
        # emit is the per-span hot path — serializing producers on a lock
        # is where the traced-vs-untraced overhead budget goes to die.
        # ``emitted`` may undercount under concurrent emits (benign:
        # it is a diagnostic counter, never a correctness input).
        self._ring.append(record)
        self.emitted += 1

    def spans(self) -> List[Dict]:
        with self._lock:
            return list(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)


class JsonlSpanSink:
    """Rotating JSONL span capture for production tracing.

    Delegates the file policy (size-capped segments, rotation, seeded
    sampling) to ``RotatingTraceSink`` so span capture and request
    capture behave identically on disk; only the header ``kind``
    differs, so the two file families can't be confused on load.
    """

    def __init__(self, path, *, max_bytes: int = 1 << 20, rotate: int = 4,
                 sample_rate: float = 1.0, seed: int = 0,
                 name: str = "spans", meta: Optional[Dict] = None):
        # deferred import: obs must stay importable without pulling the
        # whole serving stack in at module load
        from repro.serving.trace import RotatingTraceSink
        self._sink = RotatingTraceSink(
            path, max_bytes=max_bytes, rotate=rotate,
            sample_rate=sample_rate, seed=seed, name=name, meta=meta,
            kind=SPAN_TRACE_KIND)
        self.path = self._sink.path

    def emit(self, record: Dict) -> None:
        self._sink.write(record)

    @property
    def written(self) -> int:
        return self._sink.written

    @property
    def sampled_out(self) -> int:
        return self._sink.sampled_out

    def segments(self) -> List[Path]:
        return self._sink.segments()

    def close(self) -> None:
        self._sink.close()

    def __enter__(self) -> "JsonlSpanSink":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


def load_spans(path, *, rotate: int = 64) -> List[Dict]:
    """Read every span from a rotated :class:`JsonlSpanSink` capture,
    oldest first, skipping the per-segment header lines."""
    base = Path(path)
    # oldest segment first: path.N ... path.1, then the live file —
    # mirrors RotatingTraceSink.segments()
    candidates = [base.with_name(f"{base.name}.{i}")
                  for i in range(int(rotate), 0, -1)] + [base]
    out: List[Dict] = []
    for seg in (p for p in candidates if p.exists()):
        with open(seg, "r", encoding="utf-8") as fh:
            for i, line in enumerate(fh):
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                if i == 0 and rec.get("kind") == SPAN_TRACE_KIND:
                    continue  # segment header
                out.append(rec)
    return out
