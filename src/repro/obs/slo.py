"""Declarative SLOs evaluated as multi-window burn rates.

An :class:`Objective` names a serving quantity (p99 serve latency, error
rate, result-cache hit rate, queue-wait share), a bound on it, and an
error *budget* — the fraction of traffic allowed to violate the bound.
The classic SRE multi-window discipline turns those into verdicts:

* every objective reduces to a **bad-event fraction** over a window
  (requests that failed, exec spans over the latency bound, ...);
* ``burn rate = bad fraction / budget`` — 1.0 means the budget is being
  consumed exactly as fast as it accrues;
* the engine evaluates each objective over a *short* and a *long*
  window (both served by :class:`repro.obs.health.WindowAggregator`'s
  ring shards): ``failing`` requires the burn to exceed
  ``failing_burn`` on BOTH windows (a long-window burn alone is old
  news; a short-window burn alone is a blip), ``degraded`` needs only
  the long window over ``degraded_burn``.

Windows with fewer than ``min_events`` relevant events stay ``ok`` —
an idle engine has consumed no budget, and the drift detector feeds on
sparse windows without tripping anything here.

This module is deliberately standalone: it duck-types the aggregator
(anything with ``window(seconds) -> WindowStats``), so tests can drive
it from synthetic windows without an engine or a tracer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

__all__ = ["DEFAULT_SLOS", "METRICS", "Objective", "ObjectiveStatus",
           "SLOEngine"]

#: objective ``metric`` names understood by :meth:`SLOEngine.evaluate`
METRICS = ("latency_p99", "error_rate", "cache_hit_rate",
           "queue_wait_share")


@dataclasses.dataclass(frozen=True)
class Objective:
    """One declarative SLO.

    ``bound`` is the threshold on the raw metric (seconds for
    ``latency_p99``, a fraction for the rest).  ``budget`` is the
    allowed bad-event fraction; ``None`` derives the conventional
    default per metric: 0.01 for ``latency_p99`` (a p99 bound means 1%
    of requests may exceed it), ``bound`` itself for ``error_rate`` and
    ``queue_wait_share`` (the bound IS the budget for rate-shaped
    metrics), and ``1 - bound`` for ``cache_hit_rate`` (a minimum).
    """

    name: str
    metric: str
    bound: float
    budget: float = None  # type: ignore[assignment]  (resolved below)
    short_s: float = 5.0
    long_s: float = 60.0
    degraded_burn: float = 1.0
    failing_burn: float = 2.0
    min_events: int = 4

    def __post_init__(self):
        if self.metric not in METRICS:
            raise ValueError(f"unknown SLO metric {self.metric!r}; "
                             f"known: {', '.join(METRICS)}")
        if self.budget is None:
            object.__setattr__(self, "budget", self._default_budget())
        if not (0.0 < self.budget <= 1.0):
            raise ValueError(f"{self.name}: budget must be in (0, 1], "
                             f"got {self.budget}")
        if self.short_s > self.long_s:
            raise ValueError(f"{self.name}: short_s ({self.short_s}) must "
                             f"not exceed long_s ({self.long_s})")

    def _default_budget(self) -> float:
        if self.metric == "latency_p99":
            return 0.01
        if self.metric == "cache_hit_rate":
            return max(1e-9, 1.0 - self.bound)
        return max(1e-9, self.bound)       # error_rate / queue_wait_share


#: the shipped defaults: permissive bounds that catch real pathology
#: (a failing bucket storm, multi-second p99s, queues dwarfing work)
#: without tripping on CI-machine speed differences.  Hit-rate SLOs are
#: workload-specific, so none ships by default — add your own
#: ``Objective("cache-hits", "cache_hit_rate", bound=0.5)``.
DEFAULT_SLOS: Tuple[Objective, ...] = (
    Objective("serve-latency-p99", "latency_p99", bound=1.0),
    Objective("serve-errors", "error_rate", bound=0.01),
    Objective("queue-wait-share", "queue_wait_share", bound=0.9),
)


@dataclasses.dataclass(frozen=True)
class ObjectiveStatus:
    """One objective's multi-window evaluation."""

    objective: Objective
    burn_short: float
    burn_long: float
    events_long: int
    status: str                 # ok | degraded | failing
    reason: str                 # human-readable, "" while ok

    def as_dict(self) -> Dict:
        o = self.objective
        return {"slo": o.name, "metric": o.metric, "bound": o.bound,
                "budget": o.budget, "burn_short": self.burn_short,
                "burn_long": self.burn_long, "events": self.events_long,
                "status": self.status, "reason": self.reason}


class SLOEngine:
    """Evaluates a set of objectives against a window aggregator."""

    def __init__(self, objectives: Sequence[Objective] = DEFAULT_SLOS):
        names = [o.name for o in objectives]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate objective names: {names}")
        self.objectives: Tuple[Objective, ...] = tuple(objectives)

    # -- metric extraction --------------------------------------------------

    @staticmethod
    def _bad_fraction(metric: str, bound: float, win) -> Tuple[float, int]:
        """``(bad_event_fraction, n_events)`` for one metric over one
        window.  Zero events yields ``(0.0, 0)`` — no traffic has
        consumed no budget."""
        if metric == "latency_p99":
            samples = win.samples("serve.exec")
            if not samples:
                return 0.0, 0
            over = sum(1 for s in samples if s > bound)
            return over / len(samples), len(samples)
        if metric == "error_rate":
            errors = win.count("serve.error")
            served = win.req_count("serve.exec") + win.count(
                "serve.cache_hit")
            total = errors + served
            return (errors / total if total else 0.0), total
        if metric == "cache_hit_rate":
            submits = win.count("serve.submit")
            hits = win.count("serve.cache_hit")
            return ((1.0 - hits / submits) if submits else 0.0), submits
        if metric == "queue_wait_share":
            wait = win.dur_sum("serve.queue_wait")
            exec_s = win.dur_sum("serve.exec")
            total = wait + exec_s
            return ((wait / total) if total > 0 else 0.0), \
                win.count("serve.exec")
        raise ValueError(f"unknown SLO metric {metric!r}")

    def burn_rate(self, objective: Objective, win) -> Tuple[float, int]:
        """``(burn_rate, n_events)`` of one objective over one window."""
        bad, events = self._bad_fraction(objective.metric, objective.bound,
                                         win)
        return bad / objective.budget, events

    # -- verdicts -----------------------------------------------------------

    def evaluate(self, aggregator) -> List[ObjectiveStatus]:
        """Multi-window evaluation of every objective: ``aggregator``
        is anything with ``window(seconds) -> WindowStats``."""
        out: List[ObjectiveStatus] = []
        for o in self.objectives:
            burn_s, _ = self.burn_rate(o, aggregator.window(o.short_s))
            burn_l, events = self.burn_rate(o, aggregator.window(o.long_s))
            status, reason = "ok", ""
            if events >= o.min_events:
                if burn_l >= o.failing_burn and burn_s >= o.failing_burn:
                    status = "failing"
                    reason = (f"{o.name}: burn {burn_l:.1f}x over "
                              f"{o.long_s:.0f}s AND {burn_s:.1f}x over "
                              f"{o.short_s:.0f}s (budget "
                              f"{o.budget * 100:g}%, {o.metric} bound "
                              f"{o.bound:g})")
                elif burn_l >= o.degraded_burn:
                    status = "degraded"
                    reason = (f"{o.name}: burn {burn_l:.1f}x over "
                              f"{o.long_s:.0f}s (budget "
                              f"{o.budget * 100:g}%, {o.metric} bound "
                              f"{o.bound:g})")
            out.append(ObjectiveStatus(o, burn_s, burn_l, events, status,
                                       reason))
        return out
