"""Structured spans: the tracing core of ``repro.obs``.

One process-global :class:`Tracer` (installed by :func:`configure`,
removed by :func:`disable`) receives every span.  Instrumented call
sites go through the module-level :func:`span` / :func:`event` /
:func:`new_trace` helpers, which cost exactly ONE global read and one
branch when tracing is off — the subsystem's disabled-overhead
contract.  Spans never feed scheduling or ``ServeMetrics`` counters, so
enabling them cannot perturb ``deterministic_snapshot()`` (the replay
determinism contract; pinned by ``benchmarks/bench_obs.py``).

Clock discipline: spans measure *durations*, which is wall-time work by
definition, so every ``time.perf_counter`` read in this module carries a
``# lint: clock-ok(...)`` annotation and the clock-discipline lint rule
covers ``repro/obs`` exactly like ``repro/serving``.  Scheduling-path
quantities (queue wait, submit offsets) are never measured here — the
engine computes them from its injectable clock and hands them to
:func:`event` as ready-made durations.

Span identity is deterministic: trace and span ids come from process
counters, never the wall clock or an RNG, so two traced replays of one
recorded stream produce identically-numbered spans.
"""
from __future__ import annotations

import contextlib
import itertools
import threading
import time
from contextvars import ContextVar
from typing import Dict, Iterator, List, Optional

__all__ = [
    "Tracer", "configure", "counter", "current_spans", "disable",
    "enabled", "event", "get_tracer", "new_trace", "span", "tracing",
]

#: parent span id of the calling context (thread/task local): nested
#: ``span()`` blocks link into a tree the Chrome trace viewer can nest
_parent_var: ContextVar[Optional[int]] = ContextVar("obs_parent",
                                                    default=None)
#: trace id in scope for the calling context (set by request-scoped spans)
_trace_var: ContextVar[Optional[int]] = ContextVar("obs_trace",
                                                   default=None)


class _NullSpan:
    """Reusable no-op span: what every span site receives while tracing
    is disabled.  Stateless, so one shared instance is safe under any
    interleaving."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Span:
    """One live span: context manager measuring its own wall duration.

    ``set(**attrs)`` inside the block attaches attributes that are only
    known mid-flight (elected route, eviction counts).  The record is
    emitted to the tracer's sink on exit.
    """

    __slots__ = ("_tracer", "name", "span_id", "trace", "attrs",
                 "parent", "_t0", "_tok_parent", "_tok_trace")

    def __init__(self, tracer: "Tracer", name: str,
                 trace: Optional[int], attrs: Dict):
        self._tracer = tracer
        self.name = name
        self.span_id = tracer._next_span()
        self.trace = trace
        self.attrs = attrs
        self.parent = None
        self._tok_parent = None
        self._tok_trace = None

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        self.parent = _parent_var.get()
        self._tok_parent = _parent_var.set(self.span_id)
        if self.trace is None:
            self.trace = _trace_var.get()
        else:
            self._tok_trace = _trace_var.set(self.trace)
        self._t0 = time.perf_counter()  # lint: clock-ok(span start stamp)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        dur = time.perf_counter() - self._t0  # lint: clock-ok(span duration)
        if self._tok_parent is not None:
            _parent_var.reset(self._tok_parent)
        if self._tok_trace is not None:
            _trace_var.reset(self._tok_trace)
        rec = {"name": self.name, "span": self.span_id,
               "parent": self.parent, "trace": self.trace,
               "t0": self._t0, "dur": dur,
               "tid": threading.get_ident()}
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        if self.attrs:
            rec["attrs"] = self.attrs
        self._tracer._emit(rec)
        return False


class Tracer:
    """Emits span records to one pluggable sink (``emit(dict)``).

    Ids are drawn from process-wide counters (deterministic across
    replays of one stream); emission is serialized by the sink itself
    (both shipped sinks lock internally).
    """

    def __init__(self, sink):
        self.sink = sink
        self._span_counter = itertools.count(1)
        self._trace_counter = itertools.count(1)

    # itertools.count.__next__ is atomic under the GIL — no lock needed
    def _next_span(self) -> int:
        return next(self._span_counter)

    def new_trace(self) -> int:
        """Fresh per-request trace id (deterministic counter)."""
        return next(self._trace_counter)

    def span(self, name: str, *, trace: Optional[int] = None,
             **attrs) -> Span:
        return Span(self, name, trace, attrs)

    def event(self, name: str, *, dur_s: float = 0.0,
              trace: Optional[int] = None, **attrs) -> None:
        """Emit a complete span whose duration was measured elsewhere —
        the engine's clock-derived quantities (queue wait) and its
        already-annotated measurement sites (plan/exec seconds) arrive
        through here without a second stopwatch."""
        t1 = time.perf_counter()  # lint: clock-ok(event emit stamp)
        rec = {"name": name, "span": self._next_span(),
               "parent": _parent_var.get(),
               "trace": trace if trace is not None else _trace_var.get(),
               "t0": t1 - float(dur_s), "dur": float(dur_s),
               "tid": threading.get_ident()}
        if attrs:
            rec["attrs"] = attrs
        self._emit(rec)

    def counter(self, name: str, value: float,
                *, trace: Optional[int] = None) -> None:
        """Emit a counter-track sample (queue depth, in-flight
        requests, cache hit-rate): a durationless record whose
        ``counter`` key carries the instantaneous value.  Exports as a
        Perfetto counter ("ph": "C") track; the health aggregator folds
        it as a windowed gauge."""
        rec = {"name": name, "counter": float(value),
               "span": self._next_span(),
               "trace": trace if trace is not None else _trace_var.get(),
               "t0": time.perf_counter(),  # lint: clock-ok(counter stamp)
               "tid": threading.get_ident()}
        self._emit(rec)

    def _emit(self, rec: Dict) -> None:
        self.sink.emit(rec)


#: the process-global tracer; None = tracing disabled (the default).
#: Every instrumented site reads this exactly once per call.
_tracer: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    return _tracer


def enabled() -> bool:
    return _tracer is not None


def configure(sink=None, *, capacity: int = 4096) -> Tracer:
    """Install (and return) the process-global tracer.

    ``sink=None`` builds an in-memory ring of ``capacity`` spans — the
    test/inspection default.  Pass a :class:`repro.obs.sinks.JsonlSpanSink`
    for rotating production capture."""
    global _tracer
    if sink is None:
        from .sinks import InMemorySink
        sink = InMemorySink(capacity=capacity)
    _tracer = Tracer(sink)
    return _tracer


def disable() -> Optional[Tracer]:
    """Remove the global tracer; returns the one that was active (its
    sink keeps any captured spans)."""
    global _tracer
    t, _tracer = _tracer, None
    return t


@contextlib.contextmanager
def tracing(sink=None, *, capacity: int = 4096) -> Iterator[Tracer]:
    """Scoped enable: ``with obs.tracing() as tr: ...`` — the test idiom;
    restores the previously-installed tracer (usually None) on exit."""
    global _tracer
    prev = _tracer
    t = configure(sink, capacity=capacity)
    try:
        yield t
    finally:
        _tracer = prev


def span(name: str, *, trace: Optional[int] = None, **attrs):
    """Module-level span site: one global read + one branch when
    tracing is off (returns the shared no-op span)."""
    t = _tracer
    if t is None:
        return _NULL_SPAN
    return t.span(name, trace=trace, **attrs)


def event(name: str, *, dur_s: float = 0.0, trace: Optional[int] = None,
          **attrs) -> None:
    t = _tracer
    if t is None:
        return
    t.event(name, dur_s=dur_s, trace=trace, **attrs)


def counter(name: str, value: float,
            *, trace: Optional[int] = None) -> None:
    """Module-level counter-track site: one global read + one branch
    when tracing is off, like :func:`span`/:func:`event`."""
    t = _tracer
    if t is None:
        return
    t.counter(name, value, trace=trace)


def new_trace() -> Optional[int]:
    """Per-request trace id, or None while tracing is disabled (the
    engine stores it on the Request either way — None costs nothing)."""
    t = _tracer
    if t is None:
        return None
    return t.new_trace()


def current_spans() -> List[Dict]:
    """Captured spans of the active tracer's sink, when it keeps any
    (in-memory ring); empty list otherwise."""
    t = _tracer
    if t is None:
        return []
    spans = getattr(t.sink, "spans", None)
    return spans() if callable(spans) else []
