from .adamw import AdamW, OptState
