"""AdamW with cosine schedule + ZeRO-1 sharding helper (no optax on box).

Parameters are fp32 masters (layers cast to activation dtype at use);
moments are fp32.  ``zero1_specs`` shards the moments (and optionally the
masters) over the data axis — the first axis whose size divides evenly,
skipping the scan-stacked layer axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class OptState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> OptState:
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), zeros(), zeros())

    def schedule(self, step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(self.warmup, 1), 1.0)
        t = jnp.clip((step - self.warmup) /
                     max(self.total_steps - self.warmup, 1), 0.0, 1.0)
        cos = self.min_lr_frac + (1 - self.min_lr_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return self.lr * warm * cos

    def update(self, grads, state: OptState, params):
        step = state.step + 1
        lr = self.schedule(step)
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)) + 1e-12)
        scale = jnp.minimum(1.0, self.grad_clip / gnorm)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m2 / (1 - self.b1 ** step.astype(jnp.float32))
            vhat = v2 / (1 - self.b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + \
                self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
                m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, OptState(step, new_m, new_v), \
            {"lr": lr, "grad_norm": gnorm}


def zero1_specs(params, param_specs, data_axis: str = "data",
                min_size: int = 1):
    """Moment shardings: add the data axis on the first free divisible dim.

    The scan-stacked layer axis (leading, spec entry None by convention) is
    skipped when a later dim can take the sharding — layer counts rarely
    divide the mesh.
    """
    def one(p, spec):
        entries = list(spec) + [None] * (p.ndim - len(spec))
        # prefer dims 1.. (skip stacked/layer dim 0) then fall back to dim 0
        for idx in list(range(1, p.ndim)) + [0]:
            if entries[idx] is None and p.shape[idx] >= min_size:
                entries[idx] = data_axis
                break
        return P(*entries)
    return jax.tree.map(one, params, param_specs)
