"""Batched serving: prefill + token-by-token decode (greedy / temperature).

``serve_step`` is the unit the decode-shape dry-runs lower: one new token
for every sequence in the batch against a seq_len-sized cache.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, cache, pos, encoder_out=None):
        if cfg.family == "audio":
            logits, cache = T.decode_step(params, cfg, token, cache, pos,
                                          encoder_out=encoder_out)
        else:
            logits, cache = T.decode_step(params, cfg, token, cache, pos)
        return logits, cache
    return serve_step


def generate(params, cfg: ModelConfig, prompt_tokens, *, max_new: int = 16,
             temperature: float = 0.0, key=None, encoder_out=None):
    """Greedy/temperature generation.  prompt_tokens: (B, S0) int32.

    Teacher-forces the prompt through decode_step (exercising the cache
    path), then samples ``max_new`` tokens.  Returns (B, S0+max_new).
    """
    b, s0 = prompt_tokens.shape
    cache = T.init_cache(cfg, b, s0 + max_new)
    step = jax.jit(make_serve_step(cfg))
    logits = None
    for t in range(s0):
        logits, cache = step(params, prompt_tokens[:, t], cache,
                             jnp.full((b,), t, jnp.int32),
                             encoder_out=encoder_out)
    out = [prompt_tokens]
    cur = None
    for i in range(max_new):
        if temperature > 0.0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            cur = jnp.argmax(logits, axis=-1)
        cur = cur.astype(jnp.int32)
        out.append(cur[:, None])
        if i < max_new - 1:
            logits, cache = step(params, cur, cache,
                                 jnp.full((b,), s0 + i, jnp.int32),
                                 encoder_out=encoder_out)
    return jnp.concatenate(out, axis=1)
