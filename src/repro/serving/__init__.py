"""Masked SpGEMM query-serving subsystem.

``QueryEngine`` turns one-shot ``masked_spgemm`` calls into a served
stream: structure-bucketed batching (one cached plan + one compiled
program per bucket), sync and async-future submission with bounded-queue
backpressure, a content-keyed bounded result cache, and per-bucket
metrics.  See ``examples/quickstart.py`` §8 and
``benchmarks/bench_serve.py`` for the measured batching regimes.
"""
from .batcher import Batcher, Request, bucket_key, merge_planned
from .burst import BurstProgram, burst_eligible, get_program
from .cache import (ResultCache, content_fingerprint, result_key,
                    value_fingerprint)
from .clock import SystemClock, VirtualClock
from .engine import QueryEngine, Ticket
from .metrics import ServeMetrics
from .trace import (ReplayReport, Trace, TraceError, TraceRecorder,
                    golden_trace_path, replay_trace, synthesize_trace)

__all__ = [
    "Batcher", "BurstProgram", "QueryEngine", "ReplayReport", "Request",
    "ResultCache", "ServeMetrics", "SystemClock", "Ticket", "Trace",
    "TraceError", "TraceRecorder", "VirtualClock", "bucket_key",
    "burst_eligible", "content_fingerprint", "get_program",
    "golden_trace_path", "merge_planned", "replay_trace", "result_key",
    "synthesize_trace", "value_fingerprint",
]
