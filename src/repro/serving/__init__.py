"""Masked SpGEMM query-serving subsystem.

``QueryEngine`` turns one-shot ``masked_spgemm`` calls into a served
stream: structure-bucketed batching (one cached plan + one compiled
program per bucket), sync and async-future submission with bounded-queue
backpressure, a content-keyed bounded result cache, and per-bucket
metrics.  ``QueryEngine.submit_delta`` folds edge-delta batches into the
served operands incrementally: plan revalidation, compiled-program lane
patching, and row-scoped result-cache invalidation instead of a cold
restart (``examples/quickstart.py`` §11, ``benchmarks/bench_incremental``).
See ``examples/quickstart.py`` §8 and ``benchmarks/bench_serve.py`` for
the measured batching regimes.
"""
from .batcher import Batcher, Request, bucket_key, merge_planned
from .burst import (BurstProgram, burst_eligible, get_program,
                    patch_program, peek_program, record_lineage)
from .cache import (ResultCache, content_fingerprint, result_key,
                    row_bitmap, value_fingerprint)
from .clock import SystemClock, VirtualClock
from .engine import DeltaOutcome, QueryEngine, Ticket
from .metrics import ServeMetrics
from .trace import (ReplayReport, RotatingTraceSink, Trace, TraceError,
                    TraceRecorder, golden_trace_path, load_rotated,
                    replay_trace, synthesize_trace)

__all__ = [
    "Batcher", "BurstProgram", "DeltaOutcome", "QueryEngine",
    "ReplayReport", "Request", "ResultCache", "RotatingTraceSink",
    "ServeMetrics", "SystemClock", "Ticket", "Trace", "TraceError",
    "TraceRecorder", "VirtualClock", "bucket_key", "burst_eligible",
    "content_fingerprint", "get_program", "golden_trace_path",
    "load_rotated", "merge_planned", "patch_program", "peek_program",
    "record_lineage", "replay_trace", "result_key", "row_bitmap",
    "synthesize_trace", "value_fingerprint",
]
