"""Structure-bucketed batching for the serving engine.

Requests are grouped by *bucket key*: the structural signatures of A and M,
the content fingerprint of B (the batched driver shares one B across a
batch, so B must be value-identical, while A/M only need equal structure
for one plan to be exact), the semiring, mask polarity, any forced
algorithm, and the mesh.  Every request in a bucket is served by ONE
cached plan and — for the row kernels — one vmapped compiled program.

Two flush policies bound latency: a bucket flushes when it reaches
``max_batch`` requests, and the async engine flushes any bucket whose
oldest member has waited ``max_wait``.

``merge_same_shape`` is the padding-aware second level: near-same-shape
buckets (same matrix dims, same B, same elected row algorithm) are merged
into one batch with pad widths widened to the group maxima — zero padding
is numerically neutral for the row kernels (length-guarded loops), so the
merged program returns bitwise the per-bucket results.  Buckets whose
widths differ by more than ``pad_factor`` stay separate: padding cost
grows with the width ratio and would swamp the dispatch savings.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.planner import Plan, structure_signature

from .cache import content_fingerprint


@dataclasses.dataclass
class Request:
    """One masked-SpGEMM query queued in the engine."""

    A: object
    B: object
    M: object
    semiring: object
    complement: bool
    algorithm: Optional[str]          # None = planner's auto
    mesh: Optional[object]            # jax Mesh => distributed serving
    axis: str
    ticket: object
    post: Optional[Callable]          # applied to the raw result
    cache_key: Optional[tuple]
    #: engine-clock time at submit — ALWAYS supplied by the engine, never
    #: defaulted from wall clock here: a wall-clock fallback silently
    #: breaks trace-replay determinism (PR 6) the day someone relies on it
    submitted_at: float
    key: Optional[tuple] = None       # precomputed bucket key (engine)
    #: repro.obs per-request trace id (None while tracing is disabled) —
    #: carried so bucket-level spans can name their member requests
    trace_id: Optional[int] = None


def mesh_key(mesh, axis: str) -> Optional[tuple]:
    """Stable mesh identity (axis layout + device ids — never ``id()``,
    which could alias a recycled address inside a persistent cache key)."""
    if mesh is None:
        return None
    import numpy as _np
    return (axis, tuple(mesh.shape.items()),
            tuple(str(d) for d in _np.ravel(mesh.devices)))


def bucket_key(req: Request) -> tuple:
    return (structure_signature(req.A), content_fingerprint(req.B),
            structure_signature(req.M), req.semiring.name, req.complement,
            req.algorithm, mesh_key(req.mesh, req.axis))


class Batcher:
    """Bounded queue of buckets; thread-safe; no execution of its own."""

    def __init__(self, *, max_batch: int = 32):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self._lock = threading.Lock()
        self._buckets: "OrderedDict[tuple, List[Request]]" = OrderedDict()
        self._pending = 0

    @property
    def pending(self) -> int:
        with self._lock:
            return self._pending

    def add(self, req: Request) -> Optional[List[Request]]:
        """Queue a request; returns a full bucket when this add filled one
        (the caller executes it), else None."""
        key = req.key if req.key is not None else bucket_key(req)
        with self._lock:
            # bucket keys are transient routing: every bucket drains within
            # one flush, so no entry can outlive a cost-model change; the
            # PLAN for the bucket is looked up token-keyed at execute time
            # lint: plan-key-ok(transient routing, drains within one flush)
            bucket = self._buckets.setdefault(key, [])
            bucket.append(req)
            self._pending += 1
            if len(bucket) >= self.max_batch:
                del self._buckets[key]
                self._pending -= len(bucket)
                return bucket
        return None

    def rekey(self, old_key: tuple, new_key: tuple,
              rewrite=None) -> int:
        """Remap a still-queued bucket onto a new key (the delta path: a
        delta'd structure whose plan survived revalidation keeps its prior
        bucket, so pre-delta stragglers and post-delta arrivals flush as
        ONE batch).  ``rewrite``, when given, is applied to each moved
        request under the lock — the engine uses it to swap the shared
        operand references (B/M) onto the post-delta objects so a moved
        request really is a member of the new bucket.  The CALLER owns the
        safety argument: only requests whose payload (per-query A values)
        stays valid under the new key may be moved.  Returns the number of
        requests moved (0 when nothing was queued or the keys are equal).
        """
        if old_key == new_key:
            return 0
        with self._lock:
            bucket = self._buckets.pop(old_key, None)
            if bucket is None:
                return 0
            for r in bucket:
                r.key = new_key
                if rewrite is not None:
                    rewrite(r)
            self._buckets.setdefault(new_key, []).extend(bucket)
            # lint: plan-key-ok(transient routing, drains within one flush)
            return len(bucket)

    def pop_all(self) -> List[List[Request]]:
        """Drain every bucket, oldest-created first."""
        with self._lock:
            out = list(self._buckets.values())
            self._buckets.clear()
            self._pending = 0
        return out

    def pop_aged(self, max_wait_s: float, now: float) -> List[List[Request]]:
        """Drain buckets whose oldest request has waited >= ``max_wait_s``
        at engine-clock time ``now`` (required: aging against wall clock
        would break replay determinism)."""
        out = []
        with self._lock:
            for key in list(self._buckets):
                bucket = self._buckets[key]
                if now - bucket[0].submitted_at >= max_wait_s:
                    del self._buckets[key]
                    self._pending -= len(bucket)
                    out.append(bucket)
        return out

    def has_aged(self, max_wait_s: float, now: float) -> bool:
        """True when some bucket's oldest request has waited >= ``max_wait_s``
        at engine-clock time ``now`` (what ``pop_aged`` would drain) — the
        engine's quiescence probe."""
        with self._lock:
            return any(now - b[0].submitted_at >= max_wait_s
                       for b in self._buckets.values())

    def next_deadline(self) -> Optional[float]:
        """Clock time of the oldest queued request (None if empty)."""
        with self._lock:
            if not self._buckets:
                return None
            return min(b[0].submitted_at for b in self._buckets.values())


# ---------------------------------------------------------------------------
# Padding-aware merging of planned buckets
# ---------------------------------------------------------------------------


def _mergeable(reqs: Sequence[Request], plan: Plan) -> bool:
    r = reqs[0]
    return (r.mesh is None and r.algorithm is None
            and plan.algorithm != "tile")


def _merge_signature(reqs: Sequence[Request], plan: Plan) -> tuple:
    r = reqs[0]
    # the bucket key's element [1] already holds B's content fingerprint
    # (computed once at submit) — don't re-CRC B's values per flush
    b_fp = r.key[1] if r.key is not None else content_fingerprint(r.B)
    return (b_fp, r.A.shape, r.B.shape, r.M.shape,
            r.semiring.name, r.complement, plan.algorithm)


def merge_planned(groups: Sequence[Tuple[List[Request], Plan]],
                  pad_factor: float = 4.0
                  ) -> List[Tuple[List[Request], Plan, int]]:
    """Merge compatible (requests, plan) groups into wider batches.

    Returns ``(requests, plan, merged_from)`` triples; merged groups carry
    a plan whose pad widths are the element-wise maxima, so one vmapped
    program fits every member.  Only auto-planned, single-device,
    row-kernel groups merge, and only while each width stays within
    ``pad_factor`` of the group minimum (beyond that the padding work the
    widest member forces on the narrowest outweighs batching).
    """
    out: List[Tuple[List[Request], Plan, int]] = []
    by_sig: "OrderedDict[tuple, List[Tuple[List[Request], Plan]]]" = \
        OrderedDict()
    for reqs, plan in groups:
        if _mergeable(reqs, plan):
            by_sig.setdefault(_merge_signature(reqs, plan), []).append(
                (reqs, plan))
        else:
            out.append((list(reqs), plan, 1))

    for members in by_sig.values():
        members = sorted(members, key=lambda g: g[1].widths)
        pool: List[Tuple[List[Request], Plan]] = []
        for g in members:
            if not pool:
                pool.append(g)
                continue
            lo = [min(p.widths[i] for _, p in pool + [g]) for i in range(3)]
            hi = [max(p.widths[i] for _, p in pool + [g]) for i in range(3)]
            if all(h <= pad_factor * max(1, l) for l, h in zip(lo, hi)):
                pool.append(g)
            else:
                out.append(_fuse(pool))
                pool = [g]
        if pool:
            out.append(_fuse(pool))
    return out


def _fuse(pool: List[Tuple[List[Request], Plan]]
          ) -> Tuple[List[Request], Plan, int]:
    if len(pool) == 1:
        reqs, plan = pool[0]
        return list(reqs), plan, 1
    reqs = [r for g, _ in pool for r in g]
    widths = tuple(max(p.widths[i] for _, p in pool) for i in range(3))
    plan = dataclasses.replace(pool[0][1], widths=widths)
    return reqs, plan, len(pool)
