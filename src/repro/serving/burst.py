"""Structure-compiled burst programs: the serving layer's fast path.

A same-structure bucket (shared B values, shared A/M sparsity, values of A
varying per query — the burst case) re-derives NOTHING per query: the
Gustavson product structure restricted to the mask is compiled ONCE into a
flat gather program, and each query is then

    prods = sr.mul(a_values[prod_a_idx], b_values_gathered)   # |F| muls
    acc[slot] = sr.add(...)  in ascending-k order             # L adds

executed vmapped over the whole bucket in one dispatch.  |F| is the
mask-bounded flop count — the row kernels' padded state machines
(O(width * n_state) work per row) collapse to exactly the arithmetic the
paper's cost model counts.

Bitwise contract: MSA, Hash and MCA all accumulate each output slot by the
identical sequence — start from ``sr.zero``, then ``sr.add`` the products
in ascending-k order (``accumulators.py``: every ``insert_row`` walks A's
sorted row entries; a slot's state is only ever folded left-to-right).
The replay performs that same sequence (products sorted by (slot, k),
padded lanes add ``sr.zero``, which is the fold identity for every
registered semiring on its value domain), so its results are bitwise the
row kernels' — verified by ``tests/test_serving.py``.  Heap
(associative-scan tree order) and Inner (``lax.reduce``) fold in different
orders and stay on the batched row driver.

``present`` is pure structure (a slot is present iff >= 1 structural
product hits it) and is computed once per program, shared by every query.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro import caches
from repro.core.formats import CSR, _expand_rows, padded_from_csr
from repro.core.masked_spgemm import MaskedSpGEMMResult
from repro.core.planner import structure_signature
from repro.core.semiring import Semiring

#: plan algorithms whose accumulation order the replay reproduces exactly
SEQ_SCATTER_ALGOS = ("msa", "hash", "mca")

#: caps beyond which the replay falls back to the row kernels: L bounds the
#: unrolled per-slot add chain (very dense product columns), F the gather
#: footprint
MAX_PRODUCTS_PER_SLOT = 128
MAX_TOTAL_PRODUCTS = 1 << 22

#: compiled burst programs, keyed by (A structure, B content, M structure,
#: semiring, pad width); $REPRO_BURST_PROG_CAP overrides the capacity
_programs = caches.LRUCache("serve-burst-programs", 64,
                            env_var="REPRO_BURST_PROG_CAP")


def _row_sort_perm(x: CSR) -> np.ndarray:
    """Permutation mapping ``x.sorted_rows()`` entry order back to ``x.data``
    (the kernels run on ``padded_from_csr``, which sorts rows first)."""
    rows = _expand_rows(x.indptr)
    return np.lexsort((x.indices, rows))


class BurstProgram:
    """One compiled structure: executes any batch of value-vectors for A."""

    def __init__(self, A: CSR, B: CSR, M: CSR, semiring: Semiring,
                 wm: int = None):
        m, k = A.shape
        _, n = B.shape
        self.shape = (m, n)
        self.nnz_a = A.nnz
        self.semiring = semiring

        a_perm = _row_sort_perm(A)          # kernels see sorted rows
        a_rows = _expand_rows(A.indptr)[a_perm]
        a_cols = A.indices[a_perm]

        M_s = M.sorted_rows()
        M_p = padded_from_csr(M, wm)
        self.pm = pm = M_p.width
        self.mask_cols = M_p.cols

        # Gustavson expansion restricted to the mask: one product per
        # (A entry e at (r, k)) x (B entry f at (k, c)) with (r, c) in M
        B_s = B.sorted_rows()
        b_cnt = np.diff(B_s.indptr)[a_cols]
        ge_a = np.repeat(np.arange(len(a_cols)), b_cnt)   # index into perm'd A
        ge_b = (np.repeat(B_s.indptr[a_cols], b_cnt)
                + (np.arange(b_cnt.sum()) - np.repeat(
                    np.cumsum(b_cnt) - b_cnt, b_cnt)))    # index into B_s
        pr = a_rows[ge_a]                                 # product row
        pk = a_cols[ge_a]                                 # contraction index
        pc = B_s.indices[ge_b]                            # product col
        # mask membership -> slot (position within the sorted mask row),
        # via one searchsorted over the globally sorted (row, col) keys
        mkey = (_expand_rows(M_s.indptr).astype(np.int64) * (n + 1)
                + M_s.indices)
        q = pr.astype(np.int64) * (n + 1) + pc
        pos = np.searchsorted(mkey, q)
        posc = np.minimum(pos, max(len(mkey) - 1, 0))
        hit = (len(mkey) > 0) & (mkey[posc] == q)
        keep = np.nonzero(hit)[0]
        if len(keep) > MAX_TOTAL_PRODUCTS:
            raise _TooLarge()
        slot = (pr[keep] * pm
                + (posc[keep] - M_s.indptr[pr[keep]])).astype(np.int64)
        kk = pk[keep]
        order = np.lexsort((kk, slot))                    # ascending k / slot
        slot = slot[order]
        self._a_gather = np.asarray(a_perm[ge_a[keep][order]], np.int32)
        b_vals = B_s.data[ge_b[keep][order]].astype(np.float32)

        # per-slot padded product lists: P[s, l] -> product lane (sentinel F
        # selects the sr.zero pad, the fold identity)
        F = len(slot)
        counts = np.zeros(m * pm + 1, np.int64)
        np.add.at(counts, slot + 1, 1)
        starts = np.cumsum(counts)[:-1]
        L = int(counts.max(initial=0))
        if L > MAX_PRODUCTS_PER_SLOT:
            raise _TooLarge()
        self.max_chain = L
        self.n_products = F
        P = np.full((m * pm, max(L, 1)), F, np.int64)
        lane = np.arange(F) - starts[slot]
        P[slot, lane] = np.arange(F)
        present = (counts[1:].reshape(m, pm) > 0)
        present &= np.asarray(M_p.cols) < n               # pad slots absent
        self.present = jnp.asarray(present)

        zero = semiring.zero
        # per-lane gathers, laid out (L, S): IA[l] indexes the query's value
        # vector (sentinel -> the appended 0.0), BV[l] holds B's values (pad
        # lanes carry sr.zero, the fold identity for every registered
        # semiring on its value domain).  The fold MUST be a
        # ``lax.fori_loop`` with the accumulator as loop carry: the
        # loop-carried dependency pins the evaluation order (XLA reassocia-
        # tes an unrolled chain), and each trip's ``add(acc, mul(a, b))``
        # is the same expression the row kernels' insert_row folds, so XLA
        # contracts both the same way (a sequential FMA chain on CPU) —
        # that is what makes the replay bitwise-equal to msa/hash/mca, and
        # the property tests pin it per backend.
        IA = np.concatenate([self._a_gather,
                             np.full((1,), A.nnz, np.int32)])[
            np.minimum(P, F)].astype(np.int32).T.copy()
        BV = np.concatenate([b_vals, np.full((1,), zero, np.float32)])[
            np.minimum(P, F)].T.copy()
        IAj = jnp.asarray(IA)
        BVj = jnp.asarray(BV)
        pres = self.present
        mul, add = semiring.mul, semiring.add
        n_lanes = IA.shape[0]

        def one(av):                                      # av: (nnz_a,)
            av = jnp.concatenate([av, jnp.zeros((1,), av.dtype)])

            def lane(l, acc):
                return add(acc, mul(av[IAj[l]], BVj[l]))

            acc = jax.lax.fori_loop(
                0, n_lanes, lane, jnp.full((m * pm,), zero, jnp.float32))
            acc = acc.reshape(m, pm)
            return jnp.where(pres, acc, jnp.asarray(zero, acc.dtype))

        self._fn = jax.jit(jax.vmap(one))

    def run(self, As) -> list:
        """Serve a batch of same-structure A's: one device dispatch."""
        stack = jnp.asarray(np.stack([a.data.astype(np.float32)
                                      for a in As]))
        vals = self._fn(stack)
        vals.block_until_ready()
        return [MaskedSpGEMMResult(vals[i], self.present, self.mask_cols,
                                   self.shape)
                for i in range(len(As))]


class _TooLarge(Exception):
    """Structure exceeds the replay caps; callers fall back silently."""


def burst_eligible(plan_algorithm: str, complement: bool, A, B, M) -> bool:
    return (plan_algorithm in SEQ_SCATTER_ALGOS and not complement
            and isinstance(A, CSR) and isinstance(B, CSR)
            and isinstance(M, CSR))


def get_program(A: CSR, B: CSR, M: CSR, semiring: Semiring,
                wm: int = None):
    """Cached compile of the bucket's structure (None when over the caps)."""
    from .cache import content_fingerprint
    key = (structure_signature(A), content_fingerprint(B),
           structure_signature(M), semiring.name, wm)
    # a BurstProgram replays the gather/scatter pattern of the structure
    # EXACTLY — it encodes no planner election, so it stays valid across
    # calibration-profile changes; deliberately token-free so a retune
    # does not flush compiled programs
    hit = _programs.get(key)  # lint: plan-key-ok(structure-pure program)
    if hit is not None:
        return hit if hit is not _OVER_CAP else None
    try:
        prog = BurstProgram(A, B, M, semiring, wm)
    except _TooLarge:
        _programs.put(key, _OVER_CAP)  # lint: plan-key-ok(structure-pure)
        return None
    _programs.put(key, prog)  # lint: plan-key-ok(structure-pure program)
    return prog


#: cache sentinel: structure known to exceed the replay caps
_OVER_CAP = object()
