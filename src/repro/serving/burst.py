"""Structure-compiled burst programs: the serving layer's fast path.

A same-structure bucket (shared B values, shared A/M sparsity, values of A
varying per query — the burst case) re-derives NOTHING per query: the
Gustavson product structure restricted to the mask is compiled ONCE into a
flat gather program, and each query is then

    prods = sr.mul(a_values[prod_a_idx], b_values_gathered)   # |F| muls
    acc[slot] = sr.add(...)  in ascending-k order             # L adds

executed vmapped over the whole bucket in one dispatch.  |F| is the
mask-bounded flop count — the row kernels' padded state machines
(O(width * n_state) work per row) collapse to exactly the arithmetic the
paper's cost model counts.

Bitwise contract: MSA, Hash and MCA all accumulate each output slot by the
identical sequence — start from ``sr.zero``, then ``sr.add`` the products
in ascending-k order (``accumulators.py``: every ``insert_row`` walks A's
sorted row entries; a slot's state is only ever folded left-to-right).
The replay performs that same sequence (products sorted by (slot, k),
padded lanes add ``sr.zero``, which is the fold identity for every
registered semiring on its value domain), so its results are bitwise the
row kernels' — verified by ``tests/test_serving.py``.  Heap
(associative-scan tree order) and Inner (``lax.reduce``) fold in different
orders and stay on the batched row driver.

``present`` is pure structure (a slot is present iff >= 1 structural
product hits it) and is computed once per program, shared by every query.

Delta lifecycle: the lane tables (IA/BV/present) are jit ARGUMENTS, not
closure constants, and the jitted fold is memoized per (m, pm, n_lanes,
semiring) shape class — so a program whose lanes were PATCHED after an
edge delta (``BurstProgram.patched``) reuses the existing compiled
executable instead of re-tracing.  A row-local delta (A and/or M rows
changed, B content equal) re-emits only the changed rows' lane columns;
because products stay globally ordered by (slot, ascending k) and the
fori_loop carry is unchanged, a patched program's results are bitwise the
cold rebuild's.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import caches
from repro import obs
from repro.core.formats import CSR, _expand_rows, padded_from_csr
from repro.core.masked_spgemm import MaskedSpGEMMResult
from repro.core.planner import structure_signature
from repro.core.semiring import Semiring

#: plan algorithms whose accumulation order the replay reproduces exactly
SEQ_SCATTER_ALGOS = ("msa", "hash", "mca")

#: caps beyond which the replay falls back to the row kernels: L bounds the
#: unrolled per-slot add chain (very dense product columns), F the gather
#: footprint
MAX_PRODUCTS_PER_SLOT = 128
MAX_TOTAL_PRODUCTS = 1 << 22

#: compiled burst programs, keyed by (A structure, B content, M structure,
#: semiring, pad width); $REPRO_BURST_PROG_CAP overrides the capacity
_programs = caches.LRUCache("serve-burst-programs", 64,
                            env_var="REPRO_BURST_PROG_CAP")

#: lane-PATCHED programs (delta path), same key shape as ``_programs`` but
#: separately capped so a churning delta stream cannot evict the cold-built
#: programs of stable structures; $REPRO_LANE_PATCH_CAP overrides
_patches = caches.LRUCache("serve-lane-patches", 32,
                           env_var="REPRO_LANE_PATCH_CAP")

#: jitted lane folds memoized per (m, pm, n_lanes, semiring) shape class —
#: shared between a program and its patched descendants, which is what
#: makes a patch compile-free; $REPRO_BURST_FN_CAP overrides
_fns = caches.LRUCache("serve-burst-fns", 32, env_var="REPRO_BURST_FN_CAP")

#: delta lineage: post-delta program key -> (parent program, changed rows),
#: recorded by the engine's ``submit_delta``; lets ``get_program`` re-derive
#: an evicted patched program from its parent instead of compiling cold;
#: $REPRO_DELTA_LINEAGE_CAP overrides the capacity
_lineage = caches.LRUCache("serve-delta-lineage", 16,
                           env_var="REPRO_DELTA_LINEAGE_CAP")


def _padded_nnz(nnz: int) -> int:
    """Quantized value-vector length (power-of-two bucket >= nnz + 1).

    ``BurstProgram.run`` zero-pads every query's value stack to this
    length, which keeps the jitted fold's input shape stable while an
    incremental delta stream drifts A's nnz — only crossing a bucket
    boundary re-traces.  The +1 reserves the pad-lane sentinel slot
    (``IA`` points pad lanes at index ``nnz``, which must read 0.0)."""
    return max(256, 1 << (nnz + 1 - 1).bit_length())


def _lane_fn(m: int, pm: int, n_lanes: int, semiring: Semiring):
    """The compiled fold, parameterized by lane tables: patched programs
    pass different IA/BV/present ARRAYS through the same jitted callable,
    so equal shapes never re-trace."""
    key = (m, pm, n_lanes, semiring.name)
    fn = _fns.get(key)  # lint: plan-key-ok(shape-pure jit memo)
    if fn is not None:
        return fn
    zero = semiring.zero
    mul, add = semiring.mul, semiring.add

    def one(av, ia, bv, pres):       # av: zero-padded beyond the real nnz
        def lane(l, acc):
            return add(acc, mul(av[ia[l]], bv[l]))

        acc = jax.lax.fori_loop(
            0, n_lanes, lane, jnp.full((m * pm,), zero, jnp.float32))
        acc = acc.reshape(m, pm)
        return jnp.where(pres, acc, jnp.asarray(zero, acc.dtype))

    fn = jax.jit(jax.vmap(one, in_axes=(0, None, None, None)))
    _fns.put(key, fn)  # lint: plan-key-ok(shape-pure jit memo)
    return fn


def _row_sort_perm(x: CSR) -> np.ndarray:
    """Permutation mapping ``x.sorted_rows()`` entry order back to ``x.data``
    (the kernels run on ``padded_from_csr``, which sorts rows first)."""
    rows = _expand_rows(x.indptr)
    return np.lexsort((x.indices, rows))


def _expand_products(a_rows: np.ndarray, a_cols: np.ndarray,
                     a_pos: np.ndarray, B_s: CSR, M_s: CSR,
                     pm: int, n: int
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Gustavson expansion of the given A entries restricted to the mask.

    Returns ``(slot, a_gather, b_gather)`` sorted by (slot, ascending k):
    one product per (A entry at (r, k)) x (B entry at (k, c)) with (r, c)
    in M.  ``a_gather`` indexes A's data order (via ``a_pos``), ``b_gather``
    indexes ``B_s.data``.  The (slot, k) sort is THE bitwise contract: it
    is order-stable under row-local restriction, which is what lets a
    patch splice per-row lane columns without perturbing any other slot's
    fold sequence.
    """
    b_cnt = np.diff(B_s.indptr)[a_cols]
    ge_a = np.repeat(np.arange(len(a_cols)), b_cnt)       # index into entries
    ge_b = (np.repeat(B_s.indptr[a_cols], b_cnt)
            + (np.arange(b_cnt.sum()) - np.repeat(
                np.cumsum(b_cnt) - b_cnt, b_cnt)))        # index into B_s
    pr = a_rows[ge_a]                                     # product row
    pk = a_cols[ge_a]                                     # contraction index
    pc = B_s.indices[ge_b]                                # product col
    # mask membership -> slot (position within the sorted mask row),
    # via one searchsorted over the globally sorted (row, col) keys
    mkey = (_expand_rows(M_s.indptr).astype(np.int64) * (n + 1)
            + M_s.indices)
    q = pr.astype(np.int64) * (n + 1) + pc
    pos = np.searchsorted(mkey, q)
    posc = np.minimum(pos, max(len(mkey) - 1, 0))
    hit = (mkey[posc] == q) if len(mkey) else np.zeros(len(q), bool)
    keep = np.nonzero(hit)[0]
    slot = (pr[keep] * pm
            + (posc[keep] - M_s.indptr[pr[keep]])).astype(np.int64)
    kk = pk[keep]
    order = np.lexsort((kk, slot))                        # ascending k / slot
    return slot[order], a_pos[ge_a[keep][order]], ge_b[keep][order]


def _lane_tables(slot: np.ndarray, a_gather: np.ndarray,
                 b_gather: np.ndarray, b_data: np.ndarray, nslots: int,
                 n_lanes: Optional[int], nnz_a: int, zero: float
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(IA, BV, BG, counts) lane tables, laid out (n_lanes, nslots).

    IA[l] indexes the query's value vector (sentinel -> the appended 0.0),
    BV[l] holds B's values (pad lanes carry ``zero``, the fold identity),
    BG[l] the position in sorted-B data each BV came from (-1 for pads —
    the B-values patch regathers through it).  ``n_lanes=None`` sizes the
    tables to the longest chain; a patch passes the parent's lane count so
    the spliced columns keep the compiled fold's static shape.
    """
    F = len(slot)
    counts = np.zeros(nslots + 1, np.int64)
    np.add.at(counts, slot + 1, 1)
    starts = np.cumsum(counts)[:-1]
    L = int(counts[1:].max(initial=0))
    if n_lanes is None:
        n_lanes = max(L, 1)
    elif L > n_lanes:
        raise _TooLarge()
    P = np.full((nslots, n_lanes), F, np.int64)
    lane = np.arange(F) - starts[slot]
    P[slot, lane] = np.arange(F)
    sel = np.minimum(P, F)
    IA = np.concatenate([a_gather.astype(np.int32),
                         np.full((1,), nnz_a, np.int32)])[sel].T.copy()
    BV = np.concatenate([b_data[b_gather].astype(np.float32),
                         np.full((1,), zero, np.float32)])[sel].T.copy()
    BG = np.concatenate([b_gather.astype(np.int64),
                         np.full((1,), -1, np.int64)])[sel].T.copy()
    return IA, BV, BG, counts[1:]


class BurstProgram:
    """One compiled structure: executes any batch of value-vectors for A."""

    def __init__(self, A: CSR, B: CSR, M: CSR, semiring: Semiring,
                 wm: int = None):
        from .cache import content_fingerprint  # deferred: no import cycle
        m, k = A.shape
        _, n = B.shape
        self.shape = (m, n)
        self.k = k
        self.nnz_a = A.nnz
        self.semiring = semiring
        self.wm = wm
        # delta-patch identity of the operands the lanes were built from
        self._a_indptr = A.indptr.copy()
        self._m_indptr = M.indptr.copy()
        self._b_sig = structure_signature(B)
        self._b_fp = content_fingerprint(B)

        a_perm = _row_sort_perm(A)          # kernels see sorted rows
        self._a_inv = np.empty(A.nnz, np.int64)
        self._a_inv[a_perm] = np.arange(A.nnz)
        a_rows = _expand_rows(A.indptr)[a_perm]
        a_cols = A.indices[a_perm]

        M_s = M.sorted_rows()
        M_p = padded_from_csr(M, wm)
        self.pm = pm = M_p.width
        self._mask_cols_host = np.asarray(M_p.cols)
        self.mask_cols = M_p.cols

        # B's structure is pinned for the program's lifetime (patches check
        # the signature): remember the row-sort permutation so a patch can
        # take B's sorted view as an O(nnz) gather instead of a lexsort
        self._b_perm = _row_sort_perm(B)
        self._b_sorted_idx = B.indices[self._b_perm]
        B_s = CSR(B.indptr, self._b_sorted_idx,
                  B.data[self._b_perm], B.shape)
        slot, a_gather, b_gather = _expand_products(
            a_rows, a_cols, a_perm, B_s, M_s, pm, n)
        if len(slot) > MAX_TOTAL_PRODUCTS:
            raise _TooLarge()
        counts_probe = np.bincount(slot, minlength=1)
        if int(counts_probe.max(initial=0)) > MAX_PRODUCTS_PER_SLOT:
            raise _TooLarge()
        self.n_products = len(slot)

        IA, BV, BG, counts = _lane_tables(
            slot, a_gather, b_gather, B_s.data, m * pm, None, A.nnz,
            semiring.zero)
        self.max_chain = IA.shape[0] if self.n_products else 0
        present = (counts.reshape(m, pm) > 0)
        present &= self._mask_cols_host < n               # pad slots absent
        self._finish(IA, BV, BG, present)

    def _finish(self, IA, BV, BG, present_host) -> None:
        """Install lane tables (host + device) and bind the shared fold."""
        m, _ = self.shape
        self._IA, self._BV, self._BG = IA, BV, BG
        self._present_host = present_host
        self.present = jnp.asarray(present_host)
        self._IAj = jnp.asarray(IA)
        self._BVj = jnp.asarray(BV)
        self._fn = _lane_fn(m, self.pm, IA.shape[0], self.semiring)

    def run(self, As) -> list:
        """Serve a batch of same-structure A's: one device dispatch.

        The value stack is zero-padded to a power-of-two bucket so the
        jitted fold's input shape survives small nnz drifts: a structural
        delta that grows A by a few entries re-uses the compiled
        executable instead of re-tracing.  IA never indexes past
        ``nnz_a`` (the sentinel points AT it), and the sentinel keeps
        landing on a zero, so padding cannot change any fold value.
        """
        with obs.span("burst.run", size=len(As)):
            q = _padded_nnz(self.nnz_a)
            stack = np.zeros((len(As), q), np.float32)
            for i, a in enumerate(As):
                stack[i, :self.nnz_a] = a.data
            vals = self._fn(jnp.asarray(stack), self._IAj, self._BVj,
                            self.present)
            vals.block_until_ready()
        return [MaskedSpGEMMResult(vals[i], self.present, self.mask_cols,
                                   self.shape)
                for i in range(len(As))]

    # -- delta lifecycle ---------------------------------------------------

    def patched(self, A: CSR, B: CSR, M: CSR,
                changed_rows: np.ndarray
                ) -> Optional[Tuple["BurstProgram", int]]:
        """Row-local lane patch: ``(program, lane columns re-emitted)``.

        Valid when A's and M's changes are confined to ``changed_rows`` and
        B's STRUCTURE is this program's (B values may differ — they regather
        through the stored ``BG`` lanes).  Only the changed rows' slot
        columns are re-expanded; every other column of IA/BV (and the
        per-slot ascending-k fold sequences they encode) is byte-identical
        to this program's, which keeps a patched run bitwise-equal to a
        cold rebuild.  The work here is O(changed rows) plus table
        memcpys: B's sorted view is a stored-permutation gather, the mask
        is only re-sorted/re-padded over the changed rows, and the
        untouched rows' padded columns splice from the parent.  Returns
        ``None`` when the delta needs a different static shape (mask pad
        width or lane count grew, B structure changed) — the caller falls
        back to ``get_program``.
        """
        from .cache import content_fingerprint  # deferred: no import cycle
        m, n = self.shape
        if A.shape != (m, self.k) or B.shape != (self.k, n) \
                or M.shape != (m, n):
            return None
        if structure_signature(B) != self._b_sig:
            return None
        m_nnz = np.diff(M.indptr)
        w_max = int(m_nnz.max(initial=0))
        w = self.wm if self.wm is not None else max(1, w_max)
        if w != self.pm or w_max > self.pm:
            return None
        changed_rows = np.unique(np.asarray(changed_rows, np.int64))
        # unchanged rows must really be unchanged in A and M (the IA remap
        # and the mask-column splice below rely on their entry counts)
        unchanged = np.ones(m, bool)
        unchanged[changed_rows] = False
        if not np.array_equal(np.diff(self._a_indptr)[unchanged],
                              np.diff(A.indptr)[unchanged]):
            return None
        if not np.array_equal(np.diff(self._m_indptr)[unchanged],
                              m_nnz[unchanged]):
            return None

        zero = self.semiring.zero
        B_s = CSR(B.indptr, self._b_sorted_idx,
                  B.data[self._b_perm], B.shape)
        b_fp = content_fingerprint(B)
        if b_fp != self._b_fp:
            # B values drifted (same structure): regather every BV lane
            # through BG; pads (-1) keep the fold identity
            BV = np.where(self._BG >= 0,
                          np.concatenate([B_s.data.astype(np.float32),
                                          [np.float32(zero)]])[self._BG],
                          np.float32(zero))
        else:
            BV = self._BV.copy()

        # IA remap: unchanged rows' A-entry positions shift by the changed
        # rows' nnz drift.  Old IA entries are SORTED-ORDER positions of the
        # old A mapped back through a_perm; rank-within-row is preserved, so
        # new position = old sorted rank + (new indptr - old indptr)[row]
        old_nnz = self.nnz_a
        rows_old = _expand_rows(self._a_indptr)
        shift = (A.indptr[:-1] - self._a_indptr[:-1])
        posmap = np.empty(old_nnz + 1, np.int64)
        posmap[:old_nnz] = self._a_inv + shift[rows_old]
        posmap[old_nnz] = A.nnz
        IA = posmap[self._IA].astype(np.int32)
        BG = self._BG.copy()

        # re-expand ONLY the changed rows' products
        a_perm = _row_sort_perm(A)
        a_rows_all = _expand_rows(A.indptr)
        sel = np.concatenate(
            [np.arange(A.indptr[r], A.indptr[r + 1]) for r in changed_rows]
        ).astype(np.int64) if len(changed_rows) else np.zeros(0, np.int64)
        # A may arrive row-unsorted like any CSR; take its sorted view of
        # the changed rows (positions in data order via the perm)
        inv = np.empty(A.nnz, np.int64)
        inv[a_perm] = np.arange(A.nnz)
        sub_pos = a_perm[sel]                 # data positions, sorted order
        sub_rows = a_rows_all[a_perm][sel]
        sub_cols = A.indices[a_perm][sel]
        pm = self.pm
        # sorted view of ONLY the changed rows of M, with global row ids:
        # the expansion queries no other rows, and within-row offsets (the
        # slot layout) are unaffected by dropping the untouched rows
        mcnt = m_nnz[changed_rows]
        msel = np.concatenate(
            [np.arange(M.indptr[r], M.indptr[r + 1]) for r in changed_rows]
        ).astype(np.int64) if len(changed_rows) else np.zeros(0, np.int64)
        mrows = np.repeat(changed_rows, mcnt)
        mcols = M.indices[msel][np.lexsort((M.indices[msel], mrows))]
        sub_indptr = np.zeros(m + 1, np.int64)
        sub_indptr[changed_rows + 1] = mcnt
        M_s = CSR(np.cumsum(sub_indptr), mcols,
                  np.zeros(len(mcols)), (m, n))
        try:
            slot, a_gather, b_gather = _expand_products(
                sub_rows, sub_cols, sub_pos, B_s, M_s, pm, n)
            # local slot index within the changed rows' column block
            rloc = np.searchsorted(changed_rows, slot // pm)
            lslot = rloc * pm + slot % pm
            IA_s, BV_s, BG_s, counts = _lane_tables(
                lslot, a_gather, b_gather, B_s.data,
                len(changed_rows) * pm, self._IA.shape[0], A.nnz, zero)
        except _TooLarge:
            return None

        cols = (changed_rows[:, None] * pm
                + np.arange(pm)[None, :]).ravel()
        IA[:, cols] = IA_s
        BV[:, cols] = BV_s
        BG[:, cols] = BG_s
        # padded mask columns of the changed rows, laid out exactly as
        # padded_from_csr would (within-row sorted, pad value == n)
        ch_cols = np.full((len(changed_rows), pm), n, np.int32)
        if len(mcols):
            starts = np.cumsum(mcnt) - mcnt
            ch_cols[np.repeat(np.arange(len(changed_rows)), mcnt),
                    np.arange(len(mcols)) - np.repeat(starts, mcnt)] = mcols
        if np.array_equal(ch_cols, self._mask_cols_host[changed_rows]):
            # mask layout untouched (A-only or values-only-M delta): the
            # parent's host/device column tables are reusable as-is
            mask_cols_host, mask_cols_dev = self._mask_cols_host, \
                self.mask_cols
        else:
            mask_cols_host = self._mask_cols_host.copy()
            mask_cols_host[changed_rows] = ch_cols
            mask_cols_dev = jnp.asarray(mask_cols_host)
        present = self._present_host.copy()
        present[changed_rows] = (counts.reshape(len(changed_rows), pm) > 0) \
            & (ch_cols < n)

        clone = object.__new__(BurstProgram)
        clone.shape = self.shape
        clone.k = self.k
        clone.nnz_a = A.nnz
        clone.semiring = self.semiring
        clone.wm = self.wm
        clone.pm = pm
        clone._mask_cols_host = mask_cols_host
        clone.mask_cols = mask_cols_dev
        clone.n_products = int((IA != A.nnz).sum())
        clone.max_chain = self.max_chain
        clone._a_indptr = A.indptr.copy()
        clone._m_indptr = M.indptr.copy()
        clone._a_inv = inv
        clone._b_sig = self._b_sig
        clone._b_fp = b_fp
        clone._b_perm = self._b_perm
        clone._b_sorted_idx = self._b_sorted_idx
        clone._finish(IA, BV, BG, present)
        return clone, len(cols)


class _TooLarge(Exception):
    """Structure exceeds the replay caps; callers fall back silently."""


def burst_eligible(plan_algorithm: str, complement: bool, A, B, M) -> bool:
    return (plan_algorithm in SEQ_SCATTER_ALGOS and not complement
            and isinstance(A, CSR) and isinstance(B, CSR)
            and isinstance(M, CSR))


def _program_key(A: CSR, B: CSR, M: CSR, semiring: Semiring, wm) -> tuple:
    from .cache import content_fingerprint
    return (structure_signature(A), content_fingerprint(B),
            structure_signature(M), semiring.name, wm)


def peek_program(A: CSR, B: CSR, M: CSR, semiring: Semiring, wm):
    """Cached program for this structure if one exists — no build, no
    patch.  The delta path uses it to find a pre-delta parent worth
    patching without ever paying an eager cold compile."""
    key = _program_key(A, B, M, semiring, wm)
    hit = _programs.peek(key)  # lint: plan-key-ok(structure-pure program)
    if hit is not None:
        return hit if hit is not _OVER_CAP else None
    return _patches.peek(key)  # lint: plan-key-ok(structure-pure program)


def record_lineage(A: CSR, B: CSR, M: CSR, semiring: Semiring, wm,
                   parent: BurstProgram, changed_rows: np.ndarray) -> None:
    """Remember that the post-delta structure (A, B, M) descends from
    ``parent`` with only ``changed_rows`` touched.  If the patched program
    is later evicted from ``_patches``, ``get_program`` re-derives it from
    this lineage instead of compiling cold."""
    key = _program_key(A, B, M, semiring, wm)
    val = (parent, np.asarray(changed_rows, np.int64))
    _lineage.put(key, val)  # lint: plan-key-ok(structure-pure program)


def get_program(A: CSR, B: CSR, M: CSR, semiring: Semiring,
                wm: int = None):
    """Cached compile of the bucket's structure (None when over the caps)."""
    key = _program_key(A, B, M, semiring, wm)
    # a BurstProgram replays the gather/scatter pattern of the structure
    # EXACTLY — it encodes no planner election, so it stays valid across
    # calibration-profile changes; deliberately token-free so a retune
    # does not flush compiled programs
    hit = _programs.get(key)  # lint: plan-key-ok(structure-pure program)
    if hit is not None:
        return hit if hit is not _OVER_CAP else None
    hit = _patches.get(key)  # lint: plan-key-ok(structure-pure program)
    if hit is not None:
        return hit
    lin = _lineage.get(key)  # lint: plan-key-ok(structure-pure program)
    if lin is not None:
        with obs.span("burst.patch", source="lineage") as sp:
            got = lin[0].patched(A, B, M, lin[1])
            if got is not None:
                sp.set(lanes=got[1])
                _patches.put(key, got[0])  # lint: plan-key-ok(structure-pure)
                return got[0]
    try:
        with obs.span("burst.compile", nnz_a=A.nnz, nnz_m=M.nnz):
            prog = BurstProgram(A, B, M, semiring, wm)
    except _TooLarge:
        _programs.put(key, _OVER_CAP)  # lint: plan-key-ok(structure-pure)
        return None
    _programs.put(key, prog)  # lint: plan-key-ok(structure-pure program)
    return prog


def patch_program(old: BurstProgram, A: CSR, B: CSR, M: CSR,
                  semiring: Semiring, wm, changed_rows: np.ndarray
                  ) -> Tuple[Optional[BurstProgram], int]:
    """Patch ``old`` onto the post-delta operands: ``(program, lanes)``.

    A memo hit (the same post-delta structure patched before) costs one
    lookup; a fresh patch re-emits only the changed rows' lane columns and
    is registered under the post-delta key so subsequent ``get_program``
    calls for this structure serve it directly.  ``(None, 0)`` means the
    delta is not row-local at this program's static shape — the caller
    rebuilds cold via ``get_program``.
    """
    key = _program_key(A, B, M, semiring, wm)
    hit = _patches.get(key)  # lint: plan-key-ok(structure-pure program)
    if hit is not None:
        return hit, 0
    hit = _programs.peek(key)  # lint: plan-key-ok(structure-pure program)
    if hit is not None and hit is not _OVER_CAP:
        return hit, 0
    with obs.span("burst.patch", source="delta") as sp:
        got = old.patched(A, B, M, changed_rows)
        if got is None:
            return None, 0
        prog, lanes = got
        sp.set(lanes=lanes)
    _patches.put(key, prog)  # lint: plan-key-ok(structure-pure program)
    return prog, lanes


#: cache sentinel: structure known to exceed the replay caps
_OVER_CAP = object()
