"""Bounded result cache for served masked-SpGEMM queries.

Keys are *content* fingerprints (structure CRC + value-byte CRC per
operand) plus the planner's ``cost_model_token()`` — two requests share an
entry iff their operands are byte-identical and the cost model that would
plan them is unchanged, so a hit is bitwise the result a fresh computation
would produce.  This layers over the existing structure-keyed caches (plan
cache, ring prep, compiled programs): a result-cache miss still reuses all
of those.

The cache is a ``repro.caches.LRUCache`` — bounded, thread-safe, visible
to ``repro.caches.cache_info()`` and emptied by ``clear_all()``.
"""
from __future__ import annotations

import threading
import zlib
from typing import Optional, Tuple

import numpy as np

from repro import caches
from repro import obs
from repro.core.formats import CSR, PaddedCSR
from repro.core.planner import structure_signature

#: default result-cache entries; $REPRO_RESULT_CACHE_CAP overrides
DEFAULT_CAPACITY = 256


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes())


def value_fingerprint(x: CSR) -> tuple:
    """Value-only part of the content identity (the structure signature is
    the other part — callers that already hold it avoid re-CRCing the
    index arrays)."""
    return (_crc(x.data), str(x.data.dtype))


def content_fingerprint(x) -> tuple:
    """Content identity of an operand: equal fingerprints => byte-equal
    structure AND values (up to CRC collision).  ``PaddedCSR`` operands are
    device-resident; hashing them would force a transfer, so they are
    identified by object id — valid ONLY while the object is referenced
    (the batcher's queued Requests hold one), so the engine buckets such
    requests but never result-caches them (a persistent id-keyed entry
    could alias a recycled address after GC).
    """
    if isinstance(x, CSR):
        return (structure_signature(x),) + value_fingerprint(x)
    if isinstance(x, PaddedCSR):
        return ("padded-id", id(x))
    raise TypeError(f"unsupported operand type {type(x)!r}")


def result_key(A, B, M, *, semiring_name: str, complement: bool,
               algorithm: Optional[str], mesh_key: Optional[tuple],
               cost_token: str) -> Tuple:
    return (content_fingerprint(A), content_fingerprint(B),
            content_fingerprint(M), semiring_name, complement, algorithm,
            mesh_key, cost_token)


#: coarseness of the per-entry row coverage recorded at ``put`` time: rows
#: map onto this many buckets, so ``invalidate(sig, rows=...)`` skips
#: entries whose recorded coverage provably misses every changed row
ROW_BITMAP_BUCKETS = 64


def row_bitmap(rows, nrows: int) -> int:
    """Coarse coverage bitmap of a row set (bit ``r * B // nrows``)."""
    mask = 0
    n = max(1, int(nrows))
    for r in np.unique(np.asarray(rows, np.int64)):
        mask |= 1 << (int(r) * ROW_BITMAP_BUCKETS // n)
    return mask


_instance_count = 0
_instance_lock = threading.Lock()


class ResultCache:
    """LRU of served results, keyed by ``result_key``.

    Values are whatever the drivers return (``MaskedSpGEMMResult`` or the
    complement's ``(vals, present)`` arrays) — immutable, so a hit hands
    back the identical object.  Each instance registers under a unique
    name (``serve-results``, ``serve-results-2``, ...) so concurrent
    engines all stay visible to ``repro.caches``; ``unregister()`` (called
    by the owning engine's ``close``) drops the registry's reference.
    """

    def __init__(self, capacity: Optional[int] = None,
                 name: Optional[str] = None):
        global _instance_count
        cap = (capacity if capacity is not None else
               caches.env_capacity("REPRO_RESULT_CACHE_CAP",
                                   DEFAULT_CAPACITY))
        if name is None:
            with _instance_lock:
                _instance_count += 1
                name = ("serve-results" if _instance_count == 1
                        else f"serve-results-{_instance_count}")
        self.name = name
        self._lru = caches.LRUCache(name, cap)
        # structure sig -> {entry key: row coverage bitmap}: the scoped-
        # invalidation index (see ``put``/``invalidate``)
        self._tags: dict = {}
        self._tags_lock = threading.Lock()

    def unregister(self) -> None:
        """Drop this cache from the process registry (it keeps working
        locally; the registry just stops referencing it)."""
        caches.unregister(self.name)

    def get(self, key):
        return self._lru.get(key)

    def put(self, key, value, tags=None) -> None:
        """Insert; ``tags`` is an optional sequence of ``(structure_sig,
        row_bitmap)`` pairs naming the operand structures (and the coarse
        row coverage) the entry depends on — ``invalidate`` walks the tag
        index instead of the whole cache, so a delta to one structure
        never touches entries of unrelated structures sharing the engine.
        """
        self._lru.put(key, value)
        if tags:
            with self._tags_lock:
                for sig, bitmap in tags:
                    self._tags.setdefault(sig, {})[key] = int(bitmap)
                self._maybe_prune_locked()

    def invalidate(self, sig, rows_bitmap: Optional[int] = None) -> int:
        """Evict entries tagged with structure ``sig`` whose recorded row
        coverage overlaps ``rows_bitmap`` (None = every row).  Returns the
        number of live entries evicted.  Scoped: entries of other
        structures — and non-overlapping row ranges — stay cached.
        """
        with self._tags_lock:
            index = self._tags.get(sig)
            if not index:
                return 0
            if rows_bitmap is None:
                hit = list(index)
            else:
                hit = [k for k, b in index.items() if b & rows_bitmap]
            for k in hit:
                index.pop(k, None)
            if not index:
                self._tags.pop(sig, None)
        evicted = 0
        for k in hit:
            if self._lru.pop(k) is not None:
                evicted += 1
        obs.event("cache.invalidate", cache=self.name,
                  tagged=len(hit), evicted=evicted,
                  scoped=rows_bitmap is not None)
        return evicted

    def _maybe_prune_locked(self) -> None:
        """Drop tag-index records whose entries the LRU already evicted
        (called under ``_tags_lock``); keeps the index O(capacity)."""
        total = sum(len(ix) for ix in self._tags.values())
        if total <= 4 * self._lru.capacity:
            return
        for sig in list(self._tags):
            ix = self._tags[sig]
            for k in list(ix):
                if self._lru.peek(k) is None:
                    del ix[k]
            if not ix:
                del self._tags[sig]

    def clear(self) -> None:
        self._lru.clear()
        with self._tags_lock:
            self._tags.clear()

    def __len__(self) -> int:
        return len(self._lru)

    @property
    def capacity(self) -> int:
        return self._lru.capacity

    def info(self) -> dict:
        return self._lru.info()
