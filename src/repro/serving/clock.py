"""Injectable clocks for the serving engine.

Every time-dependent decision the engine makes — ``Request.submitted_at``,
the ``max_wait_ms`` aging of partial buckets, queue-wait accounting — reads
through a clock object instead of ``time.perf_counter`` directly.  Two
implementations:

* :class:`SystemClock` — wall time; the default, behaviorally identical to
  the direct ``perf_counter`` reads it replaced.
* :class:`VirtualClock` — a manually-advanced timeline.  Replaying a
  recorded trace (``serving.trace``) drives submissions at the recorded
  arrival offsets and steps this clock through each flush deadline, so the
  engine's bucket/flush decisions depend only on the trace — the same
  trace replays to the same bucket sequence every time, and the
  timing-sensitive async tests stop sleeping on real ``max_wait_ms``.

A clock can be *attached* to condition variables (the engine attaches its
internal scheduling condition): advancing a :class:`VirtualClock` notifies
them, so an async worker blocked on a virtual deadline wakes exactly when
virtual time reaches it, never on a real timer.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional


class SystemClock:
    """Wall-clock time (``time.perf_counter``)."""

    #: True when ``now()`` only moves via ``advance`` (replay determinism)
    virtual = False

    def now(self) -> float:
        return time.perf_counter()

    def wait_on(self, cond: threading.Condition, timeout: Optional[float]
                ) -> None:
        """Block on ``cond`` (held by the caller) until notified or until
        ``timeout`` real seconds pass (None = until notified)."""
        cond.wait(timeout=timeout)

    def attach(self, cond: threading.Condition) -> None:  # pragma: no cover
        pass

    def detach(self, cond: threading.Condition) -> None:  # pragma: no cover
        pass


class VirtualClock:
    """A deterministic timeline: ``now()`` changes only via ``advance``.

    ``advance``/``advance_to`` notify every attached condition, so engine
    workers waiting on virtual deadlines re-evaluate immediately.  Time
    never goes backwards (replay offsets are sorted; a regression here
    would silently reorder flush decisions, so it raises instead).
    """

    virtual = True

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()
        self._conds: List[threading.Condition] = []

    def now(self) -> float:
        with self._lock:
            return self._now

    # -- timeline -----------------------------------------------------------

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance by negative dt {dt!r}")
        with self._lock:
            target = self._now + float(dt)
        return self.advance_to(target)

    def advance_to(self, t: float) -> float:
        with self._lock:
            if t < self._now - 1e-12:
                raise ValueError(
                    f"virtual time cannot go backwards ({t!r} < {self._now!r})")
            self._now = max(self._now, float(t))
            conds = list(self._conds)
        for cond in conds:
            with cond:
                cond.notify_all()
        return t

    # -- waiter plumbing ----------------------------------------------------

    def attach(self, cond: threading.Condition) -> None:
        with self._lock:
            if cond not in self._conds:
                self._conds.append(cond)

    def detach(self, cond: threading.Condition) -> None:
        with self._lock:
            try:
                self._conds.remove(cond)
            except ValueError:
                pass

    def wait_on(self, cond: threading.Condition, timeout: Optional[float]
                ) -> None:
        """A virtual deadline must not burn real time: block until some
        event (submit, ``advance``, stop) notifies.  The short real timeout
        is only a lost-wakeup safety net, not a schedule."""
        cond.wait(timeout=0.05)
