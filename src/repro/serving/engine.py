"""Masked-SpGEMM query engine: submit/flush serving over the planner.

The paper's lesson is that structure-dependent decisions (accumulator
choice, mask layout) must be amortized; a serving layer amortizes them
across *queries*.  ``QueryEngine`` accepts a stream of masked-SpGEMM
requests, buckets them by structural signature (``batcher``), serves each
bucket through ONE cached plan and — for row-kernel plans — one vmapped
compiled program (``masked_spgemm_batched``), consults a bounded
content-keyed result cache first (``cache``), and records per-bucket
latency/throughput counters (``metrics``).

Modes:

* sync — ``submit()`` queues, ``flush()`` (or ``Ticket.result()``) drains.
* async — a worker thread flushes full buckets immediately and partial
  buckets after ``max_wait_ms``; ``submit()`` returns a future-like
  ``Ticket`` at once.

Backpressure: at most ``queue_cap`` requests may be pending.  The async
engine blocks the submitter until the worker drains; the sync engine
flushes inline — either way a producer can never grow the queue without
bound.

Tile- and distributed-elected plans are first-class: a bucket whose plan
elects the BCSR tile route executes per element on the shared block
executor, and requests carrying a ``mesh`` are served by
``distributed_masked_spgemm`` (plan + ring host-prep both cached across
the bucket by structural signature).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

import jax

from repro import caches
from repro import obs
from repro.core.formats import CSR, CSRDelta, apply_csr_delta, tril
from repro.core.masked_spgemm import masked_spgemm, masked_spgemm_batched
from repro.core import planner
from repro.core.semiring import Semiring, PLUS_TIMES

from . import burst
from .batcher import Batcher, Request, mesh_key, merge_planned
from .cache import (ResultCache, content_fingerprint, row_bitmap,
                    value_fingerprint)
from .clock import SystemClock
from .metrics import ServeMetrics

#: changed-row scratch for the delta path: incremental signatures memoized
#: per structure signature, so a chain of deltas updates each signature in
#: O(changed rows) instead of an O(m) recompute per step;
#: $REPRO_DELTA_SCRATCH_CAP overrides the capacity
_delta_scratch = caches.LRUCache("serve-delta-scratch", 64,
                                 env_var="REPRO_DELTA_SCRATCH_CAP")

#: full row coverage (every ``cache.ROW_BITMAP_BUCKETS`` bucket set): the
#: tag recorded for operands whose deltas cannot be row-scoped (B: one B
#: row feeds every output row)
_FULL_COVERAGE = (1 << 64) - 1


@dataclasses.dataclass(frozen=True)
class DeltaOutcome:
    """What ``QueryEngine.submit_delta`` did, and the operands to query
    with from now on."""

    A: CSR
    B: CSR
    M: CSR
    plan: planner.Plan
    plan_survived: bool          # revalidated in place (no cold re-plan)
    changed_rows: np.ndarray     # output rows the delta can affect
    lanes_patched: int           # burst lane columns re-emitted (0 = none)
    rows_invalidated: int        # affected output rows used to scope eviction
    entries_evicted: int         # result-cache entries actually evicted
    rekeyed: int                 # queued requests remapped onto the bucket
    signatures: Dict[str, tuple]  # per delta'd operand: incremental sig


class Ticket:
    """Future for one submitted request."""

    __slots__ = ("_engine", "_event", "_value", "_error")

    def __init__(self, engine: "QueryEngine"):
        self._engine = engine
        self._event = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        """The served result; blocks until available.

        In sync mode an unserved ticket triggers ``engine.flush()``; in
        async mode the worker's max-wait policy bounds the wait.
        """
        if not self._event.is_set() and not self._engine.async_mode:
            self._engine.flush()
        if not self._event.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise self._error
        return self._value

    def _complete(self, value) -> None:
        self._value = value
        self._event.set()

    def _fail(self, error: BaseException) -> None:
        self._error = error
        self._event.set()


class QueryEngine:
    """Serving front-end for ``masked_spgemm`` and its graph composites."""

    # NOTE: engines register their result cache in ``repro.caches``; use
    # the context manager (or call ``close()``) so a dropped engine does
    # not leave the registry referencing its cached results.
    def __init__(self, *, max_batch: int = 32, max_wait_ms: float = 2.0,
                 queue_cap: int = 1024, async_mode: bool = False,
                 merge_same_shape: bool = True, pad_factor: float = 4.0,
                 result_cache: Optional[ResultCache] = None,
                 cache_results: bool = True, use_burst: bool = True,
                 clock=None, recorder=None,
                 expose_port: Optional[int] = None,
                 monitor=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if queue_cap < max_batch:
            raise ValueError(f"queue_cap ({queue_cap}) must be >= "
                             f"max_batch ({max_batch})")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0, got {max_wait_ms}")
        if pad_factor < 1:
            raise ValueError(f"pad_factor must be >= 1, got {pad_factor} "
                             f"(1 disables width merging, it cannot shrink "
                             f"widths)")
        self.async_mode = async_mode
        self.max_wait_s = max_wait_ms / 1e3
        self.queue_cap = queue_cap
        self.merge_same_shape = merge_same_shape
        self.pad_factor = pad_factor
        self.cache_results = cache_results
        self.use_burst = use_burst
        #: every time-dependent decision reads this clock; a VirtualClock
        #: here makes the flush schedule a pure function of the submissions
        #: (trace replay, deflaked timing tests)
        self.clock = clock if clock is not None else SystemClock()
        #: trace recorder (``serving.trace.TraceRecorder``) — observes every
        #: submit; None = no capture
        self.recorder = recorder
        #: health intelligence (``repro.obs.health.HealthMonitor``) —
        #: ``engine.health()`` consults it and the exposition layer
        #: renders its repro_slo_*/repro_drift_* families.  The monitor
        #: only SEES spans when it is (or tees behind) the active
        #: tracing sink: ``with obs.tracing(monitor): ...``
        self.monitor = monitor
        self.metrics = ServeMetrics()
        self._owns_results = result_cache is None
        self.results = (result_cache if result_cache is not None
                        else ResultCache())
        self._batcher = Batcher(max_batch=max_batch)
        self._exec_lock = threading.Lock()
        # RLock: the worker holds _space while draining ready + aged work in
        # one atomic step (quiesce() must never observe the half-taken state)
        self._space = threading.Condition(threading.RLock())
        self.clock.attach(self._space)
        self._busy = False
        #: full buckets awaiting the worker (async mode only) — kept out of
        #: the batcher so new same-key requests start a fresh bucket, but
        #: still counted against queue_cap for backpressure
        self._ready: List[List[Request]] = []
        self._ready_count = 0
        self._stop = False
        self._worker: Optional[threading.Thread] = None
        if async_mode:
            self._worker = threading.Thread(target=self._worker_loop,
                                            name="repro-serve-worker",
                                            daemon=True)
            self._worker.start()
        #: /metrics + /health exposition (``repro.obs.serve``); port 0
        #: binds an ephemeral port — read ``engine.obs_server.port``
        self.obs_server = None
        if expose_port is not None:
            from repro.obs.serve import start_server
            self.obs_server = start_server(self, port=expose_port)

    # -- lifecycle ----------------------------------------------------------

    def close(self) -> None:
        """Drain outstanding work, stop the worker, and drop the engine's
        own result cache from the process registry."""
        if self.obs_server is not None:
            self.obs_server.close()
            self.obs_server = None
        self.flush()
        # sync-mode engines have no worker to stop, but a closed engine
        # must still read as stopped (basic_verdict / the "stopped"
        # field in /health key off this flag)
        with self._space:
            self._stop = True
            self._space.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
            self._worker = None
        self.clock.detach(self._space)
        if self._owns_results:
            self.results.unregister()

    def __enter__(self) -> "QueryEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def health(self):
        """This engine's :class:`repro.obs.health.HealthVerdict`.

        With a :class:`~repro.obs.health.HealthMonitor` attached the
        verdict folds liveness, every SLO's multi-window burn rate and
        cost-model drift; without one it is liveness-only.  ``/health``
        serves exactly this (503 while ``failing``)."""
        from repro.obs.health import basic_verdict
        if self.monitor is not None:
            return self.monitor.verdict(engine=self)
        return basic_verdict(self)

    # -- submission ---------------------------------------------------------

    def submit(self, A, B, M, *, semiring: Semiring = PLUS_TIMES,
               complement: bool = False, algorithm: Optional[str] = None,
               mesh=None, axis: str = "data",
               post: Optional[Callable] = None) -> Ticket:
        """Queue C = M (.) (A B); returns a future-like ``Ticket``.

        ``algorithm=None`` lets the planner decide (bucket-wide);
        a string forces that algorithm (``"tile"``, a row kernel, or —
        with ``mesh`` — ``"row"``/``"ring"``).  ``post`` transforms the raw
        result before it reaches ``Ticket.result()`` (composites use it).
        """
        ticket = Ticket(self)
        self.metrics.record_submit()
        submitted_at = self.clock.now()
        # measurement, not scheduling: hit latency must be real elapsed
        # time even under a frozen virtual clock
        t_sub = time.perf_counter()  # lint: clock-ok(hit latency measurement)
        trace_id = obs.new_trace()   # None while tracing is disabled
        if trace_id is not None:
            obs.event("serve.submit", trace=trace_id,
                      shape=list(M.shape), complement=complement,
                      algorithm=algorithm, mesh=mesh is not None)
        if self.recorder is not None:
            self.recorder.on_submit(A, B, M, t=submitted_at,
                                    semiring=semiring, complement=complement,
                                    algorithm=algorithm, mesh=mesh, axis=axis)
        key = bkey = None
        if (isinstance(A, CSR) and isinstance(B, CSR)
                and isinstance(M, CSR)):
            # one fingerprint pass feeds BOTH keys: the bucket key (A/M by
            # structure, B by content) and the result key (all by content)
            sa = planner.structure_signature(A)
            sm = planner.structure_signature(M)
            cb = content_fingerprint(B)
            mk = mesh_key(mesh, axis)
            bkey = (sa, cb, sm, semiring.name, complement, algorithm, mk)
            if self.cache_results and not complement:
                # only host-CSR, mask-bounded results are cached: device
                # operands hash by id (GC could recycle it) and complement
                # results are dense (m, n) pairs whose bytes would blow
                # past the entry-count bound
                key = ((sa,) + value_fingerprint(A), cb,
                       (sm,) + value_fingerprint(M), semiring.name,
                       complement, algorithm, mk,
                       planner.cost_model_token())
                hit = self.results.get(key)
                if hit is not None:
                    hit_s = (time.perf_counter()  # lint: clock-ok(hit latency measurement)
                             - t_sub)
                    self.metrics.record_cache_hit(latency_s=hit_s)
                    obs.event("serve.cache_hit", dur_s=hit_s,
                              trace=trace_id)
                    obs.counter("serve.cache_hit_rate",
                                self.metrics.hit_rate())
                    ticket._complete(post(hit) if post is not None else hit)
                    return ticket
        req = Request(A=A, B=B, M=M, semiring=semiring,
                      complement=complement, algorithm=algorithm, mesh=mesh,
                      axis=axis, ticket=ticket, post=post, cache_key=key,
                      key=bkey, submitted_at=submitted_at,
                      trace_id=trace_id)
        self._admit(req)
        if trace_id is not None:
            # counter track: queue depth after admission (tracing-gated —
            # _pending() takes the space lock, so untraced submits skip it)
            obs.counter("serve.queue_depth", self._pending())
        return ticket

    def submit_triangle(self, adj: CSR, *, relabel: bool = True,
                        algorithm: Optional[str] = None) -> Ticket:
        """Triangle count of an undirected graph as a served query
        (paper §8.2: #tri = sum(L .* (L @ L))).  ``Ticket.result()`` is the
        integer count; the underlying product batches/caches like any
        other request with A = B = M = L."""
        from repro.graphs.triangle_counting import degree_relabel
        a = degree_relabel(adj) if relabel else adj
        L = tril(a, strict=True)

        def count(res) -> int:
            return int(round(float(np.asarray(res.vals)[
                np.asarray(res.present)].sum())))

        return self.submit(L, L, L, algorithm=algorithm, post=count)

    def submit_delta(self, A: CSR, B: CSR, M: CSR, *,
                     delta_a: Optional[CSRDelta] = None,
                     delta_b: Optional[CSRDelta] = None,
                     delta_m: Optional[CSRDelta] = None,
                     semiring: Semiring = PLUS_TIMES,
                     complement: bool = False,
                     algorithm: Optional[str] = None,
                     rebase_queued: bool = False) -> DeltaOutcome:
        """Fold edge-delta batches into served operands WITHOUT restarting
        the serving state from cold.

        ``A``/``B``/``M`` are the current (pre-delta) operands; each
        ``delta_*`` is a :class:`repro.core.formats.CSRDelta` (or None).
        The engine:

        * applies the deltas (``apply_csr_delta``), maintaining each
          operand's incremental structure signature in O(changed rows)
          via a memo keyed by structure signature;
        * revalidates the operands' plan (``planner.revalidate``) — a
          row-local delta keeps the plan, stamped into the plan cache
          under the post-delta key, so subsequent ``submit``\\s hit;
        * patches the compiled burst program's gather lanes in place of a
          recompile when the plan survived and a pre-delta program is
          cached (``burst.patch_program``), and records the lineage so an
          evicted patch can be re-derived later;
        * invalidates result-cache entries scoped to the delta'd
          structures AND the affected row coverage — entries of unrelated
          structures sharing this engine stay cached;
        * optionally (``rebase_queued=True``) remaps still-queued requests
          of the pre-delta bucket onto the post-delta bucket, swapping the
          shared B/M references so those queries are answered against the
          post-delta database (read-your-writes).  Only taken when A's
          structure is unchanged — per-query A payloads must stay valid
          under the new bucket key.  Rebased requests drop their result
          key (it fingerprinted the pre-delta operands).

        Counters land in ``metrics.snapshot()``: ``delta_applied``,
        ``plans_revalidated``, ``lanes_patched``, ``rows_invalidated``.
        Returns a :class:`DeltaOutcome`; query with its ``A``/``B``/``M``
        from now on.
        """
        if not (isinstance(A, CSR) and isinstance(B, CSR)
                and isinstance(M, CSR)):
            raise TypeError("submit_delta requires host-CSR operands")
        if delta_a is None and delta_b is None and delta_m is None:
            raise ValueError("submit_delta needs at least one delta")
        old_ops = {"A": A, "B": B, "M": M}
        deltas = {"A": delta_a, "B": delta_b, "M": delta_m}
        sig_old = {k: planner.structure_signature(v)
                   for k, v in old_ops.items()}
        new_ops = dict(old_ops)
        signatures: Dict[str, tuple] = {}
        changed: Dict[str, np.ndarray] = {}
        values_only = {"A": True, "B": True, "M": True}
        applied = 0
        with obs.span("delta.apply") as sp:
            for name in ("A", "B", "M"):
                d = deltas[name]
                if d is None:
                    changed[name] = np.zeros(0, np.int64)
                    continue
                isig = _delta_scratch.get(
                    ("isig", sig_old[name]))  # lint: plan-key-ok(isig memo)
                res = apply_csr_delta(old_ops[name], d, old_signature=isig)
                new_ops[name] = res.csr
                changed[name] = res.changed_rows
                values_only[name] = res.values_only
                signatures[name] = res.signature
                _delta_scratch.put(
                    ("isig", planner.structure_signature(res.csr)),
                    res.signature)  # lint: plan-key-ok(isig memo)
                applied += 1
            sp.set(applied=applied)
        A1, B1, M1 = new_ops["A"], new_ops["B"], new_ops["M"]

        # plan lifecycle: revalidate the pre-delta plan onto the post-delta
        # operands; a surviving plan is stamped under the post-delta cache
        # key inside revalidate(), so the serve path's plan() call hits
        with obs.span("delta.revalidate") as sp:
            old_plan = planner.plan(A, B, M, complement=complement,
                                    semiring=semiring)
            new_plan, survived = planner.revalidate(
                old_plan, A1, B1, M1, complement=complement,
                semiring=semiring)
            sp.set(survived=survived, algorithm=new_plan.algorithm)

        # burst lifecycle: patch the compiled program's changed lane
        # columns instead of recompiling, when the delta is row-local on
        # A/M and B's structure is intact (value-only B changes regather)
        lanes = 0
        union = np.union1d(changed["A"], changed["M"]).astype(np.int64)
        if (survived and algorithm is None and self.use_burst
                and values_only["B"]
                and burst.burst_eligible(new_plan.algorithm, complement,
                                         A1, B1, M1)):
            with obs.span("delta.lane_patch") as sp:
                parent = burst.peek_program(A, B, M, semiring,
                                            old_plan.widths[2])
                if parent is not None:
                    prog, lanes = burst.patch_program(
                        parent, A1, B1, M1, semiring, new_plan.widths[2],
                        union)
                    if prog is not None:
                        burst.record_lineage(A1, B1, M1, semiring,
                                             new_plan.widths[2], parent,
                                             union)
                sp.set(lanes=int(lanes), had_parent=parent is not None)

        # result-cache lifecycle: evict by (structure, row coverage) — a
        # B delta can affect every output row, so it is never row-scoped
        m_rows = A.shape[0]
        evicted = 0
        with obs.span("delta.invalidate") as sp:
            if delta_a is not None:
                evicted += self.results.invalidate(
                    sig_old["A"], row_bitmap(changed["A"], m_rows))
            if delta_m is not None:
                evicted += self.results.invalidate(
                    sig_old["M"], row_bitmap(changed["M"], m_rows))
            if delta_b is not None:
                evicted += self.results.invalidate(sig_old["B"], None)
            sp.set(evicted=int(evicted))
        rows = int(m_rows if delta_b is not None else len(union))
        self.metrics.record_delta(applied=applied,
                                  revalidated=int(survived),
                                  lanes=int(lanes), rows=rows)

        rekeyed = 0
        if rebase_queued and survived and values_only["A"]:
            mk = None
            old_bkey = (sig_old["A"], content_fingerprint(B),
                        sig_old["M"], semiring.name, complement,
                        algorithm, mk)
            new_bkey = (sig_old["A"], content_fingerprint(B1),
                        planner.structure_signature(M1), semiring.name,
                        complement, algorithm, mk)

            def _rebase(r):
                r.B = B1
                r.M = M1
                r.cache_key = None

            rekeyed = self._batcher.rekey(old_bkey, new_bkey, _rebase)

        return DeltaOutcome(
            A=A1, B=B1, M=M1, plan=new_plan, plan_survived=survived,
            changed_rows=union, lanes_patched=int(lanes),
            rows_invalidated=rows, entries_evicted=int(evicted),
            rekeyed=int(rekeyed), signatures=signatures)

    def serve(self, requests: Sequence[tuple]) -> List:
        """Sync convenience: submit ``(A, B, M)`` (or ``(A, B, M, kwargs)``)
        tuples, flush once, return results in order."""
        tickets = []
        for r in requests:
            kwargs = r[3] if len(r) > 3 else {}
            tickets.append(self.submit(r[0], r[1], r[2], **kwargs))
        self.flush()
        return [t.result() for t in tickets]

    def _pending(self) -> int:
        # _space (RLock) also orders _ready_count against the worker's
        # _take_ready decrement — an unlocked read could admit past the
        # queue cap on a torn interleave
        with self._space:
            return self._batcher.pending + self._ready_count

    def _admit(self, req: Request) -> None:
        """Bounded-queue admission: block (async) or flush inline (sync)
        while the queue is at capacity, then enqueue.  A bucket filled to
        max_batch executes at once in sync mode; in async mode it is
        handed to the worker so submit() stays non-blocking."""
        while True:
            if self._pending() < self.queue_cap:
                break
            if self.async_mode:
                with self._space:
                    if self._pending() >= self.queue_cap and not self._stop:
                        self._space.wait(timeout=0.05)
            else:
                self.flush()
        full = self._batcher.add(req)
        if full is not None:
            if self.async_mode:
                with self._space:
                    self._ready.append(full)
                    self._ready_count += len(full)
                    self._space.notify_all()
            else:
                self._execute_bucket(full)
        elif self.async_mode:
            with self._space:
                self._space.notify_all()

    def _take_ready(self) -> List[List[Request]]:
        with self._space:
            out, self._ready = self._ready, []
            self._ready_count = 0
        return out

    # -- flushing -----------------------------------------------------------

    def flush(self) -> None:
        """Execute every queued bucket (one plan each; mergeable
        same-shape row buckets fuse into wider batches first)."""
        buckets = self._take_ready() + self._batcher.pop_all()
        if not buckets:
            return
        self._execute_many(buckets)
        with self._space:
            self._space.notify_all()

    def flush_due(self) -> int:
        """Execute exactly the work the async worker's policy would execute
        NOW: full buckets plus buckets older than ``max_wait_ms`` at the
        clock's current time.  This is the sync-mode replay step — calling
        it after each virtual-clock advance reproduces the async worker's
        flush schedule deterministically.  Returns the number of requests
        served."""
        work = self._take_ready() + self._batcher.pop_aged(
            self.max_wait_s, now=self.clock.now())
        if not work:
            return 0
        self._execute_many(work)
        with self._space:
            self._space.notify_all()
        return sum(len(b) for b in work)

    def next_flush_deadline(self) -> Optional[float]:
        """Clock time at which the oldest queued bucket becomes due
        (None when nothing is queued).  Replay drives the virtual clock
        through these deadlines."""
        d = self._batcher.next_deadline()
        return None if d is None else d + self.max_wait_s

    def quiesce(self, timeout: float = 30.0) -> None:
        """Block until no *due* work remains: the ready queue is empty, the
        worker is idle, and no bucket has outlived ``max_wait_ms`` at the
        clock's current time.  The async replay barrier — after each submit
        or virtual-clock advance it guarantees the worker has consumed
        every decision the new time implies before the trace proceeds.
        Pending-but-not-due buckets stay queued.  Sync engines serve due
        work inline."""
        if not self.async_mode:
            self.flush_due()
            return
        # the watchdog deadline is real time BY DESIGN: it bounds how long
        # we wait for the worker thread, not a scheduling decision, and
        # must fire even when the virtual clock is frozen
        end = time.perf_counter() + timeout  # lint: clock-ok(watchdog)
        with self._space:
            while (self._ready or self._busy
                   or self._batcher.has_aged(self.max_wait_s,
                                             now=self.clock.now())):
                if time.perf_counter() >= end:  # lint: clock-ok(watchdog)
                    raise TimeoutError(
                        "engine did not quiesce within "
                        f"{timeout}s (worker stuck or stopped?)")
                self._space.wait(timeout=0.05)

    def _worker_loop(self) -> None:
        while True:
            with self._space:
                if self._stop:
                    return
                deadline = self._batcher.next_deadline()
                # full buckets are ready now; empty queue sleeps until a
                # submit notifies; otherwise wake at the oldest bucket's
                # max-wait deadline
                wait = (None if deadline is None else
                        max(0.0, deadline + self.max_wait_s
                            - self.clock.now()))
                if not self._ready and (wait is None or wait > 0):
                    self.clock.wait_on(self._space, wait)
                if self._stop:
                    return
                # take ready + aged work and mark busy in ONE _space
                # critical section (RLock): quiesce() must never see the
                # gap between "popped" and "executing"
                work = self._take_ready() + self._batcher.pop_aged(
                    self.max_wait_s, now=self.clock.now())
                if work:
                    self._busy = True
            if work:
                try:
                    self._execute_many(work)
                finally:
                    with self._space:
                        self._busy = False
                        self._space.notify_all()

    # -- execution ----------------------------------------------------------

    def _execute_many(self, buckets: List[List[Request]]) -> None:
        if not self.merge_same_shape:
            for bucket in buckets:
                self._execute_bucket(bucket)
            return
        planned, direct, forced_row = [], [], []
        for bucket in buckets:
            r = bucket[0]
            if r.mesh is None and r.algorithm is None:
                t0 = time.perf_counter()  # lint: clock-ok(plan duration)
                try:
                    plan = planner.plan(r.A, r.B, r.M,
                                        complement=r.complement,
                                        semiring=r.semiring)
                except Exception as e:
                    self._fail_bucket(bucket, e)
                    continue
                planned.append(  # lint: clock-ok(plan duration)
                    ((bucket, plan), time.perf_counter() - t0))
                if obs.enabled():
                    # explain() is attached to every plan span so traces
                    # carry modeled costs next to measured exec durations
                    obs.event("serve.plan", dur_s=planned[-1][1],
                              algorithm=plan.algorithm,
                              explain=planner.explain_cached(plan),
                              traces=[q.trace_id for q in bucket])
            elif r.mesh is None and r.algorithm != "tile":
                forced_row.append(bucket)
            else:
                direct.append(bucket)
        for bucket in direct:
            self._execute_bucket(bucket)
        # forced row-kernel buckets sharing B/shape/options fuse without a
        # plan: the batched driver widens pad widths to the batch maxima
        # itself (the BC client forcing msa stays one program per depth)
        groups: dict = {}
        for bucket in forced_row:
            r = bucket[0]
            b_fp = (r.key[1] if r.key is not None
                    else content_fingerprint(r.B))
            sig = (b_fp, r.A.shape, r.M.shape, r.semiring.name,
                   r.complement, r.algorithm)
            groups.setdefault(sig, []).append(bucket)
        for members in groups.values():
            self._execute_bucket([q for b in members for q in b],
                                 merged_from=len(members))
        merged = merge_planned([g for g, _ in planned],
                               pad_factor=self.pad_factor)
        plan_s = sum(dt for _, dt in planned) / max(1, len(merged))
        for reqs, plan, merged_from in merged:
            self._execute_bucket(reqs, plan=plan, plan_s=plan_s,
                                 merged_from=merged_from)

    def _fail_bucket(self, reqs: List[Request], err: BaseException) -> None:
        self.metrics.record_failure(len(reqs))
        if obs.enabled():
            # one serve.error per request: the error-rate SLO burns
            # per-request budget, not per-bucket
            for r in reqs:
                obs.event("serve.error", trace=r.trace_id,
                          error=type(err).__name__)
            obs.counter("serve.inflight", 0)
        for r in reqs:
            r.ticket._fail(err)

    def _execute_bucket(self, reqs: List[Request],
                        plan: Optional[planner.Plan] = None,
                        plan_s: float = 0.0, merged_from: int = 1) -> None:
        """Serve one bucket: every request shares structure (or, merged,
        shape + algorithm), so one plan covers all of them."""
        rep = reqs[0]
        # queue wait is CLOCK time (virtual under replay — deterministic);
        # execution is always a real duration (it is a measurement, not a
        # scheduling decision)
        t_in = self.clock.now()
        queue_wait = t_in - min(r.submitted_at for r in reqs)
        if obs.enabled():
            # counter track: requests entering execution (drops to 0 in
            # the post-exec block) — Perfetto renders it as load context
            obs.counter("serve.inflight", len(reqs))
        t_exec = time.perf_counter()  # lint: clock-ok(exec duration)
        with self._exec_lock:
            try:
                if rep.mesh is not None:
                    results, route, algo = self._run_distributed(reqs)
                else:
                    results, route, algo, plan = self._run_local(
                        reqs, plan, uniform=(merged_from == 1))
            except Exception as e:
                self._fail_bucket(reqs, e)
                return
            exec_s = time.perf_counter() - t_exec  # lint: clock-ok(exec duration)
        if obs.enabled():
            traces = [r.trace_id for r in reqs]
            # queue wait is a CLOCK duration (deterministic under replay):
            # emitted with the engine-computed value, never re-measured
            obs.event("serve.queue_wait", dur_s=queue_wait, traces=traces)
            modeled = regime = None
            if plan is not None:
                by_name = dict(plan.costs)
                if algo in by_name:
                    modeled = float(by_name[algo])
                # regime keys the drift detector's per-(kernel, feature
                # bucket) residual statistics
                regime = planner.feature_regime(plan)
            obs.event("serve.exec", dur_s=exec_s, route=route,
                      algorithm=algo, size=len(reqs),
                      merged_from=merged_from, modeled_ms=modeled,
                      regime=regime, traces=traces)
            obs.counter("serve.inflight", 0)
            obs.counter("serve.cache_hit_rate", self.metrics.hit_rate())
        self.metrics.record_bucket(
            size=len(reqs), algorithm=algo, route=route,
            queue_wait_s=queue_wait, plan_s=plan_s, exec_s=exec_s,
            merged_from=merged_from,
            latencies_s=[(t_in - r.submitted_at) + exec_s for r in reqs])
        # Only uniform buckets' results are cached: width-merged buckets
        # return results padded to the MERGED width, not the shape a fresh
        # one-shot computation produces, and a hit must be byte-exact.
        # The token re-check guards the submit->execute window: if a
        # calibration profile activated while the request was queued, this
        # result was planned under a different token than its key records.
        cacheable = self.cache_results and merged_from == 1
        token = planner.cost_model_token() if cacheable else None
        # scoped-invalidation tags: the entry depends on A and M only where
        # the mask has entries (a delta confined to mask-empty rows cannot
        # change the result), and on EVERY row of B (one B row feeds any
        # output row).  cache_key components [0][0]/[1][0]/[2][0] are the
        # operands' structure signatures — shared across the bucket.
        cover = (row_bitmap(np.nonzero(np.diff(rep.M.indptr))[0],
                            rep.M.shape[0])
                 if cacheable and rep.cache_key is not None else 0)
        cache_puts = 0
        for r, res in zip(reqs, results):
            if (cacheable and r.cache_key is not None
                    and r.cache_key[-1] == token):
                self.results.put(r.cache_key, res, tags=(
                    (r.cache_key[0][0], cover),
                    (r.cache_key[1][0], _FULL_COVERAGE),
                    (r.cache_key[2][0], cover)))
                cache_puts += 1
            # a raising post callback must fail ONLY its own ticket — an
            # escaped exception here would strand the bucket's remaining
            # tickets and kill the async worker thread
            try:
                value = res if r.post is None else r.post(res)
            except Exception as e:
                self.metrics.record_failure(1)
                obs.event("serve.error", trace=r.trace_id,
                          error=type(e).__name__)
                r.ticket._fail(e)
                continue
            r.ticket._complete(value)
        if cache_puts:
            obs.event("serve.result_cache_put", count=cache_puts)

    def _run_distributed(self, reqs: List[Request]):
        """Mesh-carrying bucket: the distributed plan and the ring's host
        prep are signature-cached, so the bucket pays them once."""
        from repro.core.distributed import distributed_masked_spgemm
        rep = reqs[0]
        algo = rep.algorithm or "auto"
        out = []
        for r in reqs:
            res = distributed_masked_spgemm(
                r.A, r.B, r.M, r.mesh, algorithm=algo, axis=r.axis,
                semiring=r.semiring, complement=r.complement)
            out.append(res)
        jax.block_until_ready([r.vals for r in out])
        if algo == "auto":
            algo = planner.plan_distributed(
                rep.A, rep.B, rep.M, int(rep.mesh.shape[rep.axis]),
                complement=rep.complement, semiring=rep.semiring).route
        return out, "distributed", algo

    def _run_local(self, reqs: List[Request],
                   plan: Optional[planner.Plan], uniform: bool = True):
        rep = reqs[0]
        forced = rep.algorithm
        if plan is None and forced is None:
            plan = planner.plan(rep.A, rep.B, rep.M,
                                complement=rep.complement,
                                semiring=rep.semiring)
        algo = forced if forced is not None else plan.algorithm

        if (uniform and forced is None and self.use_burst
                and burst.burst_eligible(algo, rep.complement, rep.A,
                                         rep.B, rep.M)):
            # same-structure bucket on a sequential-scatter plan: the
            # structure-compiled replay serves the whole bucket in one
            # dispatch, bitwise the plan's row kernel
            prog = burst.get_program(rep.A, rep.B, rep.M, rep.semiring,
                                     wm=plan.widths[2])
            if prog is not None:
                out = prog.run([r.A for r in reqs])
                return out, "burst", algo, plan

        if algo == "tile":
            # tile-elected: the batched driver serves the plan per element
            # on the shared block executor (one plan, one compiled
            # executor).  Forced tile (plan None) goes through the one-shot
            # driver, complement passing through so it raises exactly like
            # a direct call (the planner never elects tile under
            # complement).
            if plan is not None and not rep.complement:
                out = masked_spgemm_batched(
                    [r.A for r in reqs], rep.B, [r.M for r in reqs],
                    semiring=rep.semiring, plan=plan)
            else:
                out = [masked_spgemm(r.A, r.B, r.M, algorithm="tile",
                                     semiring=r.semiring,
                                     complement=r.complement, plan=plan)
                       for r in reqs]
            jax.block_until_ready([r.vals for r in out])
            return out, "tile", "tile", plan

        if len(reqs) == 1:
            res = masked_spgemm(rep.A, rep.B, rep.M,
                                algorithm=forced or "auto",
                                semiring=rep.semiring,
                                complement=rep.complement, plan=plan)
            out = [res]
            route = "single"
        else:
            raw = masked_spgemm_batched(
                [r.A for r in reqs], rep.B, [r.M for r in reqs],
                algorithm=forced or "auto", semiring=rep.semiring,
                complement=rep.complement, plan=plan)
            if rep.complement:
                vals, present = raw
                out = [(vals[i], present[i]) for i in range(len(reqs))]
            else:
                out = raw
            route = "batched"
        if rep.complement:
            jax.block_until_ready([v for v, _ in out])
        else:
            jax.block_until_ready([r.vals for r in out])
        return out, route, algo, plan
