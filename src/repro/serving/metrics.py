"""Serving metrics: per-bucket latency/throughput counters.

The engine records one event per submitted request and one per executed
bucket; ``snapshot()`` renders the counters the benchmark consumes
(``benchmarks/bench_serve.py`` writes them into ``serve_grid.json``).
Everything is wall-clock host time — the quantity a serving SLO sees,
planner + host prep + device execution included.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional, Sequence

#: per-bucket records kept for inspection (ring buffer, oldest dropped)
BUCKET_LOG_CAPACITY = 256

#: per-request latency samples kept for percentile reporting (ring buffer)
LATENCY_RESERVOIR_CAPACITY = 65536

#: snapshot() keys that are pure functions of the request stream and the
#: engine's scheduling decisions — no wall-clock durations.  The replay
#: determinism contract (``serving.trace``) compares exactly these.
DETERMINISTIC_KEYS = ("submitted", "completed", "failed",
                      "result_cache_hits", "buckets_executed",
                      "batched_requests", "mean_batch", "max_batch",
                      "merged_groups")

#: bucket-log keys that are scheduling decisions, not timings — the
#: replayed bucket *schedule* is built from these
SCHEDULE_KEYS = ("size", "algorithm", "route", "merged_from", "label")


class ServeMetrics:
    """Thread-safe counters for one ``QueryEngine``."""

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        with self._lock:
            self.submitted = 0
            self.completed = 0
            self.failed = 0
            self.result_cache_hits = 0
            self.buckets_executed = 0
            self.batched_requests = 0
            self.max_batch_seen = 0
            self.queue_wait_s = 0.0
            self.plan_s = 0.0
            self.exec_s = 0.0
            self.merged_groups = 0
            # delta-path lifecycle counters (NOT in DETERMINISTIC_KEYS:
            # deltas arrive outside the traced request stream, so replays
            # of pre-delta traces must not be held to them)
            self.delta_applied = 0
            self.plans_revalidated = 0
            self.lanes_patched = 0
            self.rows_invalidated = 0
            self._bucket_log: deque = deque(maxlen=BUCKET_LOG_CAPACITY)
            self._latencies: deque = deque(maxlen=LATENCY_RESERVOIR_CAPACITY)
            self._hit_latencies: deque = deque(
                maxlen=LATENCY_RESERVOIR_CAPACITY)

    # -- recording ----------------------------------------------------------

    def record_submit(self, n: int = 1) -> None:
        with self._lock:
            self.submitted += n

    def record_cache_hit(self, latency_s: Optional[float] = None) -> None:
        """One result-cache hit.  Hits complete without touching the
        bucket path, so their (near-zero) latencies land in a dedicated
        reservoir: folding them into the miss reservoir — or dropping
        them, as this method did before — skews p50/p99 under high hit
        rates.  ``snapshot()`` reports hit, miss, and combined
        percentiles separately."""
        with self._lock:
            self.result_cache_hits += 1
            self.completed += 1
            if latency_s is not None:
                self._hit_latencies.append(float(latency_s))

    def record_failure(self, n: int = 1) -> None:
        with self._lock:
            self.failed += n

    def record_delta(self, *, applied: int = 0, revalidated: int = 0,
                     lanes: int = 0, rows: int = 0) -> None:
        """One ``submit_delta`` outcome: ``applied`` operand deltas folded
        in, ``revalidated`` plans kept without a cold re-plan, ``lanes``
        burst lane columns re-emitted by a patch (instead of a program
        rebuild), ``rows`` result-cache row-coverage invalidated."""
        with self._lock:
            self.delta_applied += applied
            self.plans_revalidated += revalidated
            self.lanes_patched += lanes
            self.rows_invalidated += rows

    def record_bucket(self, *, size: int, algorithm: str, route: str,
                      queue_wait_s: float, plan_s: float, exec_s: float,
                      merged_from: int = 1,
                      label: Optional[str] = None,
                      latencies_s: Optional[Sequence[float]] = None) -> None:
        """One executed bucket: ``size`` requests served by one plan.

        ``queue_wait_s`` is the oldest member's submit-to-execute wait;
        ``plan_s`` covers planning + bucket bookkeeping, ``exec_s`` the
        product itself (host prep + device, blocked until ready).
        ``latencies_s`` carries each member's submit-to-served latency
        (queue wait + execution) for the percentile reservoir.
        """
        with self._lock:
            if latencies_s is not None:
                self._latencies.extend(float(x) for x in latencies_s)
            self.buckets_executed += 1
            self.batched_requests += size
            self.completed += size
            self.max_batch_seen = max(self.max_batch_seen, size)
            self.queue_wait_s += queue_wait_s
            self.plan_s += plan_s
            self.exec_s += exec_s
            if merged_from > 1:
                self.merged_groups += merged_from - 1
            self._bucket_log.append({
                "size": size, "algorithm": algorithm, "route": route,
                "queue_wait_s": queue_wait_s, "plan_s": plan_s,
                "exec_s": exec_s, "merged_from": merged_from,
                "label": label})

    # -- reading ------------------------------------------------------------

    @staticmethod
    def _percentile(samples: List[float], q: float) -> float:
        """Nearest-rank percentile (no numpy import on the serve path)."""
        if not samples:
            return 0.0
        ordered = sorted(samples)
        idx = min(len(ordered) - 1, max(0, int(round(
            q / 100.0 * (len(ordered) - 1)))))
        return ordered[idx]

    def snapshot(self) -> Dict:
        with self._lock:
            miss = list(self._latencies)
            hit = list(self._hit_latencies)
            lat = miss + hit
            done = self.buckets_executed
            return {
                # combined = misses + recorded hits; the historic miss-only
                # view stays available as miss_lat_* (hits used to be
                # silently absent, inflating p50/p99 under high hit rates)
                "lat_count": len(lat),
                "lat_p50_s": self._percentile(lat, 50.0),
                "lat_p99_s": self._percentile(lat, 99.0),
                "miss_lat_count": len(miss),
                "miss_lat_p50_s": self._percentile(miss, 50.0),
                "miss_lat_p99_s": self._percentile(miss, 99.0),
                "hit_lat_count": len(hit),
                "hit_lat_p50_s": self._percentile(hit, 50.0),
                "hit_lat_p99_s": self._percentile(hit, 99.0),
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "result_cache_hits": self.result_cache_hits,
                "buckets_executed": done,
                "batched_requests": self.batched_requests,
                "mean_batch": (self.batched_requests / done) if done else 0.0,
                "max_batch": self.max_batch_seen,
                "merged_groups": self.merged_groups,
                "delta_applied": self.delta_applied,
                "plans_revalidated": self.plans_revalidated,
                "lanes_patched": self.lanes_patched,
                "rows_invalidated": self.rows_invalidated,
                "queue_wait_s": self.queue_wait_s,
                "plan_s": self.plan_s,
                "exec_s": self.exec_s,
                "mean_bucket_exec_s": (self.exec_s / done) if done else 0.0,
            }

    def hit_rate(self) -> float:
        """Lifetime result-cache hit rate over submissions — the value
        the ``serve.cache_hit_rate`` counter track carries (windowed
        rates live in ``repro.obs.health``)."""
        with self._lock:
            if not self.submitted:
                return 0.0
            return self.result_cache_hits / self.submitted

    def error_rate(self) -> float:
        """Lifetime failed fraction of finished requests."""
        with self._lock:
            total = self.completed + self.failed
            return (self.failed / total) if total else 0.0

    def bucket_log(self):
        with self._lock:
            return list(self._bucket_log)

    def deterministic_snapshot(self) -> Dict:
        """The scheduling-only projection of :meth:`snapshot`: counters that
        are pure functions of the request stream + flush decisions, with
        every wall-clock duration dropped.  Two replays of one trace must
        produce EQUAL deterministic snapshots (``serving.trace``)."""
        snap = self.snapshot()
        return {k: snap[k] for k in DETERMINISTIC_KEYS}

    def bucket_schedule(self) -> List[Dict]:
        """The bucket log's scheduling-only projection (sizes, algorithms,
        routes, merge arity — no timings), in execution order."""
        with self._lock:
            return [{k: row[k] for k in SCHEDULE_KEYS}
                    for row in self._bucket_log]
