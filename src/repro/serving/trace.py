"""Deterministic traffic traces: capture a served request stream, replay it
bit-identically.

The paper's regimes (density, mask structure, cache behavior) vary
per-request in a serving deployment, so the engine's throughput knobs must
be measured against *recorded traffic*, not guessed.  This module provides
the record half and the replay half of that loop:

* :class:`TraceRecorder` — hooked into ``QueryEngine.submit`` (the engine's
  ``recorder=`` parameter): logs each request's operand specs, content
  fingerprints, arrival offset (engine-clock time), and request options to
  a versioned JSONL schema (:data:`SCHEMA_VERSION`).
* :func:`replay_trace` — re-runs a trace against a fresh engine under a
  :class:`~repro.serving.clock.VirtualClock`: submissions happen at the
  recorded offsets and the clock is stepped through every ``max_wait_ms``
  flush deadline, so the bucket sequence is a pure function of the trace
  and the knobs.  Two replays of one trace produce identical bucket
  schedules, identical deterministic counters, and byte-exact results —
  in sync AND async mode (the sync path replays the async worker's flush
  policy via ``QueryEngine.flush_due``).

Operands are stored either as *generator specs* (the seeded synthetic
families from ``repro.core.formats`` — tiny traces, exact regeneration) or
*inline* (base64 of the raw CSR arrays — byte-exact for arbitrary live
operands).  Every event also records a content-fingerprint digest per
operand; replay validates regenerated operands against them, so a drifted
generator can never silently replay different traffic.

The committed golden trace lives under ``results/traces/`` and anchors the
CI perf-regression gate (``benchmarks/bench_replay.py``) and the knob
autotuner (``repro.tuning.autotune``).
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.formats import CSR, block_sparse, csr_from_dense, \
    erdos_renyi, er_mask
from repro.core.semiring import PLUS_TIMES, REGISTRY

from .cache import content_fingerprint
from .clock import VirtualClock

#: trace schema version — bump on incompatible event/field changes; the
#: loader rejects any other version outright (a misread trace would replay
#: the wrong traffic and invalidate every measurement made against it)
SCHEMA_VERSION = 1
TRACE_KIND = "repro-serve-trace"

#: registry directory for committed traces; override with $REPRO_TRACE_DIR
TRACE_DIR_ENV = "REPRO_TRACE_DIR"
DEFAULT_TRACE_DIR = os.path.join("results", "traces")
GOLDEN_TRACE_NAME = "golden_v1.jsonl"

_DEADLINE_NUDGE = 1e-9   # float-safe step past a flush deadline


class TraceError(ValueError):
    """A trace failed validation, (de)serialization, or replay checks."""


# ---------------------------------------------------------------------------
# Operand specs: how a trace names its matrices
# ---------------------------------------------------------------------------


def spec_er(n: int, avg_degree: float, seed: int) -> Dict:
    return {"kind": "er", "n": int(n), "avg_degree": float(avg_degree),
            "seed": int(seed)}


def spec_er_mask(n: int, d: float, seed: int) -> Dict:
    return {"kind": "er_mask", "n": int(n), "d": float(d), "seed": int(seed)}


def spec_block(n: int, bs: int, tile_density: float, within_density: float,
               seed: int, mask: bool = False) -> Dict:
    return {"kind": "block", "n": int(n), "bs": int(bs),
            "tile_density": float(tile_density),
            "within_density": float(within_density), "seed": int(seed),
            "mask": bool(mask)}


def spec_revalue(base: Dict, seed: int) -> Dict:
    """Same structure as ``base``, fresh uniform[0.5, 1.5) float32 values —
    the 'queries against a shared pattern' workload shape."""
    return {"kind": "revalue", "base": dict(base), "seed": int(seed)}


def _encode_array(a: np.ndarray) -> Dict:
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "b64": base64.b64encode(a.tobytes()).decode("ascii")}


def _decode_array(enc: Dict) -> np.ndarray:
    raw = base64.b64decode(enc["b64"].encode("ascii"))
    return np.frombuffer(raw, dtype=np.dtype(enc["dtype"])).reshape(
        [int(s) for s in enc["shape"]]).copy()


def spec_inline(x: CSR) -> Dict:
    """Byte-exact embedding of an arbitrary CSR operand (live capture of
    traffic no generator spec describes)."""
    return {"kind": "inline", "shape": list(x.shape),
            "indptr": _encode_array(x.indptr),
            "indices": _encode_array(x.indices),
            "data": _encode_array(x.data)}


def materialize(spec: Dict, _cache: Optional[Dict] = None) -> CSR:
    """Rebuild the operand a spec describes (deterministic: seeded
    generators or exact inline bytes).  ``_cache`` (canonical-spec -> CSR)
    lets a replay share one object per distinct spec, the way live traffic
    shares operand objects."""
    key = None
    if _cache is not None:
        key = json.dumps(spec, sort_keys=True, separators=(",", ":"))
        hit = _cache.get(key)
        if hit is not None:
            return hit
    kind = spec.get("kind")
    if kind == "er":
        out = erdos_renyi(spec["n"], spec["avg_degree"], seed=spec["seed"])
    elif kind == "er_mask":
        out = er_mask(spec["n"], spec["d"], spec["seed"])
    elif kind == "block":
        out = csr_from_dense(block_sparse(
            spec["n"], spec["bs"], spec["tile_density"],
            spec["within_density"], seed=spec["seed"],
            mask=spec.get("mask", False)))
    elif kind == "revalue":
        base = materialize(spec["base"], _cache)
        rng = np.random.default_rng(spec["seed"])
        out = CSR(base.indptr, base.indices,
                  rng.uniform(0.5, 1.5, base.nnz).astype(np.float32),
                  base.shape)
    elif kind == "inline":
        out = CSR(_decode_array(spec["indptr"]),
                  _decode_array(spec["indices"]),
                  _decode_array(spec["data"]),
                  tuple(int(s) for s in spec["shape"]))
    else:
        raise TraceError(f"unknown operand spec kind {kind!r}")
    if _cache is not None:
        _cache[key] = out
    return out


def fingerprint_digest(x: CSR) -> int:
    """One integer summarizing an operand's content fingerprint (structure
    CRC + value CRC); replay compares these against the recorded values."""
    return zlib.crc32(repr(content_fingerprint(x)).encode())


# ---------------------------------------------------------------------------
# Trace container + JSONL (de)serialization
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Trace:
    """A recorded request stream: header metadata + submit events ordered
    by arrival offset (seconds from the first submit)."""

    name: str
    events: List[Dict]
    meta: Dict = dataclasses.field(default_factory=dict)

    @property
    def n_requests(self) -> int:
        return len(self.events)

    @property
    def duration_s(self) -> float:
        return float(self.events[-1]["t"]) if self.events else 0.0

    def validate(self) -> "Trace":
        last_t = 0.0
        for i, ev in enumerate(self.events):
            if ev.get("op") != "submit":
                raise TraceError(f"event {i}: unknown op {ev.get('op')!r}")
            t = float(ev.get("t", -1.0))
            if t < last_t - 1e-12:
                raise TraceError(f"event {i}: arrival offsets must be "
                                 f"non-decreasing ({t} after {last_t})")
            last_t = max(last_t, t)
            for op in ("A", "B", "M"):
                if not isinstance(ev.get(op), dict):
                    raise TraceError(f"event {i}: missing operand {op}")
            if ev.get("semiring") not in REGISTRY:
                raise TraceError(f"event {i}: unknown semiring "
                                 f"{ev.get('semiring')!r}")
        return self

    # -- JSONL --------------------------------------------------------------

    def dumps(self) -> str:
        header = {"schema": SCHEMA_VERSION, "kind": TRACE_KIND,
                  "name": self.name, "requests": self.n_requests,
                  "meta": self.meta}
        lines = [json.dumps(header, sort_keys=True)]
        lines += [json.dumps(ev, sort_keys=True) for ev in self.events]
        return "\n".join(lines) + "\n"

    @classmethod
    def loads(cls, text: str) -> "Trace":
        lines = [ln for ln in text.splitlines() if ln.strip()]
        if not lines:
            raise TraceError("empty trace file")
        try:
            header = json.loads(lines[0])
            events = [json.loads(ln) for ln in lines[1:]]
        except json.JSONDecodeError as e:
            raise TraceError(f"not valid JSONL: {e}") from e
        if not isinstance(header, dict) or header.get("kind") != TRACE_KIND:
            raise TraceError(f"not a {TRACE_KIND} file "
                             f"(kind={header.get('kind')!r})")
        schema = header.get("schema")
        if schema != SCHEMA_VERSION:
            raise TraceError(f"unsupported trace schema {schema!r} "
                             f"(this build reads {SCHEMA_VERSION})")
        n = header.get("requests")
        if n is not None and int(n) != len(events):
            raise TraceError(f"header declares {n} requests, file holds "
                             f"{len(events)} (truncated capture?)")
        return cls(name=str(header.get("name", "trace")), events=events,
                   meta=dict(header.get("meta", {}))).validate()

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.dumps())
        return path

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.loads(f.read())

    # -- materialization ----------------------------------------------------

    def materialized(self, check: bool = True
                     ) -> List[Tuple[float, CSR, CSR, CSR, Dict]]:
        """Rebuild every request as ``(t, A, B, M, submit_kwargs)``.

        With ``check`` (default), each regenerated operand's fingerprint
        digest must equal the recorded one — a generator/seed drift fails
        loudly instead of replaying different traffic.
        """
        cache: Dict = {}
        out = []
        for i, ev in enumerate(self.events):
            ops = {name: materialize(ev[name], cache)
                   for name in ("A", "B", "M")}
            if check and "fp" in ev:
                for name, op in ops.items():
                    want = int(ev["fp"][name])
                    got = fingerprint_digest(op)
                    if got != want:
                        raise TraceError(
                            f"event {i}: operand {name} fingerprint "
                            f"{got:#010x} != recorded {want:#010x} "
                            f"(generator drift? corrupted trace?)")
            kwargs = dict(
                semiring=REGISTRY[ev["semiring"]],
                complement=bool(ev.get("complement", False)),
                algorithm=ev.get("algorithm"))
            out.append((float(ev["t"]), ops["A"], ops["B"], ops["M"],
                        kwargs))
        return out


def trace_dir() -> str:
    """Trace registry resolution, mirroring ``tuning.profile.profile_dir``:
    $REPRO_TRACE_DIR, else ``results/traces`` under the cwd if present,
    else the checkout's committed directory."""
    env = os.environ.get(TRACE_DIR_ENV)
    if env:
        return env
    if os.path.isdir(DEFAULT_TRACE_DIR):
        return DEFAULT_TRACE_DIR
    root = os.path.abspath(__file__)
    for _ in range(4):                  # serving -> repro -> src -> repo
        root = os.path.dirname(root)
    return os.path.join(root, "results", "traces")


def golden_trace_path() -> str:
    return os.path.join(trace_dir(), GOLDEN_TRACE_NAME)


# ---------------------------------------------------------------------------
# Capture
# ---------------------------------------------------------------------------


class RotatingTraceSink:
    """Streaming JSONL sink for long captures (logrotate discipline).

    Events append to ``path``; when a segment would exceed ``max_bytes``
    the files shift ``path`` → ``path.1`` → ... → ``path.N`` (``N =
    rotate``; the oldest segment falls off) and a fresh segment opens.
    EVERY segment is a standalone loadable trace: it begins with a full
    schema header that simply omits the request count (a stream cannot
    know it; ``Trace.loads`` only cross-checks the count when present).

    ``sample_rate`` keeps that fraction of events, decided by a rng
    seeded with ``seed`` — deterministic per capture, never the wall
    clock, so two captures of one virtual-clock replay sample the SAME
    events.  An event larger than ``max_bytes`` on its own still writes
    (one oversized segment beats silent data loss).
    """

    def __init__(self, path: str, *, max_bytes: int = 1 << 20,
                 rotate: int = 4, sample_rate: float = 1.0, seed: int = 0,
                 name: str = "capture", meta: Optional[Dict] = None,
                 kind: str = TRACE_KIND):
        if max_bytes < 1:
            raise ValueError(f"max_bytes must be >= 1, got {max_bytes}")
        if rotate < 1:
            raise ValueError(f"rotate must be >= 1, got {rotate}")
        if not 0.0 <= sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in [0, 1], "
                             f"got {sample_rate}")
        self.path = path
        self.max_bytes = int(max_bytes)
        self.rotate = int(rotate)
        self.sample_rate = float(sample_rate)
        self.name = name
        self.meta = dict(meta or {})
        # header kind: request captures keep TRACE_KIND; repro.obs span
        # captures stamp their own so loaders can't confuse the families
        self.kind = str(kind)
        self.written = 0        # events persisted (all segments)
        self.sampled_out = 0    # events dropped by the sampler
        self._rng = np.random.default_rng(seed)
        self._f = None
        self._size = 0
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    # -- segment plumbing ---------------------------------------------------

    def _header(self) -> str:
        # NO "requests" field: the segment is still streaming
        return json.dumps({"schema": SCHEMA_VERSION, "kind": self.kind,
                           "name": self.name, "meta": self.meta},
                          sort_keys=True) + "\n"

    def _open(self) -> None:
        self._f = open(self.path, "w")
        head = self._header()
        self._f.write(head)
        self._size = len(head)

    def _shift(self) -> None:
        self._f.close()
        self._f = None
        for i in range(self.rotate, 0, -1):
            src = self.path if i == 1 else f"{self.path}.{i - 1}"
            if os.path.exists(src):
                os.replace(src, f"{self.path}.{i}")

    # -- public API -----------------------------------------------------------

    def write(self, event: Dict) -> bool:
        """Persist one submit event; returns False when the sampler
        dropped it."""
        if (self.sample_rate < 1.0
                and float(self._rng.random()) >= self.sample_rate):
            self.sampled_out += 1
            return False
        if self._f is None:
            self._open()
        line = json.dumps(event, sort_keys=True) + "\n"
        if (self._size + len(line) > self.max_bytes
                and self._size > len(self._header())):
            self._shift()
            self._open()
        self._f.write(line)
        self._size += len(line)
        self.written += 1
        return True

    def segments(self) -> List[str]:
        """Existing segment paths, oldest first (``path.N`` ... ``path``)."""
        out = [f"{self.path}.{i}" for i in range(self.rotate, 0, -1)]
        out.append(self.path)
        return [p for p in out if os.path.exists(p)]

    def flush(self) -> None:
        if self._f is not None:
            self._f.flush()

    def close(self) -> None:
        if self._f is not None:
            self._f.flush()
            self._f.close()
            self._f = None

    def __enter__(self) -> "RotatingTraceSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def load_rotated(path: str, rotate: int = 64) -> Trace:
    """Load a rotated capture back as ONE trace: every surviving segment
    (``path.N`` oldest ... ``path`` newest), events concatenated in capture
    order.  Each segment is validated as a standalone trace first, so a
    corrupt rotation fails loudly with the segment named."""
    seg_paths = [f"{path}.{i}" for i in range(rotate, 0, -1)]
    seg_paths.append(path)
    seg_paths = [p for p in seg_paths if os.path.exists(p)]
    if not seg_paths:
        raise TraceError(f"no trace segments at {path!r}")
    segments = []
    for p in seg_paths:
        try:
            segments.append(Trace.load(p))
        except TraceError as e:
            raise TraceError(f"segment {p!r}: {e}") from e
    events = [ev for seg in segments for ev in seg.events]
    return Trace(name=segments[-1].name, events=events,
                 meta=dict(segments[-1].meta)).validate()


class TraceRecorder:
    """Observes every ``QueryEngine.submit`` (engine ``recorder=`` hook).

    Operands registered via :meth:`register_operand` serialize as their
    generator spec (tiny traces); anything else CSR-shaped embeds inline,
    byte-exact.  Arrival offsets are engine-clock seconds from the first
    submit.  ``mesh``-carrying and non-CSR requests are not representable
    in schema v1 and raise — a trace that silently dropped them would
    replay lighter traffic than it recorded.

    ``sink`` (a :class:`RotatingTraceSink`) streams each event to disk as
    it arrives — the long-capture mode, where the in-memory event list
    would grow without bound; pass ``keep_events=False`` alongside it to
    record with O(1) memory.  The sink's ``sample_rate`` applies to the
    sink only; the in-memory list (when kept) holds every event.
    """

    def __init__(self, name: str = "capture", meta: Optional[Dict] = None,
                 *, sink: Optional[RotatingTraceSink] = None,
                 keep_events: bool = True):
        self.name = name
        self.meta = dict(meta or {})
        self.sink = sink
        self.keep_events = keep_events
        self.events: List[Dict] = []
        self._t0: Optional[float] = None
        #: id(obj) -> (spec, obj); the object reference keeps the id valid
        self._specs: Dict[int, Tuple[Dict, object]] = {}

    def register_operand(self, obj: CSR, spec: Dict) -> CSR:
        """Declare that ``obj`` regenerates from ``spec`` (returns ``obj``
        for chaining)."""
        self._specs[id(obj)] = (dict(spec), obj)
        return obj

    def _spec_of(self, x) -> Dict:
        if not isinstance(x, CSR):
            raise TraceError(f"schema v1 records host-CSR operands only, "
                             f"got {type(x).__name__}")
        hit = self._specs.get(id(x))
        return dict(hit[0]) if hit is not None else spec_inline(x)

    def on_submit(self, A, B, M, *, t: float, semiring=PLUS_TIMES,
                  complement: bool = False,
                  algorithm: Optional[str] = None, mesh=None,
                  axis: str = "data") -> None:
        if mesh is not None:
            raise TraceError("mesh-carrying requests are not recordable "
                             "(trace schema v1 is single-process)")
        if self._t0 is None:
            self._t0 = t
        event = {
            "t": float(t - self._t0), "op": "submit",
            "A": self._spec_of(A), "B": self._spec_of(B),
            "M": self._spec_of(M),
            "semiring": semiring.name, "complement": bool(complement),
            "algorithm": algorithm,
            "fp": {"A": fingerprint_digest(A), "B": fingerprint_digest(B),
                   "M": fingerprint_digest(M)},
        }
        if self.keep_events:
            self.events.append(event)
        if self.sink is not None:
            self.sink.write(event)

    def trace(self) -> Trace:
        return Trace(name=self.name, events=list(self.events),
                     meta=dict(self.meta)).validate()


# ---------------------------------------------------------------------------
# Synthetic workloads (the golden trace, CI throwaway traces)
# ---------------------------------------------------------------------------


def synthesize_trace(name: str = "synthetic", *, n: int = 96,
                     n_structs: int = 3, queries: int = 48,
                     mean_gap_ms: float = 0.5, block_struct: bool = True,
                     repeat_fraction: float = 0.2, seed: int = 0) -> Trace:
    """A deterministic mixed-structure request stream, spec-based (no
    inline payloads): ER row-kernel regimes + an optional block-dense
    structure the tile route wins, fresh A values per query, a
    ``repeat_fraction`` of exact repeats (result-cache traffic), and
    seeded exponential inter-arrival gaps.
    """
    rng = np.random.default_rng(seed)
    structs: List[Tuple[Dict, Dict, Dict]] = []
    for s in range(n_structs):
        structs.append((spec_er(n, 2 + 2 * s, seed=100 + s),
                        spec_er(n, 2 + s, seed=200 + s),
                        spec_er_mask(n, max(4, n // 12), seed=300 + s)))
    if block_struct:
        bn = max(32, (n // 2) // 8 * 8)
        structs.append((spec_block(bn, 8, 0.5, 0.6, seed=400),
                        spec_block(bn, 8, 0.5, 0.6, seed=401),
                        spec_block(bn, 8, 0.6, 0.5, seed=402, mask=True)))

    cache: Dict = {}
    events: List[Dict] = []
    t = 0.0
    recent: List[Tuple[Dict, Dict, Dict]] = []
    for q in range(queries):
        if recent and rng.random() < repeat_fraction:
            sa, sb, sm = recent[int(rng.integers(len(recent)))]
        else:
            base_a, sb, sm = structs[int(rng.integers(len(structs)))]
            sa = spec_revalue(base_a, seed=1000 + q)
            recent.append((sa, sb, sm))
            if len(recent) > 8:
                recent.pop(0)
        A, B, M = (materialize(sa, cache), materialize(sb, cache),
                   materialize(sm, cache))
        events.append({
            "t": round(t, 9), "op": "submit", "A": sa, "B": sb, "M": sm,
            "semiring": "plus_times", "complement": False,
            "algorithm": None,
            "fp": {"A": fingerprint_digest(A), "B": fingerprint_digest(B),
                   "M": fingerprint_digest(M)},
        })
        t += float(rng.exponential(mean_gap_ms / 1e3))
    return Trace(name=name, events=events,
                 meta={"generator": "synthesize_trace", "n": n,
                       "n_structs": n_structs, "queries": queries,
                       "mean_gap_ms": mean_gap_ms, "seed": seed,
                       "block_struct": block_struct,
                       "repeat_fraction": repeat_fraction}).validate()


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------


def _result_crc(res) -> int:
    """CRC of a served result's bytes (vals/present/mask_cols, or the
    complement's (vals, present) pair) — the byte-exactness witness."""
    if isinstance(res, tuple):
        parts = [np.asarray(p) for p in res]
    else:
        parts = [np.asarray(res.vals), np.asarray(res.present),
                 np.asarray(res.mask_cols)]
    crc = 0
    for p in parts:
        p = np.ascontiguousarray(p)
        crc = zlib.crc32(str((p.dtype, p.shape)).encode(), crc)
        crc = zlib.crc32(p.tobytes(), crc)
    return crc


@dataclasses.dataclass
class ReplayReport:
    """One deterministic replay's outcome.

    ``digest`` covers the bucket schedule, the deterministic counters, and
    every served result's bytes — two replays of one trace must produce
    EQUAL digests.  ``wall_s``/``qps``/``lat_*`` are real measurements
    (the autotuner's ranking signal) and are deliberately NOT part of the
    digest.
    """

    trace: str
    mode: str
    n_requests: int
    digest: str
    schedule: List[Dict]
    counters: Dict
    snapshot: Dict
    wall_s: float
    qps: float
    lat_p50_s: float
    lat_p99_s: float
    result_crcs: List[int]
    results: Optional[List] = None


def _advance(clock: VirtualClock, engine, target: float) -> None:
    """Advance virtual time to ``target`` and let the engine act on it."""
    clock.advance_to(max(target, clock.now()))
    engine.quiesce()


def replay_trace(trace: Trace, *, knobs: Optional[Dict] = None,
                 async_mode: bool = False, check: bool = True,
                 keep_results: bool = False,
                 result_timeout_s: float = 120.0) -> ReplayReport:
    """Replay ``trace`` against a fresh engine under a virtual clock.

    ``knobs`` are ``QueryEngine`` constructor keywords (``max_batch``,
    ``max_wait_ms``, ``pad_factor``, ``queue_cap``, ...).  The replay
    submits each request at its recorded offset and steps the clock
    through every flush deadline in between, quiescing after each step —
    in async mode the worker thread acts on exactly the same virtual
    schedule the sync path executes inline via ``flush_due``, so the
    bucket sequence is identical across modes and across repeats.
    """
    from .engine import QueryEngine        # local: engine imports .clock

    events = trace.materialized(check=check)
    clock = VirtualClock()
    engine = QueryEngine(async_mode=async_mode, clock=clock,
                         **dict(knobs or {}))
    tickets = []
    t_real = time.perf_counter()  # lint: clock-ok(replay wall duration)
    try:
        for (t, A, B, M, kwargs) in events:
            # flush every deadline that falls before this arrival
            while True:
                d = engine.next_flush_deadline()
                if d is None or d > t:
                    break
                _advance(clock, engine, d + _DEADLINE_NUDGE)
            clock.advance_to(max(t, clock.now()))
            tickets.append(engine.submit(A, B, M, **kwargs))
            # a submit can fill a bucket (or, at max_wait_ms=0, make one
            # due immediately): drain before the trace proceeds, so bucket
            # composition never depends on worker timing
            engine.quiesce()
        # tail: step through the remaining deadlines
        while True:
            d = engine.next_flush_deadline()
            if d is None:
                break
            _advance(clock, engine, d + _DEADLINE_NUDGE)
        results = [tk.result(timeout=result_timeout_s) for tk in tickets]
        wall_s = time.perf_counter() - t_real  # lint: clock-ok(wall duration)
        snapshot = engine.metrics.snapshot()
        schedule = engine.metrics.bucket_schedule()
        counters = engine.metrics.deterministic_snapshot()
    finally:
        engine.close()

    crcs = [_result_crc(r) for r in results]
    digest_payload = json.dumps(
        {"schedule": schedule, "counters": counters, "results": crcs},
        sort_keys=True, separators=(",", ":"))
    digest = format(zlib.crc32(digest_payload.encode()), "08x")
    return ReplayReport(
        trace=trace.name, mode="async" if async_mode else "sync",
        n_requests=len(events), digest=digest, schedule=schedule,
        counters=counters, snapshot=snapshot, wall_s=wall_s,
        qps=len(events) / max(wall_s, 1e-12),
        lat_p50_s=snapshot["lat_p50_s"], lat_p99_s=snapshot["lat_p99_s"],
        result_crcs=crcs, results=results if keep_results else None)
