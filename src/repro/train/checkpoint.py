"""Fault-tolerant checkpointing: atomic, async-capable, topology-free.

Layout:  <dir>/step_<k>/arr_<i>.npy + tree.json ; <dir>/LATEST (text).

Guarantees relied on by the restart/elastic story:
* **atomic publish** — the step directory is fully written under a tmp name
  then ``os.replace``-d; LATEST is written via tmp+replace too, so a crash
  at any instant leaves a consistent previous checkpoint;
* **topology independence** — arrays are saved as full (unsharded) numpy
  values, so a 4-device restart can load a checkpoint written by 512
  devices (resharding happens at device_put against the new mesh);
* **async** — ``save(...)`` can hand off to a writer thread; ``wait()``
  joins before the next save (at most one in flight).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------

    def save(self, step: int, tree: Any, *, asynchronous: bool = False):
        leaves, treedef = _flatten(tree)
        host = [np.asarray(l) for l in leaves]    # device->host before fork
        treedef_str = str(treedef)
        if asynchronous:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, treedef_str))
            self._thread.start()
        else:
            self._write(step, host, treedef_str)

    def _write(self, step: int, host_leaves, treedef_str: str):
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for i, a in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
        meta = {"step": step, "n": len(host_leaves),
                "treedef": treedef_str}
        with open(os.path.join(tmp, "tree.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        # atomic LATEST pointer
        lt = os.path.join(self.dir, "LATEST.tmp")
        with open(lt, "w") as f:
            f.write(str(step))
        os.replace(lt, os.path.join(self.dir, "LATEST"))
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---------------- restore ----------------

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            s = int(f.read().strip())
        return s if s in self.all_steps() else (
            self.all_steps()[-1] if self.all_steps() else None)

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Load step's arrays into the structure of ``like``; device_put
        against ``shardings`` when given (topology-independent reshard)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "tree.json")) as f:
            meta = json.load(f)
        leaves_like, treedef = _flatten(like)
        assert meta["n"] == len(leaves_like), (meta["n"], len(leaves_like))
        arrays = [np.load(os.path.join(d, f"arr_{i}.npy"))
                  for i in range(meta["n"])]
        for a, l in zip(arrays, leaves_like):
            assert a.shape == tuple(l.shape), (a.shape, l.shape)
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
            arrays = [jax.device_put(a, s)
                      for a, s in zip(arrays, sh_leaves)]
        else:
            arrays = [jax.device_put(a) for a in arrays]
        return jax.tree_util.tree_unflatten(treedef, arrays)
