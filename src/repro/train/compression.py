"""Error-feedback int8 gradient compression for the DP all-reduce.

GSPMD's implicit gradient reduction cannot be intercepted, so the
compressed path runs the DP reduction *explicitly* under shard_map:

  1. pmax of the local |grad+error| maxima -> one shared scale per tensor
     (a scalar all-reduce, negligible traffic);
  2. quantize (grad + error_carry) to int8 with the shared scale;
  3. psum the int8 payload (4x less ICI traffic than fp32);
  4. dequantize; keep the local quantization residual as next step's error
     feedback — the standard EF-SGD construction (unbiased in the limit).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp


def quantize_int8(x, scale):
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def allreduce_compressed(grads, error, axis_names: Sequence[str]):
    """shard_map-local EF-int8 mean-all-reduce of a gradient pytree.

    Returns (mean grads fp32, new error carry).  Exact shared-scale
    quantization: every shard uses the same (pmax-agreed) scale, so the
    summed int payload dequantizes exactly to sum(q_i)*scale.
    """
    from repro.compat import axis_size
    n = 1
    for a in axis_names:
        n = n * axis_size(a)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(x))
        for a in axis_names:
            amax = jax.lax.pmax(amax, a)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = quantize_int8(x, scale)
        new_e = x - q.astype(jnp.float32) * scale
        tot = q.astype(jnp.int32)
        for a in axis_names:
            tot = jax.lax.psum(tot, a)
        return tot.astype(jnp.float32) * scale / n, new_e

    out = jax.tree.map(one, grads, error)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), pick(1)


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
