"""Generic train step: microbatch accumulation, GSPMD sharding, donation.

``TrainState`` = params (fp32 masters) + AdamW moments + step.  The step is
a single jit with donated state; gradient accumulation is a scan over
microbatches (keeps activation memory at 1/k while the paper-technique
attention keeps flops at the mask-admitted tiles).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.common import (make_param_specs, pscan,
                                 shardings_for)
from repro.optim.adamw import AdamW, OptState, zero1_specs


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_state(cfg: ModelConfig, key, optimizer: AdamW) -> TrainState:
    params = T.init_params(cfg, key)
    return TrainState(params, optimizer.init(params))


def state_specs(cfg: ModelConfig, state_shapes: TrainState, *,
                zero1: bool = True):
    """PartitionSpec pytree for a TrainState (ZeRO-1 on the moments)."""
    pspecs = make_param_specs(state_shapes.params)
    mspecs = zero1_specs(state_shapes.params, pspecs) if zero1 else pspecs
    return TrainState(pspecs,
                      OptState(P(), mspecs, mspecs))


def batch_specs(batch_shapes) -> Any:
    def one(path, leaf):
        return P(("pod", "data"), *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def make_train_step(cfg: ModelConfig, optimizer: AdamW, *,
                    microbatches: int = 1, aux_weight: float = 0.0):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_of(params, batch):
        return T.loss_fn(params, cfg, batch)

    def train_step(state: TrainState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_of)(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches,
                                     x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc(carry, b):
                tot, g = carry
                li, gi = jax.value_and_grad(loss_of)(state.params, b)
                return (tot + li, jax.tree.map(jnp.add, g, gi)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                state.params)
            (loss, grads), _ = pscan(acc, (0.0, zero), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        params, opt, om = optimizer.update(grads, state.opt, state.params)
        metrics = {"loss": loss, **om}
        return TrainState(params, opt), metrics

    return train_step


def jit_train_step(cfg: ModelConfig, optimizer: AdamW, mesh: Mesh,
                   state_shapes: TrainState, batch_shapes, *,
                   microbatches: int = 1, zero1: bool = True):
    """AOT-jitted train step with explicit in/out shardings + donation."""
    sspec = state_specs(cfg, state_shapes, zero1=zero1)
    bspec = batch_specs(batch_shapes)
    ssh = shardings_for(mesh, sspec, state_shapes)
    bsh = shardings_for(mesh, bspec, batch_shapes)
    step = make_train_step(cfg, optimizer, microbatches=microbatches)
    return jax.jit(
        step,
        in_shardings=(ssh, bsh),
        out_shardings=(ssh, None),
        donate_argnums=(0,),
    )
