"""``python -m repro.tune``: fit this backend's planner calibration
profile (probe -> least-squares fit -> registry).  See
``repro/tuning/cli.py`` for the flags and ``repro/tuning/__init__.py``
for the subsystem overview."""
from repro.tuning.cli import main

if __name__ == "__main__":
    raise SystemExit(main())
