"""Backend calibration subsystem: fit the planner's cost model, don't
hand-tune it.

    python -m repro.tune --smoke            # fit a quick profile, register it
    python -m repro.tune --only row,tile    # re-fit selected families
    python -m repro.tune --out my.json      # fit without touching the registry

The pipeline: :mod:`repro.tuning.probes` times the row kernels, the BCSR
tile route, and the distributed row/ring routes on small synthetic grids
(the same generators the benchmarks use, sized for minutes); :mod:`repro.
tuning.fit` solves the existing cost-hook functional forms for their
constants by weighted non-negative least squares with a prior toward the
shipped values; the result is a :class:`~repro.tuning.profile.
CalibrationProfile` registered under ``results/profiles/`` by backend
signature and installed with :func:`activate` (or the ``REPRO_TUNE_
PROFILE`` env var for child processes).

This ``__init__`` must stay import-light: ``repro.core.planner`` imports
``repro.tuning.profile`` at module top, which executes this file first —
so probes/fit/cli (which import the core) load lazily via __getattr__.
"""
from __future__ import annotations

from .profile import (BUILTIN_VERSION, CalibrationProfile, ProfileError,
                      activate, activate_from_env, active_profile,
                      active_version, backend_signature, lookup,
                      profile_dir, profile_key, profile_path, register,
                      snapshot)

__all__ = [
    "BUILTIN_VERSION", "CalibrationProfile", "ProfileError", "activate",
    "activate_from_env", "active_profile", "active_version",
    "backend_signature", "lookup", "profile_dir", "profile_key",
    "profile_path", "register", "snapshot",
    # lazy submodules
    "probes", "fit", "cli", "autotune",
]

_LAZY_SUBMODULES = ("probes", "fit", "cli", "autotune")


def __getattr__(name):
    if name in _LAZY_SUBMODULES:
        import importlib
        return importlib.import_module(f"{__name__}.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
