"""Closed-loop serving-knob autotuning over recorded traffic.

PR 4 fits the planner's cost-model *constants*; this module closes the
remaining loop: the ``QueryEngine`` throughput knobs (``max_batch``,
``max_wait_ms``, ``pad_factor``, ``queue_cap``) are searched against a
deterministically replayed traffic trace (``repro.serving.trace``) instead
of being hand-picked.  The search is a successive-halving grid: every
config replays the trace (virtual-clock arrivals, real execution), configs
are ranked by replayed throughput with p99 latency as the tie-break, and
survivors re-replay with more timing iterations until one winner remains.

The winner is written next to the PR 4 calibration profiles under
``results/profiles/`` with the same backend-signature keying
(``serving_<platform>_<device>_<count>.json``, committed reference fallback
``serving_default.json``) and the same ``cost_model_token()`` staleness
guard: a knob profile tuned under one cost model is flagged stale once the
planner's constants change, because the plans — and therefore the optimal
batching — may have changed with them.

CLI::

    python -m repro.autotune                     # golden trace, full grid
    python -m repro.autotune --smoke             # CI: small grid, 1 round
    python -m repro.autotune --trace my.jsonl --out knobs.json
    python -m repro.autotune --synthesize /tmp/t.jsonl --queries 32
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

from . import profile as profile_mod

#: serialization schema for serving-knob profiles
SERVING_SCHEMA_VERSION = 1
SERVING_KIND = "repro-serving-knobs"
SERVING_DEFAULT_NAME = "serving_default"

#: the engine's shipped constructor defaults — always evaluated first, so
#: the winner can never be worse than what an untuned engine would use
DEFAULT_KNOBS: Dict = {"max_batch": 32, "max_wait_ms": 2.0,
                       "pad_factor": 4.0, "queue_cap": 1024}


def knob_grid(smoke: bool = False) -> List[Dict]:
    """The search space: engine-knob combinations, defaults first.

    ``queue_cap`` rides along as 8x ``max_batch`` (backpressure headroom
    scales with batch size; an independent axis would mostly produce
    invalid ``queue_cap < max_batch`` points).
    """
    if smoke:
        batches = (8, 64)
        waits = (0.5, 4.0)
        pads = (4.0,)
    else:
        batches = (8, 16, 32, 64, 128)
        waits = (0.25, 1.0, 2.0, 8.0)
        pads = (1.0, 4.0, 8.0)
    grid = [dict(DEFAULT_KNOBS)]
    for mb in batches:
        for wait in waits:
            for pad in pads:
                cfg = {"max_batch": mb, "max_wait_ms": wait,
                       "pad_factor": pad,
                       "queue_cap": max(8 * mb, DEFAULT_KNOBS["queue_cap"])}
                if cfg not in grid:
                    grid.append(cfg)
    return grid


def evaluate_knobs(trace, knobs: Dict, *, iters: int = 1,
                   async_mode: bool = False) -> Dict:
    """Replay ``trace`` under ``knobs`` ``iters`` times; best-of wall time.

    Returns the ranking record: throughput (``qps``), latency percentiles
    (virtual queue wait + real execution per request), and the replay
    digest (determinism witness).
    """
    from repro.serving.trace import replay_trace
    best = None
    for _ in range(max(1, iters)):
        rep = replay_trace(trace, knobs=knobs, async_mode=async_mode)
        if best is None or rep.wall_s < best.wall_s:
            best = rep
    return {"knobs": dict(knobs), "qps": best.qps, "wall_s": best.wall_s,
            "lat_p50_s": best.lat_p50_s, "lat_p99_s": best.lat_p99_s,
            "digest": best.digest,
            "mean_batch": best.counters["mean_batch"],
            "buckets_executed": best.counters["buckets_executed"]}


def _rank_key(entry: Dict) -> Tuple:
    return (-entry["qps"], entry["lat_p99_s"], entry["lat_p50_s"])


def autotune(trace, *, smoke: bool = False, rounds: int = 2,
             keep_frac: float = 1 / 3, iters0: int = 1,
             async_mode: bool = False, verbose: bool = True) -> Dict:
    """Successive-halving knob search against a replayed trace.

    Round r evaluates the surviving configs with ``iters0 + r`` timing
    iterations each and keeps the top ``keep_frac``; the final round's
    best entry is the winner.  The first replay (default knobs) also warms
    the process-wide plan/program caches so every config is measured warm —
    the same steady state a long-running server sees.
    """
    configs = knob_grid(smoke)
    evaluate_knobs(trace, DEFAULT_KNOBS, iters=1, async_mode=async_mode)

    survivors = [dict(knobs=cfg) for cfg in configs]
    for rnd in range(max(1, rounds)):
        iters = iters0 + rnd
        for entry in survivors:
            entry.update(evaluate_knobs(trace, entry["knobs"], iters=iters,
                                        async_mode=async_mode))
        survivors.sort(key=_rank_key)
        if verbose:
            top = survivors[0]
            print(f"[autotune] round {rnd + 1}/{rounds}: "
                  f"{len(survivors)} configs x {iters} iters; best "
                  f"{top['qps']:.1f} q/s p99 {top['lat_p99_s'] * 1e3:.1f}ms "
                  f"{top['knobs']}", flush=True)
        if rnd < rounds - 1:
            keep = max(2, math.ceil(len(survivors) * keep_frac))
            survivors = survivors[:keep]

    winner = survivors[0]
    default_entry = next(
        (e for e in survivors if e["knobs"] == DEFAULT_KNOBS), None)
    if default_entry is None:
        default_entry = evaluate_knobs(trace, DEFAULT_KNOBS,
                                       iters=iters0 + rounds - 1,
                                       async_mode=async_mode)
    return {
        "winner": winner,
        "default": default_entry,
        "ranked": survivors,
        "improvement": winner["qps"] / max(default_entry["qps"], 1e-12),
        "trace": {"name": trace.name, "requests": trace.n_requests,
                  "duration_s": trace.duration_s},
        "async_mode": async_mode,
        "rounds": rounds,
        "configs_evaluated": len(configs),
    }


# ---------------------------------------------------------------------------
# Serving-knob profiles: the winner, pinned on disk
# ---------------------------------------------------------------------------


class ServingProfileError(ValueError):
    """A serving-knob profile failed validation or is stale."""


def serving_profile_path(backend: Optional[Dict] = None,
                         directory: Optional[str] = None) -> str:
    backend = backend or profile_mod.backend_signature()
    return os.path.join(directory or profile_mod.profile_dir(),
                        "serving_" + profile_mod.profile_key(backend)
                        + ".json")


def save_serving_profile(result: Dict, path: Optional[str] = None,
                         name: Optional[str] = None) -> str:
    """Write an :func:`autotune` result as a pinned knob profile.

    The profile records the planner's ``cost_model_token()`` at tune time:
    knobs were chosen for the bucket/plan behavior that token implies, so
    :func:`load_serving_knobs` treats a token mismatch as staleness — the
    same guard the plan caches use after a recalibration.
    """
    from repro.core.planner import cost_model_token
    backend = profile_mod.backend_signature()
    path = path or serving_profile_path(backend)
    payload = {
        "schema": SERVING_SCHEMA_VERSION,
        "kind": SERVING_KIND,
        "name": name or ("serving_" + profile_mod.profile_key(backend)),
        "backend": backend,
        "knobs": result["winner"]["knobs"],
        "score": {k: result["winner"][k]
                  for k in ("qps", "lat_p50_s", "lat_p99_s", "mean_batch")},
        "default_score": {k: result["default"][k]
                          for k in ("qps", "lat_p50_s", "lat_p99_s")},
        "improvement": result["improvement"],
        "trace": result["trace"],
        "async_mode": result["async_mode"],
        "cost_model_token": cost_model_token(),
        "ranked": [{"knobs": e["knobs"], "qps": e["qps"],
                    "lat_p99_s": e["lat_p99_s"]}
                   for e in result["ranked"]],
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_serving_profile(path: Optional[str] = None,
                         directory: Optional[str] = None) -> Dict:
    """Load a serving-knob profile: explicit ``path``, else this backend's
    registry entry, else the committed ``serving_default.json``."""
    if path is None:
        directory = directory or profile_mod.profile_dir()
        path = serving_profile_path(directory=directory)
        if not os.path.exists(path):
            path = os.path.join(directory, SERVING_DEFAULT_NAME + ".json")
        if not os.path.exists(path):
            raise FileNotFoundError(
                f"no serving-knob profile for this backend under "
                f"{directory!r} and no {SERVING_DEFAULT_NAME}.json fallback "
                f"(run python -m repro.autotune)")
    with open(path) as f:
        raw = json.load(f)
    if not isinstance(raw, dict) or raw.get("kind") != SERVING_KIND:
        raise ServingProfileError(f"{path}: not a {SERVING_KIND} profile")
    if raw.get("schema") != SERVING_SCHEMA_VERSION:
        raise ServingProfileError(
            f"{path}: unsupported serving-knob schema {raw.get('schema')!r} "
            f"(this build reads {SERVING_SCHEMA_VERSION})")
    missing = [k for k in ("knobs", "backend", "cost_model_token")
               if k not in raw]
    if missing:
        raise ServingProfileError(f"{path}: missing fields {missing}")
    raw["path"] = path
    return raw


def serving_knobs_stale(profile: Dict) -> bool:
    """True when the live cost model differs from the one the knobs were
    tuned under (plans — and optimal batching — may have changed)."""
    from repro.core.planner import cost_model_token
    return profile["cost_model_token"] != cost_model_token()


def load_serving_knobs(path: Optional[str] = None, *,
                       allow_stale: bool = False) -> Dict:
    """The pinned engine knobs, staleness-guarded.

    Raises :class:`ServingProfileError` when the profile was tuned under a
    different ``cost_model_token`` unless ``allow_stale`` — serving with
    knobs tuned for another cost model silently forfeits the tuning.
    """
    profile = load_serving_profile(path)
    if serving_knobs_stale(profile) and not allow_stale:
        from repro.core.planner import cost_model_token
        raise ServingProfileError(
            f"{profile['path']}: knobs tuned under cost model "
            f"{profile['cost_model_token']!r} but the live token is "
            f"{cost_model_token()!r} — retune (python -m repro.autotune) "
            f"or pass allow_stale=True")
    return dict(profile["knobs"])


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _resolve_trace(args) -> "object":
    from repro.serving.trace import (Trace, golden_trace_path,
                                     synthesize_trace)
    if args.synthesize:
        tr = synthesize_trace(
            name=os.path.splitext(os.path.basename(args.synthesize))[0],
            n=args.n, queries=args.queries, seed=args.seed)
        tr.save(args.synthesize)
        print(f"[autotune] synthesized {tr.n_requests}-request trace "
              f"-> {args.synthesize}", flush=True)
        return tr
    if args.trace:
        return Trace.load(args.trace)
    path = golden_trace_path()
    if os.path.exists(path):
        print(f"[autotune] using golden trace {path}", flush=True)
        return Trace.load(path)
    print("[autotune] no golden trace found; synthesizing a throwaway "
          "stream", flush=True)
    return synthesize_trace(name="throwaway", n=args.n,
                            queries=args.queries, seed=args.seed)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.autotune",
        description="search QueryEngine knobs against a replayed traffic "
                    "trace; pin the winner next to the calibration profile")
    ap.add_argument("--trace", default=None,
                    help="trace JSONL to replay (default: the committed "
                         "golden trace)")
    ap.add_argument("--smoke", action="store_true",
                    help="small grid + 1 round (CI)")
    ap.add_argument("--rounds", type=int, default=None,
                    help="halving rounds (default: 1 smoke, 2 full)")
    ap.add_argument("--out", default=None,
                    help="write the knob profile here instead of the "
                         "results/profiles/ registry")
    ap.add_argument("--async-replay", action="store_true",
                    help="replay through the async worker instead of the "
                         "sync flush_due path (same schedule, real threads)")
    ap.add_argument("--synthesize", metavar="PATH", default=None,
                    help="synthesize a throwaway trace, save it at PATH, "
                         "and tune against it")
    ap.add_argument("--export-golden", metavar="PATH", default=None,
                    help="write the canonical golden trace (fixed "
                         "generator parameters) and exit")
    ap.add_argument("--n", type=int, default=96,
                    help="matrix size for synthesized traces")
    ap.add_argument("--queries", type=int, default=48,
                    help="request count for synthesized traces")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.export_golden:
        from repro.serving.trace import synthesize_trace
        tr = synthesize_trace(name="golden_v1", n=96, n_structs=3,
                              queries=48, mean_gap_ms=0.5, seed=7)
        path = tr.save(args.export_golden)
        print(f"wrote {path} ({tr.n_requests} requests, "
              f"{tr.duration_s * 1e3:.1f}ms span)")
        return 0

    trace = _resolve_trace(args)
    rounds = args.rounds if args.rounds is not None else (1 if args.smoke
                                                         else 2)
    t0 = time.perf_counter()
    result = autotune(trace, smoke=args.smoke, rounds=rounds,
                      async_mode=args.async_replay)
    took = time.perf_counter() - t0

    win = result["winner"]
    print(f"[autotune] winner after {took:.1f}s: {win['knobs']}")
    print(f"[autotune]   {win['qps']:.1f} q/s (default "
          f"{result['default']['qps']:.1f} q/s, "
          f"{result['improvement']:.2f}x), p50 "
          f"{win['lat_p50_s'] * 1e3:.1f}ms p99 "
          f"{win['lat_p99_s'] * 1e3:.1f}ms, mean batch "
          f"{win['mean_batch']:.1f}")
    name = (os.path.splitext(os.path.basename(args.out))[0]
            if args.out else None)
    path = save_serving_profile(result, path=args.out, name=name)
    print(f"[autotune] wrote {path}")
    print("[autotune] engines pick it up via repro.tuning.autotune."
          "load_serving_knobs() -> QueryEngine(**knobs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
