"""``python -m repro.tune`` — calibrate the planner for this backend.

    python -m repro.tune                      # full probes, register profile
    python -m repro.tune --smoke              # minute-scale CI fit
    python -m repro.tune --only row,tile      # refit selected families
    python -m repro.tune --out my.json        # write here, skip the registry
    python -m repro.tune --validate p.json    # load + validate, no fitting
    python -m repro.tune --export-defaults p.json   # snapshot shipped tables

The fitted profile is registered under ``results/profiles/`` keyed by
backend signature (unless ``--out`` redirects it) and can be installed
with ``repro.tuning.activate(profile)`` in-process or the
``REPRO_TUNE_PROFILE`` env var for whole process trees.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from . import profile as profile_mod
from .probes import FAMILIES


def _parse_families(spec: str) -> Sequence[str]:
    fams = [f.strip() for f in spec.split(",") if f.strip()]
    unknown = sorted(set(fams) - set(FAMILIES))
    if unknown:
        raise SystemExit(
            f"repro.tune: unknown --only families {unknown}; "
            f"valid names: {', '.join(FAMILIES)}")
    if not fams:
        raise SystemExit("repro.tune: --only given but no families named")
    return fams


def _summarize(p: profile_mod.CalibrationProfile, base) -> str:
    lines = [f"profile {p.name!r} version={p.version} "
             f"backend={p.backend}"]
    for fam in FAMILIES:
        r = p.residuals.get(fam)
        lines.append(f"  {fam:4s} residual: "
                     + (f"{r:.3f} rel RMS" if r is not None else "inherited"))
    changed = sum(
        1 for alg, tbl in p.cost_constants.items()
        for k, v in tbl.items() if v != base.cost_constants[alg][k])
    lines.append(f"  row constants changed: {changed}; "
                 f"tile gates: {p.tile_gates}")
    return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.tune",
        description="fit this backend's planner cost-model profile")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny probe grids + 1 timed iteration (CI)")
    ap.add_argument("--only", default="",
                    help=f"comma-separated probe families to refit "
                         f"(subset of: {','.join(FAMILIES)}); the rest "
                         f"are inherited from the active profile")
    ap.add_argument("--out", default=None,
                    help="write the fitted profile JSON here instead of "
                         "registering it under results/profiles/")
    ap.add_argument("--name", default=None,
                    help="profile name (default: backend key)")
    ap.add_argument("--validate", metavar="PATH", default=None,
                    help="load + validate a profile JSON and exit")
    ap.add_argument("--export-defaults", metavar="PATH", default=None,
                    help="snapshot the live (shipped or activated) "
                         "constant tables as a profile JSON and exit")
    args = ap.parse_args(argv)

    if args.validate:
        p = profile_mod.CalibrationProfile.load(args.validate)
        print(f"OK: {args.validate} validates "
              f"(name={p.name!r}, version={p.version})")
        return 0

    if args.export_defaults:
        snap = profile_mod.snapshot(
            name=args.name or profile_mod.DEFAULT_PROFILE_NAME,
            note="snapshot of the shipped planner constants")
        path = snap.save(args.export_defaults)
        print(f"wrote {path} (version={snap.version})")
        return 0

    families = _parse_families(args.only) if args.only else FAMILIES

    from .fit import fit_profile
    from .probes import run_probes

    backend = profile_mod.backend_signature()
    # base = whatever the process currently plans with (shipped constants,
    # or an already-activated profile) — unprobed families inherit it
    base = profile_mod.active_profile() or profile_mod.snapshot(
        name="builtin", backend=backend)
    print(f"[tune] backend: {backend}")
    print(f"[tune] probing families: {', '.join(families)}"
          + (" (smoke grids)" if args.smoke else ""))
    ms = run_probes(families, smoke=args.smoke)
    print(f"[tune] {len(ms)} measurements; fitting...")
    fitted = fit_profile(
        ms, base, families=families,
        name=args.name or profile_mod.profile_key(backend),
        backend=backend, smoke=bool(args.smoke))

    if args.out:
        path = fitted.save(args.out)
    else:
        path = profile_mod.register(fitted)
    print(_summarize(fitted, base))
    print(f"[tune] wrote {path}")
    print(f"[tune] activate with repro.tuning.activate(CalibrationProfile."
          f"load({path!r})) or REPRO_TUNE_PROFILE={path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
