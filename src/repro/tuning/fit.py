"""Fit the planner's cost constants to probe measurements.

Every cost model in the planner is *linear in its constants* (the
feature decompositions live next to the models: ``accumulators.
COST_FEATURES``, ``planner.tile_cost_features``, ``planner.
ring_cost_features``), so calibration is weighted non-negative least
squares — solved by projected coordinate descent on the regularized
normal equations, with

* relative weighting (``1/t^2``): the planner only needs the *ranking*
  right, so a 2x error on a 5 ms point must matter as much as on a
  500 ms point;
* a ridge prior toward the incumbent constants, scaled per-constant: on
  thin grids (``--smoke``) the data pins the well-observed directions and
  the prior holds the rest, instead of letting a rank-deficient system
  send a constant to zero or infinity.

Families fit in dependency order: ``row`` first (the distributed row
route re-uses the row hooks), then ``tile`` (the ring shares its
host/mac/gather decomposition), then ``dist`` (fits only the
communication constants against the residual the first two leave).
The ``TILE_MIN_*`` gates are not regression constants; they move only
when the tile probes' win/loss outcomes cleanly separate by density /
occupancy, and stay at the incumbent values otherwise.
"""
from __future__ import annotations

import math
import time
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .probes import FAMILIES, Measurement
from .profile import CalibrationProfile, ProfileError, required_table_keys

#: prior strength, in pseudo-observations per constant at 100% relative
#: deviation from the incumbent value.  Deliberately weak: with ~N
#: samples the prior pulls a well-observed constant only ~RIDGE*P/N of
#: the way back toward the incumbent, while still pinning directions the
#: grid cannot see (near-collinear features, e.g. hash's per_slot vs
#: per_mask at a fixed load factor)
DEFAULT_RIDGE = 0.05

#: clip range for a fitted TILE_MIN_DENSITY gate
DENSITY_GATE_RANGE = (0.005, 0.45)
#: clip range for a fitted TILE_MIN_OCCUPANCY gate
OCCUPANCY_GATE_RANGE = (1.0, 64.0)

_STATS_INT_FIELDS = ("m", "k", "n", "nnz_a", "nnz_b", "nnz_m",
                     "wa", "wb", "wbt", "pm")


def _stats_from_features(f: Dict) -> "PlanStats":
    from repro.core.planner import PlanStats
    kw = {k: int(f[k]) for k in _STATS_INT_FIELDS}
    kw["complement"] = bool(f.get("complement", False))
    kw["semiring"] = str(f.get("semiring", "plus_times"))
    kw["flops"] = float(f.get("flops", 0.0))
    kw["out_nnz"] = float(f.get("out_nnz", 0.0))
    kw["b_transposable"] = bool(f.get("b_transposable", True))
    return PlanStats(**kw)


#: per-constant lower bound as a fraction of the incumbent value: a thin
#: or noisy grid may measure ~zero sensitivity to a term the incumbent
#: model knows exists (e.g. msa's per_n on a grid that never varies n),
#: and erasing it would flip asymptotic regimes the grid never visited.
#: 0.02 still allows a 50x reduction — enough for any real architecture
#: shift — while keeping every term's asymptotics alive.
FLOOR_FRAC = 0.02


def nnls_ridge(F: np.ndarray, t: np.ndarray, prior: np.ndarray, *,
               offset: Optional[np.ndarray] = None,
               ridge: float = DEFAULT_RIDGE,
               floor: float = FLOOR_FRAC,
               iters: int = 2000) -> Tuple[np.ndarray, float]:
    """Solve  min_{x >= floor*prior}
                  sum_i w_i (offset_i + F_i.x - t_i)^2
                  + ridge * sum_j ((x_j - prior_j) / s_j)^2

    with relative weights ``w_i = 1/t_i^2`` and prior scales ``s_j =
    prior_j`` (floored).  Returns ``(x, rel_rms)`` where ``rel_rms`` is
    the relative RMS residual of the FULL prediction (offset + F.x)
    against ``t``.  Projected coordinate descent; the ridge keeps the
    normal matrix positive definite, so every pass is well defined even
    for rank-deficient ``F``.
    """
    F = np.asarray(F, float)
    t = np.asarray(t, float)
    prior = np.asarray(prior, float)
    off = np.zeros_like(t) if offset is None else np.asarray(offset, float)
    w = 1.0 / np.maximum(t, 1e-9) ** 2
    y = t - off
    A = F.T @ (F * w[:, None])
    b = F.T @ (w * y)
    s = np.maximum(prior, max(1e-9, 1e-6 * float(np.max(prior, initial=0))))
    r = ridge / s ** 2
    A[np.diag_indices_from(A)] += r
    b = b + r * prior
    lo = floor * np.maximum(prior, 0.0)
    x = np.maximum(prior, lo).astype(float).copy()
    for _ in range(iters):
        x_prev = x.copy()
        for j in range(len(x)):
            num = b[j] - A[j] @ x + A[j, j] * x[j]
            x[j] = max(lo[j], num / A[j, j])
        if np.max(np.abs(x - x_prev)) <= 1e-12 * (1.0 + np.max(x)):
            break
    pred = off + F @ x
    rel = (pred - t) / np.maximum(t, 1e-12)
    return x, float(np.sqrt(np.mean(rel ** 2)))


def _select(ms: Iterable[Measurement], family: str,
            target: Optional[str] = None) -> List[Measurement]:
    return [m for m in ms if m.family == family
            and (target is None or m.target == target)]


# ---------------------------------------------------------------------------
# Row family: COST_CONSTANTS
# ---------------------------------------------------------------------------


def fit_row(ms: Sequence[Measurement],
            base: Dict[str, Dict[str, float]], *,
            ridge: float = DEFAULT_RIDGE
            ) -> Tuple[Dict[str, Dict[str, float]], float]:
    """Refit every row algorithm's constants; algorithms with no probe
    coverage keep the incumbent table.  Returns (constants, rel RMS
    pooled over all fitted algorithms)."""
    from repro.core import accumulators as acc

    out = {alg: dict(tbl) for alg, tbl in base.items()}
    sq_sum, n_samples = 0.0, 0
    for alg, keys in required_table_keys()[0].items():
        recs = _select(ms, "row", alg)
        if not recs:
            continue
        feat_fn = acc.COST_FEATURES[alg]
        F, t = [], []
        for m in recs:
            s = _stats_from_features(m.features)
            f = feat_fn(n=s.n, wa=s.wa, wb=s.wb, wbt=s.wbt, pm=s.pm)
            scale = s.m / 1024.0   # hooks are per 1024 output rows
            F.append([f[k] * scale for k in keys])
            t.append(m.seconds * 1e3)
        prior = np.array([base[alg][k] for k in keys])
        x, rel = nnls_ridge(np.array(F), np.array(t), prior, ridge=ridge)
        out[alg] = {k: float(v) for k, v in zip(keys, x)}
        sq_sum += rel ** 2 * len(recs)
        n_samples += len(recs)
    if n_samples == 0:
        raise ProfileError("row fit: no row measurements")
    return out, math.sqrt(sq_sum / n_samples)


# ---------------------------------------------------------------------------
# Tile family: TILE_COST + TILE_MIN_* gates
# ---------------------------------------------------------------------------


def fit_tile(ms: Sequence[Measurement],
             base_cost: Dict[str, float],
             base_gates: Dict[str, float], *,
             ridge: float = DEFAULT_RIDGE
             ) -> Tuple[Dict[str, float], Dict[str, float], float]:
    from repro.core.planner import tile_cost_features

    recs = _select(ms, "tile", "tile")
    if not recs:
        raise ProfileError("tile fit: no tile measurements")
    keys = list(required_table_keys()[1])
    F, t = [], []
    for m in recs:
        s = _stats_from_features(m.features)
        f = tile_cost_features(s, int(m.features["bs"]))
        F.append([f[k] for k in keys])
        t.append(m.seconds * 1e3)
    prior = np.array([base_cost[k] for k in keys])
    x, rel = nnls_ridge(np.array(F), np.array(t), prior, ridge=ridge)
    cost = {k: float(v) for k, v in zip(keys, x)}
    return cost, _fit_gates(ms, base_gates), rel


def _fit_gates(ms: Sequence[Measurement],
               base_gates: Dict[str, float]) -> Dict[str, float]:
    """Move the density/occupancy gates only where the probe outcomes
    separate cleanly: the gate lands at the geometric midpoint between
    the densest point the tile route LOST and the sparsest it WON.
    Overlapping or one-sided outcomes keep the incumbent gate — the cost
    model (also refitted) still ranks those points."""
    row_ref = {m.point: m.seconds for m in ms
               if m.family == "tile" and m.target.startswith("row:")}
    wins_d, loss_d, wins_o, loss_o = [], [], [], []
    for m in _select(ms, "tile", "tile"):
        if m.point not in row_ref:
            continue
        s = _stats_from_features(m.features)
        bs = float(m.features["bs"])
        dens = min(s.nnz_a / max(1, s.m * s.k), s.nnz_b / max(1, s.k * s.n))
        occ = dens * bs * bs
        if m.seconds < row_ref[m.point]:
            wins_d.append(dens)
            wins_o.append(occ)
        else:
            loss_d.append(dens)
            loss_o.append(occ)
    gates = dict(base_gates)

    def separated(losses, wins, clip_range):
        if not losses or not wins or max(losses) >= min(wins):
            return None
        lo, hi = clip_range
        return float(np.clip(math.sqrt(max(losses) * min(wins)), lo, hi))

    d = separated(loss_d, wins_d, DENSITY_GATE_RANGE)
    if d is not None:
        gates["min_density"] = d
    o = separated(loss_o, wins_o, OCCUPANCY_GATE_RANGE)
    if o is not None:
        gates["min_occupancy"] = o
    # min_hit_rate: the probe masks always intersect the product, so the
    # grid carries no signal for it — always inherited
    return gates


# ---------------------------------------------------------------------------
# Dist family: DIST_COST (against the residual row + tile leave)
# ---------------------------------------------------------------------------


def fit_dist(ms: Sequence[Measurement],
             row_constants: Dict[str, Dict[str, float]],
             tile_cost_table: Dict[str, float],
             base: Dict[str, float], *,
             ridge: float = DEFAULT_RIDGE
             ) -> Tuple[Dict[str, float], float]:
    """Fit the three communication constants.  The compute part of each
    route is predicted with the (already fitted) row/tile constants and
    enters as a fixed offset; only the communication terms are free."""
    from repro.core import accumulators as acc
    from repro.core.planner import (ring_cost_features,
                                    row_replication_elems)

    row_recs = _select(ms, "dist", "row")
    ring_recs = _select(ms, "dist", "ring")
    if not row_recs or not ring_recs:
        raise ProfileError("dist fit: need both row and ring measurements")

    # -- per_bcast_elem from the row route --------------------------------
    F, t, off = [], [], []
    for m in row_recs:
        s = _stats_from_features(m.features)
        p = float(m.features["p"])
        alg = str(m.features["row_algorithm"])
        f = acc.COST_FEATURES[alg](n=s.n, wa=s.wa, wb=s.wb, wbt=s.wbt,
                                   pm=s.pm)
        compute = sum(row_constants[alg][k] * f[k] for k in f) \
            * (s.m / 1024.0) / p
        F.append([row_replication_elems(s, alg)])
        t.append(m.seconds * 1e3)
        off.append(compute)
    x_b, rel_row = nnls_ridge(
        np.array(F), np.array(t), np.array([base["per_bcast_elem"]]),
        offset=np.array(off), ridge=ridge)

    # -- remaining comm constants from the ring route ---------------------
    keys = [k for k in required_table_keys()[2] if k != "per_bcast_elem"]
    F, t, off = [], [], []
    for m in ring_recs:
        s = _stats_from_features(m.features)
        p, bs = int(m.features["p"]), int(m.features["bs"])
        tile_f, comm_f = ring_cost_features(s, p, bs)
        off.append(sum(tile_cost_table[k] * tile_f[k] for k in tile_f))
        F.append([comm_f[k] for k in keys])
        t.append(m.seconds * 1e3)
    x_r, rel_ring = nnls_ridge(
        np.array(F), np.array(t), np.array([base[k] for k in keys]),
        offset=np.array(off), ridge=ridge)

    out = {"per_bcast_elem": float(x_b[0]),
           **{k: float(v) for k, v in zip(keys, x_r)}}
    n_row, n_ring = len(row_recs), len(ring_recs)
    rel = math.sqrt((rel_row ** 2 * n_row + rel_ring ** 2 * n_ring)
                    / (n_row + n_ring))
    return out, rel


# ---------------------------------------------------------------------------
# Whole-profile fit
# ---------------------------------------------------------------------------


def fit_profile(ms: Sequence[Measurement],
                base: CalibrationProfile, *,
                families: Sequence[str] = FAMILIES,
                name: str = "fitted",
                backend: Optional[Dict] = None,
                ridge: float = DEFAULT_RIDGE,
                **meta) -> CalibrationProfile:
    """Fit the selected families against ``ms``; unfitted families (and
    their residual entries) are inherited from ``base``.  Families fit
    in dependency order regardless of the order given."""
    unknown = sorted(set(families) - set(FAMILIES))
    if unknown:
        raise ProfileError(f"unknown fit families {unknown}; "
                           f"valid: {list(FAMILIES)}")
    cost_constants = {a: dict(t) for a, t in base.cost_constants.items()}
    tile_cost_table = dict(base.tile_cost)
    tile_gates = dict(base.tile_gates)
    dist_cost = dict(base.dist_cost)
    residuals = {k: float(v) for k, v in base.residuals.items()}

    if "row" in families:
        cost_constants, residuals["row"] = fit_row(
            ms, cost_constants, ridge=ridge)
    if "tile" in families:
        tile_cost_table, tile_gates, residuals["tile"] = fit_tile(
            ms, tile_cost_table, tile_gates, ridge=ridge)
    if "dist" in families:
        dist_cost, residuals["dist"] = fit_dist(
            ms, cost_constants, tile_cost_table, dist_cost, ridge=ridge)

    if backend is None:
        from .profile import backend_signature
        backend = backend_signature()
    return CalibrationProfile(
        name=name,
        backend=backend,
        cost_constants=cost_constants,
        tile_cost=tile_cost_table,
        tile_gates=tile_gates,
        dist_cost=dist_cost,
        residuals=residuals,
        meta=dict(meta, fitted_families=sorted(families),
                  n_measurements=len(ms), base_profile=base.name,
                  fitted_at=time.strftime("%Y-%m-%dT%H:%M:%S")),
    ).validate()
