"""Microbenchmark probe suite: the measurements the fit solves against.

Three probe families mirror the three constant tables:

* ``row``  — every vmapped row kernel on an ER input-degree x mask-degree
  grid (the same family ``benchmarks/bench_density.py`` sweeps), solving
  for ``accumulators.COST_CONSTANTS``;
* ``tile`` — the end-to-end BCSR tile route on block-structured operands
  plus uniform-ER controls (``benchmarks/bench_tile.py``'s families), with
  one reference row-kernel timing per point, solving for
  ``planner.TILE_COST`` and informing the ``TILE_MIN_*`` gates;
* ``dist`` — the row-parallel and sparse-ring distributed routes over a
  B-density x mesh-size grid (``benchmarks/bench_dist.py``'s family),
  solving for ``planner.DIST_COST``.  Runs in a forced-host-device child
  interpreter when the process does not already see enough devices.

Grids are sized for minutes, not hours: calibration needs the cost
*slopes*, not benchmark-grade precision — the planner's measured-trial
fallback already absorbs near-tie noise at plan time.  The generators
(``erdos_renyi``, ``er_mask``, ``block_sparse``) live in
``repro.core.formats`` and are the SAME functions the benchmarks sweep,
so profiles are fit against the distributions the acceptance grids
measure.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

#: probe families, in fit order (tile consumes row's fit, dist both)
FAMILIES = ("row", "tile", "dist")


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One timed probe point.

    ``features`` carries the PlanStats fields (plus family extras such as
    ``bs``/``p``) the fit needs to rebuild the model's feature vector —
    the probe records *what was measured*, the fit decides *how to use
    it*.
    """

    family: str          # "row" | "tile" | "dist"
    target: str          # algorithm or route that was timed
    point: str           # grid-point label (diagnostics)
    seconds: float       # min-of-k wall seconds
    features: Dict[str, float]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Measurement":
        return cls(family=d["family"], target=d["target"], point=d["point"],
                   seconds=float(d["seconds"]), features=dict(d["features"]))


def _min_time(fn, iters: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _stats_features(stats) -> Dict[str, float]:
    return {k: float(v) if not isinstance(v, (str, bool)) else v
            for k, v in dataclasses.asdict(stats).items()}


# ---------------------------------------------------------------------------
# Row-kernel probes
# ---------------------------------------------------------------------------


def probe_row(*, smoke: bool = False,
              log=print) -> List[Measurement]:
    """Time every row kernel on an ER degree grid; one Measurement per
    (point, algorithm)."""
    from repro.core.formats import er_mask, erdos_renyi
    from repro.core.masked_spgemm import ALGORITHMS, masked_spgemm
    from repro.core.planner import collect_stats

    if smoke:
        grid = [(256, (2, 8), (2, 8), 1)]
    else:
        grid = [(512, (2, 8, 32), (2, 8, 32), 2),
                (1024, (2, 8, 32), (2, 8, 32), 2)]
    out: List[Measurement] = []
    for n, degrees, mask_degrees, iters in grid:
        for d in degrees:
            A = erdos_renyi(n, d, seed=10 + d)
            B = erdos_renyi(n, d, seed=20 + d)
            for dm in mask_degrees:
                M = er_mask(n, dm, seed=30 + dm)
                stats = collect_stats(A, B, M)
                feats = _stats_features(stats)
                point = f"row_n{n}_d{d}_m{dm}"
                for algo in ALGORITHMS:
                    def go(algo=algo):
                        r = masked_spgemm(A, B, M, algorithm=algo)
                        r.vals.block_until_ready()
                    secs = _min_time(go, iters)
                    out.append(Measurement("row", algo, point, secs, feats))
                log(f"[tune/row] {point}: " + " ".join(
                    f"{m.target}={m.seconds * 1e3:.1f}ms"
                    for m in out[-len(ALGORITHMS):]))
    return out


# ---------------------------------------------------------------------------
# Tile-route probes
# ---------------------------------------------------------------------------


def probe_tile(*, smoke: bool = False,
               log=print) -> List[Measurement]:
    """Time the BCSR tile route (and, per point, the modeled-best row
    kernel as the win/loss reference the gate fit needs)."""
    from repro.core.formats import (block_sparse, csr_from_dense, er_mask,
                                    erdos_renyi)
    from repro.core.masked_spgemm import masked_spgemm
    from repro.core.planner import collect_stats, rank_algorithms

    if smoke:
        n, block_sizes, tds, mos, iters = 128, (8, 16), (0.3,), (0.5,), 1
    else:
        n, block_sizes, tds, mos, iters = 512, (8, 32), (0.1, 0.3), \
            (0.2, 0.6), 2
    out: List[Measurement] = []
    for bs in block_sizes:
        points = [
            (f"tile_bs{bs}_td{td}_mo{mo}",
             block_sparse(n, bs, td, 0.9, seed=100 + bs),
             block_sparse(n, bs, td, 0.9, seed=200 + bs),
             block_sparse(n, bs, mo, 1.0, seed=300 + int(mo * 10),
                          mask=True))
            for td in tds for mo in mos
        ]
        # uniform-ER control: the regime the gates must keep OUT of the
        # tile route — its loss margin anchors the density/occupancy fit
        points.append((f"tile_bs{bs}_er_control",
                       erdos_renyi(n, 4, seed=bs).to_dense(),
                       erdos_renyi(n, 4, seed=bs + 1).to_dense(),
                       er_mask(n, 8, seed=bs + 2).to_dense()))
        for point, A, B, M in points:
            Ac, Bc, Mc = (csr_from_dense(np.asarray(A)),
                          csr_from_dense(np.asarray(B)),
                          csr_from_dense(np.asarray(M)))
            stats = collect_stats(Ac, Bc, Mc)
            feats = dict(_stats_features(stats), bs=float(bs))

            def go_tile():
                r = masked_spgemm(Ac, Bc, Mc, algorithm="tile",
                                  tile_block=bs)
                r.vals.block_until_ready()

            t_tile = _min_time(go_tile, iters)
            out.append(Measurement("tile", "tile", point, t_tile, feats))
            row_alg = rank_algorithms(stats)[0][0]

            def go_row():
                r = masked_spgemm(Ac, Bc, Mc, algorithm=row_alg)
                r.vals.block_until_ready()

            t_row = _min_time(go_row, iters)
            out.append(Measurement("tile", f"row:{row_alg}", point, t_row,
                                   feats))
            log(f"[tune/tile] {point}: tile={t_tile * 1e3:.1f}ms "
                f"{row_alg}={t_row * 1e3:.1f}ms")
    return out


# ---------------------------------------------------------------------------
# Distributed probes (forced-host-device child when needed)
# ---------------------------------------------------------------------------


def _dist_spec(smoke: bool) -> dict:
    if smoke:
        return dict(n=256, mesh_sizes=(2, 4), densities_b=(0.02, 0.3),
                    iters=1)
    return dict(n=1024, mesh_sizes=(2, 4), densities_b=(0.02, 0.1, 0.3),
                iters=2)


def _measure_dist(n: int, mesh_sizes: Sequence[int],
                  densities_b: Sequence[float], iters: int,
                  log=print) -> List[Measurement]:
    """Measure ring + row routes; assumes enough jax devices exist."""
    import jax
    from jax.sharding import Mesh

    from repro.core.distributed import (distributed_masked_spgemm,
                                        ring_sparse_masked_spgemm)
    from repro.core.formats import block_sparse, csr_from_dense, erdos_renyi
    from repro.core.planner import collect_stats, decide_distributed

    bs = 32
    points = [(f"dist_tdb{td}",
               block_sparse(n, bs, 0.1, 0.9, seed=1),
               block_sparse(n, bs, td, 0.9, seed=2),
               block_sparse(n, bs, 0.2, 1.0, seed=3, mask=True))
              for td in densities_b]
    points.append(("dist_er_control",
                   erdos_renyi(n, 8, seed=1).to_dense(),
                   erdos_renyi(n, 8, seed=2).to_dense(),
                   erdos_renyi(n, 8, seed=3).to_dense()))
    out: List[Measurement] = []
    for point, A, B, M in points:
        Ac, Bc, Mc = (csr_from_dense(np.asarray(A)),
                      csr_from_dense(np.asarray(B)),
                      csr_from_dense(np.asarray(M)))
        stats = collect_stats(Ac, Bc, Mc)
        base_feats = _stats_features(stats)
        for p in mesh_sizes:
            mesh = Mesh(np.array(jax.devices()[:p]), ("data",))
            dplan = decide_distributed(stats, p)
            ring_bs = dplan.tile_block or bs
            feats = dict(base_feats, p=float(p), bs=float(ring_bs),
                         row_algorithm=dplan.row_algorithm)

            def go_ring():
                r = ring_sparse_masked_spgemm(Ac, Bc, Mc, mesh,
                                              block_size=ring_bs)
                r.vals.block_until_ready()

            def go_row():
                r = distributed_masked_spgemm(
                    Ac, Bc, Mc, mesh, algorithm="row",
                    row_algorithm=dplan.row_algorithm)
                r.vals.block_until_ready()

            pt = f"{point}_p{p}"
            t_ring = _min_time(go_ring, iters)
            out.append(Measurement("dist", "ring", pt, t_ring, feats))
            t_row = _min_time(go_row, iters)
            out.append(Measurement("dist", "row", pt, t_row, feats))
            log(f"[tune/dist] {pt}: ring={t_ring * 1e3:.1f}ms "
                f"row={t_row * 1e3:.1f}ms ({dplan.row_algorithm})")
    return out


def probe_dist(*, smoke: bool = False, log=print) -> List[Measurement]:
    """Distributed probes; spawns a forced-host-device child interpreter
    when this process sees fewer devices than the largest probed mesh
    (jax's device count is frozen at first use and cannot be raised
    in-process)."""
    import jax

    spec = _dist_spec(smoke)
    if len(jax.devices()) >= max(spec["mesh_sizes"]):
        return _measure_dist(log=log, **spec)

    out_path = os.path.join(os.environ.get("TMPDIR", "/tmp"),
                            f"repro_tune_dist_{os.getpid()}.json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{max(spec['mesh_sizes'])} "
                        + env.get("XLA_FLAGS", ""))
    child_spec = json.dumps(dict(spec, out=out_path))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.tuning.probes", "--dist-child",
         child_spec], env=env, timeout=3600)
    if proc.returncode != 0:
        raise RuntimeError(f"dist probe child failed: {proc.returncode}")
    try:
        with open(out_path) as f:
            records = json.load(f)
    finally:
        try:
            os.remove(out_path)
        except OSError:
            pass
    return [Measurement.from_dict(r) for r in records]


def run_probes(families: Sequence[str], *, smoke: bool = False,
               log=print) -> List[Measurement]:
    """Run the selected probe families in canonical order."""
    unknown = sorted(set(families) - set(FAMILIES))
    if unknown:
        raise ValueError(f"unknown probe families {unknown}; "
                         f"valid: {list(FAMILIES)}")
    runners = {"row": probe_row, "tile": probe_tile, "dist": probe_dist}
    out: List[Measurement] = []
    for fam in FAMILIES:
        if fam in families:
            out.extend(runners[fam](smoke=smoke, log=log))
    return out


def _main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="probe child entry (used by repro.tune; not a CLI)")
    ap.add_argument("--dist-child", required=True)
    args = ap.parse_args(argv)
    spec = json.loads(args.dist_child)
    ms = _measure_dist(spec["n"], spec["mesh_sizes"], spec["densities_b"],
                       spec["iters"])
    with open(spec["out"], "w") as f:
        json.dump([m.to_dict() for m in ms], f)
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
