"""Calibration profiles: the planner's cost constants as data, not code.

A :class:`CalibrationProfile` bundles every table the adaptive planner
consults — the row-kernel cost-hook constants (``accumulators.
COST_CONSTANTS``), the tile-route model (``planner.TILE_COST``) and its
eligibility gates (``TILE_MIN_*``), and the distributed model
(``planner.DIST_COST``) — together with the backend it was fit on and the
fit residuals.  Profiles serialize to JSON and live in an on-disk registry
keyed by backend signature (platform, device kind, device count) under
``results/profiles/``; the shipped CPU constants are committed there as
``default.json``.

``activate(profile)`` installs a profile into the live planner/accumulator
tables.  The planner keys its plan caches on :func:`active_version` plus a
fingerprint of the live tables, so activating a new profile (or mutating
the tables by hand, the legacy ROADMAP workflow) can never serve a plan
decided under the old constants.

This module must stay import-light (stdlib only at module scope): the
planner imports it at module top, so importing anything from ``repro.core``
here would cycle.  Core modules are imported lazily inside functions.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import zlib
from typing import Any, Dict, Optional, Tuple

#: registry directory; override with the REPRO_PROFILE_DIR env var
PROFILE_DIR_ENV = "REPRO_PROFILE_DIR"
DEFAULT_PROFILE_DIR = os.path.join("results", "profiles")
#: env var naming a profile JSON to activate at planner import
PROFILE_ENV = "REPRO_TUNE_PROFILE"
#: the registry's fallback profile (the committed CPU calibration)
DEFAULT_PROFILE_NAME = "default"

#: serialization schema version (bump on incompatible field changes)
SCHEMA_VERSION = 1

#: gate names — owned here (``activate`` is their only writer)
TILE_GATE_KEYS = ("min_density", "min_occupancy", "min_hit_rate")


def required_table_keys() -> Tuple[Dict[str, Tuple[str, ...]],
                                   Tuple[str, ...], Tuple[str, ...]]:
    """``(cost_constant_keys, tile_cost_keys, dist_cost_keys)`` — the
    constant names each table must carry, derived from the SAME feature
    decompositions the cost hooks dot against (``accumulators.
    COST_FEATURES``, ``planner.tile_cost_features`` / ``ring_cost_
    features``), so validation can never drift from what ``plan()`` will
    actually read.  Lazy core imports keep this module import-light.
    """
    import importlib
    acc = importlib.import_module("repro.core.accumulators")
    planner = importlib.import_module("repro.core.planner")
    probe = dict(n=2, wa=1, wb=1, wbt=1, pm=1)
    cost_keys = {alg: tuple(fn(**probe))
                 for alg, fn in acc.COST_FEATURES.items()}
    stats = planner.PlanStats(m=8, k=8, n=8, nnz_a=1, nnz_b=1, nnz_m=1,
                              wa=1, wb=1, wbt=1, pm=1, complement=False)
    tile_keys = tuple(planner.tile_cost_features(stats, 8))
    comm_keys = tuple(planner.ring_cost_features(stats, 2, 8)[1])
    return cost_keys, tile_keys, ("per_bcast_elem",) + comm_keys


class ProfileError(ValueError):
    """A profile failed validation or (de)serialization."""


def _canonical(obj) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def fingerprint_tables(cost_constants, tile_cost, tile_gates,
                       dist_cost) -> str:
    """Stable content hash of the four constant tables (8 hex chars)."""
    payload = _canonical({
        "cost_constants": cost_constants, "tile_cost": tile_cost,
        "tile_gates": tile_gates, "dist_cost": dist_cost})
    return format(zlib.crc32(payload.encode()), "08x")


@dataclasses.dataclass(frozen=True)
class CalibrationProfile:
    """One backend's fitted planner constants, plus provenance.

    ``version`` is the cache token the planner keys its plan caches on:
    two profiles with different versions never share cached plans, even
    if their constants happen to coincide.  ``residuals`` records the
    relative RMS fit error per probe family (``row``/``tile``/``dist``) —
    all entries must be finite for the profile to validate.
    """

    name: str
    backend: Dict[str, Any]           # platform / device_kind / device_count
    cost_constants: Dict[str, Dict[str, float]]
    tile_cost: Dict[str, float]
    tile_gates: Dict[str, float]
    dist_cost: Dict[str, float]
    residuals: Dict[str, float] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    version: str = ""

    def __post_init__(self):
        if not self.version:
            object.__setattr__(self, "version", self.fingerprint())

    def fingerprint(self) -> str:
        return fingerprint_tables(self.cost_constants, self.tile_cost,
                                  self.tile_gates, self.dist_cost)

    # -- validation ---------------------------------------------------------

    def validate(self) -> "CalibrationProfile":
        """Raise :class:`ProfileError` unless every table is complete and
        every constant/residual is a finite, non-negative number."""
        import math

        def check_table(label, table, keys):
            missing = [k for k in keys if k not in table]
            if missing:
                raise ProfileError(f"{label}: missing keys {missing}")
            for k, v in table.items():
                if not isinstance(v, (int, float)) or isinstance(v, bool) \
                        or not math.isfinite(v) or v < 0:
                    raise ProfileError(
                        f"{label}[{k!r}] = {v!r}: want finite number >= 0")

        cost_keys, tile_keys, dist_keys = required_table_keys()
        for alg, keys in cost_keys.items():
            if alg not in self.cost_constants:
                raise ProfileError(f"cost_constants: missing {alg!r}")
            check_table(f"cost_constants[{alg!r}]",
                        self.cost_constants[alg], keys)
        check_table("tile_cost", self.tile_cost, tile_keys)
        check_table("tile_gates", self.tile_gates, TILE_GATE_KEYS)
        check_table("dist_cost", self.dist_cost, dist_keys)
        for fam, r in self.residuals.items():
            if not math.isfinite(float(r)):
                raise ProfileError(f"residuals[{fam!r}] = {r!r}: not finite")
        if not self.version:
            raise ProfileError("empty version token")
        return self

    # -- (de)serialization --------------------------------------------------

    def to_json(self) -> str:
        return json.dumps({
            "schema": SCHEMA_VERSION,
            "name": self.name,
            "version": self.version,
            "backend": self.backend,
            "cost_constants": self.cost_constants,
            "tile_cost": self.tile_cost,
            "tile_gates": self.tile_gates,
            "dist_cost": self.dist_cost,
            "residuals": self.residuals,
            "meta": self.meta,
        }, indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "CalibrationProfile":
        try:
            raw = json.loads(text)
        except json.JSONDecodeError as e:
            raise ProfileError(f"not valid JSON: {e}") from e
        if not isinstance(raw, dict):
            raise ProfileError("profile JSON must be an object")
        schema = raw.get("schema")
        if schema != SCHEMA_VERSION:
            raise ProfileError(f"unsupported profile schema {schema!r} "
                               f"(this build reads {SCHEMA_VERSION})")
        try:
            return cls(
                name=str(raw["name"]),
                backend=dict(raw["backend"]),
                cost_constants={k: dict(v)
                                for k, v in raw["cost_constants"].items()},
                tile_cost=dict(raw["tile_cost"]),
                tile_gates=dict(raw["tile_gates"]),
                dist_cost=dict(raw["dist_cost"]),
                residuals=dict(raw.get("residuals", {})),
                meta=dict(raw.get("meta", {})),
                version=str(raw.get("version", "")),
            ).validate()
        except (KeyError, TypeError) as e:
            raise ProfileError(f"malformed profile: {e!r}") from e

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationProfile":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# Backend signature + registry
# ---------------------------------------------------------------------------


def backend_signature() -> Dict[str, Any]:
    """Identity of the accelerator the process is running on: the registry
    key.  Deliberately coarse — platform, device kind, device count — so
    one calibration serves every same-shaped host."""
    import jax
    devices = jax.devices()
    return {
        "platform": devices[0].platform,
        "device_kind": devices[0].device_kind,
        "device_count": len(devices),
    }


def _checkout_profile_dir() -> str:
    """The committed registry of THIS checkout, anchored to the package
    location (…/src/repro/tuning/profile.py -> <repo>/results/profiles)
    rather than the process cwd."""
    root = os.path.abspath(__file__)
    for _ in range(4):                      # tuning -> repro -> src -> repo
        root = os.path.dirname(root)
    return os.path.join(root, "results", "profiles")


def profile_dir() -> str:
    """Registry resolution: $REPRO_PROFILE_DIR if set, else a
    ``results/profiles`` under the cwd if one exists (running from a repo
    root), else the checkout's committed registry — so ``lookup()`` finds
    the default profile no matter where the process was started."""
    env = os.environ.get(PROFILE_DIR_ENV)
    if env:
        return env
    if os.path.isdir(DEFAULT_PROFILE_DIR):
        return DEFAULT_PROFILE_DIR
    return _checkout_profile_dir()


def profile_key(backend: Dict[str, Any]) -> str:
    """Registry filename stem for a backend signature."""
    raw = "_".join(str(backend.get(k, "unknown"))
                   for k in ("platform", "device_kind", "device_count"))
    return re.sub(r"[^A-Za-z0-9_.-]+", "-", raw)


def profile_path(backend: Dict[str, Any], directory: Optional[str] = None
                 ) -> str:
    return os.path.join(directory or profile_dir(),
                        profile_key(backend) + ".json")


def register(profile: CalibrationProfile,
             directory: Optional[str] = None) -> str:
    """Write a validated profile into the registry under its backend key."""
    profile.validate()
    return profile.save(profile_path(profile.backend, directory))


def lookup(backend: Optional[Dict[str, Any]] = None,
           directory: Optional[str] = None
           ) -> Tuple[CalibrationProfile, bool]:
    """Find the profile for ``backend`` (default: the current process's).

    Returns ``(profile, exact)``: ``exact`` is False when the backend had
    no fitted profile and the committed default was returned instead.
    Raises FileNotFoundError when neither exists.
    """
    directory = directory or profile_dir()
    backend = backend or backend_signature()
    path = profile_path(backend, directory)
    if os.path.exists(path):
        return CalibrationProfile.load(path), True
    fallback = os.path.join(directory, DEFAULT_PROFILE_NAME + ".json")
    if os.path.exists(fallback):
        return CalibrationProfile.load(fallback), False
    raise FileNotFoundError(
        f"no profile for backend {backend} under {directory!r} and no "
        f"{DEFAULT_PROFILE_NAME}.json fallback")


# ---------------------------------------------------------------------------
# Active profile: what the planner reads through
# ---------------------------------------------------------------------------

_active: Optional[CalibrationProfile] = None

#: version token reported before any profile has been activated — the
#: shipped module-literal constants
BUILTIN_VERSION = "builtin"


def active_profile() -> Optional[CalibrationProfile]:
    """The last profile passed to :func:`activate` (None = shipped
    constants)."""
    return _active


def active_version() -> str:
    """Cache token component identifying the active profile."""
    return _active.version if _active is not None else BUILTIN_VERSION


def snapshot(name: str = "snapshot",
             backend: Optional[Dict[str, Any]] = None,
             **meta) -> CalibrationProfile:
    """Capture the LIVE planner/accumulator tables as a profile.

    This is how the shipped constants become the committed default
    profile, and how callers checkpoint hand-tuned tables before
    experimenting.
    """
    import importlib
    acc = importlib.import_module("repro.core.accumulators")
    planner = importlib.import_module("repro.core.planner")

    return CalibrationProfile(
        name=name,
        backend=backend if backend is not None else backend_signature(),
        cost_constants={k: dict(v) for k, v in acc.COST_CONSTANTS.items()},
        tile_cost=dict(planner.TILE_COST),
        tile_gates={
            "min_density": planner.TILE_MIN_DENSITY,
            "min_occupancy": planner.TILE_MIN_OCCUPANCY,
            "min_hit_rate": planner.TILE_MIN_HIT_RATE,
        },
        dist_cost=dict(planner.DIST_COST),
        meta=dict(meta),
    ).validate()


def activate(profile: CalibrationProfile) -> CalibrationProfile:
    """Install ``profile`` as the planner's cost model.

    Writes the profile's tables into the live module-level tables
    (in place, so every existing reader — cost hooks, tile/ring models,
    hand-tuning workflows — sees them) and records the profile as active.
    Previously cached plans are NOT served afterwards: the planner's cache
    keys include :func:`active_version` + a table fingerprint, so old
    entries simply stop matching.
    """
    global _active
    profile.validate()
    # importlib (not ``from repro.core import ...``): this runs from the
    # bottom of planner.py's own module body when $REPRO_TUNE_PROFILE is
    # set, where the half-initialized module is only visible in
    # sys.modules, not yet as an attribute of the repro.core package
    import importlib
    acc = importlib.import_module("repro.core.accumulators")
    planner = importlib.import_module("repro.core.planner")

    for alg, table in profile.cost_constants.items():
        acc.COST_CONSTANTS.setdefault(alg, {}).clear()
        acc.COST_CONSTANTS[alg].update(table)
    planner.TILE_COST.clear()
    planner.TILE_COST.update(profile.tile_cost)
    planner.DIST_COST.clear()
    planner.DIST_COST.update(profile.dist_cost)
    planner.TILE_MIN_DENSITY = profile.tile_gates["min_density"]
    planner.TILE_MIN_OCCUPANCY = profile.tile_gates["min_occupancy"]
    planner.TILE_MIN_HIT_RATE = profile.tile_gates["min_hit_rate"]
    _active = profile
    return profile


def activate_from_env() -> Optional[CalibrationProfile]:
    """Activate the profile named by ``$REPRO_TUNE_PROFILE``, if any.

    Called once from the bottom of ``planner.py`` (after its tables are
    defined), so child processes — benchmarks, CI jobs, the distributed
    bench's forced-device interpreter — inherit a fitted profile through
    the environment without code changes.  A missing var is a no-op; a
    bad path/profile raises (a requested calibration that silently fails
    to apply would invalidate every measurement made under it).
    """
    path = os.environ.get(PROFILE_ENV)
    if not path:
        return None
    return activate(CalibrationProfile.load(path))
