"""Deterministic fallback for ``hypothesis`` when it is not installed.

The container image does not ship ``hypothesis`` (and we must not pip
install), so the property tests import through this shim:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_shim import given, settings, strategies as st

The shim replays each property ``max_examples`` times with samples drawn
from a seeded ``numpy`` generator, so the property tests still execute
(deterministically) instead of being skipped wholesale.  It implements only
the tiny strategy surface these tests use: ``integers``, ``floats``,
``sampled_from``.
"""
from __future__ import annotations

import inspect

import numpy as np


class _Strategy:
    def sample(self, rng):  # pragma: no cover - abstract
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.min_value, self.max_value = int(min_value), int(max_value)

    def sample(self, rng):
        return int(rng.integers(self.min_value, self.max_value + 1))


class _Floats(_Strategy):
    def __init__(self, min_value, max_value):
        self.min_value, self.max_value = float(min_value), float(max_value)

    def sample(self, rng):
        return float(rng.uniform(self.min_value, self.max_value))


class _SampledFrom(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def sample(self, rng):
        return self.options[int(rng.integers(len(self.options)))]


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0):
        return _Floats(min_value, max_value)

    @staticmethod
    def sampled_from(options):
        return _SampledFrom(options)


class _AnyCheck:
    """Stands in for hypothesis.HealthCheck; any attribute resolves."""

    def __getattr__(self, name):
        return name


HealthCheck = _AnyCheck()

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        def runner():
            n = getattr(runner, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(0xC0FFEE)
            for _ in range(n):
                kwargs = {k: s.sample(rng)
                          for k, s in strategy_kwargs.items()}
                fn(**kwargs)

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        # hide the property parameters from pytest's fixture resolution
        runner.__signature__ = inspect.Signature()
        return runner

    return deco
