"""Shared test fixtures/utilities.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests and benches must see the 1 real CPU device. Distributed tests spawn
subprocesses with their own XLA_FLAGS.
"""
import numpy as np
import pytest

from repro.core.formats import CSR, csr_from_coo, csr_from_dense


def random_csr(rng, m, n, density, dtype=np.float32, sorted_rows=True) -> CSR:
    a = (rng.random((m, n)) < density).astype(dtype)
    a *= rng.uniform(0.5, 1.5, size=(m, n)).astype(dtype)
    return csr_from_dense(a)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
