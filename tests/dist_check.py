"""Subprocess body for distributed tests: 8 fake host devices.

Run as:  XLA_FLAGS=... python tests/dist_check.py
(invoked by tests/test_distributed.py; asserts shard_map results equal the
single-logical-device reference).
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.compat import set_mesh  # noqa: E402
from repro.core.formats import (  # noqa: E402
    csr_from_dense, padded_from_csr)
from repro.core.distributed import (  # noqa: E402
    ring_masked_matmul, row_parallel_masked_spgemm, pad_rows_to)
from repro.core.masked_spgemm import dense_oracle  # noqa: E402


def main():
    assert jax.device_count() == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)

    # ---- row-parallel element-level masked spgemm -------------------------
    m, k, n = 64, 48, 56
    A = ((rng.random((m, k)) < 0.2) * rng.uniform(0.5, 1.5, (m, k))
         ).astype(np.float32)
    B = ((rng.random((k, n)) < 0.2) * rng.uniform(0.5, 1.5, (k, n))
         ).astype(np.float32)
    M = (rng.random((m, n)) < 0.3).astype(np.float32)
    Ap = padded_from_csr(csr_from_dense(A))
    Bp = padded_from_csr(csr_from_dense(B))
    Mp = padded_from_csr(csr_from_dense(M))
    Ap, Mp = pad_rows_to(4, Ap, Mp)

    vals, present = row_parallel_masked_spgemm(Ap, Bp, Mp, mesh,
                                               algorithm="msa")
    want_vals, want_present = dense_oracle(A, B, M)
    got = np.zeros((Mp.shape[0], n + 1), np.float32)
    rows = np.broadcast_to(np.arange(Mp.shape[0])[:, None],
                           np.asarray(Mp.cols).shape)
    cols = np.where(np.asarray(present), np.asarray(Mp.cols), n)
    got[rows.ravel(), cols.ravel()] = np.where(
        np.asarray(present), np.asarray(vals), 0).ravel()
    want = np.where(np.asarray(want_present), np.asarray(want_vals), 0)
    np.testing.assert_allclose(got[:m, :n], want, rtol=1e-5, atol=1e-5)
    print("row_parallel OK")

    # ---- ring-SUMMA masked matmul -----------------------------------------
    m2, k2, n2 = 32, 64, 40
    a = rng.standard_normal((m2, k2)).astype(np.float32)
    b = rng.standard_normal((k2, n2)).astype(np.float32)
    mask = (rng.random((m2, n2)) < 0.5).astype(np.float32)
    got = ring_masked_matmul(jnp.asarray(a), jnp.asarray(b),
                             jnp.asarray(mask), mesh, axis="data")
    want = np.where(mask != 0, a @ b, 0.0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    print("ring_summa OK")

    # ---- ring-SUMMA tile skipping: fully-masked column panels -------------
    # block=8 -> 5 column panels of the 40-wide output; panels 1 and 3 are
    # fully masked out and must be skipped (and still come out zero)
    mask2 = mask.copy()
    mask2[:, 8:16] = 0.0
    mask2[:, 24:32] = 0.0
    got = ring_masked_matmul(jnp.asarray(a), jnp.asarray(b),
                             jnp.asarray(mask2), mesh, axis="data", block=8)
    want = np.where(mask2 != 0, a @ b, 0.0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)
    assert np.abs(np.asarray(got)[:, 8:16]).sum() == 0.0
    print("ring_summa_skip OK")

    # HLO must contain collective-permute (the overlap schedule exists)
    lowered = jax.jit(
        lambda a, b, mk: ring_masked_matmul(a, b, mk, mesh)).lower(
        jax.ShapeDtypeStruct((m2, k2), jnp.float32),
        jax.ShapeDtypeStruct((k2, n2), jnp.float32),
        jax.ShapeDtypeStruct((m2, n2), jnp.float32))
    txt = lowered.compile().as_text()
    assert "collective-permute" in txt, "ring rotation missing from HLO"
    print("hlo OK")




def moe_ep_check():
    """EP shard_map MoE == dense path (capacity large enough: no drops)."""
    import dataclasses
    from repro.configs.base import get_config
    from repro.models import layers as Lyr
    cfg = get_config("moonshot_v1_16b_a3b", smoke=True)
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = Lyr.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((8, 16, 64)),
                    jnp.float32) * 0.3
    dense = Lyr._apply_moe_dense(params, cfg, x)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with set_mesh(mesh):
        ep = jax.jit(lambda p, xx: Lyr.apply_moe(p, cfg, xx))(params, x)
    np.testing.assert_allclose(np.asarray(ep), np.asarray(dense),
                               rtol=2e-4, atol=2e-5)
    print("moe_ep OK")


if __name__ == "__main__":
    main()
    moe_ep_check()
    print("DIST_ALL_OK")
