"""Subprocess body for the sparse BCSR ring-SUMMA tests: 8 fake host devices.

Run as:  python tests/dist_sparse_check.py
(invoked by tests/test_distributed.py).  Value matrices use small random
integers so every summation order is exact in float32 — assertions are
bitwise (array_equal), matching the single-device driver exactly.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.core import formats  # noqa: E402
from repro.core.formats import CSR, BCSR, PaddedCSR, csr_from_dense  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    distributed_masked_spgemm, ring_sparse_masked_spgemm)
from repro.core.masked_spgemm import dense_oracle, masked_spgemm  # noqa: E402
from repro.core.planner import collect_stats, decide_distributed  # noqa: E402
from repro.core.semiring import MIN_PLUS  # noqa: E402

rng = np.random.default_rng(0)


def int_sparse(m, n, density):
    return ((rng.random((m, n)) < density)
            * rng.integers(1, 5, (m, n))).astype(np.float32)


def mesh_of(p):
    return Mesh(np.array(jax.devices()[:p]), ("data",))


def check_bitwise(out, A, B, M):
    """out must match the single-device row kernel AND the dense oracle."""
    Ac, Bc, Mc = csr_from_dense(A), csr_from_dense(B), csr_from_dense(M)
    ref = masked_spgemm(Ac, Bc, Mc, algorithm="msa")
    np.testing.assert_array_equal(np.asarray(out.to_dense()),
                                  np.asarray(ref.to_dense()))
    np.testing.assert_array_equal(np.asarray(out.present),
                                  np.asarray(ref.present))
    np.testing.assert_array_equal(np.asarray(out.mask_cols),
                                  np.asarray(ref.mask_cols))
    want_vals, want_present = dense_oracle(A, B, M)
    np.testing.assert_array_equal(
        np.asarray(out.to_dense()),
        np.where(np.asarray(want_present), np.asarray(want_vals), 0))


def ring_vs_oracle_over_meshes():
    """Bitwise agreement at every mesh size, incl. non-divisible shapes."""
    shapes = [(64, 64, 64),     # divisible
              (50, 33, 70),     # non-divisible everything
              (8, 80, 24)]      # wide, tiny m
    for p in (1, 2, 4, 8):
        mesh = mesh_of(p)
        for m, k, n in shapes:
            A = int_sparse(m, k, 0.2)
            A[m // 2, :] = 0.0                     # empty row
            B = int_sparse(k, n, 0.2)
            M = (rng.random((m, n)) < 0.4).astype(np.float32)
            M[:, n // 2] = 0.0
            out = ring_sparse_masked_spgemm(
                csr_from_dense(A), csr_from_dense(B), csr_from_dense(M),
                mesh, block_size=8)
            check_bitwise(out, A, B, M)
    print("ring_vs_oracle OK")


def ring_empty_mask_and_empty_slabs():
    mesh = mesh_of(8)
    # empty mask: defined degenerate, no kernel work
    A = int_sparse(32, 32, 0.3)
    Z = np.zeros((32, 32), np.float32)
    out = ring_sparse_masked_spgemm(csr_from_dense(A), csr_from_dense(A),
                                    csr_from_dense(Z), mesh, block_size=8)
    assert int(out.nnz) == 0
    # empty K-slabs: k = 24, bs = 8 -> 3 occupied B block-rows over an
    # 8-stage ring; 5+ slabs are structurally empty and must contribute 0
    m, k, n = 40, 24, 40
    A = int_sparse(m, k, 0.3)
    B = int_sparse(k, n, 0.3)
    M = (rng.random((m, n)) < 0.5).astype(np.float32)
    out = ring_sparse_masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                                    csr_from_dense(M), mesh, block_size=8)
    check_bitwise(out, A, B, M)
    # B entirely empty: every slab is empty
    Bz = np.zeros((k, n), np.float32)
    out = ring_sparse_masked_spgemm(csr_from_dense(A), csr_from_dense(Bz),
                                    csr_from_dense(M), mesh, block_size=8)
    check_bitwise(out, A, Bz, M)
    print("ring_edges OK")


def ring_never_densifies():
    """No dense (k, n)/(m, n) intermediate on the sparse ring path: any
    to_dense() on any format during the call is a failure."""
    mesh = mesh_of(4)
    A = int_sparse(48, 48, 0.25)
    B = int_sparse(48, 48, 0.25)
    M = (rng.random((48, 48)) < 0.5).astype(np.float32)
    Ac, Bc, Mc = csr_from_dense(A), csr_from_dense(B), csr_from_dense(M)

    def boom(self):
        raise AssertionError("to_dense() on the sparse ring path")

    saved = [(cls, cls.to_dense) for cls in (CSR, BCSR, PaddedCSR)]
    try:
        for cls, _ in saved:
            cls.to_dense = boom
        out = ring_sparse_masked_spgemm(Ac, Bc, Mc, mesh, block_size=8)
        assert int(out.nnz) > 0
    finally:
        for cls, fn in saved:
            cls.to_dense = fn
    check_bitwise(out, A, B, M)
    print("ring_no_densify OK")


def entry_point_routes_and_matches():
    """distributed_masked_spgemm: forced + auto routes, all bitwise."""
    mesh = mesh_of(8)
    m, k, n = 100, 60, 88                      # non-divisible by 8 rows
    A = int_sparse(m, k, 0.15)
    B = int_sparse(k, n, 0.15)
    M = (rng.random((m, n)) < 0.4).astype(np.float32)
    Ac, Bc, Mc = csr_from_dense(A), csr_from_dense(B), csr_from_dense(M)
    for algorithm in ("row", "ring", "auto"):
        out = distributed_masked_spgemm(Ac, Bc, Mc, mesh,
                                        algorithm=algorithm)
        check_bitwise(out, A, B, M)
    # auto consults the distributed cost model and picks a listed route
    dplan = decide_distributed(collect_stats(Ac, Bc, Mc), 8)
    assert dplan.route in ("row", "ring"), dplan
    assert dict(dplan.costs)[dplan.route] == dplan.costs[0][1]
    # row route with the inner row kernel (exercises the B^T contract)
    out = distributed_masked_spgemm(Ac, Bc, Mc, mesh, algorithm="row",
                                    row_algorithm="inner")
    check_bitwise(out, A, B, M)
    # unsupported products: ring refuses, row handles the semiring
    try:
        distributed_masked_spgemm(Ac, Bc, Mc, mesh, algorithm="ring",
                                  semiring=MIN_PLUS)
        raise SystemExit("ring accepted a non-plus_times semiring")
    except NotImplementedError:
        pass
    out = distributed_masked_spgemm(Ac, Bc, Mc, mesh, algorithm="auto",
                                    semiring=MIN_PLUS)
    ref = masked_spgemm(Ac, Bc, Mc, algorithm="msa", semiring=MIN_PLUS)
    np.testing.assert_array_equal(np.asarray(out.to_dense()),
                                  np.asarray(ref.to_dense()))
    print("entry_point OK")


def main():
    assert jax.device_count() == 8, jax.devices()
    ring_vs_oracle_over_meshes()
    ring_empty_mask_and_empty_slabs()
    ring_never_densifies()
    entry_point_routes_and_matches()


if __name__ == "__main__":
    main()
    print("DIST_SPARSE_ALL_OK")
