"""Subprocess: lower+compile smoke configs on a (pod,data,model) mini-mesh
through the SAME spec machinery as the production dry-run."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.compat import set_mesh  # noqa: E402
from repro.configs.base import get_config, ShapeCfg  # noqa: E402
from repro.launch.dryrun import cache_specs, collective_bytes  # noqa: E402
from repro.launch.specs import (train_input_specs,  # noqa: E402
                                decode_input_specs)
from repro.models import transformer as T  # noqa: E402
from repro.models.common import (make_param_specs,  # noqa: E402
                                 shardings_for)
from repro.optim.adamw import AdamW  # noqa: E402
from repro.serve.decode import make_serve_step  # noqa: E402
from repro.train.train_step import (init_state, state_specs,  # noqa: E402
                                    batch_specs, make_train_step)

ARCHS = ["llama3_2_3b", "zamba2_7b", "moonshot_v1_16b_a3b",
         "deepseek_v2_lite_16b", "xlstm_1_3b", "seamless_m4t_large_v2",
         "internvl2_2b"]


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = ShapeCfg("mini", 64, 8, "train")
    dshape = ShapeCfg("mini_dec", 64, 8, "decode")
    opt = AdamW()
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        with set_mesh(mesh):
            # train
            state_shapes = jax.eval_shape(
                lambda: init_state(cfg, jax.random.PRNGKey(0), opt))
            sspec = state_specs(cfg, state_shapes)
            bshapes = train_input_specs(cfg, shape)
            bspec = batch_specs(bshapes)
            ssh = shardings_for(mesh, sspec, state_shapes)
            bsh = shardings_for(mesh, bspec, bshapes)
            fn = make_train_step(cfg, opt)
            c = jax.jit(fn, in_shardings=(ssh, bsh),
                        out_shardings=(ssh, None),
                        donate_argnums=(0,)).lower(
                state_shapes, bshapes).compile()
            assert c.memory_analysis() is not None
            hlo = c.as_text()
            coll = collective_bytes(hlo)
            assert sum(coll.values()) > 0, f"{arch}: no collectives?!"

            # decode
            pshapes = jax.eval_shape(
                lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
            pspec = make_param_specs(pshapes)
            d = decode_input_specs(cfg, dshape)
            cspec = cache_specs(d["cache"])
            serve = make_serve_step(cfg)
            args = [pshapes, d["token"], d["cache"], d["pos"]]
            csh = shardings_for(mesh, cspec, d["cache"])
            in_sh = [shardings_for(mesh, pspec, pshapes),
                     shardings_for(mesh, P(("pod", "data")), d["token"]),
                     csh,
                     shardings_for(mesh, P(("pod", "data")), d["pos"])]
            if cfg.family == "audio":
                args.append(d["encoder_out"])
                in_sh.append(shardings_for(
                    mesh, P(("pod", "data"), None, None),
                    d["encoder_out"]))
            jax.jit(serve, in_shardings=tuple(in_sh),
                    out_shardings=(None, csh),
                    donate_argnums=(2,)).lower(*args).compile()
        print("OK", arch, flush=True)
    print("MINI_DRYRUN_OK")


if __name__ == "__main__":
    main()
