"""Subprocess body for the fault-tolerance / elastic-restart test.

Phase "full":    8 devices, train 6 steps, checkpoint every 2 — then exit
                 ("crash") after step 4's checkpoint.
Phase "resume":  4 devices (simulated node loss), auto-resume from LATEST,
                 finish to step 6.
Phase "oracle":  8 devices, uninterrupted 6 steps.

The resumed run's post-checkpoint losses must match the oracle's exactly
(stateless data + full-state checkpoints + topology-independent restore).
"""
import os
import sys

phase = sys.argv[1]
ckpt = sys.argv[2]
n_dev = {"full": 8, "resume": 4, "oracle": 8}[phase]
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import set_mesh  # noqa: E402
from repro.configs.base import get_config  # noqa: E402
from repro.data.pipeline import SyntheticLM, batch_for  # noqa: E402
from repro.launch.mesh import make_elastic_mesh  # noqa: E402
from repro.models.common import shardings_for  # noqa: E402
from repro.optim.adamw import AdamW  # noqa: E402
from repro.train.checkpoint import CheckpointManager  # noqa: E402
from repro.train.train_step import (init_state, state_specs,  # noqa: E402
                                    make_train_step)

STEPS = 6
CKPT_EVERY = 2
CRASH_AFTER = 4


def main():
    assert jax.device_count() == n_dev
    cfg = get_config("llama3_2_1b", smoke=True)
    opt = AdamW(lr=1e-3, warmup=2, total_steps=STEPS, weight_decay=0.0)
    pipe = SyntheticLM(cfg.vocab_size, 16, 8, seed=11)
    mesh = make_elastic_mesh(n_dev, model_parallel=2)

    with set_mesh(mesh):
        state = init_state(cfg, jax.random.PRNGKey(7), opt)
        sshapes = jax.eval_shape(lambda: state)
        sspec = state_specs(cfg, sshapes, zero1=True)
        ssh = shardings_for(mesh, sspec, sshapes)
        state = jax.device_put(state, ssh)

        start = 0
        mgr = CheckpointManager(ckpt) if ckpt else None
        if phase == "resume":
            last = mgr.latest_step()
            assert last == CRASH_AFTER, f"expected ckpt at {CRASH_AFTER}," \
                f" got {last}"
            state = mgr.restore(last, sshapes, ssh)
            start = last

        step_fn = jax.jit(make_train_step(cfg, opt),
                          in_shardings=(ssh, None),
                          out_shardings=(ssh, None),
                          donate_argnums=(0,))
        for step in range(start, STEPS):
            state, m = step_fn(state, batch_for(cfg, pipe, step))
            print(f"LOSS {step} {float(m['loss']):.6f}", flush=True)
            if phase in ("full",) and (step + 1) % CKPT_EVERY == 0:
                mgr.save(step + 1, state)
            if phase == "full" and step + 1 == CRASH_AFTER:
                print("CRASH", flush=True)
                os._exit(42)       # simulated node failure (no cleanup)
    print("DONE", flush=True)


if __name__ == "__main__":
    main()
