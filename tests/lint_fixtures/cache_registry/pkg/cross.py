"""Silent: the registration for _cross_memo lives in registry.py —
the check is cross-module."""
import functools


@functools.lru_cache(maxsize=32)
def _cross_memo(x):
    return x - 1
