"""Silent cases: same-module registration, LRUCache, annotated escape."""
import functools

from repro import caches

_programs = caches.LRUCache("fixture-programs", 8)    # self-registering


@functools.lru_cache(maxsize=64)
def _local_memo(x):
    return x + 1


caches.register_lru("fixture-local-memo", _local_memo)

# a bounded worktable that is deliberately not a registered cache
_SCRATCH_MEMO = {}  # lint: cache-ok(bounded worktable, cleared per call)


def scratch(key, value):
    _SCRATCH_MEMO[key] = value
    out = _SCRATCH_MEMO.get(key)
    _SCRATCH_MEMO.clear()
    return out
