"""Registers caches defined in sibling modules."""
from repro import caches

from .cross import _cross_memo

caches.register_lru("fixture-cross-memo", _cross_memo)
