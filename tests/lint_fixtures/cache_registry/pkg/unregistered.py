"""Firing cases: module caches invisible to repro.caches."""
import functools

_result_cache = {}                               # finding (line 4)


@functools.lru_cache(maxsize=128)                # finding (line 7/8)
def _memo(x):
    return x * 2


def lookup(key):
    hit = _result_cache.get(key)
    if hit is None:
        hit = _result_cache[key] = _memo(key)
    return hit
