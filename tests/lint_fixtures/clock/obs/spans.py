"""Clock-discipline cases in an obs/ module (covered since PR 9)."""
import time


def span_duration():
    t0 = time.perf_counter()  # lint: clock-ok(span duration measurement)
    return t0


def unannotated_stamp():
    return time.perf_counter()                   # finding (line 11)


def bad_flush():
    time.sleep(0.01)  # lint: clock-ok(fires anyway, l15)
