"""Silent: clock.py is the one serving module allowed to touch real time."""
import time


def system_now():
    return time.monotonic()


def system_sleep(s):
    time.sleep(s)
