"""Clock-discipline cases in a serving/ module."""
import time
from time import monotonic                       # finding (line 3)


def flush_deadline(max_wait_s):
    # forbidden even WITH an annotation: scheduling from wall time
    # cannot be replayed
    return time.monotonic() + max_wait_s  # lint: clock-ok(still fires, l9)


def backoff():
    time.sleep(0.05)                             # finding (line 13)


def bare_use():
    return monotonic()                           # finding (line 17)


def measured_section():
    t0 = time.perf_counter()  # lint: clock-ok(duration measurement)
    work = t0 * 2
    return time.perf_counter() - work            # finding (line 23): the
    # second read is NOT annotated — annotations are per-site
