"""Firing cases: dense materialization on a hot (core/) path."""


def spgemm_via_dense(a, b, m):
    dense = a.to_dense() @ b.to_dense()          # 2 findings (line 5)
    return dense * m.toarray()                   # 1 finding  (line 6)


def debug_dump(a):
    # measurement escape hatch: annotated sites are allowed
    return a.to_dense()  # lint: densify-ok(debug dump, not a hot path)


class Tile:
    def to_dense(self):
        """Defining to_dense is fine — only calling it densifies."""
        return None
