"""Silent: ref.py reference implementations may densify freely."""


def reference_masked_matmul(a, b, m):
    return (a.to_dense() @ b.to_dense()) * m.to_dense()
