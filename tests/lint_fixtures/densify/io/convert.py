"""Silent: io/ is not a hot directory (core/kernels/serving only)."""


def export_matrix(a):
    return a.to_dense()
