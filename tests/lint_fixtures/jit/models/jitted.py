"""jit-retrace cases: mutable closure captures and per-call containers."""
import functools

import jax
import jax.numpy as jnp

_TUNING_TABLE = {"block": 128}


@jax.jit
def stale_capture(x):
    return x * _TUNING_TABLE["block"]            # finding (line 12): the
    # table's contents are baked in at first trace


@functools.partial(jax.jit, static_argnames=("n",))
def clean(x, n):
    scale = jnp.float32(n)
    return x * scale


@jax.jit
def frozen_capture(x):
    # the table is frozen after import by convention
    return x * _TUNING_TABLE["block"]  # lint: jit-ok(frozen after import)


def caller(q):
    bad = clean([q, q], 4)                       # finding (line 29): the
    # list literal's length becomes part of the trace key
    good = clean(q, 8)
    return bad + good
