"""Firing case: the PR 5-style plan-cache race, in miniature.

The worker thread installs plans into ``self._plans`` with no lock while
``submit`` reads the same dict — two threads, disjoint (empty) lock
sets, one side writing."""
import threading


class RacyEngine:
    def __init__(self):
        self._lock = threading.Lock()
        self._plans = {}
        self._queue = []
        self._worker = threading.Thread(target=self._worker_loop,
                                        daemon=True)
        self._worker.start()

    def _worker_loop(self):
        while True:
            with self._lock:
                if not self._queue:
                    continue
                key = self._queue.pop()
            self._plans[key] = object()          # finding (line 24): write
            # outside the lock submit() reads under

    def submit(self, key):
        self._queue.append(key)                  # finding (line 28): the
        # worker pops self._queue under self._lock; this append is bare
        return self._plans.get(key)              # finding (line 30)
