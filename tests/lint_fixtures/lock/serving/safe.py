"""Silent cases: both sides locked; annotated intentional races."""
import threading


class SafeEngine:
    def __init__(self):
        self._lock = threading.Lock()
        self._plans = {}
        self._capacity = 8                       # init-only: exempt
        self._worker = threading.Thread(target=self._worker_loop,
                                        daemon=True)
        self._worker.start()

    def _worker_loop(self):
        while True:
            with self._lock:
                self._plans["k"] = object()

    def submit(self, key):
        with self._lock:
            return self._plans.get(key, self._capacity)


class AnnotatedEngine:
    def __init__(self):
        self._lock = threading.Lock()
        self._hits = 0
        self._worker = threading.Thread(target=self._worker_loop,
                                        daemon=True)
        self._worker.start()

    def _worker_loop(self):
        while True:
            self._hits += 1  # lint: unlocked-ok(monotonic stat, torn read ok)

    def submit(self):
        return self._hits  # lint: unlocked-ok(approximate stat read)
