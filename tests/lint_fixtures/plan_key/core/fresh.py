"""Silent cases: token-carrying keys, annotated escapes, untainted keys."""
from repro import caches
from repro.core.formats import incremental_signature
from repro.core.planner import cost_model_token, structure_signature

_plan_cache = caches.LRUCache("fixture-fresh-plans", 8)


def lookup(a, m):
    key = (structure_signature(a), structure_signature(m),
           cost_model_token())
    return _plan_cache.get(key)


def lookup_via_local(a):
    token = cost_model_token()
    key = (structure_signature(a), token)
    return _plan_cache.get(key)


def structure_pure(a):
    key = (structure_signature(a), "prep")
    # host prep encodes no planner election — cost-model-invariant
    return _plan_cache.get(key)  # lint: plan-key-ok(structure-pure prep)


def untainted(name):
    return _plan_cache.get(("static", name))


def incremental_with_token(a):
    key = ("isig", incremental_signature(a), cost_model_token())
    return _plan_cache.get(key)


def incremental_annotated(a):
    # signature memo: pure structure identity, no planner election inside
    key = ("isig", incremental_signature(a))
    return _plan_cache.get(key)  # lint: plan-key-ok(isig memo)
