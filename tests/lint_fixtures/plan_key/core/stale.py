"""Firing cases: structure-keyed cache access without the token."""
from repro import caches
from repro.core.formats import incremental_signature
from repro.core.planner import structure_signature

_plan_cache = caches.LRUCache("fixture-stale-plans", 8)


def lookup(a, m):
    key = (structure_signature(a), structure_signature(m))
    hit = _plan_cache.get(key)                   # finding (line 11)
    if hit is None:
        hit = object()
        _plan_cache.put(key, hit)                # finding (line 14)
    return hit


def helper_lookup(a):
    sig = structure_signature(a)
    return plan_cache_get((sig, "row"))          # finding (line 20)


def incremental_lookup(a):
    # the delta path's signature is a taint source like the full one: a
    # token-less plan entry derived from it goes stale on recalibration
    key = ("isig", incremental_signature(a))
    return _plan_cache.get(key)                  # finding (line 27)


def plan_cache_get(key):
    return _plan_cache.get(key)                  # key is a param: untainted
