"""Firing cases: structure-keyed cache access without the token."""
from repro import caches
from repro.core.planner import structure_signature

_plan_cache = caches.LRUCache("fixture-stale-plans", 8)


def lookup(a, m):
    key = (structure_signature(a), structure_signature(m))
    hit = _plan_cache.get(key)                   # finding (line 10)
    if hit is None:
        hit = object()
        _plan_cache.put(key, hit)                # finding (line 13)
    return hit


def helper_lookup(a):
    sig = structure_signature(a)
    return plan_cache_get((sig, "row"))          # finding (line 19)


def plan_cache_get(key):
    return _plan_cache.get(key)                  # key is a param: untainted
