"""Element-level accumulators (paper Sec. 5) vs the dense oracle.

Covers: MSA / Hash / MCA / Heap / HeapDot / Inner, arbitrary semirings,
complemented masks (MSA, Heap), 1P/2P, mask-aligned stability.
"""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st, HealthCheck
except ImportError:  # container has no hypothesis; deterministic fallback
    from _hypothesis_shim import given, settings, strategies as st, HealthCheck

from repro.core.formats import csr_from_dense, padded_from_csr
from repro.core.masked_spgemm import masked_spgemm, dense_oracle, ALGORITHMS
from repro.core.semiring import PLUS_TIMES, MIN_PLUS, OR_AND, PLUS_SECOND

ALL_ALGOS = list(ALGORITHMS)


def make_problem(seed, m, k, n, da, db, dm):
    rng = np.random.default_rng(seed)
    A = (rng.random((m, k)) < da) * rng.uniform(0.5, 1.5, (m, k))
    B = (rng.random((k, n)) < db) * rng.uniform(0.5, 1.5, (k, n))
    M = (rng.random((m, n)) < dm).astype(np.float32)
    return A.astype(np.float32), B.astype(np.float32), M


def check(algorithm, A, B, M, semiring=PLUS_TIMES, complement=False,
          two_phase=False, **kw):
    Ac, Bc, Mc = csr_from_dense(A), csr_from_dense(B), csr_from_dense(M)
    want_vals, want_present = dense_oracle(A, B, M, semiring=semiring,
                                           complement=complement)
    out = masked_spgemm(Ac, Bc, Mc, algorithm=algorithm, semiring=semiring,
                        complement=complement, two_phase=two_phase, **kw)
    if complement:
        vals, present = out
        got_present = np.asarray(present)
        got_vals = np.asarray(vals)
    else:
        m, n = out.shape
        got_present = np.zeros((m, n), bool)
        got_vals = np.zeros((m, n), np.asarray(out.vals).dtype)
        rows, slots = np.nonzero(np.asarray(out.present))
        cols = np.asarray(out.mask_cols)[rows, slots]
        got_present[rows, cols] = True
        got_vals[rows, cols] = np.asarray(out.vals)[rows, slots]
    want_present = np.asarray(want_present)
    np.testing.assert_array_equal(got_present, want_present)
    np.testing.assert_allclose(got_vals[want_present],
                               np.asarray(want_vals)[want_present],
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("algorithm", ALL_ALGOS)
@pytest.mark.parametrize("density", [(0.1, 0.1, 0.1), (0.4, 0.3, 0.05),
                                     (0.05, 0.05, 0.6), (0.3, 0.3, 0.3)])
def test_matches_oracle(algorithm, density):
    da, db, dm = density
    A, B, M = make_problem(1, 17, 23, 19, da, db, dm)
    check(algorithm, A, B, M)


@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_empty_mask(algorithm):
    A, B, M = make_problem(2, 8, 8, 8, 0.3, 0.3, 0.2)
    M[:] = 0.0
    check(algorithm, A, B, M)


@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_empty_inputs(algorithm):
    A, B, M = make_problem(3, 8, 8, 8, 0.3, 0.3, 0.3)
    A[:] = 0.0
    check(algorithm, A, B, M)


@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_full_mask(algorithm):
    A, B, M = make_problem(4, 9, 7, 11, 0.3, 0.4, 1.1)
    assert (M == 1).all()
    check(algorithm, A, B, M)


@pytest.mark.parametrize("algorithm", ["msa", "heap"])
def test_complemented_mask(algorithm):
    A, B, M = make_problem(5, 13, 11, 12, 0.3, 0.3, 0.4)
    check(algorithm, A, B, M, complement=True)


def test_mca_complement_raises():
    A, B, M = make_problem(6, 4, 4, 4, 0.5, 0.5, 0.5)
    with pytest.raises(NotImplementedError):
        check("mca", A, B, M, complement=True)


@pytest.mark.parametrize("algorithm", ["msa", "hash", "mca", "inner"])
@pytest.mark.parametrize("semiring", [MIN_PLUS, OR_AND, PLUS_SECOND],
                         ids=lambda s: s.name)
def test_semirings(algorithm, semiring):
    A, B, M = make_problem(7, 11, 13, 9, 0.3, 0.3, 0.4)
    if semiring is OR_AND:
        A = (A > 0).astype(np.float32)
        B = (B > 0).astype(np.float32)
    check(algorithm, A, B, M, semiring=semiring)


@pytest.mark.parametrize("algorithm", ["heap", "heapdot"])
@pytest.mark.parametrize("semiring", [MIN_PLUS, PLUS_SECOND],
                         ids=lambda s: s.name)
def test_heap_semirings(algorithm, semiring):
    A, B, M = make_problem(8, 11, 13, 9, 0.3, 0.3, 0.4)
    check(algorithm, A, B, M, semiring=semiring)


@pytest.mark.parametrize("algorithm", ALL_ALGOS)
def test_two_phase_equals_one_phase(algorithm):
    A, B, M = make_problem(9, 10, 12, 14, 0.25, 0.25, 0.3)
    check(algorithm, A, B, M, two_phase=True)


def test_output_is_mask_aligned_and_sorted():
    A, B, M = make_problem(10, 12, 10, 15, 0.3, 0.3, 0.4)
    out = masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                        csr_from_dense(M), algorithm="msa")
    cols = np.asarray(out.mask_cols)
    n = out.shape[1]
    for i in range(cols.shape[0]):
        real = cols[i][cols[i] < n]
        assert (np.diff(real) > 0).all()  # sorted, unique (stable gather)


def test_symbolic_phase_counts():
    from repro.core.masked_spgemm import symbolic_phase
    A, B, M = make_problem(11, 14, 9, 13, 0.3, 0.3, 0.35)
    Ap = padded_from_csr(csr_from_dense(A))
    Bp = padded_from_csr(csr_from_dense(B))
    Mp = padded_from_csr(csr_from_dense(M))
    counts = np.asarray(symbolic_phase(Ap, Mp, Bp, shape=(14, 13), kdim=9))
    _, present = dense_oracle(A, B, M)
    np.testing.assert_array_equal(counts, np.asarray(present).sum(axis=1))


def test_result_to_csr_roundtrip():
    A, B, M = make_problem(12, 9, 9, 9, 0.35, 0.35, 0.4)
    out = masked_spgemm(csr_from_dense(A), csr_from_dense(B),
                        csr_from_dense(M), algorithm="hash")
    got = out.to_csr().to_dense()
    want = np.asarray(out.to_dense())
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 12), k=st.integers(1, 12), n=st.integers(1, 12),
    da=st.floats(0.0, 0.8), db=st.floats(0.0, 0.8), dm=st.floats(0.0, 1.0),
    algorithm=st.sampled_from(ALL_ALGOS),
)
def test_property_matches_oracle(seed, m, k, n, da, db, dm, algorithm):
    A, B, M = make_problem(seed, m, k, n, da, db, dm)
    check(algorithm, A, B, M)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1),
       algorithm=st.sampled_from(["msa", "heap"]))
def test_property_complement(seed, algorithm):
    A, B, M = make_problem(seed, 9, 8, 10, 0.3, 0.3, 0.4)
    check(algorithm, A, B, M, complement=True)
