"""Distributed masked SpGEMM: subprocess with 8 forced host devices.

The main pytest process must keep seeing 1 device (smoke tests depend on
it), so the multi-device checks run in a child interpreter.
"""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_distributed_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "dist_check.py")],
        capture_output=True, text=True, timeout=540, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "DIST_ALL_OK" in proc.stdout


@pytest.mark.slow
def test_distributed_sparse_subprocess():
    """Sparse BCSR ring-SUMMA vs the single-device driver and the dense
    oracle, across mesh sizes {1, 2, 4, 8} on forced host devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "dist_sparse_check.py")],
        capture_output=True, text=True, timeout=540, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "DIST_SPARSE_ALL_OK" in proc.stdout
