"""Mini multi-pod dry-run: the production spec machinery must lower and
compile smoke configs on a (2,2,2) pod mesh (subprocess, 8 host devices)."""
import os
import pathlib
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.mark.slow
def test_mini_dryrun():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "dryrun_mini_check.py")],
        capture_output=True, text=True, timeout=560, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    assert "MINI_DRYRUN_OK" in r.stdout
