"""Cross-algorithm equivalence matrix vs the dense oracle.

Every entry of ALGORITHMS x {plus_times, min_plus, boolean} x {masked,
complemented} is checked against ``dense_oracle``; the combinations the
paper documents as unsupported (hash/MCA/inner + complement, Sec. 8.4) are
covered with explicit ``pytest.raises(NotImplementedError)``.
"""
import numpy as np
import pytest

from repro.core.masked_spgemm import ALGORITHMS
from repro.core.semiring import MIN_PLUS, OR_AND, PLUS_TIMES

from test_accumulators import check, make_problem

SEMIRINGS = {"plus_times": PLUS_TIMES, "min_plus": MIN_PLUS,
             "boolean": OR_AND}

#: algorithms whose row kernels reject complement (paper Sec. 8.4)
NO_COMPLEMENT = ("hash", "mca", "inner")


def matrix_problem(semiring_name):
    A, B, M = make_problem(41, 13, 11, 12, 0.3, 0.3, 0.4)
    if semiring_name == "boolean":
        A = (A > 0).astype(np.float32)
        B = (B > 0).astype(np.float32)
    return A, B, M


@pytest.mark.parametrize("semiring", sorted(SEMIRINGS))
@pytest.mark.parametrize("algorithm", ALGORITHMS)
def test_masked(algorithm, semiring):
    A, B, M = matrix_problem(semiring)
    check(algorithm, A, B, M, semiring=SEMIRINGS[semiring])


@pytest.mark.parametrize("semiring", sorted(SEMIRINGS))
@pytest.mark.parametrize(
    "algorithm", [a for a in ALGORITHMS if a not in NO_COMPLEMENT])
def test_complemented(algorithm, semiring):
    A, B, M = matrix_problem(semiring)
    check(algorithm, A, B, M, semiring=SEMIRINGS[semiring],
          complement=True)


@pytest.mark.parametrize("semiring", sorted(SEMIRINGS))
@pytest.mark.parametrize("algorithm", NO_COMPLEMENT)
def test_complement_unsupported_raises(algorithm, semiring):
    A, B, M = matrix_problem(semiring)
    with pytest.raises(NotImplementedError):
        check(algorithm, A, B, M, semiring=SEMIRINGS[semiring],
              complement=True)
