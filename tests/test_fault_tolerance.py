"""Fault tolerance: crash at step 4 (8 devices), elastic resume on 4
devices, trajectory must equal an uninterrupted oracle run."""
import os
import pathlib
import re
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def run_phase(phase, ckpt):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, str(ROOT / "tests" / "ft_check.py"), phase, ckpt],
        capture_output=True, text=True, timeout=540, env=env)


def losses_of(out):
    return {int(m.group(1)): float(m.group(2))
            for m in re.finditer(r"LOSS (\d+) ([0-9.]+)", out)}


@pytest.mark.slow
def test_crash_resume_elastic(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    full = run_phase("full", ckpt)
    assert full.returncode == 42, full.stdout + full.stderr   # crashed
    assert "CRASH" in full.stdout

    resume = run_phase("resume", ckpt)
    assert resume.returncode == 0, resume.stdout + resume.stderr
    oracle = run_phase("oracle", "")
    assert oracle.returncode == 0, oracle.stdout + oracle.stderr

    l_full = losses_of(full.stdout)
    l_res = losses_of(resume.stdout)
    l_orc = losses_of(oracle.stdout)
    # pre-crash steps match oracle
    for s in range(4):
        assert abs(l_full[s] - l_orc[s]) < 1e-4, (s, l_full[s], l_orc[s])
    # resumed (4-device!) steps match oracle (8-device) trajectory
    for s in (4, 5):
        assert abs(l_res[s] - l_orc[s]) < 5e-3, (s, l_res[s], l_orc[s])
