"""Storage formats: CSR/PaddedCSR/BCSR round-trips and invariants."""
import numpy as np
import pytest
import jax.numpy as jnp
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container has no hypothesis; deterministic fallback
    from _hypothesis_shim import given, settings, strategies as st

from repro.core.formats import (
    CSR, csr_from_dense, csr_from_coo, padded_from_csr, padded_from_dense,
    bcsr_concat_row_panels, bcsr_from_csr, bcsr_from_dense,
    bcsr_pad_block_rows, bcsr_row_panels, bcsr_structure_transpose,
    erdos_renyi, pad_panel_blocks, rmat, random_mask_like, tril,
)


def rand_dense(seed, m, n, density):
    rng = np.random.default_rng(seed)
    return ((rng.random((m, n)) < density)
            * rng.uniform(0.5, 1.5, (m, n))).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), m=st.integers(1, 20), n=st.integers(1, 20),
       density=st.floats(0, 1))
def test_csr_dense_roundtrip(seed, m, n, density):
    a = rand_dense(seed, m, n, density)
    np.testing.assert_array_equal(csr_from_dense(a).to_dense(), a)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), m=st.integers(1, 16), n=st.integers(1, 16))
def test_csr_transpose(seed, m, n):
    a = rand_dense(seed, m, n, 0.3)
    np.testing.assert_array_equal(csr_from_dense(a).transpose().to_dense(), a.T)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10**6), m=st.integers(1, 16), n=st.integers(1, 16),
       density=st.floats(0, 1))
def test_padded_roundtrip(seed, m, n, density):
    a = rand_dense(seed, m, n, density)
    p = padded_from_dense(a)
    np.testing.assert_allclose(np.asarray(p.to_dense()), a, rtol=1e-6)
    # rows sorted, pads == n
    cols = np.asarray(p.cols)
    for i in range(m):
        real = cols[i][: int(p.lens[i])]
        assert (np.diff(real) > 0).all()
        assert (cols[i][int(p.lens[i]):] == n).all()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10**6), m=st.integers(1, 33), n=st.integers(1, 33),
       bs=st.sampled_from([2, 4, 8]))
def test_bcsr_roundtrip(seed, m, n, bs):
    a = rand_dense(seed, m, n, 0.2)
    b = bcsr_from_dense(a, bs)
    np.testing.assert_array_equal(b.to_dense(), a)


def test_bcsr_structure_transpose():
    a = rand_dense(3, 24, 16, 0.3)
    b = bcsr_from_dense(a, 4)
    indptr_t, rows_t, pos_t = bcsr_structure_transpose(b)
    # reconstruct block set from the transposed view
    seen = set()
    for j in range(len(indptr_t) - 1):
        for p in range(indptr_t[j], indptr_t[j + 1]):
            i = rows_t[p]
            seen.add((int(i), int(j)))
            assert int(b.indices[pos_t[p]]) == j
    want = set()
    for i in range(b.block_rows):
        for j in b.block_row(i):
            want.add((int(i), int(j)))
    assert seen == want


def test_coo_duplicate_sum():
    c = csr_from_coo([0, 0, 1], [1, 1, 0], [1.0, 2.0, 5.0], (2, 2))
    d = c.to_dense()
    assert d[0, 1] == 3.0 and d[1, 0] == 5.0 and c.nnz == 2


def test_erdos_renyi_properties():
    g = erdos_renyi(200, 8.0, seed=1)
    assert g.shape == (200, 200)
    assert abs(g.nnz / 200 - 8.0) < 1.5  # ~Poisson(8) mean


def test_rmat_properties():
    g = rmat(8, edge_factor=8, seed=2)
    n = 1 << 8
    assert g.shape == (n, n)
    d = g.to_dense()
    np.testing.assert_array_equal(d, d.T)   # symmetric
    assert np.diagonal(d).sum() == 0        # no self loops


def test_tril_and_mask():
    g = erdos_renyi(50, 5.0, seed=3)
    L = tril(g)
    d = L.to_dense()
    assert np.triu(d).sum() == 0
    m = random_mask_like(g, 0.5, seed=4)
    gd = g.to_dense() != 0
    md = m.to_dense() != 0
    assert (md & ~gd).sum() == 0  # mask pattern subset of g


# --------------------------------------------------------------------------
# BCSR panel helpers (distributed ring-SUMMA building blocks)
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10 ** 6), m=st.integers(1, 40),
       n=st.integers(1, 40), bs=st.sampled_from([4, 8]),
       nparts=st.sampled_from([1, 2, 4]))
def test_bcsr_panel_split_concat_roundtrip(seed, m, n, bs, nparts):
    a = rand_dense(seed, m, n, 0.3)
    b = bcsr_from_csr(csr_from_dense(a), bs)
    padded = bcsr_pad_block_rows(b, -(-b.block_rows // nparts) * nparts)
    panels = bcsr_row_panels(padded, nparts)
    assert len(panels) == nparts
    assert sum(p.nnzb for p in panels) == b.nnzb
    back = bcsr_concat_row_panels(panels)
    np.testing.assert_array_equal(back.indptr, padded.indptr)
    np.testing.assert_array_equal(back.indices, padded.indices)
    np.testing.assert_array_equal(np.asarray(back.blocks),
                                  np.asarray(padded.blocks))
    np.testing.assert_array_equal(back.to_dense()[:m, :n], a)


def test_bcsr_pad_block_rows_is_structural_noop():
    a = rand_dense(3, 20, 20, 0.3)
    b = bcsr_from_csr(csr_from_dense(a), 8)
    padded = bcsr_pad_block_rows(b, b.block_rows + 3)
    assert padded.block_rows == b.block_rows + 3
    assert padded.nnzb == b.nnzb
    np.testing.assert_array_equal(padded.to_dense()[:20, :20], a)
    with pytest.raises(ValueError):
        bcsr_pad_block_rows(b, b.block_rows - 1)


def test_pad_panel_blocks_static_shape():
    a = rand_dense(4, 16, 16, 0.4)
    b = bcsr_from_csr(csr_from_dense(a), 8)
    padded = pad_panel_blocks(b.blocks, b.nnzb + 5)
    assert padded.shape == (b.nnzb + 5, 8, 8)
    np.testing.assert_array_equal(np.asarray(padded[:b.nnzb]),
                                  np.asarray(b.blocks))
    assert np.abs(np.asarray(padded[b.nnzb:])).sum() == 0.0
    # empty in, at-least-one-block out (ppermute needs nonzero extents)
    empty = bcsr_from_csr(csr_from_dense(np.zeros((8, 8), np.float32)), 8)
    assert pad_panel_blocks(empty.blocks, 0).shape == (1, 8, 8)
