"""Graph applications vs networkx ground truth (paper §8 benchmarks)."""
import networkx as nx
import numpy as np
import pytest

from repro.core.formats import CSR, csr_from_dense, erdos_renyi, rmat
from repro.graphs import triangle_count, ktruss, betweenness_centrality


def nx_to_csr(g: nx.Graph) -> CSR:
    n = g.number_of_nodes()
    a = np.zeros((n, n), np.float32)
    for u, v in g.edges():
        a[u, v] = a[v, u] = 1.0
    return csr_from_dense(a)


def random_graph(seed, n=40, p=0.15) -> nx.Graph:
    return nx.gnp_random_graph(n, p, seed=seed)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("algorithm", ["msa", "hash", "mca", "heap", "inner"])
def test_triangle_count(seed, algorithm):
    g = random_graph(seed)
    want = sum(nx.triangles(g).values()) // 3
    got, _ = triangle_count(nx_to_csr(g), algorithm=algorithm)
    assert got == want


def test_triangle_count_no_relabel():
    g = random_graph(3)
    want = sum(nx.triangles(g).values()) // 3
    got, _ = triangle_count(nx_to_csr(g), relabel=False)
    assert got == want


@pytest.mark.parametrize("k", [3, 4, 5])
def test_ktruss(k):
    g = random_graph(4, n=30, p=0.25)
    truss, _, _, _ = ktruss(nx_to_csr(g), k)
    # networkx k-truss: k there == our k
    want = nx.k_truss(g, k)
    got_edges = set()
    d = truss.to_dense()
    for i, j in zip(*np.nonzero(d)):
        if i < j:
            got_edges.add((int(i), int(j)))
    want_edges = {(min(u, v), max(u, v)) for u, v in want.edges()}
    assert got_edges == want_edges


@pytest.mark.parametrize("seed", [0, 5])
@pytest.mark.parametrize("algorithm", ["msa", "heap"])
def test_betweenness_all_sources(seed, algorithm):
    g = random_graph(seed, n=25, p=0.2)
    bc, _, calls = betweenness_centrality(nx_to_csr(g), algorithm=algorithm)
    want = nx.betweenness_centrality(g, normalized=False)
    got = {v: bc[v] for v in range(g.number_of_nodes())}
    for v in want:
        assert abs(got[v] - want[v]) < 1e-3, (v, got[v], want[v])
    assert calls > 0


def test_betweenness_subset_sources():
    g = random_graph(7, n=20, p=0.25)
    srcs = [0, 3, 5]
    bc, _, _ = betweenness_centrality(nx_to_csr(g), sources=srcs)
    want = nx.betweenness_centrality_subset(g, sources=srcs,
                                            targets=list(g.nodes()),
                                            normalized=False)
    # subset BC in networkx counts (s in srcs, t any) ordered pairs / 2
    for v in want:
        assert abs(bc[v] - want[v]) < 1e-3, (v, bc[v], want[v])


def test_triangle_on_rmat():
    adj = rmat(7, edge_factor=4, seed=1)
    d = adj.to_dense()
    g = nx.from_numpy_array(np.asarray(d))
    want = sum(nx.triangles(g).values()) // 3
    got, _ = triangle_count(adj)
    assert got == want


@pytest.mark.parametrize("algorithm", ["mca", "hash", "inner"])
def test_betweenness_complement_incapable_algorithms(algorithm):
    """Regression: the forward sweep runs under complement=True, which
    hash/mca/inner cannot do — they used to raise NotImplementedError
    mid-sweep.  They must be coerced up front and produce correct BC."""
    g = random_graph(6, n=25, p=0.2)
    bc, _, calls = betweenness_centrality(nx_to_csr(g), algorithm=algorithm)
    want = nx.betweenness_centrality(g, normalized=False)
    for v in want:
        assert abs(bc[v] - want[v]) < 1e-3, (v, bc[v], want[v])
    assert calls > 0


def test_betweenness_chunked_sources_matches_unchunked():
    """source_chunks routes through masked_spgemm_batched (one plan per
    depth); results must match the per-call path exactly."""
    g = random_graph(9, n=22, p=0.25)
    a = nx_to_csr(g)
    srcs = [0, 2, 4, 7, 11]
    want, _, _ = betweenness_centrality(a, sources=srcs, algorithm="msa")
    got, _, calls = betweenness_centrality(a, sources=srcs, algorithm="msa",
                                           source_chunks=2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert calls > 0
