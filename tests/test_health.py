"""Online health intelligence (PR 10): windows, SLOs, drift, report.

The contracts under test:

* :class:`repro.obs.health.WindowAggregator` is a span sink with O(1)
  memory (ring-buffered shards, bounded samples) whose windows are
  driven entirely by the injectable clock — a virtual clock advances
  them deterministically, and data past the horizon expires;
* the SLO engine turns declarative objectives into multi-window burn
  rates: ``failing`` needs both windows hot, ``degraded`` only the
  long one, idle windows stay ``ok``;
* the drift detector folds normalized ``serve.exec`` residuals into
  per-(family, kernel, regime) Welford/EWMA stats, flags beyond the
  band with a concrete ``repro.tune --only`` recommendation, resets on
  a cost-model-token change, and skips burst-route spans;
* ``engine.health()`` + ``/health`` (503-with-reasons when failing) +
  ``/metrics`` ``repro_slo_*``/``repro_drift_*`` surface all of it;
* ``python -m repro.obs.report`` loads committed grid generations from
  git history and machine-flags acceptance-flag regressions.
"""
import json
import math
import subprocess
import sys
import urllib.error
import urllib.request

import pytest

from repro import caches, obs
from repro.core.formats import erdos_renyi, er_mask
from repro.core import planner
from repro.obs import report as report_mod
from repro.obs.drift import DriftDetector, family_of
from repro.obs.health import (HealthMonitor, HealthVerdict,
                              WindowAggregator, basic_verdict)
from repro.obs.slo import DEFAULT_SLOS, Objective, SLOEngine
from repro.serving import QueryEngine
from repro.serving.clock import VirtualClock


@pytest.fixture(autouse=True)
def _tracing_off():
    obs.disable()
    yield
    obs.disable()


def _operands(n=64, seed=0):
    return (erdos_renyi(n, 3, seed=seed), erdos_renyi(n, 3, seed=seed + 1),
            er_mask(n, 6, seed=seed + 2))


def _exec(dur=0.01, size=1, **attrs):
    return {"name": "serve.exec", "dur": dur,
            "attrs": {"size": size, **attrs}}


# ---------------------------------------------------------------------------
# WindowAggregator: ring shards on the injectable clock
# ---------------------------------------------------------------------------


def test_aggregator_windows_follow_virtual_clock():
    clk = VirtualClock()
    agg = WindowAggregator(clock=clk, horizon_s=60.0, shards=12)
    clk.advance(1.0)
    for _ in range(10):
        agg.emit({"name": "serve.error"})
        agg.emit(_exec())
    assert agg.window(60).count("serve.error") == 10
    assert agg.window(60).req_count("serve.exec") == 10
    assert agg.window(60).dur_sum("serve.exec") == pytest.approx(0.1)
    # advance past the short window but not the long one
    clk.advance(10.0)
    assert agg.window(5).count("serve.error") == 0
    assert agg.window(60).count("serve.error") == 10
    # advance past the horizon: everything expires (epoch check on read)
    clk.advance(120.0)
    assert agg.window(60).count("serve.error") == 0


def test_aggregator_ring_reuses_shards_in_place():
    clk = VirtualClock()
    agg = WindowAggregator(clock=clk, horizon_s=12.0, shards=4)
    for _ in range(50):            # many horizons worth of traffic
        agg.emit(_exec())
        clk.advance(3.0)           # one shard per emit
    assert len(agg._ring) == 4     # structure never grows
    # only the trailing horizon is visible
    assert agg.window(12).count("serve.exec") <= 4


def test_aggregator_bounds_percentile_samples():
    clk = VirtualClock()
    agg = WindowAggregator(clock=clk, horizon_s=60.0, shards=12,
                           sample_cap=4)
    for i in range(10):
        agg.emit(_exec(dur=i * 0.01))
    w = agg.window(60)
    assert w.count("serve.exec") == 10          # counts are exact
    assert len(w.samples("serve.exec")) == 4    # samples are bounded
    assert w.percentile("serve.exec", 0.99) <= 0.03


def test_aggregator_gauges_latest_wins():
    clk = VirtualClock()
    agg = WindowAggregator(clock=clk, horizon_s=60.0, shards=12)
    agg.emit({"name": "serve.queue_depth", "counter": 3.0})
    agg.emit({"name": "serve.queue_depth", "counter": 7.0})
    assert agg.window(60).gauge("serve.queue_depth") == 7.0
    clk.advance(6.0)                            # next shard
    agg.emit({"name": "serve.queue_depth", "counter": 1.0})
    assert agg.window(60).gauge("serve.queue_depth") == 1.0
    assert agg.window(60).gauge("missing") is None


def test_aggregator_validates_construction():
    with pytest.raises(ValueError):
        WindowAggregator(clock=VirtualClock(), horizon_s=0)
    with pytest.raises(ValueError):
        WindowAggregator(clock=VirtualClock(), shards=1)


# ---------------------------------------------------------------------------
# SLO engine: declarative objectives -> multi-window burn verdicts
# ---------------------------------------------------------------------------


def test_objective_derives_budgets_and_validates():
    assert Objective("p", "latency_p99", bound=0.25).budget == 0.01
    assert Objective("e", "error_rate", bound=0.02).budget == 0.02
    assert Objective("h", "cache_hit_rate", bound=0.9).budget \
        == pytest.approx(0.1)
    assert Objective("q", "queue_wait_share", bound=0.5).budget == 0.5
    with pytest.raises(ValueError, match="unknown SLO metric"):
        Objective("x", "nope", bound=1.0)
    with pytest.raises(ValueError, match="budget"):
        Objective("x", "error_rate", bound=0.01, budget=2.0)
    with pytest.raises(ValueError, match="short_s"):
        Objective("x", "error_rate", bound=0.01, short_s=90, long_s=60)
    with pytest.raises(ValueError, match="duplicate"):
        SLOEngine([Objective("a", "error_rate", bound=0.1)] * 2)


def _err_objective(**kw):
    kw.setdefault("min_events", 1)
    return Objective("err", "error_rate", bound=0.25, short_s=5.0,
                     long_s=60.0, **kw)


def test_slo_failing_needs_both_windows_degraded_only_long():
    clk = VirtualClock()
    agg = WindowAggregator(clock=clk, horizon_s=60.0, shards=12)
    eng = SLOEngine([_err_objective()])
    clk.advance(1.0)
    for _ in range(10):
        agg.emit({"name": "serve.error"})
        agg.emit(_exec())
    (st,) = eng.evaluate(agg)      # bad_frac 0.5 / budget 0.25 = 2.0x
    assert st.status == "failing" and "err" in st.reason
    assert st.burn_long == pytest.approx(2.0)
    # once the errors age out of the short window: degraded, not failing
    clk.advance(10.0)
    (st,) = eng.evaluate(agg)
    assert st.status == "degraded"
    assert st.burn_short == 0.0
    assert st.burn_long == pytest.approx(2.0)
    # and past the horizon: clean
    clk.advance(120.0)
    (st,) = eng.evaluate(agg)
    assert st.status == "ok" and st.reason == ""


def test_slo_idle_and_sparse_windows_stay_ok():
    clk = VirtualClock()
    agg = WindowAggregator(clock=clk, horizon_s=60.0, shards=12)
    eng = SLOEngine(DEFAULT_SLOS)
    assert all(st.status == "ok" for st in eng.evaluate(agg))
    # below min_events: even a 100% error rate must not flap the verdict
    agg.emit({"name": "serve.error"})
    assert all(st.status == "ok" for st in eng.evaluate(agg))


def test_slo_latency_p99_counts_over_bound_samples():
    clk = VirtualClock()
    agg = WindowAggregator(clock=clk, horizon_s=60.0, shards=12)
    obj = Objective("lat", "latency_p99", bound=0.1, budget=0.1,
                    min_events=1)
    eng = SLOEngine([obj])
    for _ in range(8):
        agg.emit(_exec(dur=0.01))
    (st,) = eng.evaluate(agg)
    assert st.status == "ok" and st.burn_long == 0.0
    for _ in range(8):
        agg.emit(_exec(dur=0.5))      # half the samples over the bound
    (st,) = eng.evaluate(agg)
    assert st.burn_long == pytest.approx(5.0)   # 0.5 / 0.1
    assert st.status == "failing"


def test_slo_queue_wait_share_and_hit_rate():
    clk = VirtualClock()
    agg = WindowAggregator(clock=clk, horizon_s=60.0, shards=12)
    for _ in range(4):
        agg.emit({"name": "serve.queue_wait", "dur": 0.9})
        agg.emit(_exec(dur=0.1))
        agg.emit({"name": "serve.submit", "dur": 0.0})
    qw = Objective("qw", "queue_wait_share", bound=0.4, min_events=1)
    (st,) = SLOEngine([qw]).evaluate(agg)
    assert st.burn_long == pytest.approx(0.9 / 0.4)  # share/budget
    assert st.status == "failing"
    hit = Objective("hits", "cache_hit_rate", bound=0.5, min_events=1)
    (st,) = SLOEngine([hit]).evaluate(agg)  # 0 hits of 4 submits
    assert st.burn_long == pytest.approx(2.0)        # miss 1.0 / budget 0.5
    assert st.status == "failing"


def test_health_verdict_worst_of_merges_reasons():
    a = HealthVerdict("ok")
    b = HealthVerdict("degraded", ("slow",))
    c = HealthVerdict("failing", ("down", "slow"))
    worst = HealthVerdict.worst(a, b, c)
    assert worst.status == "failing" and not worst.ok
    assert worst.reasons == ("slow", "down")        # deduped, ordered
    assert HealthVerdict.worst().status == "ok"
    assert b.as_dict() == {"status": "degraded", "reasons": ["slow"]}


# ---------------------------------------------------------------------------
# HealthMonitor: sink protocol, tee, verdict composition
# ---------------------------------------------------------------------------


def test_monitor_tees_to_inner_sink_and_exposes_spans():
    clk = VirtualClock()
    inner = obs.InMemorySink(capacity=64)
    mon = HealthMonitor(clock=clk, inner=inner, drift=None)
    with obs.tracing(mon):
        obs.event("serve.exec", dur_s=0.01, size=1)
        obs.counter("serve.queue_depth", 2)
    assert len(mon.spans()) == 2                    # tee preserved records
    assert mon.aggregator.window(60).count("serve.exec") == 1
    assert mon.aggregator.window(60).gauge("serve.queue_depth") == 2.0
    assert HealthMonitor(clock=clk).spans() == []   # no inner: empty


def test_monitor_verdict_folds_liveness_and_slos():
    clk = VirtualClock()
    mon = HealthMonitor(clock=clk, drift=None,
                        slos=[_err_objective()])
    assert mon.verdict().status == "ok"
    clk.advance(1.0)
    for _ in range(10):
        mon.emit({"name": "serve.error"})
        mon.emit(_exec())
    v = mon.verdict()
    assert v.status == "failing" and any("err" in r for r in v.reasons)
    # a stopped engine fails the verdict regardless of SLO state
    eng = QueryEngine()
    eng.close()
    v = HealthMonitor(clock=VirtualClock(), drift=None).verdict(engine=eng)
    assert v.status == "failing" and "engine stopped" in v.reasons
    assert basic_verdict(eng).status == "failing"


def test_engine_health_without_monitor_is_liveness_only():
    with QueryEngine() as eng:
        assert eng.monitor is None
        assert eng.health().status == "ok"
    assert eng.health().status == "failing"


# ---------------------------------------------------------------------------
# drift detector
# ---------------------------------------------------------------------------


def test_drift_flags_warped_model_quiet_when_calibrated():
    det = DriftDetector(band=4.0, min_count=8, token_fn=lambda: "tok")
    for _ in range(20):
        det.observe("msa", "r1", 1.2)      # calibrated-ish
    assert det.flags() == []
    for _ in range(20):
        det.observe("hash", "r1", 1 / 64)  # modeled 64x too high
    (flag,) = det.flags()
    assert flag.algorithm == "hash" and flag.family == "row"
    assert flag.ewma_residual == pytest.approx(1 / 64, rel=0.05)
    assert "modeled >> measured" in flag.reason
    rep = det.report()
    assert rep.families == ("row",)
    assert "python -m repro.tune --only row" in rep.command
    assert rep.token == "tok"
    assert det.snapshot()["row/hash/r1"]["count"] == 20


def test_drift_needs_min_count_before_flagging():
    det = DriftDetector(band=2.0, min_count=8, token_fn=lambda: "t")
    for _ in range(7):
        det.observe("msa", "r", 100.0)
    assert det.flags() == []               # one short of min_count
    det.observe("msa", "r", 100.0)
    assert len(det.flags()) == 1
    assert det.report().command            # recommendation materializes


def test_drift_resets_on_cost_model_token_change():
    tok = ["t1"]
    det = DriftDetector(band=2.0, min_count=4, token_fn=lambda: tok[0])
    for _ in range(10):
        det.observe("msa", "r", 100.0)
    assert det.flags() and det.token == "t1"
    tok[0] = "t2"                          # retuned table: stats void
    det.observe("msa", "r", 1.0)
    assert det.token == "t2"
    assert det.flags() == []
    assert det.snapshot()["row/msa/r"]["count"] == 1


def test_drift_observe_record_normalizes_by_size_skips_burst():
    det = DriftDetector(band=2.0, min_count=1, token_fn=lambda: "t")
    det.observe_record({"name": "serve.exec", "dur": 8e-3,
                        "attrs": {"modeled_ms": 1.0, "size": 8,
                                  "algorithm": "msa", "route": "batched",
                                  "regime": "r"}})
    st = det.snapshot()["row/msa/r"]
    assert st["count"] == 1
    assert st["ewma_residual"] == pytest.approx(1.0)   # 8ms / (1ms * 8)
    det.observe_record({"name": "serve.exec", "dur": 1.0,
                        "attrs": {"modeled_ms": 1.0, "size": 1,
                                  "algorithm": "msa", "route": "burst",
                                  "regime": "r"}})
    assert det.snapshot()["row/msa/r"]["count"] == 1   # burst skipped
    # non-residual records are ignored, not fatal
    det.observe_record({"name": "serve.submit"})
    det.observe_record({"name": "serve.exec", "counter": 1.0})
    assert det.ingest(None) == 0
    assert det.ingest([_exec()]) == 0                  # no modeled_ms


def test_drift_welford_matches_batch_statistics():
    from repro.obs.drift import KernelStats
    vals = [0.5, 1.0, 2.0, 4.0, 8.0]
    st = KernelStats()
    for v in vals:
        st.update(math.log(v))
    mean = sum(math.log(v) for v in vals) / len(vals)
    var = (sum((math.log(v) - mean) ** 2 for v in vals)
           / (len(vals) - 1))
    assert st.mean == pytest.approx(mean)
    assert st.variance == pytest.approx(var)
    assert st.mean_residual == pytest.approx(math.exp(mean))


def test_family_mapping_covers_kernels():
    assert family_of("msa") == family_of("hash") == "row"
    assert family_of("tile") == "tile"
    assert family_of("spsumma") == "dist"
    assert family_of(None) == "row"        # row kernels are the default
    with pytest.raises(ValueError):
        DriftDetector(band=1.0)


# ---------------------------------------------------------------------------
# planner hooks: feature_regime + bounded explain memo (satellite 1)
# ---------------------------------------------------------------------------


def test_feature_regime_is_stable_and_scale_sensitive():
    A, B, M = _operands(n=64)
    p = planner.plan(A, B, M)
    r1 = planner.feature_regime(p)
    assert isinstance(r1, str) and r1 == planner.feature_regime(p)
    A2, B2, M2 = _operands(n=512, seed=9)
    assert planner.feature_regime(planner.plan(A2, B2, M2)) != r1


def test_explain_memo_registered_and_bounded():
    info = caches.cache_info()
    assert "planner-explain" in info       # cache-registry lint contract
    assert info["planner-explain"]["capacity"] >= 1
    # memoization works and set_capacity bounds it immediately
    A, B, M = _operands(seed=5)
    p = planner.plan(A, B, M)
    assert planner.explain_cached(p) is planner.explain_cached(p)
    old_cap = info["planner-explain"]["capacity"]
    try:
        caches.set_capacity("planner-explain", 1)
        assert caches.cache_info()["planner-explain"]["size"] <= 1
    finally:
        caches.set_capacity("planner-explain", old_cap)


def test_explain_memo_cap_env_var():
    """$REPRO_EXPLAIN_MEMO_CAP bounds the memo at import (subprocess:
    the cache is created when repro.core.planner first loads)."""
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = ("from repro import caches; import repro.core.planner; "
            "print(caches.cache_info()['planner-explain']['capacity'])")
    env = dict(os.environ, PYTHONPATH=os.path.join(root, "src"),
               REPRO_EXPLAIN_MEMO_CAP="17", JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, env=env,
                         cwd=root, timeout=240)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip().splitlines()[-1] == "17"


# ---------------------------------------------------------------------------
# engine + HTTP integration: verdicts on the wire
# ---------------------------------------------------------------------------


def test_induced_pressure_flips_health_to_503_with_reasons():
    A, B, M = _operands(seed=61)
    mon = HealthMonitor(drift=None)
    with QueryEngine(monitor=mon, expose_port=0) as eng:
        base = eng.obs_server.url
        with obs.tracing(mon):
            eng.serve([(A, B, M)] * 4)
            with urllib.request.urlopen(f"{base}/health", timeout=10) as r:
                healthy = json.loads(r.read().decode())
            assert r.status == 200 and healthy["status"] == "ok"
            assert healthy["reasons"] == []
            # hash+complement raises NotImplementedError in the bucket:
            # a deterministic error storm that burns the error budget
            bad = [eng.submit(A, B, M, algorithm="hash", complement=True)
                   for _ in range(16)]
            eng.flush()
            for t in bad:
                with pytest.raises(NotImplementedError):
                    t.result()
            assert eng.health().status == "failing"
            with pytest.raises(urllib.error.HTTPError) as exc:
                urllib.request.urlopen(f"{base}/health", timeout=10)
            assert exc.value.code == 503
            payload = json.loads(exc.value.read().decode())
            assert payload["status"] == "failing"
            assert any("serve-errors" in r for r in payload["reasons"])


def test_metrics_exposition_gains_slo_and_drift_families():
    A, B, M = _operands(seed=71)
    mon = HealthMonitor()
    mon.drift._token_fn = lambda: "tok"     # hermetic: no planner import
    for _ in range(10):
        mon.drift.observe("msa", "r1", 1 / 64)
    with QueryEngine(monitor=mon, expose_port=0) as eng:
        with obs.tracing(mon):
            eng.serve([(A, B, M)] * 2)
        base = eng.obs_server.url
        with urllib.request.urlopen(f"{base}/metrics", timeout=10) as r:
            text = r.read().decode()
    samples = obs.parse_prometheus(text)
    assert samples[("repro_slo_burn_rate",
                    (("slo", "serve-errors"), ("window", "long")))] == 0.0
    assert samples[("repro_slo_healthy",
                    (("slo", "serve-latency-p99"),))] == 1.0
    assert ("repro_health_status", ()) in samples
    drift_labels = (("algorithm", "msa"), ("family", "row"),
                    ("regime", "r1"))
    assert samples[("repro_drift_observations", drift_labels)] == 10.0
    assert samples[("repro_drift_flagged", drift_labels)] == 1.0
    assert samples[("repro_drift_flagged_families", ())] == 1.0
    assert samples[("repro_drift_ewma_residual", drift_labels)] \
        == pytest.approx(1 / 64, rel=0.05)


def test_render_prometheus_without_monitor_has_no_slo_families():
    with QueryEngine() as eng:
        text = obs.render_prometheus(eng)
    assert "repro_slo_" not in text and "repro_drift_" not in text


# ---------------------------------------------------------------------------
# trajectory report (python -m repro.obs.report)
# ---------------------------------------------------------------------------


def _git(args, cwd):
    return subprocess.run(["git", *args], cwd=str(cwd),
                          capture_output=True, text=True)


@pytest.fixture
def grid_repo(tmp_path):
    repo = tmp_path / "repo"
    bench = repo / "results" / "bench"
    bench.mkdir(parents=True)
    assert _git(["init", "-q"], repo).returncode == 0
    _git(["config", "user.email", "t@example.com"], repo)
    _git(["config", "user.name", "t"], repo)

    def commit(payload, msg="gen"):
        text = (payload if isinstance(payload, str)
                else json.dumps(payload))
        (bench / "unit_grid.json").write_text(text)
        _git(["add", "-A"], repo)
        assert _git(["commit", "-qm", msg], repo).returncode == 0

    return repo, bench, commit


def test_report_tracks_generations_and_trends(grid_repo, tmp_path):
    repo, bench, commit = grid_repo
    commit({"perf": {"qps": 100.0}, "_ok": True}, "gen1")
    commit({"perf": {"qps": 150.0}, "_ok": True}, "gen2")
    rep = report_mod.build_report(str(bench))
    gens = rep["grids"]["unit"]
    assert len(gens) == 2 and all(g.readable for g in gens)
    assert rep["regressions"] == []
    rows = dict(report_mod._trend_rows(gens))
    assert rows["perf.qps"] == [100.0, 150.0]
    console = report_mod.render_console(rep)
    assert "unit" in console and "_ok: PASS" in console
    assert "no regressions" in console
    html_path = tmp_path / "report.html"
    rc = report_mod.main(["--dir", str(bench), "--check",
                          "--html", str(html_path)])
    assert rc == 0
    html = html_path.read_text()
    assert "<svg" in html and "perf.qps" in html


def test_report_flags_true_to_false_regression(grid_repo):
    repo, bench, commit = grid_repo
    commit({"qps": 100.0, "_ok": True}, "good")
    commit({"qps": 90.0, "_ok": False}, "bad")
    rep = report_mod.build_report(str(bench))
    assert len(rep["regressions"]) == 1
    assert "_ok regressed True->False" in rep["regressions"][0]
    assert report_mod.main(["--dir", str(bench), "--check"]) == 1
    # a flag that was never True is not a regression (new gate landing red
    # is its own PR's problem, not a trajectory regression)
    commit({"qps": 80.0, "_ok": False, "_new": False}, "still-bad")
    rep = report_mod.build_report(str(bench))
    assert rep["regressions"] == []


def test_report_flags_unreadable_newest_generation(grid_repo):
    repo, bench, commit = grid_repo
    commit({"qps": 1.0, "_ok": True}, "good")
    commit("{not json", "broken")
    rep = report_mod.build_report(str(bench))
    assert any("unreadable" in r for r in rep["regressions"])
    assert report_mod.main(["--dir", str(bench), "--check"]) == 1
    # non-flag schema: _ok must be a bool
    commit({"qps": 1.0, "_ok": "yes"}, "bad-schema")
    rep = report_mod.build_report(str(bench))
    assert any("must be a bool" in r for r in rep["regressions"])


def test_report_includes_dirty_worktree_as_generation(grid_repo):
    repo, bench, commit = grid_repo
    commit({"qps": 1.0}, "gen1")
    (bench / "unit_grid.json").write_text(json.dumps({"qps": 2.0}))
    gens = report_mod.generations(str(bench / "unit_grid.json"))
    assert [g.label for g in gens][-1] == "worktree"
    assert len(gens) == 2
    # clean worktree: no duplicate generation
    _git(["add", "-A"], repo)
    _git(["commit", "-qm", "gen2"], repo)
    gens = report_mod.generations(str(bench / "unit_grid.json"))
    assert len(gens) == 2 and gens[-1].label != "worktree"


def test_report_outside_git_uses_disk_only(tmp_path):
    bench = tmp_path  # tmp under pytest is not itself a grid-bearing repo
    (bench / "solo_grid.json").write_text(json.dumps({"x": 1.0}))
    gens = report_mod.generations(str(bench / "solo_grid.json"))
    assert [g.label for g in gens] == ["worktree"] or len(gens) >= 1
    assert gens[-1].readable


def test_report_renders_all_committed_grids():
    import os
    bench = os.path.join(os.path.dirname(__file__), "..", "results",
                         "bench")
    rep = report_mod.build_report(bench)
    assert len(rep["grids"]) >= 8          # every committed *_grid.json
    out = report_mod.render_console(rep, max_rows=2)
    assert "obs_overhead" in out
    report_mod.render_html(rep)            # must not raise


def test_sparkline_and_formatting_helpers():
    assert report_mod.sparkline([]) == ""
    assert report_mod.sparkline([1.0, 1.0]) == "▄▄"
    s = report_mod.sparkline([0.0, 0.5, 1.0])
    assert s[0] == "▁" and s[-1] == "█"
    assert " " in report_mod.sparkline([0.0, float("nan"), 1.0])
    assert report_mod._delta([1.0, 2.0]) == "+100.0%"
    assert report_mod._delta([5.0]) == ""
    assert report_mod.flatten_metrics(
        {"a": {"b": 2}, "_flag": True, "_cache_info": {"x": {"y": 1}},
         "s": "str"}) == {"a.b": 2.0}
    assert report_mod.grid_flags({"_ok": True, "_bad": False,
                                  "n": 1}) == {"_bad": False, "_ok": True}
