"""Incremental masked SpGEMM (ISSUE 8): delta-aware structures, plan
revalidation, lane patching, and scoped serving-cache invalidation.

The core contract: ANY interleaving of edge-delta batches and queries
returns results bitwise-equal to a cold recompute on the post-delta
matrices — in sync and async modes, with complemented masks, and with a
tile-elected bucket riding along.
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_shim import given, settings, strategies as st

from repro import caches
from repro.core.formats import (CSR, CSRDelta, apply_csr_delta,
                                bcsr_apply_delta, bcsr_from_csr,
                                block_sparse, csr_from_dense, erdos_renyi,
                                er_mask, incremental_signature)
from repro.core.masked_spgemm import masked_spgemm
from repro.core import planner
from repro.core.planner import clear_plan_cache, plan, revalidate
from repro.core.semiring import PLUS_TIMES
from repro.serving import (QueryEngine, ResultCache, VirtualClock,
                           row_bitmap)
from repro.serving import burst
from repro.serving.batcher import Batcher, Request

from test_serving import (POOL, assert_same_result, drain_virtual, revalue)


def dense(x: CSR) -> np.ndarray:
    out = np.zeros(x.shape, dtype=x.data.dtype)
    for i in range(x.shape[0]):
        s, e = x.indptr[i], x.indptr[i + 1]
        out[i, x.indices[s:e]] = x.data[s:e]
    return out


def random_delta(rng, x: CSR, k: int = 6) -> CSRDelta:
    """A mixed batch: upserts to fresh and existing coordinates plus
    deletes (some of entries that do not exist — must be no-ops)."""
    m, n = x.shape
    rows = rng.integers(0, m, k).astype(np.int64)
    cols = rng.integers(0, n, k).astype(np.int64)
    vals = rng.uniform(0.5, 1.5, k).astype(x.data.dtype)
    delete = rng.random(k) < 0.3
    return CSRDelta(rows, cols, vals, delete)


def values_delta(rng, x: CSR, k: int = 4) -> CSRDelta:
    """Upserts confined to EXISTING coordinates: structure survives."""
    if x.nnz == 0:
        return CSRDelta.upserts(np.zeros(0, np.int64), np.zeros(0, np.int64),
                                np.zeros(0, x.data.dtype))
    pos = rng.integers(0, x.nnz, min(k, x.nnz))
    er = np.repeat(np.arange(x.shape[0]), np.diff(x.indptr))
    return CSRDelta.upserts(er[pos], x.indices[pos],
                            rng.uniform(0.5, 1.5, len(pos)).astype(
                                x.data.dtype))


def burst_triple(n=128, seed=0):
    """Sparse A/B + wide mask: the regime whose plan elects a
    sequential-scatter kernel, so the engine serves it on the burst path."""
    return (erdos_renyi(n, 2, seed=100 + seed),
            erdos_renyi(n, 2, seed=200 + seed),
            er_mask(n, max(8, n // 8), seed=300 + seed))


# ---------------------------------------------------------------------------
# formats: CSRDelta application + incremental signature
# ---------------------------------------------------------------------------


def test_apply_csr_delta_matches_dense_oracle():
    rng = np.random.default_rng(0)
    x = erdos_renyi(40, 3, seed=1)
    d = CSRDelta(
        np.array([2, 2, 7, 7, 39, 2]),
        np.array([5, 6, 0, 0, 39, 5]),
        np.array([1.5, 2.5, 3.5, 4.5, 5.5, 9.0], dtype=x.data.dtype),
        np.array([False, False, False, True, False, False]))
    res = apply_csr_delta(x, d)
    want = dense(x)
    want[2, 5] = 9.0          # second upsert to (2,5) wins (applied in order)
    want[2, 6] = 2.5
    want[7, 0] = 0.0          # upsert then delete -> absent
    want[39, 39] = 5.5
    got = dense(res.csr)
    # delete leaves a structural zero NOT in the new structure
    assert 0 not in res.csr.row(7)[0]
    np.testing.assert_array_equal(got, want)
    assert list(res.changed_rows) == [2, 7, 39]
    assert not res.values_only
    assert res.signature == incremental_signature(res.csr)
    # untouched rows share identity-equal semantics (same entries)
    np.testing.assert_array_equal(res.csr.row(5)[0], x.row(5)[0])
    rng = rng  # noqa: F841


def test_incremental_signature_chain_matches_recompute():
    rng = np.random.default_rng(7)
    x = erdos_renyi(48, 3, seed=2)
    sig = incremental_signature(x)
    for step in range(5):
        d = random_delta(rng, x)
        res = apply_csr_delta(x, d, old_signature=sig)
        assert res.signature == incremental_signature(res.csr), step
        x, sig = res.csr, res.signature
    # signature distinguishes structures; equal structure -> equal sig
    y = CSR(x.indptr, x.indices, x.data * 2.0, x.shape)
    assert incremental_signature(y) == sig[:3] + (sig[3],)


def test_values_only_delta_detected():
    rng = np.random.default_rng(3)
    x = erdos_renyi(32, 3, seed=3)
    res = apply_csr_delta(x, values_delta(rng, x))
    assert res.values_only
    assert res.signature == incremental_signature(x)  # structure unchanged
    # a structural insert flips the flag
    free = (x.row(0)[0], 31)
    col = next(c for c in range(32) if c not in set(free[0].tolist()))
    res2 = apply_csr_delta(x, CSRDelta.upserts([0], [col], [1.0]))
    assert not res2.values_only


def test_apply_csr_delta_validates():
    x = erdos_renyi(16, 2, seed=4)
    with pytest.raises(ValueError):
        apply_csr_delta(x, CSRDelta.upserts([16], [0], [1.0]))
    with pytest.raises(ValueError):
        apply_csr_delta(x, CSRDelta.upserts([0], [0], [1.0]),
                        old_signature=("icsr", (8, 8), 0, 0))


def test_bcsr_apply_delta_matches_rebuild():
    rng = np.random.default_rng(5)
    x = csr_from_dense(block_sparse(48, 8, 0.5, 0.6, seed=6))
    b0 = bcsr_from_csr(x, 8)
    d = random_delta(rng, x, k=8)
    res = apply_csr_delta(x, d)
    got = bcsr_apply_delta(b0, res.csr, res.changed_rows)
    want = bcsr_from_csr(res.csr, 8)
    np.testing.assert_array_equal(np.asarray(got.indptr),
                                  np.asarray(want.indptr))
    np.testing.assert_array_equal(np.asarray(got.indices),
                                  np.asarray(want.indices))
    np.testing.assert_array_equal(np.asarray(got.blocks),
                                  np.asarray(want.blocks))


# ---------------------------------------------------------------------------
# planner: revalidate
# ---------------------------------------------------------------------------


def test_revalidate_survives_row_local_delta_and_stamps_cache():
    rng = np.random.default_rng(8)
    A, B, M = burst_triple(seed=1)
    p0 = plan(A, B, M)
    res = apply_csr_delta(M, random_delta(rng, M, k=4))
    p1, survived = revalidate(p0, A, B, res.csr)
    assert survived
    assert p1.algorithm == p0.algorithm
    # the surviving plan was stamped under the post-delta key: the serve
    # path's plan() call must hit it (identity, not just equality)
    p2 = plan(A, B, res.csr)
    assert p2 is p1


def test_revalidate_goes_cold_past_hysteresis():
    rng = np.random.default_rng(9)
    A, B, M = burst_triple(seed=2)
    p0 = plan(A, B, M)
    rows = rng.integers(0, M.shape[0], 3000).astype(np.int64)
    cols = rng.integers(0, M.shape[1], 3000).astype(np.int64)
    big = CSRDelta.upserts(rows, cols,
                           np.ones(3000, dtype=M.data.dtype))
    res = apply_csr_delta(M, big)
    p1, survived = revalidate(p0, A, B, res.csr)
    assert not survived          # nnz drift far beyond the band
    want = plan(A, B, res.csr)
    assert p1.algorithm == want.algorithm


def test_revalidate_rejects_mismatched_operands():
    A, B, M = burst_triple(seed=3)
    p0 = plan(A, B, M)
    A2, B2, M2 = POOL[0]
    p1, survived = revalidate(p0, A2, B2, M2)
    assert not survived          # different shapes: cold re-plan


# ---------------------------------------------------------------------------
# burst: lane patching + lineage
# ---------------------------------------------------------------------------


def test_patched_program_bitwise_equals_cold_rebuild():
    rng = np.random.default_rng(10)
    A, B, M = burst_triple(seed=4)
    p0 = plan(A, B, M)
    parent = burst.get_program(A, B, M, PLUS_TIMES, wm=p0.widths[2])
    assert parent is not None
    dm = CSRDelta.upserts(np.array([3, 3, 9]), np.array([1, 2, 3]),
                          np.ones(3, dtype=M.data.dtype))
    M1 = apply_csr_delta(M, dm).csr
    got = parent.patched(A, B, M1, np.array([3, 9], np.int64))
    assert got is not None
    prog, lanes = got
    assert lanes > 0
    cold = burst.BurstProgram(A, B, M1, PLUS_TIMES, p0.widths[2])
    # host lane tables byte-equal => device results bitwise-equal, and the
    # jitted fold is the SAME compiled callable (shape-memoized)
    np.testing.assert_array_equal(prog._IA, cold._IA)
    np.testing.assert_array_equal(prog._BV, cold._BV)
    np.testing.assert_array_equal(prog._present_host, cold._present_host)
    assert prog._fn is cold._fn
    out_p = prog.run([A])
    out_c = cold.run([A])
    assert_same_result(out_p[0], out_c[0])


def test_patch_regathers_b_values_only_delta():
    rng = np.random.default_rng(11)
    A, B, M = burst_triple(seed=5)
    p0 = plan(A, B, M)
    parent = burst.get_program(A, B, M, PLUS_TIMES, wm=p0.widths[2])
    B1 = apply_csr_delta(B, values_delta(rng, B)).csr
    got = parent.patched(A, B1, M, np.zeros(0, np.int64))
    assert got is not None
    prog, _ = got
    cold = burst.BurstProgram(A, B1, M, PLUS_TIMES, p0.widths[2])
    np.testing.assert_array_equal(prog._BV, cold._BV)
    assert_same_result(prog.run([A])[0], cold.run([A])[0])


def test_patch_refuses_b_structural_delta():
    A, B, M = burst_triple(seed=6)
    p0 = plan(A, B, M)
    parent = burst.get_program(A, B, M, PLUS_TIMES, wm=p0.widths[2])
    B1 = apply_csr_delta(B, CSRDelta.upserts([0], [5], [1.0])).csr
    assert parent.patched(A, B1, M, np.array([0], np.int64)) is None


def test_lineage_rederives_evicted_patch():
    A, B, M = burst_triple(seed=7)
    p0 = plan(A, B, M)
    parent = burst.get_program(A, B, M, PLUS_TIMES, wm=p0.widths[2])
    dm = CSRDelta.upserts(np.array([2]), np.array([4]),
                          np.ones(1, dtype=M.data.dtype))
    M1 = apply_csr_delta(M, dm).csr
    changed = np.array([2], np.int64)
    prog, lanes = burst.patch_program(parent, A, B, M1, PLUS_TIMES,
                                      p0.widths[2], changed)
    assert prog is not None and lanes > 0
    burst.record_lineage(A, B, M1, PLUS_TIMES, p0.widths[2], parent, changed)
    # evict the patched program; get_program must re-derive via lineage
    burst._patches.clear()
    again = burst.get_program(A, B, M1, PLUS_TIMES, wm=p0.widths[2])
    assert again is not None
    np.testing.assert_array_equal(again._IA, prog._IA)


# ---------------------------------------------------------------------------
# cache: row bitmaps + scoped invalidation
# ---------------------------------------------------------------------------


def test_row_bitmap_coarse_coverage():
    assert row_bitmap([], 64) == 0
    assert row_bitmap([0], 64) == 1
    assert row_bitmap([63], 64) == 1 << 63
    full = row_bitmap(range(128), 128)
    assert full == (1 << 64) - 1
    # disjoint halves -> disjoint bitmaps
    lo = row_bitmap(range(0, 64), 128)
    hi = row_bitmap(range(64, 128), 128)
    assert lo & hi == 0


def test_result_cache_scoped_invalidation():
    rc = ResultCache(capacity=16, name="test-inc-scoped")
    try:
        rc.put("k1", "v1", tags=[("sigA", row_bitmap([0, 1], 64))])
        rc.put("k2", "v2", tags=[("sigA", row_bitmap([40, 41], 64))])
        rc.put("k3", "v3", tags=[("sigB", row_bitmap([0], 64))])
        # row-scoped: only the overlapping entry of sigA goes
        n = rc.invalidate("sigA", row_bitmap([1], 64))
        assert n == 1
        assert rc.get("k1") is None
        assert rc.get("k2") == "v2"
        assert rc.get("k3") == "v3"
        # unscoped: everything tagged sigA goes, sigB untouched
        assert rc.invalidate("sigA") == 1
        assert rc.get("k2") is None
        assert rc.get("k3") == "v3"
        assert rc.invalidate("missing") == 0
    finally:
        rc.unregister()


def test_result_cache_tag_index_prunes_dead_entries():
    rc = ResultCache(capacity=2, name="test-inc-prune")
    try:
        for i in range(32):      # LRU evicts most; tags accumulate
            rc.put(("k", i), i, tags=[(("sig", i), 1)])
        total = sum(len(ix) for ix in rc._tags.values())
        assert total <= 4 * rc.capacity
    finally:
        rc.unregister()


# ---------------------------------------------------------------------------
# batcher: rekey
# ---------------------------------------------------------------------------


def _req(key, payload):
    return Request(A=payload, B=None, M=None, semiring=None,
                   complement=False, algorithm=None, mesh=None, axis="data",
                   ticket=None, post=None, cache_key=("ck",),
                   submitted_at=0.0, key=key)


def test_batcher_rekey_moves_and_rewrites():
    b = Batcher(max_batch=8)
    b.add(_req(("old",), 1))
    b.add(_req(("old",), 2))
    b.add(_req(("other",), 3))

    def rw(r):
        r.cache_key = None

    assert b.rekey(("old",), ("new",), rw) == 2
    assert b.rekey(("old",), ("new",)) == 0       # already moved
    assert b.rekey(("x",), ("x",)) == 0           # equal keys: no-op
    buckets = {bk[0].key: bk for bk in b.pop_all()}
    assert len(buckets[("new",)]) == 2
    assert all(r.cache_key is None for r in buckets[("new",)])
    assert len(buckets[("other",)]) == 1
    assert buckets[("other",)][0].cache_key == ("ck",)
    assert b.pending == 0


# ---------------------------------------------------------------------------
# engine: submit_delta
# ---------------------------------------------------------------------------


def test_submit_delta_patches_burst_program_and_counts():
    A, B, M = burst_triple(seed=8)
    with QueryEngine(async_mode=False) as eng:
        t = eng.submit(A, B, M)
        eng.flush()
        t.result()
        assert eng.metrics.bucket_log()[-1]["route"] == "burst"
        dm = CSRDelta.upserts(np.array([3, 3, 7]), np.array([1, 2, 3]),
                              np.ones(3, dtype=M.data.dtype))
        out = eng.submit_delta(A, B, M, delta_m=dm)
        assert out.plan_survived
        assert out.lanes_patched > 0
        assert list(out.changed_rows) == [3, 7]
        t = eng.submit(out.A, out.B, out.M)
        eng.flush()
        got = t.result()
        assert eng.metrics.bucket_log()[-1]["route"] == "burst"
        snap = eng.metrics.snapshot()
        assert snap["delta_applied"] == 1
        assert snap["plans_revalidated"] == 1
        assert snap["lanes_patched"] == out.lanes_patched
        assert snap["rows_invalidated"] == 2
    caches.clear_all()
    clear_plan_cache()
    assert_same_result(got, masked_spgemm(out.A, out.B, out.M))


def test_submit_delta_requires_a_delta_and_host_csr():
    A, B, M = POOL[0]
    with QueryEngine() as eng:
        with pytest.raises(ValueError):
            eng.submit_delta(A, B, M)
        with pytest.raises(TypeError):
            eng.submit_delta(object(), B, M,
                             delta_m=CSRDelta.upserts([0], [0], [1.0]))


def test_delta_flush_scoped_to_structure_fingerprint():
    """Regression (ISSUE 8 bugfix): a delta to one structure must not
    drop cached results of OTHER structures sharing the engine."""
    A1, B1, M1 = burst_triple(seed=9)
    A2, B2, M2 = POOL[0]
    with QueryEngine(async_mode=False) as eng:
        t1 = eng.submit(A1, B1, M1)
        t2 = eng.submit(A2, B2, M2)
        eng.flush()
        t1.result(), t2.result()
        assert len(eng.results) == 2
        db = CSRDelta.upserts(np.array([5]), np.array([6]),
                              np.ones(1, dtype=B1.data.dtype))
        out = eng.submit_delta(A1, B1, M1, delta_b=db)
        assert out.entries_evicted == 1      # structure 1's entry only
        hits0 = eng.metrics.snapshot()["result_cache_hits"]
        eng.submit(A2, B2, M2)               # structure 2 still hits
        assert eng.metrics.snapshot()["result_cache_hits"] == hits0 + 1


def test_delta_invalidation_row_scoped():
    """An A delta confined to rows the mask never covers leaves the entry
    cached (the result provably cannot differ there); a covered-row delta
    evicts it."""
    A, B, _ = burst_triple(seed=10)
    m = A.shape[0]
    md = np.zeros((m, m), dtype=np.float32)
    md[: m // 2] = (np.random.default_rng(0).random((m // 2, m))
                    < 0.1).astype(np.float32)
    M = csr_from_dense(md)                   # rows >= m//2 mask-empty
    with QueryEngine(async_mode=False) as eng:
        t = eng.submit(A, B, M)
        eng.flush()
        t.result()
        da = CSRDelta.upserts(np.array([m - 1]), np.array([0]),
                              np.ones(1, dtype=A.data.dtype))
        out = eng.submit_delta(A, B, M, delta_a=da)
        assert out.entries_evicted == 0      # outside the mask's coverage
        # same delta aimed at a covered row: the entry must go
        t = eng.submit(out.A, B, M)
        eng.flush()
        t.result()
        da2 = CSRDelta.upserts(np.array([0]), np.array([1]),
                               np.ones(1, dtype=A.data.dtype))
        out2 = eng.submit_delta(out.A, B, M, delta_a=da2)
        assert out2.entries_evicted == 1


def test_rebase_queued_requests_onto_post_delta_bucket():
    A, B, M = burst_triple(seed=11)
    with QueryEngine(async_mode=False, max_batch=32) as eng:
        tickets = [eng.submit(revalue(A, s), B, M) for s in range(3)]
        assert eng._batcher.pending == 3
        # a coordinate NOT in M: the delta must really change the mask's
        # structure (an existing coordinate would keep the bucket key)
        col = next(c for c in range(M.shape[1])
                   if c not in set(M.row(4)[0].tolist()))
        dm = CSRDelta.upserts(np.array([4]), np.array([col]),
                              np.ones(1, dtype=M.data.dtype))
        out = eng.submit_delta(A, B, M, delta_m=dm, rebase_queued=True)
        assert out.rekeyed == 3
        tickets.append(eng.submit(revalue(A, 99), out.B, out.M))
        eng.flush()
        log = eng.metrics.bucket_log()
        # pre-delta stragglers + post-delta arrival flushed as ONE bucket
        assert log[-1]["size"] == 4
        results = [t.result() for t in tickets]
    caches.clear_all()
    clear_plan_cache()
    for s, got in zip([0, 1, 2, 99], results):
        want = masked_spgemm(revalue(A, s), out.B, out.M)
        assert_same_result(got, want)


def test_submit_delta_chain_signature_memo():
    """Chained deltas reuse the memoized incremental signature (the
    O(changed-rows) update path) and stay bitwise-correct."""
    rng = np.random.default_rng(12)
    A, B, M = burst_triple(seed=12)
    with QueryEngine(async_mode=False) as eng:
        eng.submit(A, B, M).result()
        for step in range(3):
            dm = random_delta(rng, M, k=3)
            out = eng.submit_delta(A, B, M, delta_m=dm)
            M = out.M
            assert out.signatures["M"] == incremental_signature(M)
        got = eng.submit(A, B, M).result()
    caches.clear_all()
    clear_plan_cache()
    assert_same_result(got, masked_spgemm(A, B, M))


# ---------------------------------------------------------------------------
# property: any delta/query interleaving == cold recompute, bitwise
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       async_mode=st.sampled_from([False, True]),
       n_steps=st.integers(4, 12))
def test_delta_query_interleaving_bitwise_equals_cold(seed, async_mode,
                                                      n_steps):
    rng = np.random.default_rng(seed)
    A, B, M = POOL[int(rng.integers(3))]
    A = revalue(A, int(rng.integers(1 << 20)))
    kw = dict(async_mode=async_mode, max_batch=4)
    if async_mode:
        kw["clock"] = VirtualClock()
    checks = []
    with QueryEngine(**kw) as eng:
        for step in range(n_steps):
            action = int(rng.integers(4))
            if action == 0:
                which = int(rng.integers(3))
                target = (A, B, M)[which]
                d = (values_delta(rng, target) if rng.random() < 0.3
                     else random_delta(rng, target, k=4))
                out = eng.submit_delta(
                    A, B, M,
                    delta_a=d if which == 0 else None,
                    delta_b=d if which == 1 else None,
                    delta_m=d if which == 2 else None)
                A, B, M = out.A, out.B, out.M
            elif action in (1, 2):
                comp = action == 2
                t = eng.submit(A, B, M, complement=comp)
                checks.append((t, A, B, M, comp))
            else:
                At, Bt, Mt = POOL[3]      # tile-elected bucket rides along
                Aq = revalue(At, 500 + step)
                checks.append((eng.submit(Aq, Bt, Mt), Aq, Bt, Mt, False))
        if async_mode:
            drain_virtual(eng, [c[0] for c in checks])
        else:
            eng.flush()
        results = [(c[0].result(),) + c[1:] for c in checks]
    # cold recompute on the post-delta operands each query was issued with
    caches.clear_all()
    clear_plan_cache()
    for got, Aq, Bq, Mq, comp in results:
        want = masked_spgemm(Aq, Bq, Mq, complement=comp)
        assert_same_result(got, want, complement=comp)


# ---------------------------------------------------------------------------
# trace: rotating sink round-trips
# ---------------------------------------------------------------------------


def test_rotating_sink_segments_standalone_and_round_trip(tmp_path):
    from repro.serving.trace import (RotatingTraceSink, Trace, load_rotated,
                                     synthesize_trace)
    tr = synthesize_trace(n=48, queries=24, n_structs=2, block_struct=False)
    path = os.path.join(str(tmp_path), "cap.jsonl")
    with RotatingTraceSink(path, max_bytes=4096, rotate=8,
                           name="cap") as sink:
        for ev in tr.events:
            sink.write(ev)
    segs = sink.segments()
    assert len(segs) > 1                      # rotation actually happened
    total = 0
    for p in segs:
        seg = Trace.load(p)                   # standalone schema-valid
        for ev in seg.events:
            assert ev["op"] == "submit"
        total += seg.n_requests
    assert total == 24
    merged = load_rotated(path)
    assert merged.events == tr.events         # byte-level field round-trip
    assert merged.materialized(check=True)    # fingerprints survive rotation


def test_rotating_sink_drops_oldest_past_rotate(tmp_path):
    from repro.serving.trace import RotatingTraceSink, synthesize_trace
    tr = synthesize_trace(n=48, queries=24, n_structs=2, block_struct=False)
    path = os.path.join(str(tmp_path), "cap.jsonl")
    with RotatingTraceSink(path, max_bytes=4096, rotate=1) as sink:
        for ev in tr.events:
            sink.write(ev)
    assert len(sink.segments()) <= 2          # path.1 + path only


def test_rotating_sink_sampling_deterministic(tmp_path):
    from repro.serving.trace import RotatingTraceSink, synthesize_trace
    tr = synthesize_trace(n=48, queries=24, n_structs=2, block_struct=False)
    kept = []
    for run in range(2):
        path = os.path.join(str(tmp_path), f"s{run}.jsonl")
        with RotatingTraceSink(path, sample_rate=0.5, seed=7) as sink:
            kept.append([sink.write(ev) for ev in tr.events])
        assert sink.written + sink.sampled_out == 24
    assert kept[0] == kept[1]                 # seeded: same events sampled
    assert 0 < sum(kept[0]) < 24


def test_recorder_streams_to_sink(tmp_path):
    from repro.serving.trace import RotatingTraceSink, TraceRecorder, Trace
    A, B, M = POOL[0]
    path = os.path.join(str(tmp_path), "live.jsonl")
    sink = RotatingTraceSink(path, name="live")
    rec = TraceRecorder(name="live", sink=sink, keep_events=False)
    with QueryEngine(recorder=rec) as eng:
        for s in range(3):
            eng.submit(revalue(A, s), B, M)
        eng.flush()
    sink.close()
    assert rec.events == []                   # O(1) memory capture
    got = Trace.load(path)
    assert got.n_requests == 3
    assert got.materialized(check=True)


def test_rotating_sink_validates_knobs(tmp_path):
    from repro.serving.trace import RotatingTraceSink
    path = os.path.join(str(tmp_path), "x.jsonl")
    with pytest.raises(ValueError):
        RotatingTraceSink(path, max_bytes=0)
    with pytest.raises(ValueError):
        RotatingTraceSink(path, rotate=0)
    with pytest.raises(ValueError):
        RotatingTraceSink(path, sample_rate=1.5)
