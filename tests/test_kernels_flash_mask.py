"""Block-masked flash attention kernel vs dense oracle (interpret=True)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.flash_mask.kernel import (
    flash_mask_kernel, build_schedule)
from repro.kernels.flash_mask.ops import flash_mask_attention
from repro.kernels.flash_mask.ref import flash_mask_ref, mask_allowed


def mk(rng, s, d, dtype):
    return jnp.asarray(rng.standard_normal((s, d)) * 0.5, dtype)


PATTERNS = [
    dict(causal=True, window=0, prefix=0),            # causal (LM)
    dict(causal=True, window=16, prefix=0),           # sliding window
    dict(causal=True, window=16, prefix=8),           # window + global prefix
    dict(causal=False, window=0, prefix=0),           # dense (encoder/cross)
]


@pytest.mark.parametrize("pattern", PATTERNS,
                         ids=["causal", "window", "window+prefix", "dense"])
@pytest.mark.parametrize("shape", [(32, 32, 8, 8), (64, 64, 16, 16),
                                   (32, 64, 8, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep(pattern, shape, dtype):
    s_q, s_k, bq, bk = shape
    d = 16
    rng = np.random.default_rng(11)
    q, k, v = mk(rng, s_q, d, dtype), mk(rng, s_k, d, dtype), \
        mk(rng, s_k, d, dtype)
    q_off = s_k - s_q
    qi, ki, flags = build_schedule(s_q, s_k, bq=bq, bk=bk, q_offset=q_off,
                                   **pattern)
    got = flash_mask_kernel(q, k, v, jnp.asarray(qi), jnp.asarray(ki),
                            jnp.asarray(flags), bq=bq, bk=bk, scale=d**-0.5,
                            q_offset=q_off, interpret=True, **pattern)
    want = flash_mask_ref(q, k, v, q_offset=q_off, **pattern)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_schedule_skips_masked_tiles():
    # causal 8 blocks -> strictly-upper tiles absent: n(n+1)/2 pairs
    qi, ki, flags = build_schedule(64, 64, bq=8, bk=8, causal=True, window=0,
                                   prefix=0, q_offset=0)
    assert len(qi) == 8 * 9 // 2
    # sliding window W=2 blocks: row i keeps <= 3 tiles (the paper's saving)
    qi, ki, _ = build_schedule(512, 512, bq=64, bk=64, causal=True,
                               window=128, prefix=0, q_offset=0)
    per_row = np.bincount(qi)
    assert per_row.max() <= 3
    assert len(qi) < 8 * 9 // 2 + 8     # far below dense causal


def test_gqa_batched_op():
    rng = np.random.default_rng(5)
    b, hq, hkv, s, d = 2, 4, 2, 32, 16
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)) * 0.3, jnp.float32)
    got = flash_mask_attention(q, k, v, causal=True, bq=8, bk=8,
                               interpret=True)
    for bi in range(b):
        for h in range(hq):
            want = flash_mask_ref(q[bi, h], k[bi, h // 2], v[bi, h // 2],
                                  causal=True)
            np.testing.assert_allclose(np.asarray(got[bi, h]),
                                       np.asarray(want), rtol=2e-5, atol=2e-5)


def test_decode_offset():
    """Decode: 8 new queries attending over a 64-token history."""
    rng = np.random.default_rng(9)
    d = 16
    q, k, v = mk(rng, 8, d, jnp.float32), mk(rng, 64, d, jnp.float32), \
        mk(rng, 64, d, jnp.float32)
    qi, ki, flags = build_schedule(8, 64, bq=8, bk=8, causal=True, window=0,
                                   prefix=0, q_offset=56)
    got = flash_mask_kernel(q, k, v, jnp.asarray(qi), jnp.asarray(ki),
                            jnp.asarray(flags), bq=8, bk=8, scale=d**-0.5,
                            causal=True, window=0, prefix=0, q_offset=56,
                            interpret=True)
    want = flash_mask_ref(q, k, v, causal=True, q_offset=56)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_mask_allowed_matrix():
    ok = mask_allowed(4, 8, causal=True, window=3, prefix=2, q_offset=4)
    for qq in range(4):
        for kk in range(8):
            want = (kk <= qq + 4) and ((qq + 4 - kk) < 3 or kk < 2)
            assert ok[qq, kk] == want
