"""Pallas masked_matmul / block_spgemm kernels vs pure-jnp oracles.

All runs use interpret=True (CPU container; TPU is the target). Shapes and
dtypes are swept per the deliverable-c requirement.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.formats import bcsr_from_dense
from repro.kernels.masked_matmul.kernel import masked_matmul_kernel
from repro.kernels.masked_matmul.ops import (
    block_spgemm, build_spgemm_schedule, masked_matmul)
from repro.kernels.masked_matmul.ref import masked_matmul_ref, block_spgemm_ref


def random_block_mask(rng, mb, nb, density):
    ok = rng.random((mb, nb)) < density
    if not ok.any():
        ok[0, 0] = True
    bi, bj = np.nonzero(ok)
    return bi.astype(np.int32), bj.astype(np.int32)


@pytest.mark.parametrize("shape", [(16, 16, 16), (32, 48, 64), (64, 32, 16),
                                   (128, 128, 128)])
@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_matmul_sweep(shape, blocks, dtype):
    M, K, N = shape
    bm, bk, bn = blocks
    if M % bm or K % bk or N % bn:
        pytest.skip("not divisible")
    rng = np.random.default_rng(42)
    a = jnp.asarray(rng.standard_normal((M, K)), dtype)
    b = jnp.asarray(rng.standard_normal((K, N)), dtype)
    bi, bj = random_block_mask(rng, M // bm, N // bn, 0.4)
    got = masked_matmul_kernel(a, b, jnp.asarray(bi), jnp.asarray(bj),
                               bm=bm, bn=bn, bk=bk, interpret=True)
    want = masked_matmul_ref(a, b, bi, bj, bm=bm, bn=bn)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_masked_matmul_jit_wrapper():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    bi, bj = random_block_mask(rng, 4, 4, 0.5)
    got = masked_matmul(a, b, jnp.asarray(bi), jnp.asarray(bj),
                        bm=8, bn=8, bk=8, interpret=True)
    want = masked_matmul_ref(a, b, bi, bj, bm=8, bn=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("bs", [4, 8])
@pytest.mark.parametrize("densities", [(0.3, 0.3, 0.3), (0.1, 0.5, 0.2),
                                       (0.6, 0.1, 0.9)])
def test_block_spgemm_sweep(bs, densities):
    da, db, dm = densities
    rng = np.random.default_rng(7)
    M, K, N = 4 * bs, 6 * bs, 5 * bs

    def sp(m, n, d):
        x = (rng.random((m, n)) < d) * rng.standard_normal((m, n))
        return x.astype(np.float32)

    A, B, Mk = sp(M, K, da), sp(K, N, db), sp(M, N, dm)
    Ab, Bb, Mb = (bcsr_from_dense(A, bs), bcsr_from_dense(B, bs),
                  bcsr_from_dense((Mk != 0).astype(np.float32), bs))
    if Mb.nnzb == 0:
        pytest.skip("empty mask")
    got = block_spgemm(Ab, Bb, Mb, interpret=True)
    bi = np.repeat(np.arange(Mb.block_rows), np.diff(Mb.indptr))
    want = block_spgemm_ref(A, B, bi, Mb.indices, bs=bs)
    np.testing.assert_allclose(np.asarray(got.blocks), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # output structure == mask structure (1P allocation, paper Sec. 6)
    np.testing.assert_array_equal(got.indptr, Mb.indptr)
    np.testing.assert_array_equal(got.indices, Mb.indices)


def test_block_spgemm_empty_contribution():
    """Mask blocks with no structural product must come out exactly zero."""
    bs = 4
    A = np.zeros((8, 8), np.float32)
    A[0, 0] = 1.0                        # only block (0, 0) of A
    B = np.zeros((8, 8), np.float32)
    B[0, 0] = 2.0                        # only block (0, 0) of B
    Mk = np.ones((8, 8), np.float32)     # mask allows everything
    got = block_spgemm(bcsr_from_dense(A, bs), bcsr_from_dense(B, bs),
                       bcsr_from_dense(Mk, bs), interpret=True)
    dense = got.to_dense()
    assert dense[0, 0] == 2.0
    assert np.abs(dense).sum() == 2.0


def test_schedule_is_sorted_and_flagged():
    rng = np.random.default_rng(3)
    A = (rng.random((16, 16)) < 0.4).astype(np.float32)
    B = (rng.random((16, 16)) < 0.4).astype(np.float32)
    Mk = (rng.random((16, 16)) < 0.5).astype(np.float32)
    Ab, Bb, Mb = (bcsr_from_dense(A, 4), bcsr_from_dense(B, 4),
                  bcsr_from_dense(Mk, 4))
    rank, pa, pb, flags = build_spgemm_schedule(Ab, Bb, Mb)
    assert (np.diff(rank) >= 0).all()
    assert set(rank.tolist()) == set(range(Mb.nnzb))
    for r in range(Mb.nnzb):
        fs = flags[rank == r]
        assert fs[0] & 1 and fs[-1] & 4   # first/last flags per rank
