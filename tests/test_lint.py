"""Tests for the ``repro.analysis`` invariant linter.

Three layers:

* fixture trees (``tests/lint_fixtures/<rule>/``): every rule has at
  least one firing case and one silent case (allowlisted path, locked
  access, registered cache, or annotated escape) — asserted by exact
  ``(path, line)`` pairs, so engine changes cannot silently widen or
  narrow a rule;
* engine mechanics on temp trees: escapes need a non-empty reason,
  baseline fingerprints are content-anchored (editing the line
  invalidates the suppression), ``--only`` validates rule names;
* self-hosting: ``python -m repro.lint`` over ``src/repro`` must exit 0,
  and the committed baseline must contain NOTHING under ``serving/`` or
  ``core/`` (zero-tolerance dirs — only in-code annotated escapes are
  acceptable there).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.analysis import Baseline, rule_names, run_lint
from repro.analysis.findings import split_by_baseline

HERE = Path(__file__).resolve().parent
REPO = HERE.parent
FIXTURES = HERE / "lint_fixtures"


def _sites(findings, rule):
    return sorted((f.path, f.line) for f in findings if f.rule == rule)


def _run(tree, rule):
    return run_lint(FIXTURES / tree, only=[rule])


# ---------------------------------------------------------------------------
# per-rule fixtures
# ---------------------------------------------------------------------------


def test_no_densify_fixture():
    findings = _run("densify", "no-densify")
    assert _sites(findings, "no-densify") == [
        ("core/hot.py", 5),       # a.to_dense()
        ("core/hot.py", 5),       # b.to_dense() — same line, 2nd call
        ("core/hot.py", 6),       # m.toarray()
    ]
    # ref.py, io/ (not a hot dir), the annotated site, and the to_dense
    # *definition* are all silent
    assert not [f for f in findings if f.path != "core/hot.py"]


def test_clock_discipline_fixture():
    findings = _run("clock", "clock-discipline")
    assert _sites(findings, "clock-discipline") == [
        ("obs/spans.py", 11),       # unannotated perf_counter in obs/
        ("obs/spans.py", 15),       # time.sleep — fires even annotated
        ("serving/sched.py", 3),    # from time import monotonic
        ("serving/sched.py", 9),    # time.monotonic — fires even annotated
        ("serving/sched.py", 13),   # time.sleep
        ("serving/sched.py", 17),   # bare monotonic() use
        ("serving/sched.py", 23),   # unannotated perf_counter
    ]
    # clock.py is exempt; the annotated perf_counter sites (serving line
    # 21, obs line 6) are silent
    assert not [f for f in findings if f.path == "serving/clock.py"]
    assert ("obs/spans.py", 6) not in _sites(findings, "clock-discipline")


def test_clock_forbidden_calls_are_not_escapable():
    # line 9 carries `# lint: clock-ok(...)` and STILL fires: wall-clock
    # scheduling accepts no annotation (in obs/ either — spans.py line 15)
    findings = _run("clock", "clock-discipline")
    assert ("serving/sched.py", 9) in _sites(findings, "clock-discipline")
    assert ("obs/spans.py", 15) in _sites(findings, "clock-discipline")


def test_cache_registry_fixture():
    findings = _run("cache_registry", "cache-registry")
    assert _sites(findings, "cache-registry") == [
        ("pkg/unregistered.py", 4),   # _result_cache dict
        ("pkg/unregistered.py", 8),   # @lru_cache _memo
    ]
    # registered.py (same-module registration, LRUCache, annotated
    # worktable) and cross.py (registered from registry.py) are silent
    assert not [f for f in findings if f.path != "pkg/unregistered.py"]


def test_plan_cache_key_fixture():
    findings = _run("plan_key", "plan-cache-key")
    assert _sites(findings, "plan-cache-key") == [
        ("core/stale.py", 11),    # get(key) — tainted, tokenless
        ("core/stale.py", 14),    # put(key, ...)
        ("core/stale.py", 20),    # *cache_get helper with tainted key
        ("core/stale.py", 27),    # incremental_signature-tainted key
    ]
    # fresh.py: token in key (direct + via local), annotated
    # structure-pure site, untainted key, token-carrying + annotated
    # incremental-signature keys — all silent
    assert not [f for f in findings if f.path == "core/fresh.py"]


def test_lock_discipline_fixture():
    findings = _run("lock", "lock-discipline")
    assert _sites(findings, "lock-discipline") == [
        ("serving/racy.py", 21),   # _queue read under lock (vs bare append)
        ("serving/racy.py", 23),   # _queue.pop under lock (vs bare append)
        ("serving/racy.py", 24),   # _plans write, no lock (worker)
        ("serving/racy.py", 28),   # _queue.append, no lock (submit)
        ("serving/racy.py", 30),   # _plans read, no lock (submit)
    ]
    # safe.py: both sides locked, init-only attr, annotated stat — silent
    assert not [f for f in findings if f.path == "serving/safe.py"]


def test_jit_retrace_fixture():
    findings = _run("jit", "jit-retrace")
    assert _sites(findings, "jit-retrace") == [
        ("models/jitted.py", 12),   # mutable module capture
        ("models/jitted.py", 29),   # container literal at call site
    ]
    assert all(f.severity == "warning" for f in findings)


# ---------------------------------------------------------------------------
# engine mechanics
# ---------------------------------------------------------------------------


def test_escape_requires_nonempty_reason(tmp_path):
    tree = tmp_path / "core"
    tree.mkdir()
    (tree / "x.py").write_text(
        "def f(a):\n"
        "    return a.to_dense()  # lint: densify-ok()\n")
    findings = run_lint(tmp_path, only=["no-densify"])
    assert _sites(findings, "no-densify") == [("core/x.py", 2)]


def test_baseline_suppresses_then_line_edit_invalidates(tmp_path):
    tree = tmp_path / "core"
    tree.mkdir()
    src = tree / "x.py"
    src.write_text("def f(a):\n    return a.to_dense()\n")
    findings = run_lint(tmp_path, only=["no-densify"])
    assert len(findings) == 1

    baseline = Baseline.from_findings(findings)
    new, suppressed = split_by_baseline(findings, baseline)
    assert (len(new), len(suppressed)) == (0, 1)

    # same line number, different content: the fingerprint is anchored to
    # the line TEXT, so the old suppression no longer applies
    src.write_text("def f(a):\n    return a.to_dense().T\n")
    findings2 = run_lint(tmp_path, only=["no-densify"])
    new2, suppressed2 = split_by_baseline(findings2, baseline)
    assert (len(new2), len(suppressed2)) == (1, 0)

    # ...but pure line DRIFT (code inserted above) keeps the suppression
    src.write_text("import os\n\n\ndef f(a):\n    return a.to_dense()\n")
    findings3 = run_lint(tmp_path, only=["no-densify"])
    new3, suppressed3 = split_by_baseline(findings3, baseline)
    assert (len(new3), len(suppressed3)) == (0, 1)


def test_baseline_roundtrip(tmp_path):
    tree = tmp_path / "serving"
    tree.mkdir()
    (tree / "x.py").write_text("import time\ntime.sleep(1)\n")
    findings = run_lint(tmp_path, only=["clock-discipline"])
    assert findings
    path = tmp_path / "baseline.json"
    Baseline.from_findings(findings).save(path)
    loaded = Baseline.load(path)
    assert all(loaded.suppresses(f) for f in findings)


def test_cli_rejects_unknown_rule():
    from repro.lint import main
    assert main(["--only", "no-such-rule", str(FIXTURES / "densify")]) == 2


def test_cli_lists_all_six_rules(capsys):
    from repro.lint import main
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for name in ("no-densify", "clock-discipline", "cache-registry",
                 "plan-cache-key", "lock-discipline", "jit-retrace"):
        assert name in out
    assert set(rule_names()) == {
        "no-densify", "clock-discipline", "cache-registry",
        "plan-cache-key", "lock-discipline", "jit-retrace"}


# ---------------------------------------------------------------------------
# self-hosting: the repo must pass its own linter
# ---------------------------------------------------------------------------


def test_self_lint_is_clean():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.lint", "--format=json"],
        capture_output=True, text=True, cwd=REPO,
        env={**os.environ, "PYTHONPATH": str(REPO / "src")})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["counts"]["new"] == 0
    assert set(report["rules"]) == set(rule_names())


def test_no_baselined_findings_in_zero_tolerance_dirs():
    """Policy: serving/ and core/ accept annotated in-code escapes but no
    baseline entries — a baselined finding there is a dodged invariant."""
    baseline_path = REPO / "lint-baseline.json"
    assert baseline_path.exists()
    data = json.loads(baseline_path.read_text())
    for entry in data.get("findings", []):
        path = entry.get("path", "")
        assert "serving/" not in path and "core/" not in path, entry
