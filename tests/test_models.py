"""Per-architecture smoke tests (reduced configs, CPU, 1 device).

For each assigned arch: instantiate SMOKE config, run one forward + one
train(grad) step, assert output shapes and no NaNs.  Decode consistency
(prefill logits == step-by-step decode logits) for representative families.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.launch.specs import concrete_batch
from repro.models import transformer as T

SEQ = 32
BATCH = 2


def setup_arch(arch):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, BATCH, SEQ, seed=1)
    return cfg, params, batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg, params, batch = setup_arch(arch)
    logits = jax.jit(lambda p, b: T.forward(p, cfg, b))(params, batch)
    s_out = SEQ if cfg.family != "vlm" else SEQ
    assert logits.shape == (BATCH, s_out, cfg.vocab_size), logits.shape
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg, params, batch = setup_arch(arch)

    @jax.jit
    def step(p, b):
        loss, grads = jax.value_and_grad(
            lambda p_: T.loss_fn(p_, cfg, b))(p)
        p2 = jax.tree.map(lambda w, g: w - 1e-3 * g, p, grads)
        return loss, p2

    loss, p2 = step(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss {loss}"
    finite = jax.tree.map(lambda a: bool(jnp.isfinite(a).all()), p2)
    assert all(jax.tree.leaves(finite)), f"{arch}: non-finite params"
    # loss roughly ln(V) at init
    assert 0.1 * np.log(cfg.vocab_size) < float(loss) < \
        3.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", ["llama3_2_3b", "zamba2_7b",
                                  "moonshot_v1_16b_a3b", "xlstm_1_3b",
                                  "deepseek_v2_lite_16b"])
def test_decode_matches_prefill(arch):
    """Teacher-forced decode must reproduce the forward logits."""
    cfg, params, batch = setup_arch(arch)
    tokens = batch["tokens"]
    want = T.forward(params, cfg, batch)           # (B, S, V)

    cache = T.init_cache(cfg, BATCH, SEQ)
    step = jax.jit(lambda p, t, c, pos: T.decode_step(p, cfg, t, c, pos))
    errs = []
    for t in range(tokens.shape[1]):
        logits, cache = step(params, tokens[:, t],
                             cache, jnp.full((BATCH,), t, jnp.int32))
        errs.append(np.abs(np.asarray(logits) -
                           np.asarray(want[:, t])).max())
    assert max(errs) < 2e-2, f"{arch}: decode drift {max(errs)}"


def test_encdec_decode_matches_prefill():
    cfg, params, batch = setup_arch("seamless_m4t_large_v2")
    want = T.forward(params, cfg, batch)

    # encoder output (recompute the encoder once, as serving would)
    from repro.models import layers as Lyr
    from repro.models.common import rms_norm
    frames = batch["frames"]
    b, s_src, _ = frames.shape
    enc = frames.astype(cfg.activation_dtype) @ \
        params["frame_proj"].astype(cfg.activation_dtype)
    pos_src = jnp.broadcast_to(jnp.arange(s_src)[None, :], (b, s_src))

    def enc_step(x, p):
        h = Lyr._norm(cfg, p, x, "ln1")
        h = Lyr.apply_attn(p["attn"], cfg, h, pos_src, causal=False)
        x = x + h
        h = Lyr._norm(cfg, p, x, "ln2")
        return x + Lyr.apply_mlp(p["ffn"], cfg, h), None

    enc, _ = jax.lax.scan(enc_step, enc, params["enc_layers"])
    enc = Lyr.layer_norm(enc, params["encfinal_ln_scale"],
                         params["encfinal_ln_bias"])

    cache = T.init_cache(cfg, BATCH, SEQ)
    errs = []
    for t in range(SEQ):
        logits, cache = T.decode_step(params, cfg, batch["tokens"][:, t],
                                      cache,
                                      jnp.full((BATCH,), t, jnp.int32),
                                      encoder_out=enc)
        errs.append(np.abs(np.asarray(logits) -
                           np.asarray(want[:, t])).max())
    assert max(errs) < 2e-2, f"enc-dec decode drift {max(errs)}"


def test_vlm_prefix_attends_bidirectionally():
    cfg, params, batch = setup_arch("internvl2_2b")
    logits = T.forward(params, cfg, batch)
    assert logits.shape[1] == batch["tokens"].shape[1] + cfg.img_tokens


def test_starcoder_window_schedule_saves_tiles():
    from repro.models.attention import _balanced_schedule
    _, _, kv, _, valid, _ = _balanced_schedule(
        512, 512, 64, 64, True, 128, 0, 0)
    dense_tiles = (512 // 64) ** 2
    assert valid.sum() < dense_tiles / 2
