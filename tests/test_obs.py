"""Observability layer (PR 9): spans, sinks, explain, exposition, HTTP.

The contracts under test:

* span sites cost one branch when tracing is off, and spans NEVER feed
  scheduling — a traced engine produces the same
  ``deterministic_snapshot()`` as an untraced one;
* span/trace ids are deterministic counters (replay-stable), nesting
  links parents, and the Chrome-trace export round-trips;
* ``planner.explain`` decomposes every plan into its cost-feature
  vector + per-candidate modeled costs (the repro.tune residual feed);
* the Prometheus exposition round-trips through its own parser and the
  stdlib HTTP endpoint serves it live.
"""
import json
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.core.formats import CSR, erdos_renyi, er_mask
from repro.core.planner import explain, plan
from repro.obs.exposition import (HISTOGRAM_BUCKETS, parse_prometheus,
                                  render_prometheus)
from repro.obs.sinks import InMemorySink, JsonlSpanSink, load_spans
from repro.obs.spans import _NULL_SPAN
from repro.serving import QueryEngine


@pytest.fixture(autouse=True)
def _tracing_off():
    """Every test starts and ends untraced (the process default)."""
    obs.disable()
    yield
    obs.disable()


def _operands(n=64, seed=0):
    return (erdos_renyi(n, 3, seed=seed), erdos_renyi(n, 3, seed=seed + 1),
            er_mask(n, 6, seed=seed + 2))


def _revalue(x: CSR, seed: int) -> CSR:
    rng = np.random.default_rng(seed)
    return CSR(x.indptr, x.indices,
               rng.uniform(0.5, 1.5, x.nnz).astype(np.float32), x.shape)


# ---------------------------------------------------------------------------
# spans: disabled cost, nesting, determinism
# ---------------------------------------------------------------------------


def test_disabled_sites_are_null_and_shared():
    assert not obs.enabled()
    s = obs.span("anything", attr=1)
    assert s is _NULL_SPAN and s is obs.span("other")
    with s as inner:
        inner.set(whatever=2)           # all no-ops
    assert obs.event("x") is None
    assert obs.new_trace() is None
    assert obs.current_spans() == []


def test_span_nesting_links_parents_and_traces():
    with obs.tracing() as tr:
        tid = obs.new_trace()
        with obs.span("outer", trace=tid) as outer:
            with obs.span("inner") as inner:
                obs.event("leaf", dur_s=0.5)
    recs = {r["name"]: r for r in tr.sink.spans()}
    # exit order: inner closes first
    assert [r["name"] for r in tr.sink.spans()] == ["leaf", "inner",
                                                    "outer"]
    assert recs["outer"]["parent"] is None
    assert recs["inner"]["parent"] == outer.span_id
    assert recs["leaf"]["parent"] == inner.span_id
    # the trace id set on the outer span flows to everything nested
    assert {recs[k]["trace"] for k in recs} == {tid}
    assert recs["leaf"]["dur"] == 0.5


def test_span_ids_are_deterministic_counters():
    def capture():
        with obs.tracing() as tr:
            t1, t2 = obs.new_trace(), obs.new_trace()
            with obs.span("a", trace=t1):
                pass
            with obs.span("b", trace=t2):
                pass
        return [(r["span"], r["trace"]) for r in tr.sink.spans()]

    assert capture() == capture() == [(1, 1), (2, 2)]


def test_span_records_error_and_attrs():
    with obs.tracing() as tr:
        with pytest.raises(RuntimeError):
            with obs.span("boom", stage="setup") as sp:
                sp.set(progress=3)
                raise RuntimeError("x")
    (rec,) = tr.sink.spans()
    assert rec["error"] == "RuntimeError"
    assert rec["attrs"] == {"stage": "setup", "progress": 3}
    assert rec["dur"] >= 0.0


def test_tracing_scope_restores_previous_tracer():
    t_outer = obs.configure()
    with obs.tracing() as t_inner:
        assert obs.get_tracer() is t_inner is not t_outer
    assert obs.get_tracer() is t_outer
    obs.disable()
    assert not obs.enabled()


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------


def test_inmemory_sink_is_a_bounded_ring():
    sink = InMemorySink(capacity=3)
    with obs.tracing(sink):
        for i in range(5):
            obs.event(f"e{i}")
    assert len(sink) == 3 and sink.emitted == 5
    assert [r["name"] for r in sink.spans()] == ["e2", "e3", "e4"]
    sink.clear()
    assert len(sink) == 0 and sink.emitted == 5


def test_jsonl_sink_roundtrips_and_rotates(tmp_path):
    path = str(tmp_path / "spans.jsonl")
    with JsonlSpanSink(path, max_bytes=512, rotate=16) as sink:
        with obs.tracing(sink):
            for i in range(24):
                obs.event("serve.exec", dur_s=i * 1e-3, idx=i)
    assert sink.written == 24
    assert len(sink.segments()) >= 2                # rotation happened
    # header lines carry the span kind, so loaders skip them
    head = json.loads(open(path).readline())
    assert head["kind"] == "repro-span-trace"
    recs = load_spans(path, rotate=16)
    assert len(recs) == 24                          # headers not counted
    assert [r["attrs"]["idx"] for r in recs] == list(range(24))


def test_jsonl_sink_seeded_sampling(tmp_path):
    def run(fname, seed):
        s = JsonlSpanSink(str(tmp_path / fname), sample_rate=0.5,
                          seed=seed)
        with obs.tracing(s):
            for i in range(40):
                obs.event("e", idx=i)
        s.close()
        return [r["attrs"]["idx"]
                for r in load_spans(str(tmp_path / fname))]

    a, b = run("a.jsonl", seed=5), run("b.jsonl", seed=5)
    assert a == b and 0 < len(a) < 40
    assert run("c.jsonl", seed=6) != a


# ---------------------------------------------------------------------------
# export: Chrome trace events + modeled-vs-measured residuals
# ---------------------------------------------------------------------------


def test_chrome_trace_export_shape():
    with obs.tracing() as tr:
        with obs.span("serve.exec", algorithm="msa"):
            obs.event("spgemm.row", dur_s=1e-3)
    doc = obs.chrome_trace(tr.sink.spans())
    evs = doc["traceEvents"]
    assert len(evs) == 2 and all(e["ph"] == "X" for e in evs)
    by_name = {e["name"]: e for e in evs}
    assert by_name["spgemm.row"]["dur"] == pytest.approx(1e3)  # micros
    assert by_name["serve.exec"]["args"]["algorithm"] == "msa"
    assert by_name["serve.exec"]["cat"] == "serve"
    assert min(e["ts"] for e in evs) == 0.0         # rebased to t_min
    json.dumps(doc)                                 # serializable as-is


def test_save_chrome_trace_writes_loadable_json(tmp_path):
    with obs.tracing() as tr:
        obs.event("x", dur_s=0.25)
    p = tmp_path / "trace.json"
    obs.save_chrome_trace(str(p), tr.sink.spans())
    loaded = json.load(open(p))
    assert len(loaded["traceEvents"]) == 1
    assert loaded["displayTimeUnit"] == "ms"


def test_residuals_pair_modeled_and_measured():
    with obs.tracing() as tr:
        obs.event("serve.exec", dur_s=2e-3, algorithm="msa", route="row",
                  modeled_ms=1.0)
        obs.event("serve.exec", dur_s=4e-3, algorithm="msa", route="row",
                  modeled_ms=1.0)
        obs.event("serve.exec", dur_s=1e-3, route="burst")  # no model
    rows = obs.residuals(tr.sink.spans())
    assert len(rows) == 2
    assert rows[0]["residual"] == pytest.approx(2.0)
    summary = obs.export.residual_summary(tr.sink.spans())
    assert summary["msa"]["count"] == 2
    assert summary["msa"]["mean_residual"] == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# planner.explain
# ---------------------------------------------------------------------------


def test_explain_decomposes_row_plan():
    A, B, M = _operands()
    info = explain(plan(A, B, M))
    assert info["elected"] == info["algorithm"]
    assert info["elected"] in info["costs_ms"]
    assert info["elected_cost_ms"] == min(info["costs_ms"].values())
    # every candidate cost decomposes into its feature vector
    for algo, feats in info["features"].items():
        assert algo in info["costs_ms"]
        assert all(np.isfinite(v) for v in feats.values())
    assert info["stats"]["n"] == 64
    assert isinstance(info["cost_model_token"], str)
    json.dumps(info)                                # span-attachable


def test_explain_decomposes_dist_plan():
    from repro.core.planner import plan_distributed
    A, B, M = _operands(n=96)
    info = explain(plan_distributed(A, B, M, 2))
    assert info["route"] in ("row", "ring")
    assert info["p"] == 2
    assert set(info["costs_ms"]) >= {"row", "ring"}
    json.dumps(info)


def test_plan_build_span_carries_explain():
    from repro.core.planner import clear_plan_cache
    clear_plan_cache()
    A, B, M = _operands(seed=11)
    with obs.tracing() as tr:
        p = plan(A, B, M)
        plan(A, B, M)                       # cache hit: no second span
    builds = [r for r in tr.sink.spans() if r["name"] == "plan.build"]
    assert len(builds) == 1
    ex = builds[0]["attrs"]["explain"]
    assert ex["elected"] == p.algorithm
    assert builds[0]["attrs"]["algorithm"] == p.algorithm


# ---------------------------------------------------------------------------
# exposition + HTTP endpoint
# ---------------------------------------------------------------------------


def test_render_parse_roundtrip_with_histograms():
    with obs.tracing():
        obs.event("serve.exec", dur_s=5e-4)
        obs.event("serve.exec", dur_s=2e-2)
        text = render_prometheus()
    samples = parse_prometheus(text)
    name = "repro_span_duration_seconds"
    count = samples[(f"{name}_count", (("phase", "serve.exec"),))]
    total = samples[(f"{name}_sum", (("phase", "serve.exec"),))]
    inf = samples[(f"{name}_bucket",
                   (("le", "+Inf"), ("phase", "serve.exec")))]
    assert count == inf == 2.0
    assert total == pytest.approx(5e-4 + 2e-2)
    # buckets are cumulative (monotone in le)
    counts = [samples[(f"{name}_bucket",
                       (("le", repr(le)), ("phase", "serve.exec")))]
              for le in HISTOGRAM_BUCKETS]
    assert counts == sorted(counts) and counts[-1] == 2.0
    # registry caches appear with labels
    assert any(k[0] == "repro_cache_size" for k in samples)


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus("not a sample line at all with {\n")
    with pytest.raises(ValueError):
        parse_prometheus('metric{label=unquoted} 1\n')


def test_http_endpoint_serves_metrics_and_health():
    A, B, M = _operands()
    with QueryEngine(expose_port=0) as engine:
        engine.serve([(A, B, M)])
        engine.serve([(A, B, M)])                   # result-cache hit
        base = engine.obs_server.url
        with urllib.request.urlopen(f"{base}/metrics", timeout=10) as r:
            assert r.headers["Content-Type"].startswith("text/plain")
            samples = parse_prometheus(r.read().decode())
        with urllib.request.urlopen(f"{base}/health", timeout=10) as r:
            health = json.loads(r.read().decode())
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope", timeout=10)
    assert samples[("repro_serve_completed_total", ())] == 2.0
    assert samples[("repro_serve_result_cache_hits_total", ())] == 1.0
    assert ("repro_serve_queue_depth", ()) in samples
    assert health["status"] == "ok" and health["queue_depth"] == 0
    assert health["completed"] == 2 and health["stopped"] is False


def test_engine_close_shuts_exposition_down():
    engine = QueryEngine(expose_port=0)
    url = engine.obs_server.url
    engine.close()
    assert engine.obs_server is None
    with pytest.raises(Exception):
        urllib.request.urlopen(f"{url}/health", timeout=2)


# ---------------------------------------------------------------------------
# engine integration: lifecycle spans + determinism
# ---------------------------------------------------------------------------


def test_request_lifecycle_spans_cover_the_pipeline():
    from repro.core.planner import clear_plan_cache
    clear_plan_cache()
    A, B, M = _operands(seed=21)
    stream = [(_revalue(A, s), B, M) for s in range(4)]
    with obs.tracing() as tr:
        with QueryEngine(cache_results=True) as engine:
            engine.serve(stream)
            engine.serve([stream[0]])               # exact repeat -> hit
    names = {r["name"] for r in tr.sink.spans()}
    assert {"serve.submit", "serve.queue_wait", "serve.plan",
            "serve.exec", "serve.result_cache_put",
            "serve.cache_hit"} <= names
    # per-request trace ids: every submit got its own
    submits = [r for r in tr.sink.spans() if r["name"] == "serve.submit"]
    assert len(submits) == 5
    tids = [r["trace"] for r in submits]
    assert len(set(tids)) == 5 and None not in tids
    # the exec event links back to the bucket's member traces
    execs = [r for r in tr.sink.spans() if r["name"] == "serve.exec"]
    assert execs and set(execs[0]["attrs"]["traces"]) <= set(tids)


def test_delta_lifecycle_spans():
    from repro.core.formats import CSRDelta
    A, B, M = _operands(seed=31)
    with obs.tracing() as tr:
        with QueryEngine(max_batch=8) as engine:
            engine.serve([(A, B, M)])
            delta = CSRDelta.upserts([0, 2], [3, 5], [1.5, 0.25])
            engine.submit_delta(A, B, M, delta_a=delta)
    names = {r["name"] for r in tr.sink.spans()}
    assert {"delta.apply", "delta.revalidate",
            "delta.invalidate"} <= names
    recs = {r["name"]: r for r in tr.sink.spans()}
    assert recs["delta.apply"]["attrs"]["applied"] == 1  # one operand delta
    assert "survived" in recs["delta.revalidate"]["attrs"]


def test_tracing_never_perturbs_deterministic_snapshot():
    A, B, M = _operands(seed=41)
    stream = [(_revalue(A, s), B, M) for s in range(6)]

    def run(traced):
        with QueryEngine(cache_results=False) as engine:
            if traced:
                with obs.tracing():
                    engine.serve(stream)
            else:
                engine.serve(stream)
            return engine.metrics.deterministic_snapshot()

    assert run(traced=False) == run(traced=True)


# ---------------------------------------------------------------------------
# ServeMetrics: hit/miss latency split (the percentile-skew fix)
# ---------------------------------------------------------------------------


def test_cache_hit_latencies_tracked_separately():
    from repro.serving.metrics import ServeMetrics
    m = ServeMetrics()
    m.record_bucket(size=3, algorithm="msa", route="row",
                    queue_wait_s=0.0, plan_s=0.0, exec_s=0.3,
                    latencies_s=(0.10, 0.20, 0.30))
    for s in (0.001, 0.002):
        m.record_cache_hit(latency_s=s)
    snap = m.snapshot()
    assert snap["miss_lat_count"] == 3 and snap["hit_lat_count"] == 2
    assert snap["lat_count"] == 5                   # combined view
    # hits no longer silently vanish: combined p50 sits below miss-only
    assert snap["lat_p50_s"] < snap["miss_lat_p50_s"]
    assert snap["hit_lat_p99_s"] < snap["miss_lat_p50_s"]
    # legacy no-latency call still counts the hit, skews nothing
    m.record_cache_hit()
    snap2 = m.snapshot()
    assert snap2["result_cache_hits"] == 3
    assert snap2["hit_lat_count"] == 2


def test_engine_records_hit_latency():
    A, B, M = _operands(seed=51)
    with QueryEngine() as engine:
        engine.serve([(A, B, M)])
        engine.serve([(A, B, M)])
        snap = engine.metrics.snapshot()
    assert snap["result_cache_hits"] == 1
    assert snap["hit_lat_count"] == 1
    assert snap["lat_count"] == snap["miss_lat_count"] + 1


# ---------------------------------------------------------------------------
# wiring
# ---------------------------------------------------------------------------


def test_obs_registered_in_benchmark_order():
    from benchmarks.run import ORDER
    assert "obs" in ORDER


def test_bench_save_attaches_cache_info(tmp_path, monkeypatch):
    from benchmarks.common import save
    monkeypatch.setenv("REPRO_BENCH_OUT", str(tmp_path))
    path = save("unit_grid", {"k": 1})
    payload = json.load(open(path))
    assert payload["k"] == 1
    info = payload["_cache_info"]
    assert "planner-plans" in info
    assert {"size", "capacity", "hits", "misses"} <= set(
        next(iter(info.values())))


# ---------------------------------------------------------------------------
# counter tracks (PR 10): Perfetto "C" events alongside the slices
# ---------------------------------------------------------------------------


def test_counter_tracks_export_as_chrome_counters():
    with obs.tracing() as tr:
        obs.counter("serve.queue_depth", 3)
        with obs.span("serve.exec"):
            obs.counter("serve.inflight", 2.5)
        spans = tr.sink.spans()
    evs = obs.chrome_trace(spans)["traceEvents"]
    counters = [e for e in evs if e["ph"] == "C"]
    slices = [e for e in evs if e["ph"] == "X"]
    assert len(counters) == 2 and len(slices) == 1
    by_name = {e["name"]: e for e in counters}
    assert by_name["serve.queue_depth"]["args"] == {"value": 3.0}
    assert by_name["serve.inflight"]["args"] == {"value": 2.5}
    # counter records carry no duration and sit on the emitting thread's
    # row like any slice
    assert all("dur" not in e and e["pid"] == 1 for e in counters)
    assert all(e["cat"] == "serve" for e in counters)


def test_counter_disabled_is_a_noop():
    assert not obs.enabled()
    obs.counter("serve.queue_depth", 9)     # must not raise or record
    with obs.tracing() as tr:
        pass
    assert tr.sink.spans() == []


def test_counter_records_skipped_by_span_histograms():
    with obs.tracing() as tr:
        obs.counter("serve.queue_depth", 4)
        obs.event("serve.exec", dur_s=0.01)
        text = render_prometheus(tracer=tr)
    samples = parse_prometheus(text)
    # the exec span histogram exists; no histogram family for the counter
    assert ("repro_span_duration_seconds_count",
            (("phase", "serve.exec"),)) in samples
    assert not any("queue_depth" in name for name, _ in samples)


# ---------------------------------------------------------------------------
# exposition parser edge cases (PR 10): the round trip is lossless
# ---------------------------------------------------------------------------


def test_parse_prometheus_nonfinite_values():
    import math
    text = ('b_bucket{le="+Inf"} 7\n'
            'q{quantile="0.99"} NaN\n'
            'lo -Inf\n'
            'hi +Inf\n')
    s = parse_prometheus(text)
    assert s[("b_bucket", (("le", "+Inf"),))] == 7.0
    assert math.isnan(s[("q", (("quantile", "0.99"),))])
    assert s[("lo", ())] == float("-inf")
    assert s[("hi", ())] == float("inf")


def test_parse_prometheus_unescapes_label_values():
    text = ('m{v="a\\nb\\"c\\\\d"} 1\n'
            'm{v="x,y"} 2\n'          # comma inside quotes
            'm{v="tail\\\\"} 3\n')    # value ENDING in a backslash
    s = parse_prometheus(text)
    assert s[("m", (("v", 'a\nb"c\\d'),))] == 1.0
    assert s[("m", (("v", "x,y"),))] == 2.0
    assert s[("m", (("v", "tail\\"),))] == 3.0


def test_render_parse_round_trip_is_lossless():
    import math
    from repro.obs.exposition import _Writer
    w = _Writer()
    w.sample("rt_nan", float("nan"))
    w.sample("rt_inf", float("inf"))
    w.sample("rt_esc", 1.5, {"path": 'a\\b"c\nd', "tail": "z\\"})
    s = parse_prometheus(w.render())
    assert math.isnan(s[("rt_nan", ())])
    assert s[("rt_inf", ())] == float("inf")
    assert s[("rt_esc", (("path", 'a\\b"c\nd'), ("tail", "z\\")))] == 1.5


# ---------------------------------------------------------------------------
# residual extraction robustness (PR 10): sparse/empty captures
# ---------------------------------------------------------------------------


def test_residuals_tolerate_empty_and_planless_captures():
    from repro.obs.export import residual_summary, residuals
    assert residuals([]) == []
    assert residuals(None) == []
    assert residual_summary([]) == {}
    assert residual_summary(None) == {}
    # spans exist but none carries a modeled cost (plan spans absent)
    planless = [{"name": "serve.exec", "dur": 0.01},
                {"name": "serve.queue_wait", "dur": 0.0},
                {"name": "serve.exec", "counter": 1.0}]
    assert residuals(planless) == []
    assert residual_summary(planless) == {}


def test_residual_record_filters_and_normalizes():
    from repro.obs.export import residual_record
    rec = {"name": "serve.exec", "dur": 4e-3,
           "attrs": {"modeled_ms": 2.0, "size": 2, "algorithm": "msa",
                     "route": "batched", "regime": "r"}}
    r = residual_record(rec)
    assert r["residual"] == pytest.approx(1.0)      # 4ms / (2ms * 2)
    assert r["size"] == 2 and r["algorithm"] == "msa"
    assert residual_record({"name": "other", "dur": 1.0}) is None
    assert residual_record({"name": "serve.exec", "counter": 2.0}) is None
    bad = {"name": "serve.exec", "dur": 1.0,
           "attrs": {"modeled_ms": "garbage"}}
    assert residual_record(bad) is None
    zero = {"name": "serve.exec", "dur": 1.0, "attrs": {"modeled_ms": 0.0}}
    assert residual_record(zero) is None
